//! Recall@k harness for the navigable-graph query search
//! (`knn::search`) against the exact `kernels::nearest_k` oracle.
//!
//! Sweeps N x d x k over the synthetic gaussian-mixture generator,
//! asserting the two promises the serving path relies on:
//!
//! * **accuracy** — recall@10 >= 0.95 for every (N, d) config, and
//! * **sub-linearity** — the walk's visited count barely grows with N
//!   (visited at the large N under 3x the small N, while scoring well
//!   under 10% of N per query at the large config).
//!
//! Scale: the full sweep (10k/50k points) runs under `--release` (the
//! CI recall-gate leg); plain debug `cargo test` shrinks N by
//! `LARGEVIS_RECALL_SCALE` (default 0.04) so tier-1 stays fast. A
//! machine-readable summary is written to
//! `$LARGEVIS_RECALL_DIR/search_recall.json` (default `target/`),
//! mirroring the fault-coverage artifacts.

use largevis::data::synth::gaussian_mixture;
use largevis::kernels::nearest_k;
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::knn::search::{search_nearest, SearchIndex};
use largevis::util::heap::BoundedMaxHeap;
use std::fmt::Write as _;

const GRAPH_K: usize = 16;
const N_SEEDS: usize = 64;
const BEAM: usize = 64;
const QUERIES: usize = 100;

fn scale() -> f64 {
    if let Ok(s) = std::env::var("LARGEVIS_RECALL_SCALE") {
        return s.parse().expect("LARGEVIS_RECALL_SCALE must be a float");
    }
    if cfg!(debug_assertions) {
        0.04
    } else {
        1.0
    }
}

/// One (n, d, k) sweep cell.
struct Cell {
    n: usize,
    d: usize,
    k: usize,
    recall: f64,
    mean_visited: f64,
    mean_scored: f64,
    fallbacks: u64,
    queries: usize,
}

/// Write the JSON artifact the CI recall gate uploads.
fn write_report(cells: &[Cell], scale: f64) {
    let dir = std::env::var("LARGEVIS_RECALL_DIR").unwrap_or_else(|_| "target".into());
    if std::fs::create_dir_all(&dir).is_err() {
        return;
    }
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"scale\": {scale},\n  \"beam_width\": {BEAM},\n  \"search_seeds\": {N_SEEDS},\n  \"configs\": ["
    );
    for (i, c) in cells.iter().enumerate() {
        let _ = write!(
            s,
            "{}\n    {{\"n\": {}, \"d\": {}, \"k\": {}, \"recall\": {:.4}, \
             \"mean_visited\": {:.1}, \"mean_scored\": {:.1}, \
             \"fallbacks\": {}, \"queries\": {}}}",
            if i == 0 { "" } else { "," },
            c.n,
            c.d,
            c.k,
            c.recall,
            c.mean_visited,
            c.mean_scored,
            c.fallbacks,
            c.queries,
        );
    }
    s.push_str("\n  ]\n}\n");
    let path = format!("{dir}/search_recall.json");
    if std::fs::write(&path, &s).is_ok() {
        eprintln!("[search_recall] wrote {path}");
    }
}

#[test]
fn graph_search_recall_and_sublinear_visited() {
    let scale = scale();
    let base_ns = [10_000usize, 50_000];
    let ds = [16usize, 128];
    let ks = [5usize, 10, 20];
    let ns: Vec<usize> =
        base_ns.iter().map(|&n| ((n as f64 * scale) as usize).max(200)).collect();
    // The sub-linearity and scoring-fraction bounds only mean anything
    // once the large config is genuinely large; debug-scale runs keep
    // the recall gate but skip them.
    let full = ns[1] >= 10_000;
    let mut cells: Vec<Cell> = Vec::new();

    for &d in &ds {
        let mut visited_at_10 = Vec::new();
        for &n in &ns {
            let (data, _labels) =
                gaussian_mixture(n, d, 10, 0.5, 0xa11ce ^ (n as u64) ^ ((d as u64) << 32));
            let kcfg = LargeVisKnnConfig { iters: 2, ..Default::default() };
            let knn = largevis_knn(&data, GRAPH_K, &kcfg);
            let index = SearchIndex::build(&data, &knn, None, N_SEEDS);
            let nq = QUERIES.min(n);
            let kmax = *ks.iter().max().unwrap();

            // Exact oracle once per query at the largest k; the
            // (dist, id) order makes every smaller k a prefix.
            let mut dists = Vec::new();
            let mut heap = BoundedMaxHeap::new(kmax);
            let oracles: Vec<Vec<(u32, f32)>> = (0..nq)
                .map(|i| {
                    let q = data.row(i * n / nq);
                    nearest_k(q, &data, kmax, &mut dists, &mut heap)
                })
                .collect();

            for &k in &ks {
                let (mut hit, mut visited, mut scored, mut fallbacks) = (0u64, 0u64, 0u64, 0u64);
                for (i, oracle) in oracles.iter().enumerate() {
                    let q = data.row(i * n / nq);
                    let (got, stats) = search_nearest(q, &data, &knn, &index, k, BEAM);
                    assert_eq!(got.len(), k.min(n), "short result at n={n} d={d} k={k}");
                    let truth: std::collections::HashSet<u32> =
                        oracle[..k].iter().map(|&(id, _)| id).collect();
                    hit += got.iter().filter(|&&(id, _)| truth.contains(&id)).count() as u64;
                    visited += stats.visited;
                    scored += stats.scored;
                    fallbacks += stats.fallback as u64;
                }
                let cell = Cell {
                    n,
                    d,
                    k,
                    recall: hit as f64 / (nq * k) as f64,
                    mean_visited: visited as f64 / nq as f64,
                    mean_scored: scored as f64 / nq as f64,
                    fallbacks,
                    queries: nq,
                };
                eprintln!(
                    "[search_recall] n={} d={} k={}: recall {:.4}, visited {:.0}, \
                     scored {:.0}, fallbacks {}",
                    cell.n, cell.d, cell.k, cell.recall, cell.mean_visited, cell.mean_scored,
                    cell.fallbacks,
                );
                if k == 10 {
                    visited_at_10.push(cell.mean_visited);
                    assert!(
                        cell.recall >= 0.95,
                        "recall@10 = {:.4} < 0.95 at n={} d={}",
                        cell.recall,
                        n,
                        d
                    );
                }
                if full && n == ns[1] {
                    assert!(
                        cell.mean_scored < 0.1 * n as f64,
                        "graph walk scored {:.0} >= 10% of n={n} (d={d} k={k})",
                        cell.mean_scored
                    );
                }
                cells.push(cell);
            }
        }
        if full {
            assert!(
                visited_at_10[1] < 3.0 * visited_at_10[0],
                "visited not sub-linear at d={d}: {:.0} (n={}) vs {:.0} (n={})",
                visited_at_10[1],
                ns[1],
                visited_at_10[0],
                ns[0]
            );
        }
    }

    write_report(&cells, scale);
}
