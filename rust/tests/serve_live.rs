//! Concurrency fuzz test for the live (mutable) layout server:
//! interleave `POST /insert`/`/insert_batch` writers with
//! `/knn`+`/viewport`+`/healthz` readers — the `/knn` readers run in
//! the default *graph* search mode, so the beam walk is fuzzed against
//! concurrent graph splices — and assert every response is internally
//! consistent with a single epoch — no torn layout/index reads — while
//! the server keeps answering lock-free. Freshly-inserted points must
//! be findable through the graph walk (in-edge splices) within one
//! refine pass. Then simulate a restart and assert the WAL recovers
//! every inserted point bit-identically (data *and* spliced KNN graph).

use largevis::config::{PipelineConfig, SearchMode, ServeConfig};
use largevis::coordinator::{run_pipeline, CheckpointPaths};
use largevis::serve::{Server, ServerState};
use largevis::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

#[path = "util/mod.rs"]
mod util;
use util::{as_f64, header_value, json_row, request, request_full, request_json};

fn test_dir() -> PathBuf {
    std::env::temp_dir().join(format!("largevis_serve_live_{}", std::process::id()))
}

fn checkpointed_run(out_dir: &Path) -> largevis::coordinator::PipelineOutput {
    let mut cfg = PipelineConfig {
        dataset: "20ng-like".into(),
        scale: 0.02, // ~380 points
        k: 8,
        out_dir: out_dir.to_path_buf(),
        ..Default::default()
    };
    cfg.vis.samples_per_vertex = 300;
    cfg.knn.forest.n_trees = 2;
    run_pipeline(&cfg).expect("pipeline run")
}

/// Every observed `(epoch, points)` pair, across every client. The
/// torn-read detector: one epoch must never report two sizes.
struct EpochLog {
    seen: Mutex<HashMap<u64, usize>>,
}

impl EpochLog {
    fn new() -> Self {
        EpochLog { seen: Mutex::new(HashMap::new()) }
    }

    fn record(&self, epoch: u64, points: usize, what: &str) {
        let mut seen = self.seen.lock().unwrap();
        if let Some(&prev) = seen.get(&epoch) {
            assert_eq!(
                prev, points,
                "torn read: epoch {epoch} reported {prev} and {points} points ({what})"
            );
        } else {
            seen.insert(epoch, points);
        }
    }
}

#[test]
fn concurrent_inserts_epoch_consistency_and_wal_recovery() {
    let out_dir = test_dir();
    // A stale run may exist from an earlier failed attempt.
    std::fs::remove_dir_all(&out_dir).ok();
    let run = checkpointed_run(&out_dir);
    let n_base = run.layout.n();
    let ckpt = CheckpointPaths::new(&out_dir);

    let cfg = ServeConfig {
        checkpoints: ckpt.dir.clone(),
        addr: "127.0.0.1:0".to_string(),
        threads: 4,
        insert_samples: 60,
        refine_samples: 40,
        refine_interval_ms: 50,
        idle_timeout_ms: 2000,
        grid: 32,
        ..Default::default()
    };
    // The fuzz exercises the navigable-graph query path: readers below
    // issue `/knn` through the beam walk while writers splice the graph.
    assert_eq!(cfg.search, SearchMode::Graph, "graph search must be the serving default");
    let state = ServerState::load(cfg.clone()).expect("load server state");
    let server = Server::bind(state).expect("bind");
    let addr = server.local_addr().unwrap();
    let shared = server.state();
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());

    let base_snap = shared.snapshot();
    let d = base_snap.data.d();
    assert_eq!(base_snap.epoch, 0);

    // --- phase 1: concurrent writers + readers ---
    let writers = 3usize;
    let batches_per_writer = 3usize;
    let rows_per_batch = 3usize;
    let readers = 4usize;
    let reader_rounds = 10usize;
    let log = EpochLog::new();

    std::thread::scope(|s| {
        for wid in 0..writers {
            let log = &log;
            let base_snap = &base_snap;
            s.spawn(move || {
                for b in 0..batches_per_writer {
                    // Perturbed copies of base rows: valid dims, finite,
                    // unique per (writer, batch, row).
                    let mut rows = Vec::new();
                    for r in 0..rows_per_batch {
                        let src = (wid * 31 + b * 7 + r) % base_snap.data.n();
                        let vals: Vec<f32> = base_snap
                            .data
                            .row(src)
                            .iter()
                            .map(|v| v + 0.01 * (1 + wid + b + r) as f32)
                            .collect();
                        rows.push(json_row(&vals));
                    }
                    let body = format!("{{\"points\":[{}]}}", rows.join(","));
                    let (status, resp) =
                        request_json(addr, "POST", "/insert_batch", Some(&body));
                    assert_eq!(status, 200, "insert_batch failed: {resp:?}");
                    let epoch = as_f64(resp.get("epoch").unwrap()) as u64;
                    let points = as_f64(resp.get("points").unwrap()) as usize;
                    let ids = match resp.get("ids") {
                        Some(Json::Arr(a)) => a.iter().map(as_f64).collect::<Vec<_>>(),
                        other => panic!("ids: {other:?}"),
                    };
                    assert_eq!(ids.len(), rows_per_batch);
                    assert!(epoch >= 1);
                    // The insert's own ids are inside its epoch's size.
                    for &id in &ids {
                        assert!((id as usize) < points, "id {id} outside {points} points");
                        assert!(id as usize >= n_base, "id {id} collides with the base");
                    }
                    log.record(epoch, points, "insert_batch");
                }
            });
        }
        for rid in 0..readers {
            let log = &log;
            let q: Vec<f32> = base_snap.data.row(rid * 2).to_vec();
            s.spawn(move || {
                for round in 0..reader_rounds {
                    match round % 3 {
                        0 => {
                            let (status, h) = request_json(addr, "GET", "/healthz", None);
                            assert_eq!(status, 200);
                            let epoch = as_f64(h.get("epoch").unwrap()) as u64;
                            let points = as_f64(h.get("points").unwrap()) as usize;
                            let inserted = as_f64(h.get("inserted").unwrap()) as usize;
                            assert_eq!(points, n_base + inserted, "healthz fields disagree");
                            log.record(epoch, points, "healthz");
                        }
                        1 => {
                            let body = format!("{{\"point\":{},\"k\":3}}", json_row(&q));
                            let (status, j) = request_json(addr, "POST", "/knn", Some(&body));
                            assert_eq!(status, 200);
                            let epoch = as_f64(j.get("epoch").unwrap()) as u64;
                            let points = as_f64(j.get("points").unwrap()) as usize;
                            let ids = match j.get("ids") {
                                Some(Json::Arr(a)) => a.iter().map(as_f64).collect::<Vec<_>>(),
                                other => panic!("ids: {other:?}"),
                            };
                            // Internal consistency: every id addresses
                            // the same epoch's dataset.
                            for &id in &ids {
                                assert!(
                                    (id as usize) < points,
                                    "knn id {id} outside epoch {epoch}'s {points} points"
                                );
                            }
                            log.record(epoch, points, "knn");
                        }
                        _ => {
                            let (status, svg) = request(addr, "GET", "/viewport", None);
                            assert_eq!(status, 200);
                            let svg = String::from_utf8(svg).unwrap();
                            // Parse the trailing `<!-- epoch=E points=N -->`.
                            let tag = svg.rsplit("epoch=").next().unwrap();
                            let epoch: u64 =
                                tag.split_whitespace().next().unwrap().parse().unwrap();
                            let points: usize = tag
                                .split("points=")
                                .nth(1)
                                .unwrap()
                                .split_whitespace()
                                .next()
                                .unwrap()
                                .trim_end_matches("-->")
                                .parse()
                                .unwrap();
                            let circles = svg.matches("<circle").count();
                            assert!(
                                circles <= points,
                                "viewport drew {circles} points, epoch {epoch} holds {points}"
                            );
                            log.record(epoch, points, "viewport");
                        }
                    }
                }
            });
        }
    });

    let total_inserted = writers * batches_per_writer * rows_per_batch;

    // --- a distinctive point is immediately findable via /knn ---
    let marker: Vec<f32> = (0..d).map(|i| 42.5 + i as f32).collect();
    let body = format!("{{\"point\":{}}}", json_row(&marker));
    let (status, ins) = request_json(addr, "POST", "/insert", Some(&body));
    assert_eq!(status, 200, "single insert failed: {ins:?}");
    let marker_id = match ins.get("ids") {
        Some(Json::Arr(a)) => as_f64(&a[0]) as usize,
        other => panic!("ids: {other:?}"),
    };
    let body = format!("{{\"point\":{},\"k\":2}}", json_row(&marker));
    let (status, j) = request_json(addr, "POST", "/knn", Some(&body));
    assert_eq!(status, 200);
    let (ids, dists) = match (j.get("ids"), j.get("dists")) {
        (Some(Json::Arr(a)), Some(Json::Arr(b))) => (
            a.iter().map(as_f64).collect::<Vec<_>>(),
            b.iter().map(as_f64).collect::<Vec<_>>(),
        ),
        other => panic!("knn response: {other:?}"),
    };
    assert_eq!(ids[0] as usize, marker_id, "marker point not its own nearest neighbor");
    assert_eq!(dists[0], 0.0);

    // --- fresh inserts stay findable through the graph walk within
    //     one refine pass: insert one more probe point (guaranteeing
    //     the refiner has pending work), wait for the pass that
    //     consumes it, then re-query inserted points ---
    let refine_passes = |metrics: &Json| -> f64 {
        metrics.get("refine.passes").map(as_f64).unwrap_or(0.0)
    };
    let (_, m0) = request_json(addr, "GET", "/metrics", None);
    let passes0 = refine_passes(&m0);
    let probe_pt: Vec<f32> = (0..d).map(|i| -17.25 - i as f32).collect();
    let body = format!("{{\"point\":{}}}", json_row(&probe_pt));
    let (status, _) = request_json(addr, "POST", "/insert", Some(&body));
    assert_eq!(status, 200, "refine-probe insert failed");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let (_, m) = request_json(addr, "GET", "/metrics", None);
        if refine_passes(&m) > passes0 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "refine pass never completed");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let probe_snap = shared.snapshot();
    for probe in (n_base..probe_snap.data.n()).step_by(5).chain([marker_id]) {
        let q: Vec<f32> = probe_snap.data.row(probe).to_vec();
        let body = format!("{{\"point\":{},\"k\":1}}", json_row(&q));
        let (status, j) = request_json(addr, "POST", "/knn", Some(&body));
        assert_eq!(status, 200);
        let dist0 = match j.get("dists") {
            Some(Json::Arr(a)) => as_f64(&a[0]),
            other => panic!("dists: {other:?}"),
        };
        // Not necessarily `probe` itself (concurrent writers can insert
        // bit-identical rows), but some zero-distance point must be
        // reachable — an insert the walk cannot see would surface here
        // as a strictly positive distance.
        assert_eq!(
            dist0, 0.0,
            "inserted point {probe} not findable via the graph walk after a refine pass"
        );
    }
    drop(probe_snap);

    // --- the full set is visible through the spatial index ---
    let final_snap = shared.snapshot();
    // total_inserted batch rows + the marker + the refine probe.
    assert_eq!(final_snap.data.n(), n_base + total_inserted + 2);
    let (status, svg) = request(
        addr,
        "GET",
        "/viewport?x0=-100000&y0=-100000&x1=100000&y1=100000",
        None,
    );
    assert_eq!(status, 200);
    let svg = String::from_utf8(svg).unwrap();
    let circles = svg.matches("<circle").count();
    assert_eq!(
        circles,
        final_snap.data.n(),
        "wide viewport must draw every live point (base + inserted)"
    );

    // --- metrics cover the write path ---
    let (_, metrics) = request_json(addr, "GET", "/metrics", None);
    assert!(
        as_f64(metrics.get("insert.points").unwrap()) as usize >= total_inserted + 1,
        "insert.points metric missing traffic"
    );
    // Graph-mode accounting: every insert's base-neighbor lookup and
    // every `/knn` above went through the beam walk, so the search
    // counters must have moved (and the fallback counter must exist —
    // a fallback is legal, a missing counter is not).
    assert!(
        as_f64(metrics.get("serve.search_queries").unwrap()) as usize >= total_inserted + 2,
        "serve.search_queries missing graph-walk traffic"
    );
    assert!(as_f64(metrics.get("serve.search_visited").unwrap()) > 0.0);
    assert!(metrics.get("serve.search_fallbacks").is_some(), "fallback counter missing");

    // The base prefix of the layout never moves, no matter how much
    // insert/refine traffic happened.
    for i in 0..n_base {
        assert_eq!(
            final_snap.layout.row(i),
            run.layout.row(i),
            "frozen base point {i} moved under live traffic"
        );
    }

    // Reader isolation under chunked copy-on-write storage: the epoch-0
    // snapshot held across the entire concurrent fuzz still shows
    // exactly the pre-insert state, bit for bit — no writer mutation
    // ever reached a published chunk.
    assert_eq!(base_snap.epoch, 0, "held snapshot changed epoch");
    assert_eq!(base_snap.data.n(), n_base, "held epoch-0 snapshot grew");
    for i in 0..n_base {
        assert!(
            base_snap
                .data
                .row(i)
                .iter()
                .zip(final_snap.data.row(i))
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "base data row {i} differs between epoch 0 and the final epoch"
        );
        assert_eq!(
            base_snap.layout.row(i),
            run.layout.row(i),
            "held epoch-0 layout row {i} moved under live traffic"
        );
    }

    // --- simulated restart: WAL replay bit-identity ---
    handle.shutdown();
    server_thread.join().expect("server thread").expect("server run");
    let pre_data = final_snap.data.clone();
    let pre_knn = final_snap.knn.clone();
    let pre_epoch_points = final_snap.data.n();
    drop(final_snap);
    drop(base_snap);
    drop(shared); // close the old WAL handle before reopening

    assert!(ckpt.wal.exists(), "no WAL written by live inserts");
    let restarted = ServerState::load(cfg).expect("reload with WAL replay");
    let snap = restarted.snapshot();
    // Every acknowledged insert recovered, bit for bit: the raw points
    // and the spliced KNN graph both match the pre-restart state.
    assert_eq!(snap.data.n(), pre_epoch_points);
    assert_eq!(snap.data, pre_data, "WAL replay lost or altered inserted points");
    assert_eq!(snap.knn.k, pre_knn.k);
    assert_eq!(
        snap.knn, pre_knn,
        "WAL replay produced a different spliced KNN graph"
    );
    // One recovered epoch per WAL batch (insert request): the writer
    // batches, the marker, and the refine probe.
    let expected_batches = (writers * batches_per_writer + 2) as u64;
    assert_eq!(snap.epoch, expected_batches);
    assert!(snap.layout.values().all(|v| v.is_finite()));
    assert_eq!(snap.layout.n(), snap.data.n());

    // --- read-only mode refuses writes but still recovers the WAL ---
    let ro_cfg = ServeConfig {
        checkpoints: ckpt.dir.clone(),
        read_only: true,
        ..ServeConfig::default()
    };
    drop(snap);
    drop(restarted);
    let ro = ServerState::load(ro_cfg).expect("read-only load");
    assert_eq!(ro.snapshot().data.n(), pre_epoch_points, "read-only replay incomplete");
    let one = largevis::data::matrix::Matrix::from_vec(vec![0.5; d], 1, d);
    let err = format!("{:#}", ro.insert(&one).unwrap_err());
    assert!(err.contains("read-only"), "{err}");
}

/// Minimal fabricated checkpoints (no pipeline run): `n` points, ring
/// KNN — enough for the overload/readiness test, which exercises the
/// serving layer, not layout quality.
fn fabricate_checkpoints(dir: &Path, n: usize, d: usize) {
    use largevis::data::formats::{binary, checkpoint};
    use largevis::data::matrix::Matrix;
    use largevis::knn::KnnGraph;
    std::fs::create_dir_all(dir).unwrap();
    let paths = CheckpointPaths::in_dir(dir);
    let data: Vec<f32> = (0..n * d).map(|i| i as f32 * 0.25).collect();
    let layout: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.5).collect();
    binary::write_binary(&paths.data, &Matrix::from_vec(data, n, d)).unwrap();
    binary::write_binary(&paths.layout, &Matrix::from_vec(layout, n, 2)).unwrap();
    let mut knn = KnnGraph::empty(n, 1);
    for (i, nb) in knn.neighbors.iter_mut().enumerate() {
        *nb = vec![(((i + 1) % n) as u32, 1.0)];
    }
    checkpoint::write_knn(&paths.knn, &knn).unwrap();
    std::fs::write(&paths.meta, "overload-test").unwrap();
}

/// Overload and failure containment, end to end: `/readyz` answers 503
/// until WAL replay finishes, connections beyond `max_inflight` are
/// shed with `503` + `Retry-After`, a handler panic costs one request
/// a `500` (never the server), every response under concurrent
/// overload is a valid 200 or 503, and every *acknowledged* insert
/// survives a restart.
#[test]
fn overload_shedding_readiness_and_panic_containment() {
    let dir = std::env::temp_dir()
        .join(format!("largevis_serve_overload_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let (n_base, d) = (24usize, 4usize);
    fabricate_checkpoints(&dir, n_base, d);

    let cfg = ServeConfig {
        checkpoints: dir.clone(),
        addr: "127.0.0.1:0".to_string(),
        threads: 1,
        max_inflight: 2,
        insert_samples: 20,
        refine_samples: 0,
        idle_timeout_ms: 2000,
        debug_panic: true,
        ..Default::default()
    };

    // Two-phase startup: the server listens (and answers reads) before
    // WAL replay has run; readiness and inserts gate on the replay.
    let state = ServerState::open(cfg.clone()).expect("open server state");
    let server = Server::bind(state).expect("bind");
    let addr = server.local_addr().unwrap();
    let shared = server.state();
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());

    // --- readiness: 503 + Retry-After before recover(), 200 after ---
    let (status, headers, _) = request_full(addr, "GET", "/readyz", None);
    assert_eq!(status, 503, "readyz must fail before WAL replay");
    assert_eq!(header_value(&headers, "retry-after"), Some("1"));
    let probe: Vec<f32> = (0..d).map(|i| 500.0 + i as f32).collect();
    let insert_body = format!("{{\"point\":{}}}", json_row(&probe));
    let (status, headers, _) = request_full(addr, "POST", "/insert", Some(&insert_body));
    assert_eq!(status, 503, "inserts must be refused before WAL replay");
    assert_eq!(header_value(&headers, "retry-after"), Some("1"));
    let (status, _) = request_json(addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "healthz (liveness) must answer while not ready");
    shared.recover().expect("recover");
    let (status, _, _) = request_full(addr, "GET", "/readyz", None);
    assert_eq!(status, 200, "readyz must pass after WAL replay");

    // --- deterministic shed: fill max_inflight, then one more ---
    {
        // The previous requests' connections release their admission
        // slots a moment after the response is read; start clean.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while shared.inflight() > 0 {
            assert!(std::time::Instant::now() < deadline, "stale admissions never drained");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let mut c1 = util::KeepAlive::connect(addr);
        assert_eq!(c1.request("GET", "/healthz", ""), 200);
        // A second connection is admitted (queued behind the single
        // worker, which is parked on c1's keep-alive read).
        let c2 = std::net::TcpStream::connect(addr).expect("connect c2");
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while shared.inflight() < 2 {
            assert!(std::time::Instant::now() < deadline, "admission never reached 2");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let (status, headers, body) = request_full(addr, "GET", "/healthz", None);
        assert_eq!(status, 503, "connection beyond max_inflight must be shed");
        assert_eq!(header_value(&headers, "retry-after"), Some("1"));
        assert!(
            String::from_utf8(body).unwrap().contains("overloaded"),
            "shed response names the cause"
        );
        drop(c2);
        drop(c1);
    }
    // Let the worker drain the two closed connections.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while shared.inflight() > 0 {
        assert!(std::time::Instant::now() < deadline, "admission never drained");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    // --- panic containment: /__panic costs that request a 500 ---
    let (status, _, body) = request_full(addr, "GET", "/__panic", None);
    assert_eq!(status, 500, "handler panic must surface as 500");
    assert!(String::from_utf8(body).unwrap().contains("panic"));
    let (status, _) = request_json(addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "server must survive a handler panic");

    // --- overload fuzz: concurrent writers, every response 200/503,
    //     every acked insert recorded ---
    let writer_threads = 8usize;
    let acked: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for tid in 0..writer_threads {
            let acked = &acked;
            s.spawn(move || {
                let point: Vec<f32> =
                    (0..d).map(|i| 1000.0 * (tid + 1) as f32 + i as f32).collect();
                let body = format!("{{\"point\":{}}}", json_row(&point));
                for _attempt in 0..400 {
                    let (status, headers, _) =
                        request_full(addr, "POST", "/insert", Some(&body));
                    match status {
                        200 => {
                            acked.lock().unwrap().push(point.clone());
                            return;
                        }
                        503 => {
                            // Shed responses must carry backoff advice.
                            assert!(
                                header_value(&headers, "retry-after").is_some(),
                                "503 without Retry-After"
                            );
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        other => panic!("unexpected status {other} under overload"),
                    }
                }
                panic!("writer {tid} never got through (all 503)");
            });
        }
    });
    let acked = acked.into_inner().unwrap();
    assert_eq!(acked.len(), writer_threads, "every writer retried to success");

    // --- counters: shedding and the panic were observed ---
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while shared.inflight() > 0 {
        assert!(std::time::Instant::now() < deadline, "fuzz admissions never drained");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let (_, metrics) = request_json(addr, "GET", "/metrics", None);
    assert!(as_f64(metrics.get("serve.shed").unwrap()) >= 1.0, "shed never counted");
    assert!(as_f64(metrics.get("serve.panics").unwrap()) >= 1.0, "panic never counted");
    assert!(metrics.get("serve.write_timeouts").is_some(), "write-timeout counter missing");
    assert!(metrics.get("serve.sockopt_errors").is_some(), "sockopt counter missing");

    // --- graceful shutdown + restart: acked inserts, exactly once ---
    handle.shutdown();
    server_thread.join().expect("server thread").expect("server run");
    drop(shared);

    let restarted = ServerState::load(cfg).expect("restart with WAL replay");
    let snap = restarted.snapshot();
    assert_eq!(
        snap.data.n(),
        n_base + acked.len(),
        "restart must recover exactly the acknowledged inserts"
    );
    for point in &acked {
        let hits = (n_base..snap.data.n())
            .filter(|&i| {
                snap.data
                    .row(i)
                    .iter()
                    .zip(point)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
            })
            .count();
        assert_eq!(hits, 1, "acked insert {point:?} recovered {hits} times, want exactly 1");
    }
    let (ready, epoch) = (restarted.is_ready(), snap.epoch);
    assert!(ready, "load() implies ready");
    assert_eq!(epoch, acked.len() as u64, "one replayed epoch per acked insert batch");
    std::fs::remove_dir_all(&dir).ok();
}
