//! End-to-end pipeline integration tests over several datasets, plus
//! determinism and CLI/config plumbing.

use largevis::config::{Ini, PipelineConfig, Stage};
use largevis::coordinator::run_pipeline;

/// Per-process test root: concurrent `cargo test` runs (or parallel CI
/// legs) must not collide on a shared fixed path.
fn it_root() -> std::path::PathBuf {
    std::env::temp_dir().join(format!("largevis_it_{}", std::process::id()))
}

fn tiny_cfg(dataset: &str, dir: &str) -> PipelineConfig {
    let mut cfg = PipelineConfig {
        dataset: dataset.into(),
        scale: 0.01,
        k: 10,
        out_dir: it_root().join(dir),
        ..Default::default()
    };
    cfg.vis.samples_per_vertex = 300;
    cfg.knn.forest.n_trees = 2;
    cfg
}

#[test]
fn pipeline_all_vector_datasets() {
    for ds in ["20ng-like", "mnist-like", "wikiword-like", "wikidoc-like"] {
        let cfg = tiny_cfg(ds, ds);
        let out = run_pipeline(&cfg).unwrap_or_else(|e| panic!("{ds}: {e:#}"));
        assert!(out.layout.as_slice().iter().all(|v| v.is_finite()), "{ds}");
        assert!(out.metrics.get("knn.sampled_recall").unwrap() > 0.3, "{ds}");
        assert!(cfg.out_dir.join("layout.svg").exists());
        assert!(cfg.out_dir.join("layout.tsv").exists());
    }
}

#[test]
fn pipeline_network_dataset() {
    let cfg = tiny_cfg("dblp-like", "dblp");
    let out = run_pipeline(&cfg).unwrap();
    assert!(out.labels.is_some());
    assert!(out.metrics.get("eval.knn_accuracy").is_some());
}

#[test]
fn labeled_pipeline_beats_chance() {
    let mut cfg = tiny_cfg("20ng-like", "acc");
    cfg.scale = 0.05;
    cfg.vis.samples_per_vertex = 1500;
    let out = run_pipeline(&cfg).unwrap();
    let acc = out.metrics.get("eval.knn_accuracy").unwrap();
    assert!(acc > 0.25, "accuracy {acc} (chance = 0.05 for 20 classes)");
}

#[test]
fn pipeline_seeded_determinism() {
    // Single-threaded everything => bit-identical layouts.
    let mk = |dir: &str| {
        let mut cfg = tiny_cfg("20ng-like", dir);
        cfg.knn.threads = 1;
        cfg.knn.forest.threads = 1;
        cfg.weights.threads = 1;
        cfg.vis.threads = 1;
        cfg
    };
    let a = run_pipeline(&mk("det_a")).unwrap();
    let b = run_pipeline(&mk("det_b")).unwrap();
    assert_eq!(a.layout, b.layout);
}

#[test]
fn resume_from_weights_bit_identical() {
    // An uninterrupted single-threaded run writes its KNN checkpoint;
    // resuming at the weights stage from that checkpoint must produce a
    // bit-identical layout (same seeds, threads=1 everywhere).
    let mut cfg = tiny_cfg("20ng-like", "resume");
    cfg.knn.threads = 1;
    cfg.knn.forest.threads = 1;
    cfg.weights.threads = 1;
    cfg.vis.threads = 1;
    cfg.save_checkpoints = true;
    let full = run_pipeline(&cfg).unwrap();

    let mut resumed_cfg = cfg.clone();
    resumed_cfg.resume_from = Some(Stage::Weights);
    let resumed = run_pipeline(&resumed_cfg).unwrap();
    assert_eq!(full.layout, resumed.layout, "resumed layout must be bit-identical");
    assert_eq!(full.labels, resumed.labels);
    assert_eq!(
        full.metrics.get("graph.directed_edges"),
        resumed.metrics.get("graph.directed_edges")
    );

    // Resuming at the layout stage (weighted-graph checkpoint) must
    // also reproduce the layout bit-identically.
    let mut layout_cfg = cfg.clone();
    layout_cfg.resume_from = Some(Stage::Layout);
    let from_graph = run_pipeline(&layout_cfg).unwrap();
    assert_eq!(full.layout, from_graph.layout);
}

#[test]
fn ini_to_pipeline_roundtrip() {
    let ini = Ini::parse(
        "dataset = wikidoc-like\nscale = 0.02\n[knn]\nk = 12\n[vis]\nsamples_per_vertex = 200",
    )
    .unwrap();
    let cfg = PipelineConfig::from_ini(&ini).unwrap();
    assert_eq!(cfg.dataset, "wikidoc-like");
    assert_eq!(cfg.k, 12);
    assert_eq!(cfg.vis.samples_per_vertex, 200);
}
