//! Integration tests for the AOT/PJRT path. These need `artifacts/`
//! built (`make artifacts`); they are skipped with a notice otherwise
//! so `cargo test` stays green on a fresh checkout.

use largevis::data::synth::gaussian_mixture;
use largevis::runtime::{literal_f32, literal_f32_2d, literal_to_f32, Runtime};
use largevis::util::rng::Rng;
use largevis::vis::objective::ProbFn;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn grad_kernel_matches_native_math() {
    let Some(rt) = runtime_or_skip() else { return };
    let mf = rt.manifest;
    let (b, m, s) = (mf.batch, mf.negatives, mf.dim);
    let mut rng = Rng::new(42);
    let yi: Vec<f32> = (0..b * s).map(|_| 2.0 * rng.gaussian()).collect();
    let yj: Vec<f32> = (0..b * s).map(|_| 2.0 * rng.gaussian()).collect();
    let yneg: Vec<f32> = (0..b * m * s).map(|_| 2.0 * rng.gaussian()).collect();
    let gamma = 7.0f32;

    let outs = rt
        .run(
            "grad_kernel",
            &[
                literal_f32_2d(&yi, b, s).unwrap(),
                literal_f32_2d(&yj, b, s).unwrap(),
                literal_f32_2d(&yneg, b, m * s).unwrap(),
                literal_f32(gamma),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 3);
    let gi = literal_to_f32(&outs[0]).unwrap();
    let gj = literal_to_f32(&outs[1]).unwrap();
    let gneg = literal_to_f32(&outs[2]).unwrap();
    assert_eq!(gi.len(), b * s);
    assert_eq!(gj.len(), b * s);
    assert_eq!(gneg.len(), b * m * s);

    let f = ProbFn::InvQuad { a: 1.0 };
    for e in 0..b {
        let d2: f32 = (0..s).map(|k| (yi[e * s + k] - yj[e * s + k]).powi(2)).sum();
        let c = f.coeff_pos(d2);
        for k in 0..s {
            let gpos = (c * (yi[e * s + k] - yj[e * s + k])).clamp(-5.0, 5.0);
            assert!((gj[e * s + k] + gpos).abs() < 1e-4, "gj mismatch at edge {e}");
        }
        // gi = gpos + sum of negative terms.
        let mut want = [0f32; 8];
        for k in 0..s {
            want[k] += (c * (yi[e * s + k] - yj[e * s + k])).clamp(-5.0, 5.0);
        }
        for neg in 0..m {
            let off = (e * m + neg) * s;
            let d2: f32 = (0..s).map(|k| (yi[e * s + k] - yneg[off + k]).powi(2)).sum();
            let cn = gamma * f.coeff_neg(d2);
            for k in 0..s {
                let gterm = (cn * (yi[e * s + k] - yneg[off + k])).clamp(-5.0, 5.0);
                want[k] += gterm;
                assert!(
                    (gneg[off + k] + gterm).abs() < 1e-4,
                    "gneg mismatch at edge {e} neg {neg}"
                );
            }
        }
        for k in 0..s {
            assert!(
                (gi[e * s + k] - want[k]).abs() < 1e-4,
                "gi mismatch at edge {e}: {} vs {}",
                gi[e * s + k],
                want[k]
            );
        }
    }
}

#[test]
fn pdist_artifact_matches_rust_sqdist() {
    let Some(rt) = runtime_or_skip() else { return };
    let mf = rt.manifest;
    let (tile, d) = (mf.pdist_tile, mf.pdist_d);
    let (m, _) = gaussian_mixture(tile, d, 4, 0.2, 7);
    let xa = m.as_slice().to_vec();
    let outs = rt
        .run(
            "pdist",
            &[literal_f32_2d(&xa, tile, d).unwrap(), literal_f32_2d(&xa, tile, d).unwrap()],
        )
        .unwrap();
    let dist = literal_to_f32(&outs[0]).unwrap();
    assert_eq!(dist.len(), tile * tile);
    let mut rng = Rng::new(9);
    for _ in 0..200 {
        let i = rng.below(tile);
        let j = rng.below(tile);
        let want = m.sqdist(i, j);
        let got = dist[i * tile + j];
        assert!(
            (got - want).abs() < 1e-2 * (1.0 + want),
            "pdist[{i},{j}] = {got} vs rust {want}"
        );
    }
}

#[test]
fn largevis_step_artifact_runs_and_updates() {
    let Some(rt) = runtime_or_skip() else { return };
    let mf = rt.manifest;
    let (n, b, m, s) = (mf.step_n, mf.batch, mf.negatives, mf.dim);
    let mut rng = Rng::new(5);
    let y: Vec<f32> = (0..n * s).map(|_| 0.01 * rng.gaussian()).collect();
    let idx_i: Vec<i32> = (0..b).map(|_| rng.below(n) as i32).collect();
    let idx_j: Vec<i32> = (0..b).map(|_| rng.below(n) as i32).collect();
    let idx_neg: Vec<i32> = (0..b * m).map(|_| rng.below(n) as i32).collect();

    let outs = rt
        .run(
            "largevis_step",
            &[
                literal_f32_2d(&y, n, s).unwrap(),
                largevis::runtime::literal_i32_1d(&idx_i),
                largevis::runtime::literal_i32_1d(&idx_j),
                largevis::runtime::literal_i32_2d(&idx_neg, b, m).unwrap(),
                literal_f32(1.0),
                literal_f32(7.0),
            ],
        )
        .unwrap();
    let y2 = literal_to_f32(&outs[0]).unwrap();
    assert_eq!(y2.len(), n * s);
    assert!(y2.iter().all(|v| v.is_finite()));
    // Touched rows changed, untouched identical.
    let touched: std::collections::HashSet<usize> = idx_i
        .iter()
        .chain(&idx_j)
        .chain(&idx_neg)
        .map(|&v| v as usize)
        .collect();
    let changed = (0..n)
        .filter(|v| (0..s).any(|k| y2[v * s + k] != y[v * s + k]))
        .collect::<Vec<_>>();
    assert!(!changed.is_empty());
    for &v in &changed {
        assert!(touched.contains(&v), "untouched row {v} changed");
    }
}

#[test]
fn batched_optimizer_separates_communities() {
    let Some(rt) = runtime_or_skip() else { return };
    // A graph large relative to the batch size (B=1024): mini-batch SGD
    // with stale in-batch gradients needs touched vertices to rarely
    // repeat within a batch, just like Hogwild needs rare collisions.
    let g = largevis::data::synth::sbm(2500, 5, 12.0, 1.0, 11);
    let edges: Vec<(u32, u32, f64)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
    let graph = largevis::graph::CsrGraph::from_undirected(g.n, &edges);
    let cfg = largevis::vis::LargeVisConfig { samples_per_vertex: 800, ..Default::default() };
    let mut y = largevis::vis::init_layout(g.n, 2, 1);
    largevis::vis::batched::optimize_batched(&graph, &mut y, &cfg, &rt).unwrap();
    assert!(y.as_slice().iter().all(|v| v.is_finite()));
    let acc = largevis::eval::knn_classifier::knn_accuracy(
        &y,
        &g.communities,
        &largevis::eval::knn_classifier::KnnEvalConfig { k: 5, sample: 1500, ..Default::default() },
    );
    assert!(acc > 0.6, "XLA layout community accuracy {acc} (chance 0.2)");
}
