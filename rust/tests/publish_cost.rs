//! Publish-cost regression harness for the chunked copy-on-write
//! snapshot store (`data::chunked`).
//!
//! The serving path's contract is that publishing a new epoch after an
//! insert batch costs **O(batch), not O(N)**: a publish clones chunk
//! pointers, and the only chunk *data* copied is what the batch
//! actually touched — the tail chunks it appends to plus the chunks
//! holding the spliced KNN rows of its base neighbors. This test
//! proves the contract with the library's bytes-copied counter
//! ([`largevis::data::chunked::copied_bytes`]):
//!
//! * **bounded** — the steady-state bytes copied per `insert` publish
//!   stay under a fixed budget derived from the chunk sizes, far below
//!   the O(N) bytes a full-snapshot memcpy would count, and
//! * **flat** — the per-publish cost at a ~10x larger base is within
//!   1.5x of the small base's. Bases are chunk-aligned and the insert
//!   batches target the same base row neighborhoods at both sizes, so
//!   the touched-chunk sets match and any growth would be a real
//!   O(N) leak.
//!
//! Scale: the full pair (10240 / 102400 base rows) runs under
//! `--release`; plain debug `cargo test` shrinks both by
//! `LARGEVIS_PUBLISH_SCALE` (default 0.04, floored at one data chunk)
//! so tier-1 stays fast. A machine-readable summary is written to
//! `$LARGEVIS_PUBLISH_DIR/publish_cost.json` (default `target/`),
//! mirroring the recall and fault-coverage artifacts.
//!
//! The counter is process-global, so this file is its own test binary
//! with a single `#[test]` — nothing else may copy chunks while the
//! deltas are being read.

use largevis::config::{SearchMode, ServeConfig};
use largevis::coordinator::CheckpointPaths;
use largevis::data::chunked::{copied_bytes, MATRIX_CHUNK_ROWS};
use largevis::data::formats::{binary, checkpoint};
use largevis::data::matrix::Matrix;
use largevis::knn::KnnGraph;
use largevis::serve::ServerState;
use std::fmt::Write as _;
use std::path::Path;

/// Data dimensionality (small so exact insert lookups stay fast at the
/// 102400-row release base).
const D: usize = 4;
/// Fabricated graph degree: ring neighbors `i±1`, `i±2`.
const K: usize = 4;
/// Rows per insert batch.
const BATCH: usize = 2;
/// Insert batches per base size; the first publish is warmup (it cuts
/// fresh tail chunks), the rest are the steady-state measurement.
const BATCHES: usize = 6;

/// Steady-state per-publish budget, in bytes. Generous against the
/// real cost (tail-chunk copies of a few freshly inserted rows plus at
/// most `BATCH * K` spliced base KNN chunks of 32 rows each — a few
/// KiB), but far below a full O(N) snapshot copy even at the smallest
/// debug-scale base (1024 rows ≈ 56 KiB of data + layout + graph).
const PUBLISH_BUDGET: u64 = 48 * 1024;

fn scale() -> f64 {
    if let Ok(s) = std::env::var("LARGEVIS_PUBLISH_SCALE") {
        return s.parse().expect("LARGEVIS_PUBLISH_SCALE must be a float");
    }
    if cfg!(debug_assertions) {
        0.04
    } else {
        1.0
    }
}

/// Scale a full-size base row count, rounded to whole data chunks so
/// every fabricated base is chunk-aligned (inserts then open fresh
/// tail chunks instead of copying a partially-filled base chunk whose
/// size would depend on `n % chunk_rows`).
fn scaled_base(full_rows: usize, scale: f64) -> usize {
    let chunks = ((full_rows as f64 * scale / MATRIX_CHUNK_ROWS as f64).round() as usize).max(1);
    chunks * MATRIX_CHUNK_ROWS
}

/// Row `i`'s data vector: a line in feature space, so exact nearest
/// neighbors of a query near row `i` are the same row indices at every
/// base size.
fn feature(i: usize) -> [f32; D] {
    [i as f32 * 0.25; D]
}

fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Minimal valid checkpoint directory: `n` collinear points, circular
/// ring KNN of degree [`K`], no labels.
fn fabricate_checkpoints(dir: &Path, n: usize) {
    std::fs::create_dir_all(dir).unwrap();
    let paths = CheckpointPaths::in_dir(dir);
    let mut data = Vec::with_capacity(n * D);
    for i in 0..n {
        data.extend_from_slice(&feature(i));
    }
    let data = Matrix::from_vec(data, n, D);
    let layout: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.5).collect();
    binary::write_binary(&paths.data, &data).unwrap();
    binary::write_binary(&paths.layout, &Matrix::from_vec(layout, n, 2)).unwrap();
    let mut knn = KnnGraph::empty(n, K);
    for i in 0..n {
        let mut row: Vec<(u32, f32)> = [n - 2, n - 1, 1, 2]
            .iter()
            .map(|&off| {
                let j = (i + off) % n;
                (j as u32, sqdist(data.row(i), data.row(j)))
            })
            .collect();
        row.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        knn.neighbors[i] = row;
    }
    checkpoint::write_knn(&paths.knn, &knn).unwrap();
    std::fs::write(&paths.meta, "publish-cost").unwrap();
}

/// Batch `b`: [`BATCH`] points just off base rows 100.. and 300.. —
/// inside the smallest (one-chunk) base, so the spliced neighborhoods
/// are the same chunk indices at every base size.
fn insert_batch(b: usize) -> Matrix {
    let mut vals = Vec::with_capacity(BATCH * D);
    for r in 0..BATCH {
        let near = 100 + 200 * r + 3 * b;
        for v in feature(near) {
            vals.push(v + 0.1);
        }
    }
    Matrix::from_vec(vals, BATCH, D)
}

/// Run the insert workload against a fresh server over an `n`-row base
/// and return the copied-bytes delta of every `insert` publish.
fn measure(n: usize) -> Vec<u64> {
    let dir = std::env::temp_dir()
        .join(format!("largevis_publish_cost_{}_{n}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    fabricate_checkpoints(&dir, n);
    let cfg = ServeConfig {
        checkpoints: dir.clone(),
        // Exact base-neighbor lookups: no search-index maintenance in
        // the measured path, and identical neighbor choices per base.
        search: SearchMode::Exact,
        insert_samples: 8,
        refine_samples: 0,
        // Keep WAL rotation + compaction out of the measured inserts.
        wal_segment_bytes: 1 << 30,
        wal_max_segments: 1 << 20,
        ..Default::default()
    };
    let st = ServerState::load(cfg).unwrap_or_else(|e| panic!("load base n={n}: {e:#}"));
    let mut deltas = Vec::with_capacity(BATCHES);
    for b in 0..BATCHES {
        let before = copied_bytes();
        st.insert(&insert_batch(b)).unwrap_or_else(|e| panic!("insert {b} at n={n}: {e:#}"));
        deltas.push(copied_bytes() - before);
    }
    std::fs::remove_dir_all(&dir).ok();
    deltas
}

/// Worst steady-state publish (every batch after the warmup).
fn steady_max(deltas: &[u64]) -> u64 {
    deltas[1..].iter().copied().max().unwrap()
}

/// The O(N) yardstick: bytes a full-snapshot copy of the base would
/// count (data + layout + KNN pairs).
fn full_copy_bytes(n: usize) -> u64 {
    (n * D * 4 + n * 2 * 4 + n * K * 8) as u64
}

fn write_report(pairs: &[(usize, &[u64])], scale: f64) {
    let dir = std::env::var("LARGEVIS_PUBLISH_DIR").unwrap_or_else(|_| "target".into());
    if std::fs::create_dir_all(&dir).is_err() {
        return;
    }
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"scale\": {scale},\n  \"batch\": {BATCH},\n  \
         \"publish_budget_bytes\": {PUBLISH_BUDGET},\n  \"bases\": ["
    );
    for (i, (n, deltas)) in pairs.iter().enumerate() {
        let _ = write!(
            s,
            "{}\n    {{\"base_rows\": {n}, \"full_copy_bytes\": {}, \
             \"steady_max_bytes\": {}, \"per_publish_bytes\": {deltas:?}}}",
            if i == 0 { "" } else { "," },
            full_copy_bytes(*n),
            steady_max(deltas),
        );
    }
    s.push_str("\n  ]\n}\n");
    let path = format!("{dir}/publish_cost.json");
    if std::fs::write(&path, &s).is_ok() {
        eprintln!("[publish_cost] wrote {path}");
    }
}

#[test]
fn publish_bytes_are_o_batch_and_flat_across_base_sizes() {
    let scale = scale();
    let small_n = scaled_base(10_240, scale);
    let large_n = scaled_base(102_400, scale).max(small_n * 2);

    let small = measure(small_n);
    let large = measure(large_n);
    eprintln!("[publish_cost] n={small_n}: per-publish bytes {small:?}");
    eprintln!("[publish_cost] n={large_n}: per-publish bytes {large:?}");

    let (s_max, l_max) = (steady_max(&small), steady_max(&large));

    // Bounded: O(batch * chunk), never anywhere near an O(N) copy.
    assert!(
        s_max <= PUBLISH_BUDGET,
        "steady publish copied {s_max} bytes at n={small_n}, budget {PUBLISH_BUDGET}"
    );
    assert!(
        l_max <= PUBLISH_BUDGET,
        "steady publish copied {l_max} bytes at n={large_n}, budget {PUBLISH_BUDGET}"
    );
    assert!(
        l_max * 8 < full_copy_bytes(large_n),
        "publish copied {l_max} bytes — within 8x of a full {}-byte snapshot copy \
         at n={large_n}; the store is not copy-on-write",
        full_copy_bytes(large_n)
    );

    // Flat: a ~10x larger base must not raise the per-publish cost.
    // (The 1 KiB floor keeps the ratio meaningful for tiny deltas.)
    let (lo, hi) = (s_max.min(l_max), s_max.max(l_max));
    assert!(
        hi as f64 <= 1.5 * (lo.max(1024) as f64),
        "publish cost not flat: {s_max} bytes at n={small_n} vs {l_max} at n={large_n}"
    );

    write_report(&[(small_n, &small[..]), (large_n, &large[..])], scale);
}
