//! Parity property tests for the SIMD distance-kernel subsystem: every
//! variant available on this machine (scalar, sse2, avx2, neon) must
//! match the scalar reference within 1e-4 relative tolerance across
//! dimensionalities 1..=200 — including the ragged-tail dims 1, 3, 7,
//! 31, 33 that exercise every remainder path — and `sqdist_bounded`'s
//! early exit must never hand a too-small distance to a caller that
//! would accept it.

use largevis::data::matrix::Matrix;
use largevis::kernels::{self, scalar};
use largevis::util::rng::Rng;

fn rand_vec(rng: &mut Rng, d: usize, scale: f32) -> Vec<f32> {
    (0..d).map(|_| rng.gaussian() * scale).collect()
}

fn assert_rel_close(got: f32, want: f32, what: &str) {
    let tol = 1e-4 * (1.0 + want.abs().max(got.abs()));
    assert!((got - want).abs() <= tol, "{what}: got {got}, want {want} (tol {tol})");
}

#[test]
fn every_variant_matches_scalar_across_dims_1_to_200() {
    let mut rng = Rng::new(0x5e1);
    for ks in kernels::available() {
        for d in 1..=200usize {
            let a = rand_vec(&mut rng, d, 2.0);
            let b = rand_vec(&mut rng, d, 2.0);
            let want_sq = scalar::sqdist(&a, &b);
            assert_rel_close((ks.sqdist)(&a, &b), want_sq, &format!("{} sqdist d={d}", ks.name));
            assert_rel_close(
                (ks.sqdist_bounded)(&a, &b, f32::INFINITY),
                want_sq,
                &format!("{} sqdist_bounded(inf) d={d}", ks.name),
            );
            assert_rel_close(
                (ks.dot)(&a, &b),
                scalar::dot(&a, &b),
                &format!("{} dot d={d}", ks.name),
            );
        }
    }
}

#[test]
fn ragged_tail_dims_with_adversarial_magnitudes() {
    // Dims around every SIMD width boundary, with large-magnitude
    // values so lane mis-handling cannot hide below tolerance.
    let dims = [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65];
    let mut rng = Rng::new(0x5e2);
    for ks in kernels::available() {
        for &d in &dims {
            let a = rand_vec(&mut rng, d, 100.0);
            let b = rand_vec(&mut rng, d, 100.0);
            assert_rel_close(
                (ks.sqdist)(&a, &b),
                scalar::sqdist(&a, &b),
                &format!("{} sqdist ragged d={d}", ks.name),
            );
            assert_rel_close(
                (ks.dot)(&a, &b),
                scalar::dot(&a, &b),
                &format!("{} dot ragged d={d}", ks.name),
            );
        }
    }
}

#[test]
fn x4_kernel_matches_scalar_per_row() {
    let mut rng = Rng::new(0x5e3);
    for ks in kernels::available() {
        for d in 1..=200usize {
            let q = rand_vec(&mut rng, d, 1.5);
            let rows = rand_vec(&mut rng, 4 * d, 1.5);
            let got = (ks.sqdist_x4)(&q, &rows, d);
            for r in 0..4 {
                let want = scalar::sqdist(&q, &rows[r * d..(r + 1) * d]);
                assert_rel_close(got[r], want, &format!("{} sqdist_x4 d={d} row={r}", ks.name));
            }
        }
    }
}

#[test]
fn bounded_early_exit_never_underestimates_below_the_bound() {
    // Contract: a result <= bound is the exact distance; a result >
    // bound may be a partial sum but is never larger than the true
    // distance. Either way a caller filtering `d < heap.threshold()`
    // makes exactly the right accept/reject decision.
    let mut rng = Rng::new(0x5e4);
    for ks in kernels::available() {
        for trial in 0..600 {
            let d = 1 + rng.below(200);
            let a = rand_vec(&mut rng, d, 2.0);
            let b = rand_vec(&mut rng, d, 2.0);
            let truth = scalar::sqdist(&a, &b);
            // Bounds below, around and above the true distance.
            let bound = truth * (rng.f32() * 1.5);
            let got = (ks.sqdist_bounded)(&a, &b, bound);
            let tol = 1e-4 * (1.0 + truth);
            if got <= bound {
                // Claimed exact: must be the true distance.
                assert!(
                    (got - truth).abs() <= tol,
                    "{} trial={trial} d={d}: accepted {got} but truth {truth} (bound {bound})",
                    ks.name
                );
            } else {
                // Early exit: a partial sum can undershoot the truth but
                // must never overshoot it (all terms are non-negative).
                assert!(
                    got <= truth + tol,
                    "{} trial={trial} d={d}: partial {got} exceeds truth {truth}",
                    ks.name
                );
            }
        }
    }
}

#[test]
fn batched_kernel_matches_scalar_across_dims_and_counts() {
    let mut rng = Rng::new(0x5e5);
    for &d in &[1usize, 3, 7, 10, 31, 33, 50, 100, 200] {
        let n = 150;
        let m = Matrix::from_vec(rand_vec(&mut rng, n * d, 1.5), n, d);
        let q = rand_vec(&mut rng, d, 1.5);
        let mut out = Vec::new();
        // Counts around the x4 unroll and the gather-block boundary.
        for &cnt in &[0usize, 1, 3, 4, 5, 63, 64, 65, 130] {
            let ids: Vec<u32> = (0..cnt).map(|_| rng.below(n) as u32).collect();
            kernels::sqdist_batch(&q, &m, &ids, &mut out);
            assert_eq!(out.len(), ids.len(), "d={d} cnt={cnt}");
            for (&id, &got) in ids.iter().zip(&out) {
                let want = scalar::sqdist(&q, m.row(id as usize));
                assert_rel_close(got, want, &format!("sqdist_batch d={d} cnt={cnt} id={id}"));
            }
        }
        // The no-gather all-rows variant agrees with the gather path.
        let all: Vec<u32> = (0..n as u32).collect();
        let mut via_ids = Vec::new();
        kernels::sqdist_batch(&q, &m, &all, &mut via_ids);
        kernels::sqdist_to_all(&q, &m, &mut out);
        assert_eq!(via_ids, out, "sqdist_to_all divergence at d={d}");
    }
}

#[test]
fn scalar_fallback_is_always_available() {
    // Non-x86/ARM targets must keep building and running: the scalar
    // set is unconditionally present and the active set is one of the
    // available ones.
    let names: Vec<&str> = kernels::available().iter().map(|k| k.name).collect();
    assert!(names.contains(&"scalar"), "{names:?}");
    assert!(names.contains(&kernels::active().name), "{names:?}");
}
