//! Golden-file tests for the LargeVis text parser: reference files
//! checked into `rust/tests/data/` exercise CRLF endings, scientific
//! notation, ragged rows (error), and unparsable values (error).

use largevis::data::formats::text::read_text;
use std::path::PathBuf;

fn golden(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data").join(name)
}

#[test]
fn basic_file_parses() {
    let m = read_text(&golden("basic.txt")).unwrap();
    assert_eq!((m.n(), m.d()), (4, 3));
    assert_eq!(m.row(0), &[0.0, 1.0, 2.5]);
    assert_eq!(m.row(1), &[-3.0, 4.25, 5.0]);
    assert_eq!(m.row(3), &[9.0, 10.5, -11.0]);
}

#[test]
fn crlf_endings_accepted() {
    let m = read_text(&golden("crlf.txt")).unwrap();
    assert_eq!((m.n(), m.d()), (3, 2));
    assert_eq!(m.row(0), &[1.5, -2.0]);
    assert_eq!(m.row(1), &[0.25, 3.0]);
    assert_eq!(m.row(2), &[-4.0, 5.125]);
}

#[test]
fn scientific_notation_parsed() {
    let m = read_text(&golden("scientific.txt")).unwrap();
    assert_eq!((m.n(), m.d()), (2, 4));
    assert_eq!(m.row(0), &[1e-3, -2.5e2, 1.5e2, 3.14159]);
    assert_eq!(m.row(1), &[1e2, -7e-2, 6.02e23, -1.0e-30]);
}

#[test]
fn ragged_row_is_error_with_line_number() {
    let err = read_text(&golden("ragged.txt")).unwrap_err().to_string();
    assert!(err.contains("ragged row"), "{err}");
    assert!(err.contains(":3:"), "error must name line 3: {err}");
    assert!(err.contains("2 values, expected 3"), "{err}");
}

#[test]
fn unparsable_value_is_error_with_line_number() {
    let err = read_text(&golden("badfloat.txt")).unwrap_err().to_string();
    assert!(err.contains("unparsable value"), "{err}");
    assert!(err.contains(":3:"), "error must name line 3: {err}");
}
