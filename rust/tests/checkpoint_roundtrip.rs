//! Property tests for the checkpoint formats and the parallel
//! symmetrizer: round-trips must be bit-identical, and the sharded
//! sort-merge symmetrization must match the single-threaded HashMap
//! reference exactly.

use largevis::data::formats::checkpoint::{read_csr, read_knn, write_csr, write_knn};
use largevis::data::synth::gaussian_mixture;
use largevis::graph::weights::{weighted_graph, weighted_graph_reference, WeightConfig};
use largevis::graph::CsrGraph;
use largevis::knn::bruteforce::exact_knn;
use largevis::knn::KnnGraph;
use largevis::util::proptest::{run_prop, PropConfig};
use largevis::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("largevis_ckpt_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Random KNN graph: rows of random length (including empty), sorted
/// ascending by distance, ids in range, no self-loops.
fn random_knn(rng: &mut Rng, size: usize) -> KnnGraph {
    let n = 2 + size;
    let k = 1 + rng.below(8);
    let mut g = KnnGraph::empty(n, k);
    for i in 0..n {
        let len = rng.below(k + 1); // may be 0 (empty row)
        let mut ids = std::collections::BTreeSet::new();
        while ids.len() < len.min(n - 1) {
            let j = rng.below(n) as u32;
            if j as usize != i {
                ids.insert(j);
            }
        }
        let mut dists: Vec<f32> = (0..ids.len()).map(|_| rng.f32() * 10.0).collect();
        dists.sort_by(f32::total_cmp);
        g.neighbors[i] = ids.into_iter().zip(dists).collect();
    }
    g
}

fn knn_bits(g: &KnnGraph) -> Vec<(usize, Vec<(u32, u32)>)> {
    g.neighbors
        .iter()
        .map(|row| (row.len(), row.iter().map(|&(id, d)| (id, d.to_bits())).collect()))
        .collect()
}

fn csr_bits(g: &CsrGraph) -> (Vec<u64>, Vec<u32>, Vec<u64>) {
    (
        g.offsets().to_vec(),
        g.cols().to_vec(),
        g.weights().iter().map(|w| w.to_bits()).collect(),
    )
}

#[test]
fn prop_knn_checkpoint_roundtrip_bit_identical() {
    run_prop("knn-ckpt", PropConfig { cases: 30, max_size: 60, ..Default::default() }, |rng, size| {
        let g = random_knn(rng, size);
        let p = tmp(&format!("knn_{size}.ckpt"));
        write_knn(&p, &g).map_err(|e| e.to_string())?;
        let back = read_knn(&p).map_err(|e| e.to_string())?;
        if back.k != g.k {
            return Err(format!("k {} -> {}", g.k, back.k));
        }
        if knn_bits(&g) != knn_bits(&back) {
            return Err("knn rows not bit-identical after round-trip".into());
        }
        Ok(())
    });
}

#[test]
fn prop_csr_checkpoint_roundtrip_bit_identical() {
    run_prop("csr-ckpt", PropConfig { cases: 30, max_size: 50, ..Default::default() }, |rng, size| {
        let n = 3 + size;
        // Random undirected edges, intentionally including duplicates
        // (from_undirected keeps parallel edges) and leaving some
        // vertices isolated (empty CSR rows).
        let mut edges: Vec<(u32, u32, f64)> = Vec::new();
        for _ in 0..(2 * n) {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            if a != b {
                let w = rng.f64() * 2.0 + 1e-12;
                edges.push((a, b, w));
                if rng.below(4) == 0 {
                    edges.push((a, b, w * 0.5)); // duplicate edge
                }
            }
        }
        let g = CsrGraph::from_undirected(n, &edges);
        let p = tmp(&format!("csr_{size}.ckpt"));
        write_csr(&p, &g).map_err(|e| e.to_string())?;
        let back = read_csr(&p).map_err(|e| e.to_string())?;
        if csr_bits(&g) != csr_bits(&back) {
            return Err("csr arrays not bit-identical after round-trip".into());
        }
        if g.edges() != back.edges() {
            return Err("rebuilt edge list differs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_symmetrization_matches_reference() {
    let prop_cfg = PropConfig { cases: 10, max_size: 40, ..Default::default() };
    run_prop("sym-parity", prop_cfg, |rng, size| {
        let n = 40 + 4 * size;
        let d = 3 + rng.below(8);
        let (m, _) = gaussian_mixture(n, d, 3, 0.25, rng.next_u64());
        let k = 3 + rng.below(6);
        let knn = exact_knn(&m, k, 2);
        let cfg = WeightConfig {
            perplexity: 2.0 + rng.f64() * (k as f64 - 2.0).max(0.5),
            threads: 1 + rng.below(8),
            ..Default::default()
        };
        let fast = weighted_graph(&knn, &cfg);
        let reference = weighted_graph_reference(&knn, &cfg);
        if csr_bits(&fast) != csr_bits(&reference) {
            return Err(format!(
                "sharded vs reference CSR mismatch (n={n} k={k} threads={})",
                cfg.threads
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_symmetrization_thread_count_invariant() {
    // The sharded symmetrizer's output must not depend on the shard
    // count: per-(src,dst) sums are order-independent and the sort is a
    // total order.
    let prop_cfg = PropConfig { cases: 6, max_size: 30, ..Default::default() };
    run_prop("sym-threads", prop_cfg, |rng, size| {
        let n = 50 + 4 * size;
        let (m, _) = gaussian_mixture(n, 6, 3, 0.3, rng.next_u64());
        let knn = exact_knn(&m, 6, 2);
        let base = weighted_graph(&knn, &WeightConfig { threads: 1, ..Default::default() });
        for threads in [2, 3, 7] {
            let alt = weighted_graph(&knn, &WeightConfig { threads, ..Default::default() });
            if csr_bits(&base) != csr_bits(&alt) {
                return Err(format!("threads=1 vs threads={threads} differ"));
            }
        }
        Ok(())
    });
}

#[test]
fn knn_checkpoint_empty_graph() {
    let g = KnnGraph::empty(5, 3);
    let p = tmp("empty.knn");
    write_knn(&p, &g).unwrap();
    let back = read_knn(&p).unwrap();
    assert_eq!(back.n(), 5);
    assert!(back.neighbors.iter().all(|r| r.is_empty()));
}

#[test]
fn csr_checkpoint_no_edges() {
    let g = CsrGraph::from_undirected(4, &[]);
    let p = tmp("noedges.csr");
    write_csr(&p, &g).unwrap();
    let back = read_csr(&p).unwrap();
    assert_eq!(back.n(), 4);
    assert_eq!(back.n_directed_edges(), 0);
}
