//! Property-based tests over module invariants, driven by the
//! `util::proptest` harness (seeded, shrink-lite, `PROP_SEED=<n>` to
//! reproduce).

use largevis::data::matrix::Matrix;
use largevis::data::synth::gaussian_mixture;
use largevis::graph::weights::{calibrate_row, weighted_graph, WeightConfig};
use largevis::graph::CsrGraph;
use largevis::knn::bruteforce::exact_knn;
use largevis::knn::explore::{explore_once, LargeVisKnnConfig};
use largevis::knn::rptree::{rp_forest_knn, RpForestConfig};
use largevis::util::alias::AliasTable;
use largevis::util::proptest::{run_prop, PropConfig};

#[test]
fn prop_alias_table_mean_matches_weights() {
    run_prop("alias-mean", PropConfig { cases: 20, max_size: 64, ..Default::default() }, |rng, size| {
        let n = 2 + size.min(40);
        let w: Vec<f64> = (0..n).map(|_| rng.f64() * 5.0 + 0.05).collect();
        let t = AliasTable::new(&w);
        let total: f64 = w.iter().sum();
        let draws = 40_000;
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[t.sample(rng)] += 1;
        }
        for (i, (&c, &wi)) in counts.iter().zip(&w).enumerate() {
            let p = wi / total;
            let se = (p * (1.0 - p) / draws as f64).sqrt();
            let got = c as f64 / draws as f64;
            if (got - p).abs() > 6.0 * se + 1e-3 {
                return Err(format!("outcome {i}: freq {got:.4} vs p {p:.4}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_perplexity_calibration_hits_target() {
    run_prop("perplexity", PropConfig { cases: 30, max_size: 200, ..Default::default() }, |rng, size| {
        let k = 4 + size.min(180);
        let dists: Vec<f32> = (0..k).map(|_| rng.f32() * 10.0 + 0.01).collect();
        let u = 2.0 + rng.f64() * (k as f64 * 0.8 - 2.0);
        let probs = calibrate_row(&dists, u, 100, 1e-6);
        let sum: f64 = probs.iter().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("probs sum {sum}"));
        }
        let entropy: f64 = -probs.iter().filter(|&&p| p > 0.0).map(|p| p * p.ln()).sum::<f64>();
        let perp = entropy.exp();
        if (perp - u).abs() > 0.05 * u {
            return Err(format!("target perplexity {u:.2}, got {perp:.2} (k={k})"));
        }
        Ok(())
    });
}

#[test]
fn prop_csr_roundtrip_preserves_edges() {
    run_prop("csr-roundtrip", PropConfig { cases: 30, max_size: 60, ..Default::default() }, |rng, size| {
        let n = 3 + size;
        let mut set = std::collections::BTreeSet::new();
        for _ in 0..(2 * n) {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            if a != b {
                set.insert((a.min(b), a.max(b)));
            }
        }
        let edges: Vec<(u32, u32, f64)> =
            set.iter().map(|&(a, b)| (a, b, 1.0 + (a + b) as f64)).collect();
        let g = CsrGraph::from_undirected(n, &edges);
        if g.n_directed_edges() != 2 * edges.len() {
            return Err("directed edge count".into());
        }
        // Every undirected edge appears in both rows with its weight.
        for &(a, b, w) in &edges {
            let fwd = g.row(a as usize).find(|&(c, _)| c == b);
            let bwd = g.row(b as usize).find(|&(c, _)| c == a);
            match (fwd, bwd) {
                (Some((_, wf)), Some((_, wb))) if wf == w && wb == w => {}
                _ => return Err(format!("edge ({a},{b}) lost")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_knn_recall_monotone_in_trees() {
    run_prop("rp-trees-monotone", PropConfig { cases: 6, max_size: 40, ..Default::default() }, |rng, size| {
        let n = 150 + size * 4;
        let d = 4 + rng.below(20);
        let (m, _) = gaussian_mixture(n, d, 4, 0.2, rng.next_u64());
        let truth = exact_knn(&m, 8, 2);
        let seed = rng.next_u64();
        let r_few = rp_forest_knn(&m, 8, &RpForestConfig { n_trees: 1, leaf_size: 16, threads: 2, seed, ..Default::default() })
            .recall_against(&truth);
        let r_many =
            rp_forest_knn(&m, 8, &RpForestConfig { n_trees: 10, leaf_size: 16, threads: 2, seed, ..Default::default() })
                .recall_against(&truth);
        // Allow small sampling noise but require the trend.
        if r_many + 0.02 < r_few {
            return Err(format!("recall decreased with more trees: {r_few:.3} -> {r_many:.3}"));
        }
        Ok(())
    });
}

#[test]
fn prop_explore_never_regresses_mean_distance() {
    run_prop("explore-monotone", PropConfig { cases: 6, max_size: 30, ..Default::default() }, |rng, size| {
        let n = 120 + size * 5;
        let (m, _) = gaussian_mixture(n, 8, 3, 0.3, rng.next_u64());
        let cfg = LargeVisKnnConfig {
            forest: RpForestConfig { n_trees: 1, leaf_size: 8, threads: 2, seed: rng.next_u64(), ..Default::default() },
            iters: 0,
            max_candidates: usize::MAX,
            threads: 2,
        };
        let g0 = rp_forest_knn(&m, 6, &cfg.forest);
        let g1 = explore_once(&m, &g0, &cfg);
        g1.check_invariants().map_err(|e| e.to_string())?;
        for i in 0..n {
            let s0: f32 = g0.neighbors[i].iter().map(|&(_, d)| d).sum();
            let s1: f32 = g1.neighbors[i].iter().map(|&(_, d)| d).sum();
            let l0 = g0.neighbors[i].len();
            let l1 = g1.neighbors[i].len();
            if l1 < l0 {
                return Err(format!("node {i} lost neighbors {l0} -> {l1}"));
            }
            if l1 == l0 && s1 > s0 + 1e-4 {
                return Err(format!("node {i} distance sum regressed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_graph_total_mass_one() {
    run_prop("weights-mass", PropConfig { cases: 8, max_size: 30, ..Default::default() }, |rng, size| {
        let n = 60 + 4 * size;
        let (m, _) = gaussian_mixture(n, 6, 3, 0.2, rng.next_u64());
        let knn = exact_knn(&m, 8, 2);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 5.0, ..Default::default() });
        let total: f64 = (0..g.n()).map(|i| g.row(i).map(|(_, w)| w).sum::<f64>()).sum();
        if (total - 1.0).abs() > 1e-6 {
            return Err(format!("total weight {total}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sgd_objective_improves_on_random_cluster_graphs() {
    run_prop("sgd-objective", PropConfig { cases: 4, max_size: 20, ..Default::default() }, |rng, size| {
        // Random 2-4 clique clusters; SGD must increase the objective.
        let k = 2 + rng.below(3);
        let per = 5 + size / 4;
        let n = k * per;
        let mut edges = Vec::new();
        for c in 0..k {
            for a in 0..per {
                for b in (a + 1)..per {
                    edges.push(((c * per + a) as u32, (c * per + b) as u32, 1.0f64));
                }
            }
        }
        let g = CsrGraph::from_undirected(n, &edges);
        let cfg = largevis::vis::LargeVisConfig {
            samples_per_vertex: 3000,
            threads: 1,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let mut y = largevis::vis::init_layout(n, 2, rng.next_u64());
        let before =
            largevis::vis::objective::exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        largevis::vis::sgd::optimize(&g, &mut y, &cfg);
        let after =
            largevis::vis::objective::exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        if after <= before {
            return Err(format!("objective {before:.3} -> {after:.3}"));
        }
        if !y.as_slice().iter().all(|v| v.is_finite()) {
            return Err("non-finite layout".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_sqdist_triangle_inequality() {
    run_prop("sqdist-triangle", PropConfig { cases: 40, max_size: 64, ..Default::default() }, |rng, size| {
        let d = 1 + size.min(48);
        let mut m = Matrix::zeros(3, d);
        for i in 0..3 {
            for x in m.row_mut(i).iter_mut() {
                *x = rng.gaussian() * 3.0;
            }
        }
        let dab = m.sqdist(0, 1).sqrt();
        let dbc = m.sqdist(1, 2).sqrt();
        let dac = m.sqdist(0, 2).sqrt();
        if dac > dab + dbc + 1e-3 {
            return Err(format!("triangle violated: {dac} > {dab} + {dbc}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_below_never_out_of_range() {
    run_prop("rng-below", PropConfig { cases: 64, max_size: 1000, ..Default::default() }, |rng, size| {
        let n = 1 + size;
        for _ in 0..1000 {
            let v = rng.below(n);
            if v >= n {
                return Err(format!("below({n}) = {v}"));
            }
        }
        Ok(())
    });
}
