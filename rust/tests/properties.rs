//! Property-based tests over module invariants, driven by the
//! `util::proptest` harness (seeded, shrink-lite, `PROP_SEED=<n>` to
//! reproduce).

use largevis::data::matrix::Matrix;
use largevis::data::synth::gaussian_mixture;
use largevis::graph::weights::{calibrate_row, weighted_graph, WeightConfig};
use largevis::graph::CsrGraph;
use largevis::knn::bruteforce::exact_knn;
use largevis::knn::explore::{explore_once, LargeVisKnnConfig};
use largevis::knn::rptree::{rp_forest_knn, RpForestConfig};
use largevis::util::alias::AliasTable;
use largevis::util::proptest::{run_prop, PropConfig};

#[test]
fn prop_alias_table_mean_matches_weights() {
    run_prop("alias-mean", PropConfig { cases: 20, max_size: 64, ..Default::default() }, |rng, size| {
        let n = 2 + size.min(40);
        let w: Vec<f64> = (0..n).map(|_| rng.f64() * 5.0 + 0.05).collect();
        let t = AliasTable::new(&w);
        let total: f64 = w.iter().sum();
        let draws = 40_000;
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[t.sample(rng)] += 1;
        }
        for (i, (&c, &wi)) in counts.iter().zip(&w).enumerate() {
            let p = wi / total;
            let se = (p * (1.0 - p) / draws as f64).sqrt();
            let got = c as f64 / draws as f64;
            if (got - p).abs() > 6.0 * se + 1e-3 {
                return Err(format!("outcome {i}: freq {got:.4} vs p {p:.4}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_perplexity_calibration_hits_target() {
    run_prop("perplexity", PropConfig { cases: 30, max_size: 200, ..Default::default() }, |rng, size| {
        let k = 4 + size.min(180);
        let dists: Vec<f32> = (0..k).map(|_| rng.f32() * 10.0 + 0.01).collect();
        let u = 2.0 + rng.f64() * (k as f64 * 0.8 - 2.0);
        let probs = calibrate_row(&dists, u, 100, 1e-6);
        let sum: f64 = probs.iter().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("probs sum {sum}"));
        }
        let entropy: f64 = -probs.iter().filter(|&&p| p > 0.0).map(|p| p * p.ln()).sum::<f64>();
        let perp = entropy.exp();
        if (perp - u).abs() > 0.05 * u {
            return Err(format!("target perplexity {u:.2}, got {perp:.2} (k={k})"));
        }
        Ok(())
    });
}

#[test]
fn prop_csr_roundtrip_preserves_edges() {
    run_prop("csr-roundtrip", PropConfig { cases: 30, max_size: 60, ..Default::default() }, |rng, size| {
        let n = 3 + size;
        let mut set = std::collections::BTreeSet::new();
        for _ in 0..(2 * n) {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            if a != b {
                set.insert((a.min(b), a.max(b)));
            }
        }
        let edges: Vec<(u32, u32, f64)> =
            set.iter().map(|&(a, b)| (a, b, 1.0 + (a + b) as f64)).collect();
        let g = CsrGraph::from_undirected(n, &edges);
        if g.n_directed_edges() != 2 * edges.len() {
            return Err("directed edge count".into());
        }
        // Every undirected edge appears in both rows with its weight.
        for &(a, b, w) in &edges {
            let fwd = g.row(a as usize).find(|&(c, _)| c == b);
            let bwd = g.row(b as usize).find(|&(c, _)| c == a);
            match (fwd, bwd) {
                (Some((_, wf)), Some((_, wb))) if wf == w && wb == w => {}
                _ => return Err(format!("edge ({a},{b}) lost")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_knn_recall_monotone_in_trees() {
    run_prop("rp-trees-monotone", PropConfig { cases: 6, max_size: 40, ..Default::default() }, |rng, size| {
        let n = 150 + size * 4;
        let d = 4 + rng.below(20);
        let (m, _) = gaussian_mixture(n, d, 4, 0.2, rng.next_u64());
        let truth = exact_knn(&m, 8, 2);
        let seed = rng.next_u64();
        let r_few = rp_forest_knn(&m, 8, &RpForestConfig { n_trees: 1, leaf_size: 16, threads: 2, seed, ..Default::default() })
            .recall_against(&truth);
        let r_many =
            rp_forest_knn(&m, 8, &RpForestConfig { n_trees: 10, leaf_size: 16, threads: 2, seed, ..Default::default() })
                .recall_against(&truth);
        // Allow small sampling noise but require the trend.
        if r_many + 0.02 < r_few {
            return Err(format!("recall decreased with more trees: {r_few:.3} -> {r_many:.3}"));
        }
        Ok(())
    });
}

#[test]
fn prop_explore_never_regresses_mean_distance() {
    run_prop("explore-monotone", PropConfig { cases: 6, max_size: 30, ..Default::default() }, |rng, size| {
        let n = 120 + size * 5;
        let (m, _) = gaussian_mixture(n, 8, 3, 0.3, rng.next_u64());
        let cfg = LargeVisKnnConfig {
            forest: RpForestConfig { n_trees: 1, leaf_size: 8, threads: 2, seed: rng.next_u64(), ..Default::default() },
            iters: 0,
            max_candidates: usize::MAX,
            threads: 2,
        };
        let g0 = rp_forest_knn(&m, 6, &cfg.forest);
        let g1 = explore_once(&m, &g0, &cfg);
        g1.check_invariants().map_err(|e| e.to_string())?;
        for i in 0..n {
            let s0: f32 = g0.neighbors[i].iter().map(|&(_, d)| d).sum();
            let s1: f32 = g1.neighbors[i].iter().map(|&(_, d)| d).sum();
            let l0 = g0.neighbors[i].len();
            let l1 = g1.neighbors[i].len();
            if l1 < l0 {
                return Err(format!("node {i} lost neighbors {l0} -> {l1}"));
            }
            if l1 == l0 && s1 > s0 + 1e-4 {
                return Err(format!("node {i} distance sum regressed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_graph_total_mass_one() {
    run_prop("weights-mass", PropConfig { cases: 8, max_size: 30, ..Default::default() }, |rng, size| {
        let n = 60 + 4 * size;
        let (m, _) = gaussian_mixture(n, 6, 3, 0.2, rng.next_u64());
        let knn = exact_knn(&m, 8, 2);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 5.0, ..Default::default() });
        let total: f64 = (0..g.n()).map(|i| g.row(i).map(|(_, w)| w).sum::<f64>()).sum();
        if (total - 1.0).abs() > 1e-6 {
            return Err(format!("total weight {total}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sgd_objective_improves_on_random_cluster_graphs() {
    run_prop("sgd-objective", PropConfig { cases: 4, max_size: 20, ..Default::default() }, |rng, size| {
        // Random 2-4 clique clusters; SGD must increase the objective.
        let k = 2 + rng.below(3);
        let per = 5 + size / 4;
        let n = k * per;
        let mut edges = Vec::new();
        for c in 0..k {
            for a in 0..per {
                for b in (a + 1)..per {
                    edges.push(((c * per + a) as u32, (c * per + b) as u32, 1.0f64));
                }
            }
        }
        let g = CsrGraph::from_undirected(n, &edges);
        let cfg = largevis::vis::LargeVisConfig {
            samples_per_vertex: 3000,
            threads: 1,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let mut y = largevis::vis::init_layout(n, 2, rng.next_u64());
        let before =
            largevis::vis::objective::exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        largevis::vis::sgd::optimize(&g, &mut y, &cfg);
        let after =
            largevis::vis::objective::exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        if after <= before {
            return Err(format!("objective {before:.3} -> {after:.3}"));
        }
        if !y.as_slice().iter().all(|v| v.is_finite()) {
            return Err("non-finite layout".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_sqdist_triangle_inequality() {
    run_prop("sqdist-triangle", PropConfig { cases: 40, max_size: 64, ..Default::default() }, |rng, size| {
        let d = 1 + size.min(48);
        let mut m = Matrix::zeros(3, d);
        for i in 0..3 {
            for x in m.row_mut(i).iter_mut() {
                *x = rng.gaussian() * 3.0;
            }
        }
        let dab = m.sqdist(0, 1).sqrt();
        let dbc = m.sqdist(1, 2).sqrt();
        let dac = m.sqdist(0, 2).sqrt();
        if dac > dab + dbc + 1e-3 {
            return Err(format!("triangle violated: {dac} > {dab} + {dbc}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_below_never_out_of_range() {
    run_prop("rng-below", PropConfig { cases: 64, max_size: 1000, ..Default::default() }, |rng, size| {
        let n = 1 + size;
        for _ in 0..1000 {
            let v = rng.below(n);
            if v >= n {
                return Err(format!("below({n}) = {v}"));
            }
        }
        Ok(())
    });
}

/// Exact integer squared distance — every value representable in f32,
/// so graph-walk and full-scan paths agree bitwise whatever order the
/// SIMD lanes accumulate in.
fn int_sqdist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[test]
fn prop_wide_beam_search_matches_exact_oracle() {
    use largevis::kernels::nearest_k;
    use largevis::knn::search::{search_nearest, SearchIndex};
    use largevis::knn::KnnGraph;
    use largevis::util::heap::BoundedMaxHeap;
    // A beam at least as wide as the dataset must degenerate to the
    // exact result set on any *connected* graph: the pool can hold
    // every point, so the walk only terminates once the frontier is
    // exhausted, and the (dist, id) ordering ties out to the oracle.
    run_prop(
        "wide-beam-exact",
        PropConfig { cases: 12, max_size: 90, ..Default::default() },
        |rng, size| {
            let n = 8 + size;
            let d = 2 + rng.below(6);
            let mut m = Matrix::zeros(n, d);
            for i in 0..n {
                for x in m.row_mut(i).iter_mut() {
                    *x = rng.below(17) as f32 - 8.0; // small integers
                }
            }
            // Random directed lists, symmetrized, plus a chain backbone
            // so every point is reachable from any seed.
            let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
            let fan = 2 + rng.below(4);
            for i in 0..n {
                for _ in 0..fan {
                    let j = rng.below(n);
                    if j != i {
                        adj[i].push(j as u32);
                        adj[j].push(i as u32);
                    }
                }
            }
            for i in 0..n - 1 {
                adj[i].push(i as u32 + 1);
                adj[i + 1].push(i as u32);
            }
            let mut knn = KnnGraph::empty(n, n);
            for i in 0..n {
                adj[i].sort_unstable();
                adj[i].dedup();
                let mut list: Vec<(u32, f32)> = adj[i]
                    .iter()
                    .map(|&j| (j, int_sqdist(m.row(i), m.row(j as usize))))
                    .collect();
                list.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
                knn.neighbors[i] = list;
            }
            let index = SearchIndex::build(&m, &knn, None, 1 + rng.below(8));
            let k = 1 + rng.below(n);
            let qi = rng.below(n);
            let mut q: Vec<f32> = m.row(qi).to_vec();
            for x in q.iter_mut() {
                *x += rng.below(5) as f32 - 2.0;
            }
            let (got, stats) = search_nearest(&q, &m, &knn, &index, k, n);
            if stats.fallback {
                return Err("wide beam fell back on a connected graph".into());
            }
            let mut dists = Vec::new();
            let mut heap = BoundedMaxHeap::new(k);
            let want = nearest_k(&q, &m, k, &mut dists, &mut heap);
            if got != want {
                return Err(format!("n={n} d={d} k={k}: graph {got:?} vs exact {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_disconnected_query_falls_back_never_short() {
    use largevis::knn::search::{search_nearest, SearchIndex};
    use largevis::knn::KnnGraph;
    // Points the walk cannot reach (edgeless component, fewer seeds
    // than isolated points) must trigger the exact fallback — the
    // caller always gets min(k, n) results, never a silently truncated
    // set, and the stats say the oracle answered.
    run_prop(
        "disconnected-fallback",
        PropConfig { cases: 10, max_size: 60, ..Default::default() },
        |rng, size| {
            let na = 8 + size; // chained (connected) component
            let nb = 6 + rng.below(10); // edgeless points, far away
            let n = na + nb;
            let d = 3;
            let mut m = Matrix::zeros(n, d);
            for i in 0..n {
                let off = if i < na { 0.0 } else { 100.0 };
                for x in m.row_mut(i).iter_mut() {
                    *x = rng.below(9) as f32 - 4.0 + off;
                }
            }
            let mut knn = KnnGraph::empty(n, 2);
            for i in 0..na - 1 {
                let dij = int_sqdist(m.row(i), m.row(i + 1));
                knn.neighbors[i].push((i as u32 + 1, dij));
                knn.neighbors[i + 1].push((i as u32, dij));
            }
            for list in knn.neighbors.iter_mut() {
                list.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            }
            // Strictly fewer seeds than isolated points: whatever the
            // seed picker does, some point stays unreachable.
            let n_seeds = 1 + rng.below(nb - 1);
            let index = SearchIndex::build(&m, &knn, None, n_seeds);
            let q: Vec<f32> = m.row(rng.below(n)).to_vec();
            let (got, stats) = search_nearest(&q, &m, &knn, &index, n, 8);
            if !stats.fallback {
                return Err(format!("na={na} nb={nb} seeds={n_seeds}: no fallback"));
            }
            if got.len() != n {
                return Err(format!("silently short result: {} of {n}", got.len()));
            }
            let mut prev = (0u32, f32::NEG_INFINITY);
            let mut seen = std::collections::HashSet::new();
            for &(id, dist) in &got {
                if !seen.insert(id) {
                    return Err(format!("duplicate id {id}"));
                }
                if dist < prev.1 {
                    return Err("result not sorted".into());
                }
                prev = (id, dist);
            }
            Ok(())
        },
    );
}

#[test]
fn disconnected_server_query_counts_fallback_metric() {
    use largevis::config::{SearchMode, ServeConfig};
    use largevis::coordinator::pipeline::CheckpointPaths;
    use largevis::data::formats::{binary, checkpoint};
    use largevis::kernels::nearest_k;
    use largevis::knn::KnnGraph;
    use largevis::serve::ServerState;
    use largevis::util::heap::BoundedMaxHeap;
    // End-to-end flavor of the fallback property: a served snapshot
    // whose graph strands points still answers /knn-style queries
    // exactly, and the miss is observable in serve.search_fallbacks.
    let dir = std::env::temp_dir()
        .join(format!("largevis_prop_fallback_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let paths = CheckpointPaths::in_dir(&dir);
    let (na, nb, d) = (30usize, 10usize, 3usize);
    let n = na + nb;
    let mut data = Matrix::zeros(n, d);
    for i in 0..n {
        let off = if i < na { 0.0 } else { 50.0 };
        for (j, x) in data.row_mut(i).iter_mut().enumerate() {
            *x = (i * d + j) as f32 * 0.125 + off;
        }
    }
    let mut layout = Matrix::zeros(n, 2);
    for i in 0..n {
        layout.row_mut(i)[0] = i as f32;
    }
    let mut knn = KnnGraph::empty(n, 2);
    for i in 0..na {
        // Symmetric ring over the connected component only; the last
        // nb points are edgeless.
        let j = (i + 1) % na;
        let dij = int_sqdist(data.row(i), data.row(j));
        knn.neighbors[i].push((j as u32, dij));
        knn.neighbors[j].push((i as u32, dij));
    }
    for list in knn.neighbors.iter_mut() {
        list.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
    }
    binary::write_binary(&paths.data, &data).unwrap();
    binary::write_binary(&paths.layout, &layout).unwrap();
    checkpoint::write_knn(&paths.knn, &knn).unwrap();
    std::fs::write(&paths.meta, "prop-fallback").unwrap();

    let cfg = ServeConfig { checkpoints: dir.clone(), search_seeds: 4, ..Default::default() };
    assert_eq!(cfg.search, SearchMode::Graph);
    let st = ServerState::load(cfg).unwrap();
    let snap = st.snapshot();
    let q: Vec<f32> = data.row(na + 3).to_vec(); // stranded-component point
    let got = st.query_knn(&snap, &q, n);
    let mut dists = Vec::new();
    let mut heap = BoundedMaxHeap::new(n);
    let want = nearest_k(&q, &snap.data, n, &mut dists, &mut heap);
    assert_eq!(got, want, "fallback must reproduce the exact oracle");
    assert_eq!(got.len(), n, "no silent truncation");
    {
        let m = st.metrics.lock().unwrap();
        assert_eq!(m.get("serve.search_queries"), Some(1.0));
        assert_eq!(m.get("serve.search_fallbacks"), Some(1.0));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_chunked_cow_shares_untouched_chunks_across_epochs() {
    use largevis::data::chunked::{ChunkedKnn, ChunkedMatrix};
    use largevis::knn::KnnGraph;
    use std::collections::BTreeSet;

    run_prop("chunked-cow", PropConfig { cases: 30, max_size: 60, ..Default::default() }, |rng, size| {
        let chunk_rows = 1 + rng.below(6);
        let n = 4 + size;
        let d = 1 + rng.below(4);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for v in m.row_mut(i) {
                // Include NaN payloads: sharing and old-epoch identity
                // must be bitwise, not semantic.
                *v = if rng.below(16) == 0 { f32::NAN } else { rng.f32() * 8.0 - 4.0 };
            }
        }
        let mut g = KnnGraph::empty(n, 2);
        for i in 0..n {
            g.neighbors[i] = vec![((i as u32 + 1) % n as u32, rng.f32())];
        }

        // "Epoch": clone the writer's stores, then keep mutating the
        // writer — the moral equivalent of `publish` + more inserts.
        let mut wm = ChunkedMatrix::from_matrix(&m, chunk_rows);
        let mut wg = ChunkedKnn::from_graph(&g, chunk_rows);
        let epoch_m = wm.clone();
        let epoch_g = wg.clone();

        let mut touched = BTreeSet::new();
        for _ in 0..1 + rng.below(4) {
            let i = rng.below(n);
            wm.row_mut(i)[rng.below(d)] = 99.0;
            wg.row_mut(i).push((((i + 2) % n) as u32, 0.5));
            touched.insert(i / chunk_rows);
        }
        // Appends touch only the (possibly partial) tail chunk.
        let grows = rng.below(3);
        if grows > 0 && n % chunk_rows != 0 {
            touched.insert(n / chunk_rows);
        }
        for _ in 0..grows {
            wm.push_row(&vec![1.5; d]);
            wg.push_row(vec![(0, 1.0)]);
        }

        // Untouched chunks are pointer-shared with the old epoch;
        // touched ones were copied.
        for ci in 0..epoch_m.n_chunks() {
            let shared = ChunkedMatrix::chunk_shared(&wm, &epoch_m, ci)
                && ChunkedKnn::chunk_shared(&wg, &epoch_g, ci);
            if shared == touched.contains(&ci) {
                return Err(format!(
                    "chunk {ci}: shared={shared}, touched={} (chunk_rows={chunk_rows}, n={n})",
                    touched.contains(&ci)
                ));
            }
        }

        // A reader holding the old epoch sees the original rows bit
        // for bit, no matter what the writer did since.
        for i in 0..n {
            let same = epoch_m
                .row(i)
                .iter()
                .zip(m.row(i))
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if !same || epoch_m.n() != n {
                return Err(format!("old epoch row {i} changed under the reader"));
            }
            if epoch_g.row(i) != g.neighbors[i].as_slice() {
                return Err(format!("old epoch knn row {i} changed under the reader"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_incremental_equals_full_rebuild() {
    use largevis::data::chunked::{ChunkedKnn, ChunkedLabels, ChunkedMatrix};
    use largevis::knn::KnnGraph;

    run_prop("chunked-rebuild", PropConfig { cases: 30, max_size: 80, ..Default::default() }, |rng, size| {
        let chunk_rows = 1 + rng.below(7);
        let n = 1 + size;
        let d = 1 + rng.below(4);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for v in m.row_mut(i) {
                *v = if rng.below(16) == 0 { f32::NAN } else { rng.f32() * 8.0 - 4.0 };
            }
        }
        let mut g = KnnGraph::empty(n, 3);
        for i in 0..n {
            let deg = rng.below(3);
            g.neighbors[i] =
                (0..deg).map(|j| (((i + j + 1) % n) as u32, rng.f32())).collect();
        }
        let labels: Vec<u32> = (0..n).map(|_| rng.below(7) as u32).collect();

        // Grow row by row (the serving insert path)...
        let mut im = ChunkedMatrix::from_matrix(&Matrix::zeros(0, d), chunk_rows);
        let mut ig = ChunkedKnn::from_graph(&KnnGraph::empty(0, 3), chunk_rows);
        let mut il = ChunkedLabels::from_slice(&[], chunk_rows);
        for i in 0..n {
            im.push_row(m.row(i));
            ig.push_row(g.neighbors[i].clone());
            il.push(labels[i]);
        }
        // ...and rebuild from scratch (the restart path).
        let fm = ChunkedMatrix::from_matrix(&m, chunk_rows);
        let fg = ChunkedKnn::from_graph(&g, chunk_rows);
        let fl = ChunkedLabels::from_slice(&labels, chunk_rows);

        if im != fm {
            return Err(format!("matrix: incremental != rebuild (n={n}, cr={chunk_rows})"));
        }
        if ig != fg {
            return Err(format!("knn: incremental != rebuild (n={n}, cr={chunk_rows})"));
        }
        if il != fl {
            return Err(format!("labels: incremental != rebuild (n={n}, cr={chunk_rows})"));
        }
        // Same chunk structure too — replay must reproduce the exact
        // layout, not just the logical contents.
        if im.n_chunks() != fm.n_chunks() || ig.n_chunks() != fg.n_chunks() {
            return Err("chunk layout diverged between incremental and rebuild".into());
        }
        // And the flat round-trip is bit-identical to the source.
        let back = im.to_matrix();
        for i in 0..n {
            if back.row(i).iter().zip(m.row(i)).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("to_matrix row {i} not bit-identical"));
            }
        }
        if ig.to_graph().neighbors != g.neighbors || il.to_vec() != labels {
            return Err("knn/labels round-trip diverged".into());
        }
        Ok(())
    });
}
