//! Integration test for the live layout query server: run a tiny
//! pipeline once, then serve its checkpoint directory on an ephemeral
//! port and exercise every read endpoint — including concurrently —
//! with raw `std::net` HTTP clients. No pipeline stage re-runs at
//! serve time, and `/embed` must leave the base layout bit-identical.
//! (Write-path coverage — `/insert`, WAL recovery, epoch consistency
//! under concurrent mutation — lives in `serve_live.rs`.)

use largevis::config::{PipelineConfig, ServeConfig};
use largevis::coordinator::{run_pipeline, CheckpointPaths};
use largevis::serve::{Server, ServerState};
use largevis::util::json::Json;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

#[path = "util/mod.rs"]
mod util;
use util::{as_f64, read_keepalive_response, request, request_json};

fn test_dir() -> PathBuf {
    std::env::temp_dir().join(format!("largevis_serve_it_{}", std::process::id()))
}

/// One tiny checkpointed pipeline run shared by the whole test.
fn checkpointed_run(out_dir: &Path) -> largevis::coordinator::PipelineOutput {
    let mut cfg = PipelineConfig {
        dataset: "20ng-like".into(),
        scale: 0.02, // ~380 points
        k: 8,
        out_dir: out_dir.to_path_buf(),
        ..Default::default()
    };
    cfg.vis.samples_per_vertex = 300;
    cfg.knn.forest.n_trees = 2;
    run_pipeline(&cfg).expect("pipeline run")
}

#[test]
fn server_end_to_end() {
    let out_dir = test_dir();
    let run = checkpointed_run(&out_dir);
    let n_base = run.layout.n();
    let ckpt = CheckpointPaths::new(&out_dir);

    let cfg = ServeConfig {
        checkpoints: ckpt.dir.clone(),
        addr: "127.0.0.1:0".to_string(), // ephemeral port
        threads: 4,
        embed_samples: 200,
        grid: 32,
        idle_timeout_ms: 2000,
        ..Default::default()
    };
    let state = ServerState::load(cfg).expect("load server state");
    {
        let snap = state.snapshot();
        assert_eq!(snap.data.n(), n_base);
        // Serving answers from checkpoints alone: the layout the server
        // loaded equals the pipeline's final layout bit for bit.
        assert_eq!(snap.layout.to_matrix(), run.layout);
        assert_eq!(snap.epoch, 0, "fresh checkpoint dir starts at epoch 0");
    }

    let server = Server::bind(state).expect("bind");
    let addr = server.local_addr().unwrap();
    let shared = server.state();
    let handle = server.handle();
    let snap0 = shared.snapshot();
    let layout_before = snap0.layout.clone();
    let data_before = snap0.data.clone();
    let server_thread = std::thread::spawn(move || server.run());

    // --- /readyz --- (state came from `load`, so replay is done)
    let (status, ready) = request_json(addr, "GET", "/readyz", None);
    assert_eq!(status, 200);
    assert_eq!(ready.get("ready"), Some(&Json::Bool(true)));

    // --- /healthz ---
    let (status, health) = request_json(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(|j| j.as_str()), Some("ok"));
    assert_eq!(as_f64(health.get("points").unwrap()) as usize, n_base);
    assert_eq!(as_f64(health.get("base_points").unwrap()) as usize, n_base);
    assert_eq!(as_f64(health.get("inserted").unwrap()) as usize, 0);
    assert_eq!(as_f64(health.get("epoch").unwrap()) as u64, 0);
    assert_eq!(as_f64(health.get("layout_dim").unwrap()) as usize, 2);
    assert!(as_f64(health.get("graph_edges").unwrap()) > 0.0);

    // --- /knn: query an exact base row -> itself at distance 0 ---
    let q: Vec<f32> = snap0.data.row(5).to_vec();
    let q_json: Vec<String> = q.iter().map(|v| v.to_string()).collect();
    let body = format!("{{\"point\":[{}],\"k\":4}}", q_json.join(","));
    let (status, knn) = request_json(addr, "POST", "/knn", Some(&body));
    assert_eq!(status, 200);
    let ids = match knn.get("ids") {
        Some(Json::Arr(a)) => a.iter().map(as_f64).collect::<Vec<_>>(),
        other => panic!("ids: {other:?}"),
    };
    let dists = match knn.get("dists") {
        Some(Json::Arr(a)) => a.iter().map(as_f64).collect::<Vec<_>>(),
        other => panic!("dists: {other:?}"),
    };
    assert_eq!(ids.len(), 4);
    assert_eq!(ids[0] as usize, 5, "nearest neighbor of a base row is itself");
    assert_eq!(dists[0], 0.0);
    assert!(dists.windows(2).all(|w| w[0] <= w[1]), "dists sorted: {dists:?}");
    // Epoch consistency fields present on every layout response.
    assert_eq!(as_f64(knn.get("epoch").unwrap()) as u64, 0);
    assert_eq!(as_f64(knn.get("points").unwrap()) as usize, n_base);

    // --- /viewport: full bounds vs a narrow tile ---
    let (bx0, by0, bx1, by1) = snap0.grid.bounds();
    let (status, svg) = request(
        addr,
        "GET",
        &format!("/viewport?x0={bx0}&y0={by0}&x1={bx1}&y1={by1}"),
        None,
    );
    assert_eq!(status, 200);
    let svg = String::from_utf8(svg).unwrap();
    assert!(svg.starts_with("<svg"), "viewport returns SVG");
    assert!(svg.contains("epoch=0"), "viewport carries the epoch comment");
    let full_circles = svg.matches("<circle").count();
    assert_eq!(full_circles, n_base, "full-bounds tile draws every point");
    // A narrow central tile: the spatial index must cull — the cells
    // it examines cannot cover the whole layout (the extremal points
    // defining the bounds live in cells the tile never touches).
    let (_, before) = request_json(addr, "GET", "/metrics", None);
    let examined_before = as_f64(before.get("viewport.examined").unwrap());
    let (cx, cy) = ((bx0 + bx1) / 2.0, (by0 + by1) / 2.0);
    let (w, h) = ((bx1 - bx0) / 10.0, (by1 - by0) / 10.0);
    let (status, tile) = request(
        addr,
        "GET",
        &format!("/viewport?x0={cx}&y0={cy}&x1={}&y1={}", cx + w, cy + h),
        None,
    );
    assert_eq!(status, 200);
    let tile = String::from_utf8(tile).unwrap();
    let tile_circles = tile.matches("<circle").count();
    assert!(tile_circles < n_base, "narrow tile rendered all {n_base} points");
    let (_, after) = request_json(addr, "GET", "/metrics", None);
    let examined = as_f64(after.get("viewport.examined").unwrap()) - examined_before;
    assert!(
        (examined as usize) < n_base,
        "narrow tile examined {examined} candidates — no spatial culling"
    );

    // --- /embed: project perturbed copies of base rows ---
    let mut rows = Vec::new();
    for i in 0..6 {
        let row: Vec<String> = snap0
            .data
            .row(i * 3)
            .iter()
            .map(|v| (v + 0.001).to_string())
            .collect();
        rows.push(format!("[{}]", row.join(",")));
    }
    let body = format!("{{\"points\":[{}],\"samples\":150}}", rows.join(","));
    let (status, emb) = request_json(addr, "POST", "/embed", Some(&body));
    assert_eq!(status, 200);
    assert_eq!(as_f64(emb.get("n").unwrap()) as usize, 6);
    assert_eq!(as_f64(emb.get("dim").unwrap()) as usize, 2);
    let positions = match emb.get("positions") {
        Some(Json::Arr(a)) => a,
        other => panic!("positions: {other:?}"),
    };
    assert_eq!(positions.len(), 6);
    for (i, p) in positions.iter().enumerate() {
        let Json::Arr(xy) = p else { panic!("positions[{i}] not an array") };
        assert_eq!(xy.len(), 2);
        for v in xy {
            assert!(as_f64(v).is_finite(), "positions[{i}] non-finite");
        }
    }
    // A perturbed copy of base row i*3 should list that row among its
    // base neighbors.
    let neighbors = match emb.get("neighbors") {
        Some(Json::Arr(a)) => a,
        other => panic!("neighbors: {other:?}"),
    };
    let Json::Arr(first) = &neighbors[0] else { panic!("neighbors[0]") };
    assert!(
        first.iter().map(as_f64).any(|id| id as usize == 0),
        "row 0's perturbed copy should neighbor row 0"
    );

    // The base is bit-identical after embedding (no epoch published).
    let snap_now = shared.snapshot();
    assert_eq!(snap_now.epoch, 0, "/embed must not publish an epoch");
    assert_eq!(snap_now.layout, layout_before, "/embed moved the base layout");
    assert_eq!(snap_now.data, data_before, "/embed grew the base dataset");

    // --- keep-alive: several requests on one connection ---
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        for round in 0..3 {
            writer
                .write_all(b"GET /healthz HTTP/1.1\r\nHost: test\r\n\r\n")
                .unwrap();
            let (status, connection, body) = read_keepalive_response(&mut reader);
            assert_eq!(status, 200, "keep-alive round {round}");
            assert_eq!(connection, "keep-alive", "round {round} closed early");
            Json::parse(&body).expect("healthz json");
        }
        // Client-requested close is honored.
        writer
            .write_all(b"GET /healthz HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n")
            .unwrap();
        let (status, connection, _) = read_keepalive_response(&mut reader);
        assert_eq!(status, 200);
        assert_eq!(connection, "close");
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server kept the connection open after close");
    }

    // --- error paths ---
    let (status, _) = request(addr, "POST", "/embed", Some("not json"));
    assert_eq!(status, 400);
    let (status, _) = request(addr, "POST", "/knn", Some("{\"point\":[1,2]}"));
    assert_eq!(status, 400, "dimension mismatch rejected");
    let (status, _) = request(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _) = request(addr, "GET", "/embed", None);
    assert_eq!(status, 405);
    let (status, _) = request(addr, "GET", "/viewport?x0=9&x1=1", None);
    assert_eq!(status, 400, "inverted viewport rejected");
    // Oversized Content-Length is refused up front with 413, before
    // any body bytes are read.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.write_all(b"POST /embed HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n").unwrap();
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).unwrap();
        let head = String::from_utf8_lossy(&raw);
        assert!(head.starts_with("HTTP/1.1 413 "), "{head}");
    }

    // --- concurrent clients over every endpoint ---
    let rounds = 5;
    let clients = 8;
    let knn_body = format!("{{\"point\":[{}],\"k\":3}}", q_json.join(","));
    let embed_body = format!("{{\"points\":[{}],\"samples\":50}}", rows[0]);
    std::thread::scope(|s| {
        for c in 0..clients {
            let knn_body = &knn_body;
            let embed_body = &embed_body;
            s.spawn(move || {
                for _ in 0..rounds {
                    match c % 4 {
                        0 => {
                            let (st, j) = request_json(addr, "POST", "/knn", Some(knn_body));
                            assert_eq!(st, 200);
                            assert!(matches!(j.get("ids"), Some(Json::Arr(_))));
                        }
                        1 => {
                            let (st, j) = request_json(addr, "POST", "/embed", Some(embed_body));
                            assert_eq!(st, 200);
                            assert_eq!(as_f64(j.get("n").unwrap()) as usize, 1);
                        }
                        2 => {
                            let (st, b) = request(addr, "GET", "/viewport", None);
                            assert_eq!(st, 200);
                            assert!(b.starts_with(b"<svg"));
                        }
                        _ => {
                            let (st, j) = request_json(addr, "GET", "/healthz", None);
                            assert_eq!(st, 200);
                            assert_eq!(j.get("status").and_then(|x| x.as_str()), Some("ok"));
                        }
                    }
                }
            });
        }
    });
    // Still bit-identical after concurrent embeds.
    assert_eq!(shared.snapshot().layout, layout_before);

    // --- /metrics reflects the traffic ---
    let (status, metrics) = request_json(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(as_f64(metrics.get("serve.requests").unwrap()) >= (rounds * clients) as f64);
    assert!(as_f64(metrics.get("embed.requests").unwrap()) >= 1.0 + rounds as f64);
    assert!(as_f64(metrics.get("knn.requests").unwrap()) >= 1.0 + rounds as f64);
    assert!(as_f64(metrics.get("viewport.requests").unwrap()) >= 2.0 + rounds as f64);
    assert!(as_f64(metrics.get("serve.errors").unwrap()) >= 5.0);
    assert_eq!(as_f64(metrics.get("serve.points").unwrap()) as usize, n_base);

    // --- clean shutdown ---
    handle.shutdown();
    server_thread.join().expect("server thread").expect("server run");
}
