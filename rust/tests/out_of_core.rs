//! Acceptance test for the out-of-core ingestion + checkpoint pipeline:
//! a 100k-point binary dataset round-trips disk → chunked reader → KNN
//! checkpoint → resumed layout. The resumed weighted graph must be
//! bit-identical to the in-memory run's, and peak parse memory is
//! asserted to stay bounded by the chunk size.

use largevis::config::{PipelineConfig, Stage};
use largevis::coordinator::{run_pipeline, CheckpointPaths};
use largevis::data::formats::binary::{ChunkedMatrixReader, MatrixWriter};
use largevis::data::formats::checkpoint::read_csr;
use largevis::data::synth::gaussian_mixture;

const N: usize = 100_000;
const D: usize = 8;
const CHUNK_ROWS: usize = 4_096;

fn test_root() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("largevis_ooc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn hundred_k_points_roundtrip_and_resume() {
    let root = test_root();
    let input = root.join("points100k.lvec");

    // 1. Generate 100k points and stream them to disk row-by-row (the
    //    writer never sees the whole matrix as one buffer).
    let (m, _) = gaussian_mixture(N, D, 10, 0.4, 0x100c);
    let mut w = MatrixWriter::create(&input, D).unwrap();
    for i in 0..N {
        w.write_row(m.row(i)).unwrap();
    }
    assert_eq!(w.finish().unwrap(), N);

    // 2. Chunked read back: parse buffers stay bounded by the chunk
    //    size at every step, and the reassembled data is bit-identical.
    let mut r = ChunkedMatrixReader::open(&input, CHUNK_ROWS).unwrap();
    assert_eq!((r.n(), r.d()), (N, D));
    let bound = CHUNK_ROWS * D * 8; // 4B raw + 4B decoded per value
    let mut reassembled: Vec<f32> = Vec::with_capacity(N * D);
    while let Some(chunk) = r.next_chunk().unwrap() {
        reassembled.extend_from_slice(chunk);
        assert!(
            r.parse_buffer_bytes() <= bound,
            "parse buffers {} exceed chunk bound {}",
            r.parse_buffer_bytes(),
            bound
        );
    }
    assert_eq!(reassembled.len(), N * D);
    for (a, b) in m.as_slice().iter().zip(&reassembled) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    drop(reassembled);

    // 3. Full pipeline over the on-disk file (ingestion goes through
    //    the same chunked reader), writing stage checkpoints.
    let out_dir = root.join("run");
    let mut cfg = PipelineConfig {
        k: 4,
        out_dir: out_dir.clone(),
        input: Some(input),
        chunk_rows: CHUNK_ROWS,
        ..Default::default()
    };
    cfg.knn.forest.n_trees = 1;
    cfg.knn.forest.search_leaves = 1;
    cfg.knn.iters = 0;
    cfg.vis.samples_per_vertex = 10;
    cfg.vis.threads = 1; // deterministic layout for the resume check
    let full = run_pipeline(&cfg).unwrap();
    assert_eq!(full.layout.n(), N);
    assert!(full.layout.as_slice().iter().all(|v| v.is_finite()));

    let ckpt = CheckpointPaths::new(&out_dir);
    assert!(ckpt.knn.exists() && ckpt.graph.exists());
    let graph_full = read_csr(&ckpt.graph).unwrap();

    // 4. Resume from the weights stage: the KNN stage is NOT recomputed
    //    (the dataset file is not even read); weights + layout re-run
    //    from the checkpoint.
    let mut resumed_cfg = cfg.clone();
    resumed_cfg.resume_from = Some(Stage::Weights);
    let resumed = run_pipeline(&resumed_cfg).unwrap();

    // The resumed graph (re-symmetrized from the checkpointed KNN) must
    // be bit-identical to the in-memory run's graph.
    let graph_resumed = read_csr(&ckpt.graph).unwrap();
    assert_eq!(graph_full.offsets(), graph_resumed.offsets());
    assert_eq!(graph_full.cols(), graph_resumed.cols());
    let bits = |g: &largevis::graph::CsrGraph| -> Vec<u64> {
        g.weights().iter().map(|w| w.to_bits()).collect()
    };
    assert_eq!(bits(&graph_full), bits(&graph_resumed), "resumed graph weights differ");

    // And with a single-threaded layout engine the resumed layout is
    // bit-identical too.
    assert_eq!(full.layout, resumed.layout, "resumed layout must be bit-identical");
}
