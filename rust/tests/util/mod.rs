//! Minimal blocking HTTP client helpers shared (via `#[path]`
//! inclusion) by the serve integration tests and the serve bench — one
//! copy of the request framing, so a protocol tweak lands everywhere.
#![allow(dead_code)] // each includer uses a subset

use largevis::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// One request on its own connection (explicit `Connection: close`);
/// returns `(status, body)`.
pub fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let header_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header terminator");
    let head = std::str::from_utf8(&raw[..header_end]).unwrap();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    (status, raw[header_end + 4..].to_vec())
}

/// One request on its own connection, returning the response headers
/// too: `(status, lowercase header (name, value) pairs, body)`. The
/// overload tests assert on `Retry-After` with this.
pub fn request_full(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let header_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header terminator");
    let head = std::str::from_utf8(&raw[..header_end]).unwrap();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let headers: Vec<(String, String)> = head
        .lines()
        .skip(1)
        .filter_map(|line| {
            line.split_once(':')
                .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        })
        .collect();
    (status, headers, raw[header_end + 4..].to_vec())
}

/// Value of a (lowercase) header from a [`request_full`] response.
pub fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

/// [`request`] with the body parsed as JSON.
pub fn request_json(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let (status, body) = request(addr, method, path, body);
    let text = String::from_utf8(body).expect("utf8 body");
    (status, Json::parse(&text).expect("json body"))
}

/// Extract a JSON number or panic with context.
pub fn as_f64(j: &Json) -> f64 {
    match j {
        Json::Num(n) => *n,
        other => panic!("expected number, got {other:?}"),
    }
}

/// Format a float slice as a JSON array literal.
pub fn json_row(vals: &[f32]) -> String {
    let parts: Vec<String> = vals.iter().map(|v| v.to_string()).collect();
    format!("[{}]", parts.join(","))
}

/// Read one keep-alive response off a persistent connection: headers
/// until the blank line, then exactly `Content-Length` body bytes.
/// Returns `(status, connection_header, body)`.
pub fn read_keepalive_response(r: &mut BufReader<TcpStream>) -> (u16, String, String) {
    let mut status_line = String::new();
    r.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let mut content_length = 0usize;
    let mut connection = String::new();
    loop {
        let mut line = String::new();
        r.read_line(&mut line).expect("header line");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v.trim().parse().expect("content length");
        }
        if let Some(v) = lower.strip_prefix("connection:") {
            connection = v.trim().to_string();
        }
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).expect("body");
    (status, connection, String::from_utf8(body).expect("utf8 body"))
}

/// A persistent keep-alive connection issuing many requests.
pub struct KeepAlive {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl KeepAlive {
    /// Open a persistent connection to `addr`.
    pub fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let writer = stream.try_clone().expect("clone");
        KeepAlive { writer, reader: BufReader::new(stream) }
    }

    /// Issue one request on the persistent connection; returns the
    /// status code (response body is drained by Content-Length).
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> u16 {
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.writer.write_all(req.as_bytes()).expect("send");
        read_keepalive_response(&mut self.reader).0
    }
}
