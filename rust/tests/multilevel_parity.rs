//! Acceptance test for the multilevel coarse-to-fine layout engine
//! (ISSUE 3 tentpole): on the 100k out-of-core-scale acceptance
//! dataset, multilevel with **half** the fine-level gradient samples
//! must reach KNN-preservation at least equal to flat SGD — the coarse
//! levels resolve the global structure that flat SGD burns most of its
//! budget untangling.

use largevis::data::synth::gaussian_mixture;
use largevis::eval::metrics::neighborhood_preservation;
use largevis::graph::weights::weighted_graph;
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::knn::rptree::RpForestConfig;
use largevis::vis::multilevel::{optimize_multilevel, MultilevelConfig};
use largevis::vis::{init_layout, sgd, LargeVisConfig};

const N: usize = 100_000;
const D: usize = 8;
const FLAT_SPV: usize = 40;

#[test]
fn multilevel_matches_flat_with_half_the_fine_samples() {
    let (points, _) = gaussian_mixture(N, D, 10, 0.4, 0x100c);
    let knn_cfg = LargeVisKnnConfig {
        forest: RpForestConfig { n_trees: 1, search_leaves: 1, ..Default::default() },
        iters: 0,
        ..Default::default()
    };
    let knn = largevis_knn(&points, 6, &knn_cfg);
    let graph = weighted_graph(&knn, &Default::default());

    // Single-threaded SGD keeps both layouts bit-deterministic, so this
    // comparison can never flake on Hogwild race noise.
    let flat_cfg = LargeVisConfig {
        samples_per_vertex: FLAT_SPV,
        threads: 1,
        seed: 0x5eed,
        ..Default::default()
    };
    let mut flat = init_layout(graph.n(), 2, flat_cfg.seed);
    let flat_report = sgd::optimize(&graph, &mut flat, &flat_cfg);

    // Half the fine-level budget; default coarse schedule.
    let ml_cfg = LargeVisConfig { samples_per_vertex: FLAT_SPV / 2, ..flat_cfg.clone() };
    let ml = MultilevelConfig::default();
    let mut mlvl = init_layout(graph.n(), 2, ml_cfg.seed);
    let report = optimize_multilevel(&graph, &mut mlvl, &ml_cfg, &ml, |_, _, _| Ok(())).unwrap();
    assert!(report.levels.len() > 2, "expected a real hierarchy on 100k points");
    assert!(
        report.fine().samples * 2 <= flat_report.samples,
        "fine budget not halved: {} vs {}",
        report.fine().samples,
        flat_report.samples
    );

    let flat_score = neighborhood_preservation(&points, &flat, 10, 300, 0xe5a1, 4);
    let ml_score = neighborhood_preservation(&points, &mlvl, 10, 300, 0xe5a1, 4);
    eprintln!(
        "[multilevel_parity] knn-preservation: flat({} spv) = {flat_score:.4}, \
         multilevel({} fine spv, {} levels) = {ml_score:.4}",
        FLAT_SPV,
        FLAT_SPV / 2,
        report.levels.len()
    );
    assert!(
        ml_score >= flat_score,
        "multilevel ({ml_score:.4}) must reach flat ({flat_score:.4}) with half the fine samples"
    );
}
