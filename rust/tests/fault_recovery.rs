//! Crash-recovery torture harness: enumerate every injectable fault
//! point of the durability stack and prove the invariant that matters —
//! **after a crash at any byte, a restart recovers exactly the
//! acknowledged prefix**, bit-identically, under the fail-fast policy.
//!
//! The harness leans on [`FaultStorage`]'s determinism: the workload's
//! write/fsync schedule is identical up to the first injected fault, so
//! one probe run yields the operation count `M`, and sweeping
//! `trigger_op` over `0..M` for each [`FaultKind`] visits every fault
//! point exactly once. Each iteration runs the scripted workload in a
//! fresh directory, records which appends/inserts were acknowledged
//! (`Ok` returns), simulates the crash by dropping everything, reopens
//! on the *real* filesystem, and asserts the recovered state equals the
//! acked prefix.
//!
//! Four layers are tortured:
//! 1. the WAL set itself (append + rotate),
//! 2. the full server insert path (WAL + rotation + compaction +
//!    checkpoint rewrite),
//! 3. bounded replay (compaction keeps restart work proportional to the
//!    segment budget, not insert history),
//! 4. the explicit recovery-policy switch (fail-fast vs
//!    salvage-and-quarantine).
//!
//! `LARGEVIS_FAULT_SEED` varies the torn/short-write split points (CI
//! sweeps several seeds); a per-kind coverage summary is written to
//! `$LARGEVIS_FAULT_COVERAGE_DIR` (default `target/`) for the CI
//! artifact upload.

use largevis::config::ServeConfig;
use largevis::coordinator::pipeline::CheckpointPaths;
use largevis::data::formats::wal::{self, RecoveryPolicy, WalSet};
use largevis::data::formats::{binary, checkpoint};
use largevis::data::matrix::Matrix;
use largevis::knn::KnnGraph;
use largevis::serve::ServerState;
use largevis::util::faultio::{FaultKind, FaultPlan, FaultStorage, RealStorage, Storage};
use largevis::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const KINDS: &[(FaultKind, &str)] = &[
    (FaultKind::ShortWrite, "short_write"),
    (FaultKind::Enospc, "enospc"),
    (FaultKind::FsyncFail, "fsync_fail"),
    (FaultKind::TornWrite, "torn_write"),
];

/// Base RNG seed for fault split points; CI sweeps several values.
fn fault_seed() -> u64 {
    std::env::var("LARGEVIS_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

/// Per-trigger seed: decorrelate the torn/short split point from the
/// trigger index so one sweep exercises many prefix lengths.
fn trigger_seed(base: u64, trigger: u64) -> u64 {
    (base ^ trigger).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("largevis_fault_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: value {i} differs ({x} vs {y})");
    }
}

/// Write the per-kind coverage summary consumed by the CI artifact.
fn write_coverage(file: &str, stats: &[(&str, u64, u64, u64)]) {
    let dir = std::env::var("LARGEVIS_FAULT_COVERAGE_DIR").unwrap_or_else(|_| "target".into());
    if std::fs::create_dir_all(&dir).is_err() {
        return;
    }
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"seed\": {},\n", fault_seed()));
    for (i, (name, runs, fired, recovered)) in stats.iter().enumerate() {
        out.push_str(&format!(
            "  \"{name}\": {{\"runs\": {runs}, \"fired\": {fired}, \"recovered\": {recovered}}}{}\n",
            if i + 1 < stats.len() { "," } else { "" }
        ));
    }
    out.push('}');
    out.push('\n');
    let _ = std::fs::write(Path::new(&dir).join(file), out);
}

// ---------------------------------------------------------------------
// Part 1: WAL-set torture — append + rotate under every fault point.
// ---------------------------------------------------------------------

const WAL_D: usize = 3;

/// Deterministic batch with awkward bit patterns (−0.0, subnormals).
fn wal_batch(i: u32) -> Matrix {
    let b = i as f32;
    let vals = vec![b, -b * 0.5, b * 0.25 + 0.125, b + 0.5, f32::MIN_POSITIVE * (b + 1.0), -0.0];
    Matrix::from_vec(vals, 2, WAL_D)
}

/// The scripted WAL workload: 8 appends with rotations after batches 2
/// and 5. Errors are recorded (not acked) and the workload continues —
/// transient faults must leave the log appendable. Returns the batches
/// that were acknowledged (`append` returned `Ok`).
fn run_wal_workload(storage: Arc<dyn Storage>, active: &Path) -> Vec<Matrix> {
    let mut acked = Vec::new();
    let Ok((mut set, _)) = WalSet::open(storage, active, WAL_D, RecoveryPolicy::FailFast) else {
        return acked;
    };
    for i in 0..8u32 {
        let b = wal_batch(i);
        if set.append(&b).is_ok() {
            acked.push(b);
        }
        if i == 2 || i == 5 {
            let _ = set.rotate();
        }
    }
    acked
}

#[test]
fn wal_set_recovers_acked_prefix_under_every_fault() {
    // Probe once to learn the clean workload's operation schedule.
    let probe = FaultStorage::probe();
    let dir = fresh_dir("wal_probe");
    let acked = run_wal_workload(Arc::new(probe.clone()), &dir.join("inserts.wal"));
    assert_eq!(acked.len(), 8, "probe run must ack everything");
    let ops = probe.ops();
    assert!(ops >= 20, "workload too small to be interesting ({ops} ops)");
    std::fs::remove_dir_all(&dir).ok();

    let seed = fault_seed();
    let mut stats: Vec<(&str, u64, u64, u64)> = Vec::new();
    for &(kind, name) in KINDS {
        let (mut runs, mut fired, mut recovered) = (0u64, 0u64, 0u64);
        for trigger in 0..ops {
            runs += 1;
            let dir = fresh_dir("wal");
            let active = dir.join("inserts.wal");
            let plan =
                FaultPlan { kind, trigger_op: trigger, seed: trigger_seed(seed, trigger) };
            let storage = FaultStorage::new(plan);
            let acked = run_wal_workload(Arc::new(storage.clone()), &active);
            fired += storage.fired() as u64;

            // "Restart": replay on the real filesystem, fail-fast. Any
            // residue a fault left behind (torn tail, partial header,
            // empty rotated segment) must read as normal crash state,
            // never as corruption.
            let rec = wal::read_wal_set(&active, WAL_D, RecoveryPolicy::FailFast)
                .unwrap_or_else(|e| {
                    panic!("{name} at op {trigger}: fail-fast replay refused: {e:#}")
                });
            assert_eq!(
                rec.batches.len(),
                acked.len(),
                "{name} at op {trigger}: recovered {} batches, acked {}",
                rec.batches.len(),
                acked.len()
            );
            assert_eq!(rec.next_seq, acked.len() as u64, "{name} at op {trigger}: seq drift");
            for (k, (a, b)) in acked.iter().zip(&rec.batches).enumerate() {
                assert_bits_eq(
                    a.as_slice(),
                    b.as_slice(),
                    &format!("{name} at op {trigger}, batch {k}"),
                );
            }

            // The recovered set must also be appendable again.
            let (mut set2, rec2) = WalSet::open(
                Arc::new(RealStorage),
                &active,
                WAL_D,
                RecoveryPolicy::FailFast,
            )
            .unwrap_or_else(|e| panic!("{name} at op {trigger}: reopen failed: {e:#}"));
            assert_eq!(rec2.batches.len(), acked.len());
            let seq = set2.append(&wal_batch(99)).unwrap();
            assert_eq!(seq, acked.len() as u64, "{name} at op {trigger}: post-recovery seq");
            recovered += 1;
            std::fs::remove_dir_all(&dir).ok();
        }
        stats.push((name, runs, fired, recovered));
    }
    write_coverage("fault_coverage_wal.json", &stats);
}

// ---------------------------------------------------------------------
// Part 2: server-level torture — the full insert path (WAL append,
// rotation, compaction into the checkpoints) under every fault point.
// ---------------------------------------------------------------------

const SRV_N: usize = 16;
const SRV_D: usize = 4;

/// Minimal valid checkpoint directory: `n` points, ring KNN, no labels.
fn fabricate_checkpoints(dir: &Path) -> Vec<f32> {
    let paths = CheckpointPaths::in_dir(dir);
    let data: Vec<f32> = (0..SRV_N * SRV_D).map(|i| (i as f32) * 0.375 - 7.0).collect();
    let layout: Vec<f32> = (0..SRV_N * 2).map(|i| (i as f32) * 0.5).collect();
    binary::write_binary(&paths.data, &Matrix::from_vec(data.clone(), SRV_N, SRV_D)).unwrap();
    binary::write_binary(&paths.layout, &Matrix::from_vec(layout, SRV_N, 2)).unwrap();
    let mut knn = KnnGraph::empty(SRV_N, 1);
    for (i, nb) in knn.neighbors.iter_mut().enumerate() {
        *nb = vec![(((i + 1) % SRV_N) as u32, 1.0)];
    }
    checkpoint::write_knn(&paths.knn, &knn).unwrap();
    std::fs::write(&paths.meta, "fault-torture").unwrap();
    data
}

/// Tiny segments and an aggressive compaction threshold so the 5-insert
/// workload crosses every WAL-maintenance code path.
fn server_cfg(dir: &Path) -> ServeConfig {
    ServeConfig {
        checkpoints: dir.to_path_buf(),
        insert_samples: 8,
        refine_samples: 0,
        wal_segment_bytes: 64,
        wal_max_segments: 2,
        ..Default::default()
    }
}

/// Deterministic 2-row insert batch.
fn insert_batch(i: u32) -> Matrix {
    let b = i as f32 + 100.0;
    let vals: Vec<f32> = (0..2 * SRV_D).map(|j| b + j as f32 * 0.25).collect();
    Matrix::from_vec(vals, 2, SRV_D)
}

/// Scripted server workload: load under the given storage, insert 5
/// batches, record the acked ones. Errors anywhere are the point.
fn run_server_workload(dir: &Path, storage: Arc<dyn Storage>) -> Vec<Matrix> {
    let mut acked = Vec::new();
    let Ok(st) = ServerState::load_with(server_cfg(dir), storage) else {
        return acked;
    };
    for i in 0..5u32 {
        let b = insert_batch(i);
        if st.insert(&b).is_ok() {
            acked.push(b);
        }
    }
    acked
}

#[test]
fn server_recovers_acked_inserts_under_every_fault() {
    // Probe the clean workload for its operation count.
    let dir = fresh_dir("srv_probe");
    fabricate_checkpoints(&dir);
    let probe = FaultStorage::probe();
    let acked = run_server_workload(&dir, Arc::new(probe.clone()));
    assert_eq!(acked.len(), 5, "probe run must ack everything");
    let ops = probe.ops();
    assert!(ops >= 20, "server workload too small to be interesting ({ops} ops)");
    std::fs::remove_dir_all(&dir).ok();

    let seed = fault_seed();
    let mut stats: Vec<(&str, u64, u64, u64)> = Vec::new();
    for &(kind, name) in KINDS {
        let (mut runs, mut fired, mut recovered) = (0u64, 0u64, 0u64);
        for trigger in 0..ops {
            runs += 1;
            let dir = fresh_dir("srv");
            let base = fabricate_checkpoints(&dir);
            let plan =
                FaultPlan { kind, trigger_op: trigger, seed: trigger_seed(seed, trigger) };
            let storage = FaultStorage::new(plan);
            let acked = run_server_workload(&dir, Arc::new(storage.clone()));
            fired += storage.fired() as u64;

            // "Restart" on the real filesystem, fail-fast: whatever the
            // fault interrupted (an append, a rotation, either side of
            // a compaction commit) must recover to base + acked rows.
            let st = ServerState::load(server_cfg(&dir)).unwrap_or_else(|e| {
                panic!("{name} at op {trigger}: restart refused: {e:#}")
            });
            let snap = st.snapshot();
            let acked_rows: usize = acked.iter().map(|b| b.n()).sum();
            assert_eq!(
                snap.data.n(),
                SRV_N + acked_rows,
                "{name} at op {trigger}: wrong recovered row count ({} acked batches)",
                acked.len()
            );
            assert_eq!(snap.layout.n(), snap.data.n(), "{name} at op {trigger}: layout shape");
            assert_eq!(snap.knn.n(), snap.data.n(), "{name} at op {trigger}: knn shape");
            // Base rows survive compaction rewrites bit-identically.
            let base_rows: Vec<f32> = snap.data.values().take(SRV_N * SRV_D).collect();
            assert_bits_eq(
                &base_rows,
                &base,
                &format!("{name} at op {trigger}: base data"),
            );
            // Acked rows are recovered bit-identically, in ack order.
            let mut row = SRV_N;
            for (k, b) in acked.iter().enumerate() {
                for r in 0..b.n() {
                    assert_bits_eq(
                        snap.data.row(row),
                        b.row(r),
                        &format!("{name} at op {trigger}: acked batch {k} row {r}"),
                    );
                    row += 1;
                }
            }
            // And the recovered server accepts new inserts.
            let (ids, _) = st.insert(&insert_batch(77)).unwrap_or_else(|e| {
                panic!("{name} at op {trigger}: post-recovery insert refused: {e:#}")
            });
            assert_eq!(ids[0], SRV_N + acked_rows);
            recovered += 1;
            std::fs::remove_dir_all(&dir).ok();
        }
        stats.push((name, runs, fired, recovered));
    }
    write_coverage("fault_coverage_server.json", &stats);
}

// ---------------------------------------------------------------------
// Part 3: bounded replay — compaction keeps the WAL (and therefore
// restart work) proportional to the segment budget, not insert history.
// ---------------------------------------------------------------------

#[test]
fn compaction_bounds_replay() {
    let dir = fresh_dir("bounded");
    fabricate_checkpoints(&dir);
    let st = ServerState::load(server_cfg(&dir)).unwrap();
    let total_batches = 12u32;
    for i in 0..total_batches {
        st.insert(&insert_batch(i)).unwrap();
    }
    let metrics = Json::parse(&st.metrics_json()).unwrap();
    let compactions = metrics.get("serve.compactions").and_then(Json::as_usize).unwrap();
    assert!(compactions >= 1, "tiny segments + 12 inserts must compact at least once");
    drop(st);

    // What a restart must replay is far less than what was inserted.
    let paths = CheckpointPaths::in_dir(&dir);
    let rec = wal::read_wal_set(&paths.wal, SRV_D, RecoveryPolicy::FailFast).unwrap();
    assert!(
        rec.batches.len() < total_batches as usize,
        "WAL still holds all {} batches — compaction never absorbed anything",
        rec.batches.len()
    );

    // And the restart still recovers every row.
    let st2 = ServerState::load(server_cfg(&dir)).unwrap();
    let snap = st2.snapshot();
    assert_eq!(snap.data.n(), SRV_N + 2 * total_batches as usize);
    let metrics = Json::parse(&st2.metrics_json()).unwrap();
    let replayed = metrics.get("serve.replayed_batches").and_then(Json::as_usize).unwrap();
    assert!(
        replayed < total_batches as usize,
        "restart replayed {replayed} batches — replay is unbounded"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Part 4: the recovery policy is an explicit switch — fail-fast refuses
// to start on corruption; truncate salvages, quarantines, and counts.
// ---------------------------------------------------------------------

#[test]
fn recovery_policy_failfast_vs_truncate() {
    let dir = fresh_dir("policy");
    fabricate_checkpoints(&dir);
    // High compaction threshold: rotations happen (tiny segments) but
    // sealed segments accumulate instead of being absorbed.
    let mut cfg = server_cfg(&dir);
    cfg.wal_max_segments = 100;
    {
        let st = ServerState::load(cfg.clone()).unwrap();
        for i in 0..3u32 {
            st.insert(&insert_batch(i)).unwrap();
        }
    }
    let paths = CheckpointPaths::in_dir(&dir);
    // Each insert rotated right after its append, so sealed segment 1
    // holds exactly batch 1: flip one payload byte mid-record.
    let seg1 = wal::segment_path(&paths.wal, 1);
    let mut bytes = std::fs::read(&seg1).unwrap();
    let off = wal::header_bytes(wal::VERSION) as usize + 8 + 4;
    bytes[off] ^= 0x40;
    std::fs::write(&seg1, &bytes).unwrap();

    // Fail-fast (the default): refuse to serve rather than silently
    // dropping acknowledged data.
    let err = format!("{:#}", ServerState::load(cfg.clone()).unwrap_err());
    assert!(err.contains("does not end cleanly"), "{err}");

    // Truncate: salvage the clean prefix (batch 0), quarantine the
    // rest, count the damage, and keep serving.
    cfg.recovery_policy = RecoveryPolicy::Truncate;
    let st = ServerState::load(cfg).unwrap();
    let snap = st.snapshot();
    assert_eq!(snap.data.n(), SRV_N + 2, "only the pre-corruption batch survives");
    assert!(!seg1.exists(), "corrupt segment must be quarantined, not left in place");
    let metrics = Json::parse(&st.metrics_json()).unwrap();
    let corrupt = metrics.get("serve.wal_corrupt_segments").and_then(Json::as_usize).unwrap();
    assert!(corrupt >= 1, "quarantined segments must be counted");
    // The salvaged server keeps accepting inserts.
    st.insert(&insert_batch(9)).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
