//! `largevis` — CLI entrypoint for the LargeVis reproduction.

use anyhow::{bail, Result};
use largevis::cli::{self, Args};
use largevis::config::{Ini, PipelineConfig, ServeConfig};
use largevis::coordinator::run_pipeline;
use largevis::data::datasets;
use largevis::knn::explore::LargeVisKnnConfig;
use largevis::knn::rptree::RpForestConfig;
use largevis::serve::{Server, ServerState};
use largevis::vis::ProbFn;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = cli::parse(argv)?;
    match args.command.as_str() {
        "" | "help" => {
            println!("{}", cli::USAGE);
            Ok(())
        }
        "datasets" => cmd_datasets(&args),
        "info" => cmd_info(),
        "knn" => cmd_knn(&args),
        "pipeline" => cmd_pipeline(&args),
        "serve" => cmd_serve(&args),
        "convert" => cmd_convert(&args),
        other => bail!("unknown command {other:?}\n\n{}", cli::USAGE),
    }
}

/// Assemble a PipelineConfig from `--config` INI plus CLI overrides.
fn build_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = match args.get_str("config") {
        Some(path) => PipelineConfig::from_ini(&Ini::load(std::path::Path::new(path))?)?,
        None => PipelineConfig::default(),
    };
    if let Some(ds) = args.get_str("dataset") {
        cfg.dataset = ds.to_string();
    }
    cfg.scale = args.get_or("scale", if args.get_str("config").is_some() { cfg.scale } else { 0.1 })?;
    cfg.k = args.get_or("k", cfg.k)?;
    cfg.knn.forest.n_trees = args.get_or("trees", cfg.knn.forest.n_trees)?;
    cfg.knn.iters = args.get_or("explore-iters", cfg.knn.iters)?;
    cfg.weights.perplexity = args.get_or("perplexity", cfg.weights.perplexity)?;
    cfg.vis.dim = args.get_or("dim", cfg.vis.dim)?;
    cfg.vis.samples_per_vertex = args.get_or("samples", cfg.vis.samples_per_vertex)?;
    cfg.vis.negatives = args.get_or("negatives", cfg.vis.negatives)?;
    cfg.vis.gamma = args.get_or("gamma", cfg.vis.gamma)?;
    cfg.vis.rho0 = args.get_or("rho0", cfg.vis.rho0)?;
    let threads: usize = args.get_or("threads", 0)?;
    cfg.vis.threads = threads;
    cfg.knn.threads = threads;
    cfg.knn.forest.threads = threads;
    cfg.weights.threads = threads;
    let seed: u64 = args.get_or("seed", cfg.data_seed)?;
    cfg.data_seed = seed;
    cfg.vis.seed = seed ^ 0x1a9;
    if let Some(a) = args.get_str("prob-fn") {
        cfg.vis.prob_fn = match a {
            "invquad" => ProbFn::InvQuad { a: args.get_or("prob-a", 1.0f32)? },
            "sigmoid" => ProbFn::SigmoidSq,
            other => bail!("--prob-fn: unknown {other:?}"),
        };
    }
    match args.get_str("engine").unwrap_or("hogwild") {
        "hogwild" => cfg.use_xla = false,
        "xla" => cfg.use_xla = true,
        other => bail!("--engine must be hogwild|xla, got {other:?}"),
    }
    if let Some(mode) = args.get_str("layout") {
        cfg.layout_mode = mode.parse()?;
    }
    cfg.multilevel.coarsen.max_levels =
        args.get_or("ml-levels", cfg.multilevel.coarsen.max_levels)?;
    cfg.multilevel.coarsen.min_coarse_size =
        args.get_or("ml-min-size", cfg.multilevel.coarsen.min_coarse_size)?;
    cfg.multilevel.coarse_samples_multiplier =
        args.get_or("ml-coarse-samples", cfg.multilevel.coarse_samples_multiplier)?;
    cfg.multilevel.jitter = args.get_or("ml-jitter", cfg.multilevel.jitter)?;
    cfg.multilevel.level_rho_decay =
        args.get_or("ml-rho-decay", cfg.multilevel.level_rho_decay)?;
    if let Some(out) = args.get_str("out") {
        cfg.out_dir = out.into();
    }
    if let Some(input) = args.get_str("input") {
        cfg.input = Some(input.into());
    }
    if let Some(labels) = args.get_str("labels") {
        cfg.input_labels = Some(labels.into());
    }
    if let Some(stage) = args.get_str("resume-from") {
        cfg.resume_from = Some(stage.parse()?);
    }
    if args.has_flag("no-checkpoints") {
        cfg.save_checkpoints = false;
    }
    cfg.chunk_rows = args.get_or("chunk-rows", cfg.chunk_rows)?;
    Ok(cfg)
}

fn cmd_convert(args: &Args) -> Result<()> {
    let [src, dst] = args.positionals.as_slice() else {
        bail!("usage: largevis convert <src> <dst>\n\n{}", cli::USAGE);
    };
    let chunk_rows: usize =
        args.get_or("chunk-rows", largevis::data::formats::DEFAULT_CHUNK_ROWS)?;
    let (n, d) = largevis::data::formats::convert(
        std::path::Path::new(src),
        std::path::Path::new(dst),
        chunk_rows,
    )?;
    println!("converted {src} -> {dst} ({n} points, {d} dims)");
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let out = run_pipeline(&cfg)?;
    out.metrics.report(&cfg.dataset);
    Ok(())
}

/// Assemble a ServeConfig from `--config` INI `[serve]` plus CLI
/// overrides, then run the query server until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get_str("config") {
        Some(path) => ServeConfig::from_ini(&Ini::load(std::path::Path::new(path))?)?,
        None => ServeConfig::default(),
    };
    if let Some(dir) = args.get_str("checkpoints") {
        cfg.checkpoints = dir.into();
    } else if let Some(out) = args.get_str("out") {
        cfg.checkpoints = std::path::PathBuf::from(out).join("checkpoints");
    }
    if let Some(addr) = args.get_str("addr") {
        cfg.addr = addr.to_string();
    }
    cfg.threads = args.get_or("threads", cfg.threads)?;
    cfg.embed_samples = args.get_or("embed-samples", cfg.embed_samples)?;
    cfg.embed_k = args.get_or("embed-k", cfg.embed_k)?;
    cfg.grid = args.get_or("grid", cfg.grid)?;
    cfg.tile_max_points = args.get_or("tile-max-points", cfg.tile_max_points)?;
    cfg.max_body_bytes = args.get_or("max-body-bytes", cfg.max_body_bytes)?;
    if args.has_flag("read-only") {
        cfg.read_only = true;
    }
    cfg.insert_samples = args.get_or("insert-samples", cfg.insert_samples)?;
    cfg.refine_samples = args.get_or("refine-samples", cfg.refine_samples)?;
    cfg.refine_interval_ms = args.get_or("refine-interval-ms", cfg.refine_interval_ms)?;
    cfg.keep_alive_max = args.get_or("keep-alive-max", cfg.keep_alive_max)?;
    cfg.idle_timeout_ms = args.get_or("idle-timeout-ms", cfg.idle_timeout_ms)?;
    cfg.max_inflight = args.get_or("max-inflight", cfg.max_inflight)?;
    cfg.write_timeout_ms = args.get_or("write-timeout-ms", cfg.write_timeout_ms)?;
    cfg.wal_segment_bytes = args.get_or("wal-segment-bytes", cfg.wal_segment_bytes)?;
    cfg.wal_max_segments = args.get_or("wal-max-segments", cfg.wal_max_segments)?;
    cfg.recovery_policy = args.get_or("recovery-policy", cfg.recovery_policy)?;
    cfg.search = args.get_or("search", cfg.search)?;
    cfg.beam_width = args.get_or("beam-width", cfg.beam_width)?;
    cfg.search_seeds = args.get_or("search-seeds", cfg.search_seeds)?;

    // Two-phase startup: open the checkpoints, start listening, and
    // replay the insert WAL in the background. The server answers
    // queries against the base snapshot immediately; `/readyz` (and
    // inserts) answer 503 until the replay finishes.
    let state = ServerState::open(cfg)?;
    {
        let snap = state.snapshot();
        eprintln!(
            "[serve] loaded {}: {} points (d={}), layout dim {}, \
             knn k={}, {} graph edges, epoch {}",
            state.dataset,
            snap.data.n(),
            snap.data.d(),
            snap.layout.d(),
            snap.knn.k,
            state.graph_edges,
            snap.epoch,
        );
    }
    let server = Server::bind(state)?;
    eprintln!(
        "[serve] listening on http://{} (POST /embed, POST /knn, POST /insert, \
         POST /insert_batch, GET /viewport, GET /healthz, GET /readyz, GET /metrics)",
        server.local_addr()?
    );
    let state = server.state();
    let handle = server.handle();
    let recover_err: std::sync::Arc<std::sync::Mutex<Option<anyhow::Error>>> =
        std::sync::Arc::new(std::sync::Mutex::new(None));
    let recover_thread = {
        let state = state.clone();
        let handle = handle.clone();
        let recover_err = recover_err.clone();
        std::thread::spawn(move || {
            if let Err(e) = state.recover() {
                // A replay failure is fatal under fail_fast: record it,
                // stop the server, and let the exit path report it.
                *recover_err.lock().unwrap_or_else(|p| p.into_inner()) = Some(e);
                handle.shutdown();
            } else {
                let snap = state.snapshot();
                eprintln!(
                    "[serve] ready: WAL replay done ({} points recovered, epoch {})",
                    snap.data.n() - state.base_n,
                    snap.epoch,
                );
            }
        })
    };
    let run_result = server.run();
    let _ = recover_thread.join();
    if let Some(e) = recover_err.lock().unwrap_or_else(|p| p.into_inner()).take() {
        return Err(e.context("insert WAL replay failed"));
    }
    run_result
}

fn cmd_knn(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let ds = datasets::generate(&cfg.dataset, cfg.scale, cfg.data_seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {:?}", cfg.dataset))?;
    let k = cfg.k.min(ds.points.n() - 1);
    let knn_cfg = LargeVisKnnConfig {
        forest: RpForestConfig { n_trees: cfg.knn.forest.n_trees, ..Default::default() },
        iters: cfg.knn.iters,
        ..Default::default()
    };
    let t = largevis::util::Timer::start("knn total");
    let g = largevis::knn::explore::largevis_knn(&ds.points, k, &knn_cfg);
    let secs = t.report();
    let recall = largevis::knn::sampled_recall(&ds.points, &g, 500, 11, 0);
    println!(
        "dataset={} n={} d={} k={k} trees={} iters={} time={:.2}s sampled-recall={recall:.4}",
        ds.name,
        ds.points.n(),
        ds.points.d(),
        cfg.knn.forest.n_trees,
        cfg.knn.iters,
        secs
    );
    Ok(())
}

fn cmd_datasets(_args: &Args) -> Result<()> {
    println!(
        "{:<18} {:>12} {:>10} {:>6} {:>9}  {}",
        "name", "paper N", "our N", "dim", "classes", "paper dataset"
    );
    for s in datasets::REGISTRY {
        println!(
            "{:<18} {:>12} {:>10} {:>6} {:>9}  {}",
            s.name,
            s.paper_n,
            s.full_n,
            s.d,
            if s.classes > 0 { s.classes.to_string() } else { "-".into() },
            s.paper_name
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("largevis {}", largevis::VERSION);
    println!("threads: {}", largevis::util::pool::default_threads());
    match largevis::runtime::Runtime::from_default_dir() {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!(
                "artifacts: batch={} M={} dim={} step_n={}",
                rt.manifest.batch, rt.manifest.negatives, rt.manifest.dim, rt.manifest.step_n
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
