//! Weighted-graph substrate: perplexity-calibrated edge weights
//! (paper Eqs. 1–2) and a CSR sparse representation consumed by the
//! layout engines.

pub mod weights;
pub mod sparse;

pub use sparse::CsrGraph;
pub use weights::{weighted_graph, WeightConfig};
