//! Weighted-graph substrate: perplexity-calibrated edge weights
//! (paper Eqs. 1–2), a CSR sparse representation consumed by the
//! layout engines, and the heavy-edge-matching coarsener behind the
//! multilevel coarse-to-fine engine.

pub mod coarsen;
pub mod weights;
pub mod sparse;

pub use coarsen::{build_hierarchy, CoarsenConfig, Coarsening};
pub use sparse::CsrGraph;
pub use weights::{weighted_graph, WeightConfig};
