//! CSR (compressed sparse row) weighted undirected graph — the layout
//! engines' input format. Stores both directions of every undirected
//! edge plus a flat edge list for O(1) alias-sampled access.

/// CSR weighted graph.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    /// Row offsets, length n+1.
    offsets: Vec<u64>,
    /// Column ids (neighbor vertex), length = 2 × #undirected edges.
    cols: Vec<u32>,
    /// Edge weights aligned with `cols`.
    weights: Vec<f64>,
    /// Flat *directed* edge list (src, dst, weight) mirroring CSR order.
    edges: Vec<(u32, u32, f64)>,
}

impl CsrGraph {
    /// Build from undirected edges `(a, b, w)`; both directions stored.
    pub fn from_undirected(n: usize, undirected: &[(u32, u32, f64)]) -> Self {
        let mut deg = vec![0u64; n];
        for &(a, b, _) in undirected {
            assert!((a as usize) < n && (b as usize) < n && a != b, "bad edge ({a},{b})");
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut offsets = vec![0u64; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let m2 = offsets[n] as usize;
        let mut cols = vec![0u32; m2];
        let mut weights = vec![0f64; m2];
        let mut cursor = offsets.clone();
        for &(a, b, w) in undirected {
            let ca = cursor[a as usize] as usize;
            cols[ca] = b;
            weights[ca] = w;
            cursor[a as usize] += 1;
            let cb = cursor[b as usize] as usize;
            cols[cb] = a;
            weights[cb] = w;
            cursor[b as usize] += 1;
        }
        // Sort each row by column for deterministic layout + bsearch.
        for i in 0..n {
            let (lo, hi) = (offsets[i] as usize, offsets[i + 1] as usize);
            let mut row: Vec<(u32, f64)> =
                cols[lo..hi].iter().copied().zip(weights[lo..hi].iter().copied()).collect();
            row.sort_unstable_by_key(|&(c, _)| c);
            for (slot, (c, w)) in row.into_iter().enumerate() {
                cols[lo + slot] = c;
                weights[lo + slot] = w;
            }
        }
        let mut edges = Vec::with_capacity(m2);
        for i in 0..n {
            let (lo, hi) = (offsets[i] as usize, offsets[i + 1] as usize);
            for e in lo..hi {
                edges.push((i as u32, cols[e], weights[e]));
            }
        }
        CsrGraph { offsets, cols, weights, edges }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of *directed* edges (2 × undirected).
    #[inline]
    pub fn n_directed_edges(&self) -> usize {
        self.cols.len()
    }

    /// Neighbors of `i` as `(col, weight)` pairs, sorted by col.
    #[inline]
    pub fn row(&self, i: usize) -> RowIter<'_> {
        let (lo, hi) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        RowIter { cols: &self.cols[lo..hi], weights: &self.weights[lo..hi], pos: 0 }
    }

    /// Weighted degree of vertex `i`.
    pub fn weighted_degree(&self, i: usize) -> f64 {
        let (lo, hi) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        self.weights[lo..hi].iter().sum()
    }

    /// Unweighted degree of vertex `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// The flat directed edge list (src, dst, w), CSR order.
    #[inline]
    pub fn edges(&self) -> &[(u32, u32, f64)] {
        &self.edges
    }
}

/// Iterator over one CSR row, yielding owned `(col, weight)` pairs.
pub struct RowIter<'a> {
    cols: &'a [u32],
    weights: &'a [f64],
    pos: usize,
}

impl Iterator for RowIter<'_> {
    type Item = (u32, f64);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos < self.cols.len() {
            let out = (self.cols[self.pos], self.weights[self.pos]);
            self.pos += 1;
            Some(out)
        } else {
            None
        }
    }
}

impl RowIter<'_> {
    /// All pairs as a vector (convenience for tests).
    pub fn collect_pairs(self) -> Vec<(u32, f64)> {
        self.collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrGraph {
        CsrGraph::from_undirected(4, &[(0, 1, 1.0), (1, 2, 2.0), (0, 3, 0.5)])
    }

    #[test]
    fn degrees_and_rows() {
        let g = sample();
        assert_eq!(g.n(), 4);
        assert_eq!(g.n_directed_edges(), 6);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(2), 1);
        assert_eq!(g.row(0).collect_pairs(), vec![(1, 1.0), (3, 0.5)]);
        assert_eq!(g.row(2).collect_pairs(), vec![(1, 2.0)]);
    }

    #[test]
    fn weighted_degree() {
        let g = sample();
        assert!((g.weighted_degree(1) - 3.0).abs() < 1e-12);
        assert!((g.weighted_degree(3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn edges_match_rows() {
        let g = sample();
        assert_eq!(g.edges().len(), 6);
        let total: f64 = g.edges().iter().map(|&(_, _, w)| w).sum();
        assert!((total - 7.0).abs() < 1e-12); // 2*(1+2+0.5)
        for &(s, d, _) in g.edges() {
            assert!(g.row(s as usize).collect_pairs().iter().any(|&(c, _)| c == d));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        CsrGraph::from_undirected(3, &[(1, 1, 1.0)]);
    }

    #[test]
    fn isolated_vertices_ok() {
        let g = CsrGraph::from_undirected(5, &[(0, 1, 1.0)]);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.row(4).collect_pairs(), vec![]);
    }
}
