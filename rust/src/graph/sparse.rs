//! CSR (compressed sparse row) weighted undirected graph — the layout
//! engines' input format. Stores both directions of every undirected
//! edge plus a flat edge list for O(1) alias-sampled access.

/// CSR weighted graph.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrGraph {
    /// Row offsets, length n+1.
    offsets: Vec<u64>,
    /// Column ids (neighbor vertex), length = 2 × #undirected edges.
    cols: Vec<u32>,
    /// Edge weights aligned with `cols`.
    weights: Vec<f64>,
    /// Flat *directed* edge list (src, dst, weight) mirroring CSR order.
    edges: Vec<(u32, u32, f64)>,
}

impl CsrGraph {
    /// Build from undirected edges `(a, b, w)`; both directions stored.
    pub fn from_undirected(n: usize, undirected: &[(u32, u32, f64)]) -> Self {
        let mut deg = vec![0u64; n];
        for &(a, b, _) in undirected {
            assert!((a as usize) < n && (b as usize) < n && a != b, "bad edge ({a},{b})");
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut offsets = vec![0u64; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let m2 = offsets[n] as usize;
        let mut cols = vec![0u32; m2];
        let mut weights = vec![0f64; m2];
        let mut cursor = offsets.clone();
        for &(a, b, w) in undirected {
            let ca = cursor[a as usize] as usize;
            cols[ca] = b;
            weights[ca] = w;
            cursor[a as usize] += 1;
            let cb = cursor[b as usize] as usize;
            cols[cb] = a;
            weights[cb] = w;
            cursor[b as usize] += 1;
        }
        // Sort each row by column for deterministic layout + bsearch.
        for i in 0..n {
            let (lo, hi) = (offsets[i] as usize, offsets[i + 1] as usize);
            let mut row: Vec<(u32, f64)> =
                cols[lo..hi].iter().copied().zip(weights[lo..hi].iter().copied()).collect();
            row.sort_unstable_by_key(|&(c, _)| c);
            for (slot, (c, w)) in row.into_iter().enumerate() {
                cols[lo + slot] = c;
                weights[lo + slot] = w;
            }
        }
        let edges = build_edge_list(&offsets, &cols, &weights);
        CsrGraph { offsets, cols, weights, edges }
    }

    /// Assemble a graph directly from CSR arrays (e.g. the parallel
    /// symmetrizer's shard outputs, or a checkpoint read back from
    /// disk). Validates structure; the flat edge list is rebuilt
    /// deterministically from the arrays.
    ///
    /// Unlike [`CsrGraph::from_undirected`] this does not sort rows or
    /// deduplicate — the arrays are stored verbatim, which is what
    /// makes checkpoint round-trips bit-identical.
    pub fn from_raw_parts(
        offsets: Vec<u64>,
        cols: Vec<u32>,
        weights: Vec<f64>,
    ) -> Result<Self, String> {
        if offsets.is_empty() {
            return Err("offsets must have length n+1 >= 1".into());
        }
        if offsets[0] != 0 {
            return Err(format!("offsets[0] = {} != 0", offsets[0]));
        }
        if offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err("offsets not monotone".into());
        }
        if *offsets.last().unwrap() != cols.len() as u64 {
            return Err(format!(
                "offsets end {} != cols len {}",
                offsets.last().unwrap(),
                cols.len()
            ));
        }
        if cols.len() != weights.len() {
            return Err(format!("cols len {} != weights len {}", cols.len(), weights.len()));
        }
        let n = offsets.len() - 1;
        if let Some(&bad) = cols.iter().find(|&&c| c as usize >= n) {
            return Err(format!("column {bad} out of range for n={n}"));
        }
        let edges = build_edge_list(&offsets, &cols, &weights);
        Ok(CsrGraph { offsets, cols, weights, edges })
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of *directed* edges (2 × undirected).
    #[inline]
    pub fn n_directed_edges(&self) -> usize {
        self.cols.len()
    }

    /// Neighbors of `i` as `(col, weight)` pairs, sorted by col.
    #[inline]
    pub fn row(&self, i: usize) -> RowIter<'_> {
        let (lo, hi) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        RowIter { cols: &self.cols[lo..hi], weights: &self.weights[lo..hi], pos: 0 }
    }

    /// Weighted degree of vertex `i`.
    pub fn weighted_degree(&self, i: usize) -> f64 {
        let (lo, hi) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        self.weights[lo..hi].iter().sum()
    }

    /// Unweighted degree of vertex `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// The flat directed edge list (src, dst, w), CSR order.
    #[inline]
    pub fn edges(&self) -> &[(u32, u32, f64)] {
        &self.edges
    }

    /// Raw row offsets (length n+1) — checkpoint serialization.
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw column ids aligned with [`CsrGraph::weights`].
    #[inline]
    pub fn cols(&self) -> &[u32] {
        &self.cols
    }

    /// Raw edge weights aligned with [`CsrGraph::cols`].
    #[inline]
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

/// Flatten CSR arrays into the directed edge list (CSR order) the
/// alias sampler consumes.
fn build_edge_list(offsets: &[u64], cols: &[u32], weights: &[f64]) -> Vec<(u32, u32, f64)> {
    let n = offsets.len() - 1;
    let mut edges = Vec::with_capacity(cols.len());
    for i in 0..n {
        let (lo, hi) = (offsets[i] as usize, offsets[i + 1] as usize);
        for e in lo..hi {
            edges.push((i as u32, cols[e], weights[e]));
        }
    }
    edges
}

/// Iterator over one CSR row, yielding owned `(col, weight)` pairs.
pub struct RowIter<'a> {
    cols: &'a [u32],
    weights: &'a [f64],
    pos: usize,
}

impl Iterator for RowIter<'_> {
    type Item = (u32, f64);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos < self.cols.len() {
            let out = (self.cols[self.pos], self.weights[self.pos]);
            self.pos += 1;
            Some(out)
        } else {
            None
        }
    }
}

impl RowIter<'_> {
    /// All pairs as a vector (convenience for tests).
    pub fn collect_pairs(self) -> Vec<(u32, f64)> {
        self.collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrGraph {
        CsrGraph::from_undirected(4, &[(0, 1, 1.0), (1, 2, 2.0), (0, 3, 0.5)])
    }

    #[test]
    fn degrees_and_rows() {
        let g = sample();
        assert_eq!(g.n(), 4);
        assert_eq!(g.n_directed_edges(), 6);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(2), 1);
        assert_eq!(g.row(0).collect_pairs(), vec![(1, 1.0), (3, 0.5)]);
        assert_eq!(g.row(2).collect_pairs(), vec![(1, 2.0)]);
    }

    #[test]
    fn weighted_degree() {
        let g = sample();
        assert!((g.weighted_degree(1) - 3.0).abs() < 1e-12);
        assert!((g.weighted_degree(3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn edges_match_rows() {
        let g = sample();
        assert_eq!(g.edges().len(), 6);
        let total: f64 = g.edges().iter().map(|&(_, _, w)| w).sum();
        assert!((total - 7.0).abs() < 1e-12); // 2*(1+2+0.5)
        for &(s, d, _) in g.edges() {
            assert!(g.row(s as usize).collect_pairs().iter().any(|&(c, _)| c == d));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        CsrGraph::from_undirected(3, &[(1, 1, 1.0)]);
    }

    #[test]
    fn isolated_vertices_ok() {
        let g = CsrGraph::from_undirected(5, &[(0, 1, 1.0)]);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.row(4).collect_pairs(), vec![]);
    }

    #[test]
    fn raw_parts_roundtrip_identical() {
        let g = sample();
        let back = CsrGraph::from_raw_parts(
            g.offsets().to_vec(),
            g.cols().to_vec(),
            g.weights().to_vec(),
        )
        .unwrap();
        assert_eq!(g, back);
        assert_eq!(g.edges(), back.edges());
    }

    #[test]
    fn raw_parts_rejects_corruption() {
        let g = sample();
        // Truncated cols.
        assert!(CsrGraph::from_raw_parts(
            g.offsets().to_vec(),
            g.cols()[..g.cols().len() - 1].to_vec(),
            g.weights()[..g.weights().len() - 1].to_vec(),
        )
        .is_err());
        // Out-of-range column.
        let mut cols = g.cols().to_vec();
        cols[0] = 99;
        assert!(CsrGraph::from_raw_parts(g.offsets().to_vec(), cols, g.weights().to_vec())
            .is_err());
        // Non-monotone offsets.
        let mut off = g.offsets().to_vec();
        off[1] = off[2] + 1;
        assert!(CsrGraph::from_raw_parts(off, g.cols().to_vec(), g.weights().to_vec()).is_err());
        // Empty offsets.
        assert!(CsrGraph::from_raw_parts(vec![], vec![], vec![]).is_err());
    }
}
