//! Edge weights for the KNN graph (paper §3.1, Eqs. 1–2) — identical to
//! t-SNE's input similarities.
//!
//! For each point i a bandwidth σ_i is found by binary search so the
//! conditional distribution `p_{·|i}` over i's KNN edges has a target
//! perplexity `u` (paper default 50). The graph is then symmetrized:
//! `w_ij = (p_{j|i} + p_{i|j}) / 2N`.

use crate::graph::sparse::CsrGraph;
use crate::knn::KnnGraph;
use crate::util::pool;

/// Weighting parameters.
#[derive(Clone, Debug)]
pub struct WeightConfig {
    /// Target perplexity `u` (paper: 50).
    pub perplexity: f64,
    /// Binary-search iterations for σ_i.
    pub max_iters: usize,
    /// |log(perp) - log(u)| tolerance.
    pub tol: f64,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for WeightConfig {
    fn default() -> Self {
        WeightConfig { perplexity: 50.0, max_iters: 64, tol: 1e-5, threads: 0 }
    }
}

/// Conditional probabilities for one row given `beta = 1/(2σ²)`.
/// Returns (probs, perplexity). Distances are squared Euclidean.
fn row_probs(dists: &[f32], beta: f64) -> (Vec<f64>, f64) {
    // Subtract min for numerical stability.
    let dmin = dists.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let mut probs: Vec<f64> = dists.iter().map(|&d| (-beta * (d as f64 - dmin)).exp()).collect();
    let sum: f64 = probs.iter().sum();
    let mut entropy = 0.0;
    for p in probs.iter_mut() {
        *p /= sum;
        if *p > 1e-300 {
            entropy -= *p * p.ln();
        }
    }
    (probs, entropy.exp())
}

/// Binary-search σ_i for the target perplexity on one node's KNN edges.
/// Returns the conditional probabilities `p_{j|i}` aligned with `dists`.
pub fn calibrate_row(dists: &[f32], perplexity: f64, max_iters: usize, tol: f64) -> Vec<f64> {
    if dists.is_empty() {
        return Vec::new();
    }
    // Perplexity can't exceed the support size; clamp the target.
    let target = perplexity.min(dists.len() as f64).max(1.0);
    let (mut lo, mut hi) = (0.0f64, f64::INFINITY);
    let mut beta = 1.0f64;
    let mut probs = Vec::new();
    for _ in 0..max_iters {
        let (p, perp) = row_probs(dists, beta);
        probs = p;
        let diff = perp.ln() - target.ln();
        if diff.abs() < tol {
            break;
        }
        if diff > 0.0 {
            // Too flat (perplexity too high) -> increase beta.
            lo = beta;
            beta = if hi.is_finite() { (lo + hi) / 2.0 } else { beta * 2.0 };
        } else {
            hi = beta;
            beta = (lo + hi) / 2.0;
        }
    }
    probs
}

/// Build the symmetrized weighted graph from a KNN graph (Eqs. 1–2).
pub fn weighted_graph(knn: &KnnGraph, cfg: &WeightConfig) -> CsrGraph {
    let n = knn.n();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };

    // Conditional p_{j|i} per node, in KNN order.
    let conds: Vec<Vec<f64>> = pool::parallel_map(n, threads, |i| {
        let dists: Vec<f32> = knn.neighbors[i].iter().map(|&(_, d)| d).collect();
        calibrate_row(&dists, cfg.perplexity, cfg.max_iters, cfg.tol)
    });

    // Symmetrize: w_ij = (p_{j|i} + p_{i|j}) / (2N).
    // Build a map for p_{i|j} lookups.
    let mut pair_weight: std::collections::HashMap<(u32, u32), f64> =
        std::collections::HashMap::with_capacity(n * knn.k);
    for (i, nbrs) in knn.neighbors.iter().enumerate() {
        for (slot, &(j, _)) in nbrs.iter().enumerate() {
            let key = if (i as u32) < j { (i as u32, j) } else { (j, i as u32) };
            *pair_weight.entry(key).or_insert(0.0) += conds[i][slot];
        }
    }
    let scale = 1.0 / (2.0 * n as f64);
    let edges: Vec<(u32, u32, f64)> = pair_weight
        .into_iter()
        .filter(|&(_, w)| w > 0.0)
        .map(|((a, b), w)| (a, b, w * scale))
        .collect();
    CsrGraph::from_undirected(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn calibration_hits_target_perplexity() {
        let dists: Vec<f32> = (1..=100).map(|i| i as f32 * 0.3).collect();
        for &u in &[5.0, 20.0, 50.0] {
            let probs = calibrate_row(&dists, u, 100, 1e-7);
            let entropy: f64 = -probs.iter().filter(|&&p| p > 0.0).map(|p| p * p.ln()).sum::<f64>();
            assert!(
                (entropy.exp() - u).abs() < 0.05,
                "target {u}, got {}",
                entropy.exp()
            );
        }
    }

    #[test]
    fn probs_sum_to_one_and_order_by_distance() {
        let dists = vec![0.1f32, 0.5, 2.0, 8.0];
        let probs = calibrate_row(&dists, 2.0, 64, 1e-6);
        let sum: f64 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        for w in probs.windows(2) {
            assert!(w[0] >= w[1], "closer neighbor must get more mass: {probs:?}");
        }
    }

    #[test]
    fn symmetric_and_normalized() {
        let (m, _) = gaussian_mixture(200, 8, 4, 0.2, 1);
        let knn = exact_knn(&m, 10, 2);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 5.0, ..Default::default() });
        // Symmetry: CSR stores both directions with equal weight.
        for i in 0..g.n() {
            for (j, w) in g.row(i) {
                let back = g.row(j as usize).find(|&(b, _)| b as usize == i);
                let (_, wb) = back.expect("missing reverse edge");
                assert!((w - wb).abs() < 1e-12);
            }
        }
        // Total weight = sum of w_ij over ordered pairs ≈ sum_i sum_j p_{j|i} / 2N * 2 = 1/N * N...
        // Each conditional row sums to 1, so total over ordered pairs = 2 * (1/2N) * N = 1.
        let total: f64 = (0..g.n()).map(|i| g.row(i).map(|(_, w)| w).sum::<f64>()).sum();
        assert!((total - 1.0).abs() < 1e-6, "total weight {total}");
    }

    #[test]
    fn empty_row_ok() {
        assert!(calibrate_row(&[], 30.0, 10, 1e-5).is_empty());
    }
}
