//! Edge weights for the KNN graph (paper §3.1, Eqs. 1–2) — identical to
//! t-SNE's input similarities.
//!
//! For each point i a bandwidth σ_i is found by binary search so the
//! conditional distribution `p_{·|i}` over i's KNN edges has a target
//! perplexity `u` (paper default 50). The graph is then symmetrized:
//! `w_ij = (p_{j|i} + p_{i|j}) / 2N`.

use crate::graph::sparse::CsrGraph;
use crate::knn::KnnGraph;
use crate::util::pool;

/// Weighting parameters.
#[derive(Clone, Debug)]
pub struct WeightConfig {
    /// Target perplexity `u` (paper: 50).
    pub perplexity: f64,
    /// Binary-search iterations for σ_i.
    pub max_iters: usize,
    /// |log(perp) - log(u)| tolerance.
    pub tol: f64,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for WeightConfig {
    fn default() -> Self {
        WeightConfig { perplexity: 50.0, max_iters: 64, tol: 1e-5, threads: 0 }
    }
}

/// Conditional probabilities for one row given `beta = 1/(2σ²)`.
/// Returns (probs, perplexity). Distances are squared Euclidean.
fn row_probs(dists: &[f32], beta: f64) -> (Vec<f64>, f64) {
    // Subtract min for numerical stability.
    let dmin = dists.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let mut probs: Vec<f64> = dists.iter().map(|&d| (-beta * (d as f64 - dmin)).exp()).collect();
    let sum: f64 = probs.iter().sum();
    let mut entropy = 0.0;
    for p in probs.iter_mut() {
        *p /= sum;
        if *p > 1e-300 {
            entropy -= *p * p.ln();
        }
    }
    (probs, entropy.exp())
}

/// Binary-search σ_i for the target perplexity on one node's KNN edges.
/// Returns the conditional probabilities `p_{j|i}` aligned with `dists`.
pub fn calibrate_row(dists: &[f32], perplexity: f64, max_iters: usize, tol: f64) -> Vec<f64> {
    if dists.is_empty() {
        return Vec::new();
    }
    // Perplexity can't exceed the support size; clamp the target.
    let target = perplexity.min(dists.len() as f64).max(1.0);
    let (mut lo, mut hi) = (0.0f64, f64::INFINITY);
    let mut beta = 1.0f64;
    let mut probs = Vec::new();
    for _ in 0..max_iters {
        let (p, perp) = row_probs(dists, beta);
        probs = p;
        let diff = perp.ln() - target.ln();
        if diff.abs() < tol {
            break;
        }
        if diff > 0.0 {
            // Too flat (perplexity too high) -> increase beta.
            lo = beta;
            beta = if hi.is_finite() { (lo + hi) / 2.0 } else { beta * 2.0 };
        } else {
            hi = beta;
            beta = (lo + hi) / 2.0;
        }
    }
    probs
}

/// Conditional probabilities `p_{j|i}` for every node, aligned with
/// each node's KNN order (parallel over nodes).
fn conditional_probs(knn: &KnnGraph, cfg: &WeightConfig, threads: usize) -> Vec<Vec<f64>> {
    pool::parallel_map(knn.n(), threads, |i| {
        let dists: Vec<f32> = knn.neighbors[i].iter().map(|&(_, d)| d).collect();
        calibrate_row(&dists, cfg.perplexity, cfg.max_iters, cfg.tol)
    })
}

/// Build the symmetrized weighted graph from a KNN graph (Eqs. 1–2).
///
/// Symmetrization — `w_ij = (p_{j|i} + p_{i|j}) / 2N` — is a parallel
/// shard-by-source sort-merge that builds the CSR arrays directly (see
/// [`symmetrize_sharded`]), replacing the single-threaded `HashMap`
/// pass that used to be the last serial stage between KNN and SGD.
/// Output is deterministic and bit-identical to the reference
/// implementation ([`weighted_graph_reference`]) on valid KNN graphs.
pub fn weighted_graph(knn: &KnnGraph, cfg: &WeightConfig) -> CsrGraph {
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let conds = conditional_probs(knn, cfg, threads);
    symmetrize_sharded(knn, &conds, threads)
}

/// Reference symmetrization: single-threaded `HashMap` pair
/// accumulation, then [`CsrGraph::from_undirected`]. Kept as the
/// differential-testing oracle for [`symmetrize_sharded`]
/// (`rust/tests/checkpoint_roundtrip.rs` asserts bit-identical CSR on
/// seeded inputs); not used on the hot path.
pub fn weighted_graph_reference(knn: &KnnGraph, cfg: &WeightConfig) -> CsrGraph {
    let n = knn.n();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let conds = conditional_probs(knn, cfg, threads);

    let mut pair_weight: std::collections::HashMap<(u32, u32), f64> =
        std::collections::HashMap::with_capacity(n * knn.k);
    for (i, nbrs) in knn.neighbors.iter().enumerate() {
        for (slot, &(j, _)) in nbrs.iter().enumerate() {
            let key = if (i as u32) < j { (i as u32, j) } else { (j, i as u32) };
            *pair_weight.entry(key).or_insert(0.0) += conds[i][slot];
        }
    }
    let scale = 1.0 / (2.0 * n as f64);
    let edges: Vec<(u32, u32, f64)> = pair_weight
        .into_iter()
        .filter(|&(_, w)| w > 0.0)
        .map(|((a, b), w)| (a, b, w * scale))
        .collect();
    CsrGraph::from_undirected(n, &edges)
}

/// Parallel shard-by-source sort-merge symmetrization.
///
/// Every directed KNN edge `(i → j, p_{j|i})` contributes two
/// half-edges — `(i, j, p_{j|i})` into row `i` and `(j, i, p_{j|i})`
/// into row `j` — so after merging duplicates, row `i`'s entry for `j`
/// holds exactly `p_{j|i} + p_{i|j}`, which scaled by `1/2N` is Eq. 2.
///
/// Three phases, each parallel:
/// 1. **Shuffle**: workers walk disjoint KNN row ranges and bucket both
///    half-edges of every entry by the shard owning the *source* row
///    (shards are contiguous row ranges).
/// 2. **Sort-merge**: each shard concatenates its buckets, sorts by
///    `(src, dst, weight bits)` — a total order, so the result is
///    deterministic regardless of thread interleaving — and merges
///    duplicate `(src, dst)` runs by summation (IEEE addition of the
///    two conditionals is commutative, keeping bit-parity with the
///    reference accumulation order).
/// 3. **Stitch**: shard outputs are already globally sorted by source
///    row, so the CSR arrays are a prefix-sum plus disjoint copies.
fn symmetrize_sharded(knn: &KnnGraph, conds: &[Vec<f64>], threads: usize) -> CsrGraph {
    let n = knn.n();
    let shards = threads.max(1).min(n.max(1));
    let rows_per_shard = n.div_ceil(shards).max(1);

    // Phase 1: shuffle half-edges into per-(worker, shard) buckets.
    let buckets: Vec<Vec<Vec<(u32, u32, f64)>>> = pool::parallel_map(shards, shards, |w| {
        let lo = w * rows_per_shard;
        let hi = ((w + 1) * rows_per_shard).min(n);
        let mut out: Vec<Vec<(u32, u32, f64)>> = vec![Vec::new(); shards];
        for i in lo..hi {
            for (slot, &(j, _)) in knn.neighbors[i].iter().enumerate() {
                let p = conds[i][slot];
                out[w].push((i as u32, j, p));
                out[(j as usize / rows_per_shard).min(shards - 1)].push((j, i as u32, p));
            }
        }
        out
    });

    // Phase 2: per-shard deterministic sort + duplicate merge + scale.
    let scale = 1.0 / (2.0 * n as f64);
    let merged: Vec<(Vec<u32>, Vec<u32>, Vec<f64>)> = pool::parallel_map(shards, shards, |s| {
        let total: usize = buckets.iter().map(|b| b[s].len()).sum();
        let mut halves: Vec<(u32, u32, f64)> = Vec::with_capacity(total);
        for b in &buckets {
            halves.extend_from_slice(&b[s]);
        }
        halves.sort_unstable_by_key(|&(src, dst, p)| (src, dst, p.to_bits()));
        let mut srcs: Vec<u32> = Vec::with_capacity(halves.len());
        let mut dsts: Vec<u32> = Vec::with_capacity(halves.len());
        let mut ws: Vec<f64> = Vec::with_capacity(halves.len());
        let mut idx = 0;
        while idx < halves.len() {
            let (src, dst, _) = halves[idx];
            let mut w = 0.0f64;
            while idx < halves.len() && halves[idx].0 == src && halves[idx].1 == dst {
                w += halves[idx].2;
                idx += 1;
            }
            // Matches the reference's `w > 0.0` pre-scale filter.
            if w > 0.0 {
                srcs.push(src);
                dsts.push(dst);
                ws.push(w * scale);
            }
        }
        (srcs, dsts, ws)
    });

    // Phase 3: stitch shard outputs (already globally source-sorted)
    // into the final CSR arrays.
    let mut offsets = vec![0u64; n + 1];
    for (srcs, _, _) in &merged {
        for &s in srcs {
            offsets[s as usize + 1] += 1;
        }
    }
    for i in 0..n {
        offsets[i + 1] += offsets[i];
    }
    let m2 = offsets[n] as usize;
    let mut cols = vec![0u32; m2];
    let mut weights = vec![0f64; m2];
    let mut cursor = 0usize;
    for (_, dsts, ws) in &merged {
        cols[cursor..cursor + dsts.len()].copy_from_slice(dsts);
        weights[cursor..cursor + ws.len()].copy_from_slice(ws);
        cursor += dsts.len();
    }
    CsrGraph::from_raw_parts(offsets, cols, weights)
        .expect("sharded symmetrizer produced invalid CSR")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn calibration_hits_target_perplexity() {
        let dists: Vec<f32> = (1..=100).map(|i| i as f32 * 0.3).collect();
        for &u in &[5.0, 20.0, 50.0] {
            let probs = calibrate_row(&dists, u, 100, 1e-7);
            let entropy: f64 = -probs.iter().filter(|&&p| p > 0.0).map(|p| p * p.ln()).sum::<f64>();
            assert!(
                (entropy.exp() - u).abs() < 0.05,
                "target {u}, got {}",
                entropy.exp()
            );
        }
    }

    #[test]
    fn probs_sum_to_one_and_order_by_distance() {
        let dists = vec![0.1f32, 0.5, 2.0, 8.0];
        let probs = calibrate_row(&dists, 2.0, 64, 1e-6);
        let sum: f64 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        for w in probs.windows(2) {
            assert!(w[0] >= w[1], "closer neighbor must get more mass: {probs:?}");
        }
    }

    #[test]
    fn symmetric_and_normalized() {
        let (m, _) = gaussian_mixture(200, 8, 4, 0.2, 1);
        let knn = exact_knn(&m, 10, 2);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 5.0, ..Default::default() });
        // Symmetry: CSR stores both directions with equal weight.
        for i in 0..g.n() {
            for (j, w) in g.row(i) {
                let back = g.row(j as usize).find(|&(b, _)| b as usize == i);
                let (_, wb) = back.expect("missing reverse edge");
                assert!((w - wb).abs() < 1e-12);
            }
        }
        // Total weight = sum of w_ij over ordered pairs ≈ sum_i sum_j p_{j|i} / 2N * 2 = 1/N * N...
        // Each conditional row sums to 1, so total over ordered pairs = 2 * (1/2N) * N = 1.
        let total: f64 = (0..g.n()).map(|i| g.row(i).map(|(_, w)| w).sum::<f64>()).sum();
        assert!((total - 1.0).abs() < 1e-6, "total weight {total}");
    }

    #[test]
    fn empty_row_ok() {
        assert!(calibrate_row(&[], 30.0, 10, 1e-5).is_empty());
    }

    #[test]
    fn sharded_matches_reference_small() {
        let (m, _) = gaussian_mixture(120, 6, 3, 0.25, 7);
        let knn = exact_knn(&m, 8, 2);
        let cfg = WeightConfig { perplexity: 4.0, threads: 3, ..Default::default() };
        let fast = weighted_graph(&knn, &cfg);
        let reference = weighted_graph_reference(&knn, &cfg);
        assert_eq!(fast, reference);
    }

    #[test]
    fn sharded_handles_empty_and_tiny_graphs() {
        // Graph with no edges at all.
        let g = weighted_graph(&KnnGraph::empty(5, 3), &WeightConfig::default());
        assert_eq!(g.n(), 5);
        assert_eq!(g.n_directed_edges(), 0);
        // Two mutual neighbors.
        let mut knn = KnnGraph::empty(2, 1);
        knn.neighbors[0] = vec![(1, 1.0)];
        knn.neighbors[1] = vec![(0, 1.0)];
        let g = weighted_graph(&knn, &WeightConfig::default());
        assert_eq!(g.n_directed_edges(), 2);
        // Single conditional prob is 1.0 each way: w = (1+1)/(2*2) = 0.5.
        let (_, w) = g.row(0).next().unwrap();
        assert!((w - 0.5).abs() < 1e-12);
    }
}
