//! Heavy-edge-matching graph coarsener — the substrate of the
//! multilevel coarse-to-fine layout engine (`vis::multilevel`).
//!
//! A flat SGD layout spends most of its sample budget untangling the
//! random initialization; NCVis (Artemenkov & Panov, 2020) and ShapeVis
//! (Kumari et al., 2020) both show that optimizing a coarsened graph
//! hierarchy first converges far faster at million-point scale. The
//! coarsener here is the classic heavy-edge matching (HEM) of
//! Karypis–Kumar's METIS: visit vertices in random order, match each
//! unmatched vertex with its heaviest unmatched neighbor, and contract
//! every matched pair into one coarse vertex. Parallel edges created by
//! the contraction are merged by summing weights (so total cross-pair
//! weight — and therefore the edge-sampling distribution's shape — is
//! conserved), and interior edges collapse away.
//!
//! Each level roughly halves the vertex count, so a full hierarchy
//! costs O(|E|) to build and holds ~2× the input graph in total.

use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// Knobs for hierarchy construction.
#[derive(Clone, Copy, Debug)]
pub struct CoarsenConfig {
    /// Stop coarsening once a level has at most this many vertices.
    pub min_coarse_size: usize,
    /// Hard cap on the number of coarse levels built.
    pub max_levels: usize,
    /// Stop if a round shrinks the graph by less than this factor
    /// (matching has degenerated, e.g. on a star graph).
    pub min_shrink: f64,
    /// Seed for the random visit order (fixed by default so pipeline
    /// re-runs and checkpoint resumes see an identical hierarchy).
    pub seed: u64,
}

impl Default for CoarsenConfig {
    fn default() -> Self {
        CoarsenConfig { min_coarse_size: 1024, max_levels: 16, min_shrink: 0.95, seed: 0xc0a5 }
    }
}

/// One coarsening step: the contracted graph plus the vertex mapping.
#[derive(Clone, Debug)]
pub struct Coarsening {
    /// The contracted graph.
    pub graph: CsrGraph,
    /// `map[fine_vertex] = coarse_vertex`; every coarse vertex has one
    /// or two fine preimages.
    pub map: Vec<u32>,
}

/// Contract one level: heavy-edge matching, then merge matched pairs.
pub fn coarsen_once(g: &CsrGraph, rng: &mut Rng) -> Coarsening {
    let n = g.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    // Phase 1: heavy-edge matching. `match_of[u] == u` marks a
    // singleton (no unmatched neighbor was left, or u is isolated).
    let unmatched = u32::MAX;
    let mut match_of: Vec<u32> = vec![unmatched; n];
    for &u in &order {
        let ui = u as usize;
        if match_of[ui] != unmatched {
            continue;
        }
        let mut best: Option<(u32, f64)> = None;
        for (v, w) in g.row(ui) {
            if match_of[v as usize] != unmatched {
                continue;
            }
            // Strict `>` keeps the first (lowest-id) neighbor on ties,
            // so the matching is a function of the visit order alone.
            let better = match best {
                None => true,
                Some((_, bw)) => w > bw,
            };
            if better {
                best = Some((v, w));
            }
        }
        match best {
            Some((v, _)) => {
                match_of[ui] = v;
                match_of[v as usize] = u;
            }
            None => match_of[ui] = u,
        }
    }

    // Phase 2: assign coarse ids in fine-id order (deterministic given
    // the matching) and aggregate cross-pair edges.
    let mut map = vec![unmatched; n];
    let mut next = 0u32;
    for u in 0..n {
        if map[u] != unmatched {
            continue;
        }
        map[u] = next;
        let p = match_of[u] as usize;
        if p != u {
            map[p] = next;
        }
        next += 1;
    }
    let coarse_n = next as usize;

    // Sum parallel edges; drop edges interior to a matched pair.
    // Sort-then-merge-runs instead of a hash map: lower constants on
    // multi-million-edge levels (same reasoning as the sharded
    // symmetrizer in `graph::weights`) and fully deterministic — the
    // sort is an unstable but deterministic algorithm, so equal-key
    // runs always accumulate in the same order.
    let mut pairs: Vec<(u32, u32, f64)> = Vec::with_capacity(g.n_directed_edges() / 2);
    for &(s, d, w) in g.edges() {
        if s >= d {
            continue; // each undirected edge once
        }
        let (a, b) = (map[s as usize], map[d as usize]);
        if a == b {
            continue;
        }
        pairs.push((a.min(b), a.max(b), w));
    }
    pairs.sort_unstable_by_key(|&(a, b, _)| (a, b));
    let mut edges: Vec<(u32, u32, f64)> = Vec::with_capacity(pairs.len());
    for (a, b, w) in pairs {
        match edges.last_mut() {
            Some(last) if last.0 == a && last.1 == b => last.2 += w,
            _ => edges.push((a, b, w)),
        }
    }
    Coarsening { graph: CsrGraph::from_undirected(coarse_n, &edges), map }
}

/// Build the full hierarchy, finest-to-coarsest: `out[0]` is one level
/// above the input graph and `out.last()` is the coarsest level. Empty
/// when the input is already at or below `min_coarse_size` (the
/// multilevel driver then degenerates to a flat optimization).
pub fn build_hierarchy(g: &CsrGraph, cfg: &CoarsenConfig) -> Vec<Coarsening> {
    let mut rng = Rng::new(cfg.seed);
    let mut out: Vec<Coarsening> = Vec::new();
    loop {
        if out.len() >= cfg.max_levels {
            break;
        }
        let (c, parent_n) = {
            let parent = out.last().map_or(g, |c| &c.graph);
            if parent.n() <= cfg.min_coarse_size {
                break;
            }
            (coarsen_once(parent, &mut rng), parent.n())
        };
        // A level the SGD engine cannot lay out (no edges) or that
        // barely shrinks is useless — stop before pushing it.
        if c.graph.n_directed_edges() == 0 {
            break;
        }
        if (c.graph.n() as f64) > cfg.min_shrink * parent_n as f64 {
            break;
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ring of `n` vertices with unit weights.
    fn ring(n: usize) -> CsrGraph {
        let edges: Vec<(u32, u32, f64)> =
            (0..n as u32).map(|i| (i, (i + 1) % n as u32, 1.0)).collect();
        CsrGraph::from_undirected(n, &edges)
    }

    fn check_map(fine_n: usize, c: &Coarsening) {
        assert_eq!(c.map.len(), fine_n);
        let coarse_n = c.graph.n();
        let mut preimages = vec![0usize; coarse_n];
        for &m in &c.map {
            assert!((m as usize) < coarse_n, "map out of range");
            preimages[m as usize] += 1;
        }
        for (cv, &k) in preimages.iter().enumerate() {
            assert!(k == 1 || k == 2, "coarse vertex {cv} has {k} preimages");
        }
    }

    #[test]
    fn ring_roughly_halves() {
        let g = ring(64);
        let mut rng = Rng::new(1);
        let c = coarsen_once(&g, &mut rng);
        // A ring admits a near-perfect matching; random-order HEM gets
        // most of it. Bounds: perfect = 32, no matching = 64.
        assert!(c.graph.n() >= 32 && c.graph.n() < 56, "coarse n = {}", c.graph.n());
        check_map(64, &c);
    }

    #[test]
    fn cross_pair_weight_conserved() {
        let g = ring(40);
        let mut rng = Rng::new(2);
        let c = coarsen_once(&g, &mut rng);
        // Sum of fine edges whose endpoints land in different coarse
        // vertices must equal the coarse graph's total weight exactly
        // (same additions, same deterministic order).
        let mut expect = 0.0f64;
        for &(s, d, w) in g.edges() {
            if s < d && c.map[s as usize] != c.map[d as usize] {
                expect += w;
            }
        }
        let got: f64 = c.graph.edges().iter().filter(|&&(s, d, _)| s < d).map(|&(_, _, w)| w).sum();
        assert!((got - expect).abs() < 1e-9, "got {got}, expect {expect}");
    }

    #[test]
    fn heavy_edges_matched_first() {
        // Two heavy pairs joined by light edges: HEM must contract the
        // heavy pairs, never across the light bridge.
        let g = CsrGraph::from_undirected(
            4,
            &[(0, 1, 100.0), (2, 3, 100.0), (1, 2, 0.1), (0, 3, 0.1)],
        );
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let c = coarsen_once(&g, &mut rng);
            assert_eq!(c.graph.n(), 2);
            assert_eq!(c.map[0], c.map[1], "heavy pair (0,1) split: {:?}", c.map);
            assert_eq!(c.map[2], c.map[3], "heavy pair (2,3) split: {:?}", c.map);
        }
    }

    #[test]
    fn isolated_vertices_become_singletons() {
        let g = CsrGraph::from_undirected(5, &[(0, 1, 1.0)]);
        let mut rng = Rng::new(3);
        let c = coarsen_once(&g, &mut rng);
        assert_eq!(c.graph.n(), 4); // {0,1} merged; 2,3,4 singletons
        check_map(5, &c);
        // The merged pair's edge was interior: the coarse graph keeps
        // only vertices, no edges between the singletons appear.
        assert_eq!(c.graph.n_directed_edges(), 0);
    }

    #[test]
    fn hierarchy_shrinks_to_min_size_and_is_deterministic() {
        let g = ring(600);
        let cfg = CoarsenConfig { min_coarse_size: 40, ..Default::default() };
        let h = build_hierarchy(&g, &cfg);
        assert!(!h.is_empty());
        let mut prev = g.n();
        for c in &h {
            assert!(c.graph.n() < prev, "level did not shrink");
            prev = c.graph.n();
        }
        // Terminated properly: coarsest at/below the floor, or the cap.
        assert!(
            h.last().unwrap().graph.n() <= cfg.min_coarse_size || h.len() == cfg.max_levels,
            "coarsest n = {}",
            h.last().unwrap().graph.n()
        );
        let h2 = build_hierarchy(&g, &cfg);
        assert_eq!(h.len(), h2.len());
        for (a, b) in h.iter().zip(&h2) {
            assert_eq!(a.map, b.map);
            assert_eq!(a.graph, b.graph);
        }
    }

    #[test]
    fn hierarchy_empty_when_already_small() {
        let g = ring(16);
        let cfg = CoarsenConfig { min_coarse_size: 1024, ..Default::default() };
        assert!(build_hierarchy(&g, &cfg).is_empty());
    }
}
