//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the rust hot path (the only place the `xla` crate is touched).
//!
//! `make artifacts` (build-time Python) writes `artifacts/*.hlo.txt` and
//! `manifest.json`; this module compiles them once on the PJRT CPU
//! client and caches the executables. Python never runs at layout time.
//!
//! The external `xla` crate is unavailable in the offline build
//! environment, so the PJRT-backed implementation is gated behind the
//! `xla` cargo feature — and that dependency is deliberately left
//! undeclared, so enabling the feature without vendoring an `xla`
//! crate fails to compile. The default build compiles an API-identical
//! stub whose `Runtime` constructors return an error; every consumer
//! (CLI `info`, benches, the XLA parity tests, `vis::batched` callers)
//! already treats that as "artifacts unavailable" and degrades
//! gracefully, so the rest of the system is unaffected.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Shapes baked into the artifacts at AOT time (from manifest.json).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Manifest {
    /// Edge batch size B.
    pub batch: usize,
    /// Negatives per edge M.
    pub negatives: usize,
    /// Output dimensionality s.
    pub dim: usize,
    /// Table size of the fused `largevis_step` artifact.
    pub step_n: usize,
    /// pdist tile edge length.
    pub pdist_tile: usize,
    /// pdist feature dimension.
    pub pdist_d: usize,
}

impl Manifest {
    /// Parse from manifest.json text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json")?;
        let field = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("manifest missing {k}"))
        };
        Ok(Manifest {
            batch: field("batch")?,
            negatives: field("negatives")?,
            dim: field("dim")?,
            step_n: field("step_n")?,
            pdist_tile: field("pdist_tile")?,
            pdist_d: field("pdist_d")?,
        })
    }
}

/// Default artifact location (`$LARGEVIS_ARTIFACTS` or `artifacts/`).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("LARGEVIS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        // Walk up from cwd so examples/tests work from any subdir.
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    })
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::{default_artifact_dir, Manifest};
    use anyhow::{bail, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    pub use xla::Literal;

    /// PJRT CPU client + compiled-executable cache over an artifact dir.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        /// The baked shapes.
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        /// Default artifact location (`$LARGEVIS_ARTIFACTS` or `artifacts/`).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        /// Create a runtime over an artifact directory.
        pub fn new(dir: &Path) -> Result<Runtime> {
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path).with_context(|| {
                format!("{} not found — run `make artifacts` first", manifest_path.display())
            })?;
            let manifest = Manifest::parse(&text)?;
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            Ok(Runtime {
                client,
                dir: dir.to_path_buf(),
                manifest,
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// Convenience: runtime over [`Runtime::default_dir`].
        pub fn from_default_dir() -> Result<Runtime> {
            Runtime::new(&Self::default_dir())
        }

        /// PJRT platform name (for `largevis info`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile (cached) an artifact by name, e.g. `grad_kernel`.
        pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.lock().unwrap().get(name) {
                return Ok(exe.clone());
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!("artifact {} missing — run `make artifacts`", path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
            let exe = std::sync::Arc::new(exe);
            self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute an artifact on literal inputs; returns the tuple elements
        /// (aot.py lowers with `return_tuple=True`).
        pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = self.executable(name)?;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {name} result: {e}"))?;
            lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))
        }
    }

    /// Build an `[n, d]` f32 literal from a flat row-major slice.
    pub fn literal_f32_2d(data: &[f32], n: usize, d: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), n * d);
        xla::Literal::vec1(data)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
    }

    /// Build an `[n]` i32 literal.
    pub fn literal_i32_1d(data: &[i32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// Build an `[n, m]` i32 literal from a flat slice.
    pub fn literal_i32_2d(data: &[i32], n: usize, m: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), n * m);
        xla::Literal::vec1(data)
            .reshape(&[n as i64, m as i64])
            .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
    }

    /// Scalar f32 literal.
    pub fn literal_f32(v: f32) -> xla::Literal {
        xla::Literal::from(v)
    }

    /// Copy a literal's f32 payload out.
    pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))
    }
}

#[cfg(feature = "xla")]
pub use pjrt::*;

#[cfg(not(feature = "xla"))]
mod stub {
    use super::{default_artifact_dir, Manifest};
    use anyhow::{bail, Result};
    use std::path::{Path, PathBuf};

    const DISABLED: &str =
        "PJRT runtime unavailable: built without the `xla` cargo feature (offline build)";

    /// Opaque stand-in for `xla::Literal` when built without `xla`.
    pub struct Literal;

    /// Stub runtime: constructors always fail with a clear message.
    pub struct Runtime {
        /// The baked shapes (never observable — construction fails).
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Default artifact location (`$LARGEVIS_ARTIFACTS` or `artifacts/`).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        /// Always fails: the PJRT client needs the `xla` feature.
        pub fn new(_dir: &Path) -> Result<Runtime> {
            bail!("{DISABLED}")
        }

        /// Always fails: the PJRT client needs the `xla` feature.
        pub fn from_default_dir() -> Result<Runtime> {
            Runtime::new(&Self::default_dir())
        }

        /// Platform name placeholder.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Always fails (unreachable: construction already failed).
        pub fn run(&self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            bail!("{DISABLED}")
        }
    }

    /// Shape-checked stub (data is dropped; execution can never happen).
    pub fn literal_f32_2d(data: &[f32], n: usize, d: usize) -> Result<Literal> {
        assert_eq!(data.len(), n * d);
        Ok(Literal)
    }

    /// Stub literal constructor.
    pub fn literal_i32_1d(_data: &[i32]) -> Literal {
        Literal
    }

    /// Shape-checked stub.
    pub fn literal_i32_2d(data: &[i32], n: usize, m: usize) -> Result<Literal> {
        assert_eq!(data.len(), n * m);
        Ok(Literal)
    }

    /// Stub literal constructor.
    pub fn literal_f32(_v: f32) -> Literal {
        Literal
    }

    /// Always fails (no payload exists without the `xla` feature).
    pub fn literal_to_f32(_lit: &Literal) -> Result<Vec<f32>> {
        bail!("{DISABLED}")
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::*;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            r#"{"batch":1024,"negatives":5,"dim":2,"step_n":10000,"pdist_tile":256,"pdist_d":100,"artifacts":{}}"#,
        )
        .unwrap();
        assert_eq!(m.batch, 1024);
        assert_eq!(m.negatives, 5);
        assert_eq!(m.dim, 2);
    }

    #[test]
    fn manifest_missing_field_errors() {
        assert!(Manifest::parse(r#"{"batch":1}"#).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::new(std::path::Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("xla"), "{err}");
    }

    // Runtime-dependent tests live in rust/tests/xla_parity.rs (they
    // need artifacts/ built).
}
