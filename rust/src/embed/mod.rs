//! Embedding-learning substrate.
//!
//! The paper preprocesses its network datasets with LINE (Tang et al.,
//! WWW 2015) to 100-d representations before visualization, and also
//! evaluates LINE *directly at 2-d* as a (poor) visualization baseline
//! (Fig 5). Both uses are served by [`line`].

pub mod line;

pub use line::{Line, LineConfig};
