//! LINE: Large-scale Information Network Embedding (first-order
//! proximity), trained by asynchronous SGD with edge sampling and
//! degree^0.75 negative sampling — the same optimization machinery the
//! LargeVis layout engine uses, at arbitrary output dimension.
//!
//! First-order LINE models `P(e_ij) = σ(u_i · u_j)` over observed edges
//! plus M negative samples; we follow the paper's settings (ρ0=0.025,
//! M=5).

use crate::data::matrix::Matrix;
use crate::util::alias::AliasTable;
use crate::util::pool;
use crate::util::rng::Rng;

/// LINE hyper-parameters.
#[derive(Clone, Debug)]
pub struct LineConfig {
    /// Output dimensionality (100 for preprocessing, 2 for the baseline).
    pub dim: usize,
    /// Total edge samples; the paper suggests ~10K·N for 1M nodes. We
    /// default to `samples_per_vertex * n` via [`LineConfig::total_samples`].
    pub samples_per_vertex: usize,
    /// Negative samples per positive edge.
    pub negatives: usize,
    /// Initial learning rate (paper: 0.025 for LINE).
    pub rho0: f32,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LineConfig {
    fn default() -> Self {
        LineConfig { dim: 100, samples_per_vertex: 600, negatives: 5, rho0: 0.025, threads: 0, seed: 0x11e }
    }
}

impl LineConfig {
    fn total_samples(&self, n: usize) -> u64 {
        (self.samples_per_vertex as u64) * (n as u64)
    }
}

/// Trained LINE model.
pub struct Line {
    /// Vertex embeddings, `n × dim`.
    pub embedding: Matrix,
}

/// Shared mutable embedding for Hogwild updates.
///
/// Safety: Hogwild (Recht et al., NIPS 2011) performs unsynchronized
/// concurrent writes on purpose; on sparse graphs conflicting updates
/// are rare and convergence is unaffected. All access stays in-bounds;
/// racing writes can only produce stale/torn *values*, never UB beyond
/// the data race itself, which we accept exactly as the paper does.
pub(crate) struct SharedParams {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: `ptr`/`len` come from an exclusive borrow that outlives every
// worker (`spawn_workers` joins before `train_line` returns), so the
// pointer stays valid for the whole Hogwild phase; cross-thread aliasing
// through it is the documented tradeoff above.
unsafe impl Sync for SharedParams {}
// SAFETY: same argument as `Sync` — the buffer outlives all workers.
unsafe impl Send for SharedParams {}

impl SharedParams {
    pub(crate) fn new(buf: &mut [f32]) -> Self {
        SharedParams { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// Mutable slice for vertex `v`'s `dim` parameters.
    ///
    /// # Safety
    /// Caller must keep `v*dim + dim <= len`. Concurrent calls may alias
    /// (Hogwild semantics).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn row(&self, v: usize, dim: usize) -> &mut [f32] {
        debug_assert!((v + 1) * dim <= self.len);
        // SAFETY: the caller contract keeps `v*dim + dim <= len`, so the
        // range is in-bounds of the buffer `ptr` was derived from; the
        // aliasing `&mut` is the accepted Hogwild exception (type docs).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(v * dim), dim) }
    }
}

/// Train first-order LINE on an undirected edge list with weights.
///
/// `edges` are (src, dst, weight); both directions are sampled.
pub fn train_line(n: usize, edges: &[(u32, u32, f32)], cfg: &LineConfig) -> Line {
    assert!(n > 0 && !edges.is_empty());
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };

    // Edge alias table over weights (each undirected edge sampled in both
    // directions with equal probability, handled by a coin flip).
    let weights: Vec<f64> = edges.iter().map(|&(_, _, w)| w as f64).collect();
    let edge_table = AliasTable::new(&weights);

    // Negative table over deg^0.75.
    let mut deg = vec![0f64; n];
    for &(a, b, w) in edges {
        deg[a as usize] += w as f64;
        deg[b as usize] += w as f64;
    }
    let neg_weights: Vec<f64> = deg.iter().map(|&d| d.max(1e-12).powf(0.75)).collect();
    let neg_table = AliasTable::new(&neg_weights);

    // Init embeddings small-uniform like the reference implementation.
    let mut emb = Matrix::zeros(n, cfg.dim);
    {
        let mut rng = Rng::new(cfg.seed);
        for x in emb.as_mut_slice().iter_mut() {
            *x = (rng.f32() - 0.5) / cfg.dim as f32;
        }
    }

    let total = cfg.total_samples(n);
    let shared = SharedParams::new(emb.as_mut_slice());
    let progress = std::sync::atomic::AtomicU64::new(0);
    let dim = cfg.dim;
    let rho0 = cfg.rho0;
    let negatives = cfg.negatives;
    let base_rng = Rng::new(cfg.seed ^ 0x5eed);

    pool::spawn_workers(threads, |tid| {
        let mut rng = base_rng.split(tid as u64);
        let my_samples = total / threads as u64 + 1;
        let mut grad_j = vec![0f32; dim];
        for s in 0..my_samples {
            // Learning-rate schedule ρ_t = ρ0 (1 - t/T), floored.
            if s % 1024 == 0 {
                // ordering: Relaxed — `progress` only drives the
                // statistical learning-rate decay; it publishes no
                // memory and tolerates arbitrary skew.
                progress.fetch_add(1024, std::sync::atomic::Ordering::Relaxed);
            }
            // ordering: Relaxed — see the fetch_add above.
            let t = progress.load(std::sync::atomic::Ordering::Relaxed).min(total);
            let rho = (rho0 * (1.0 - t as f32 / total as f32)).max(rho0 * 1e-4);

            let e = edge_table.sample(&mut rng);
            let (mut i, mut j) = (edges[e].0 as usize, edges[e].1 as usize);
            if rng.f32() < 0.5 {
                std::mem::swap(&mut i, &mut j);
            }
            // SAFETY: i, j, negatives all < n; rows length dim.
            let vi = unsafe { shared.row(i, dim) };
            grad_j.iter_mut().for_each(|g| *g = 0.0);
            // Positive + M negatives, sigmoid objective.
            for m in 0..=negatives {
                let (target, label) = if m == 0 {
                    (j, 1.0f32)
                } else {
                    let neg = neg_table.sample(&mut rng);
                    if neg == i || neg == j {
                        continue;
                    }
                    (neg, 0.0f32)
                };
                // SAFETY: `target` is j or a negative draw, both < n;
                // row length is dim, so the range stays in-bounds.
                let vt = unsafe { shared.row(target, dim) };
                let score: f32 = vi.iter().zip(vt.iter()).map(|(a, b)| a * b).sum();
                let sig = 1.0 / (1.0 + (-score).exp());
                let g = (label - sig) * rho;
                for k in 0..dim {
                    grad_j[k] += g * vt[k];
                    vt[k] += g * vi[k];
                }
            }
            for k in 0..dim {
                vi[k] += grad_j[k];
            }
        }
    });

    Line { embedding: emb }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::dot;
    use crate::data::synth::sbm;

    fn mean_cos(emb: &Matrix, pairs: &[(usize, usize)]) -> f64 {
        let mut s = 0.0;
        for &(a, b) in pairs {
            let (ra, rb) = (emb.row(a), emb.row(b));
            let na = dot(ra, ra).sqrt().max(1e-9);
            let nb = dot(rb, rb).sqrt().max(1e-9);
            s += (dot(ra, rb) / na / nb) as f64;
        }
        s / pairs.len() as f64
    }

    #[test]
    fn line_separates_sbm_communities() {
        let g = sbm(600, 3, 12.0, 1.0, 42);
        let edges: Vec<(u32, u32, f32)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
        let cfg = LineConfig { dim: 16, samples_per_vertex: 2000, threads: 4, ..Default::default() };
        let line = train_line(g.n, &edges, &cfg);

        let mut rng = Rng::new(7);
        let mut within = vec![];
        let mut across = vec![];
        while within.len() < 300 || across.len() < 300 {
            let a = rng.below(g.n);
            let b = rng.below(g.n);
            if a == b {
                continue;
            }
            if g.communities[a] == g.communities[b] {
                if within.len() < 300 {
                    within.push((a, b));
                }
            } else if across.len() < 300 {
                across.push((a, b));
            }
        }
        let cw = mean_cos(&line.embedding, &within);
        let ca = mean_cos(&line.embedding, &across);
        assert!(cw > ca + 0.1, "within-cos={cw:.3} across-cos={ca:.3}");
    }

    #[test]
    fn deterministic_single_thread() {
        let g = sbm(100, 2, 8.0, 1.0, 1);
        let edges: Vec<(u32, u32, f32)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
        let cfg =
            LineConfig { dim: 8, samples_per_vertex: 100, threads: 1, seed: 3, ..Default::default() };
        let a = train_line(g.n, &edges, &cfg);
        let b = train_line(g.n, &edges, &cfg);
        assert_eq!(a.embedding, b.embedding);
    }

    #[test]
    fn embedding_finite() {
        let g = sbm(200, 4, 6.0, 2.0, 9);
        let edges: Vec<(u32, u32, f32)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
        let cfg = LineConfig { dim: 4, samples_per_vertex: 500, threads: 2, ..Default::default() };
        let line = train_line(g.n, &edges, &cfg);
        assert!(line.embedding.as_slice().iter().all(|x| x.is_finite()));
    }
}
