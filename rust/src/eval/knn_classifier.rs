//! KNN-classifier accuracy on low-dimensional layouts (paper §4.3
//! "Evaluation", used by Figs 5–7).
//!
//! For sampled query points, classify by majority vote of the K nearest
//! *other* points in the 2D layout and compare with the true label.
//! The neighbor scan runs through [`exact_knn_for`], i.e. the batched
//! SIMD distance kernels in [`crate::kernels`].

use crate::data::matrix::Matrix;
use crate::knn::bruteforce::exact_knn_for;
use crate::util::rng::Rng;

/// Evaluation parameters.
#[derive(Clone, Debug)]
pub struct KnnEvalConfig {
    /// Neighbors for the classifier vote (paper tries several).
    pub k: usize,
    /// Number of query points sampled (caps O(N²) cost on big layouts).
    pub sample: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed for the query sample.
    pub seed: u64,
}

impl Default for KnnEvalConfig {
    fn default() -> Self {
        KnnEvalConfig { k: 5, sample: 5000, threads: 0, seed: 0xe7a1 }
    }
}

/// Classification accuracy of a KNN vote over the layout coordinates.
pub fn knn_accuracy(layout: &Matrix, labels: &[u32], cfg: &KnnEvalConfig) -> f64 {
    assert_eq!(layout.n(), labels.len());
    let n = layout.n();
    if n < 2 {
        return 1.0;
    }
    let mut rng = Rng::new(cfg.seed);
    let queries = rng.sample_indices(n, cfg.sample.min(n));
    let neighbors = exact_knn_for(layout, &queries, cfg.k, cfg.threads);
    let mut correct = 0usize;
    for (row, &q) in neighbors.iter().zip(&queries) {
        // Majority vote (ties broken by the nearest member of the tie).
        let mut votes: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
        for &(id, _) in row {
            *votes.entry(labels[id as usize]).or_insert(0) += 1;
        }
        let best = votes.iter().max_by_key(|&(_, &c)| c).map(|(&l, &c)| (l, c));
        if let Some((label, count)) = best {
            let tied: Vec<u32> =
                votes.iter().filter(|&(_, &c)| c == count).map(|(&l, _)| l).collect();
            let winner = if tied.len() == 1 {
                label
            } else {
                // Nearest neighbor whose label is among the tied ones.
                row.iter()
                    .map(|&(id, _)| labels[id as usize])
                    .find(|l| tied.contains(l))
                    .unwrap_or(label)
            };
            if winner == labels[q] {
                correct += 1;
            }
        }
    }
    correct as f64 / queries.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two well-separated blobs in 2D.
    fn blobs(n: usize) -> (Matrix, Vec<u32>) {
        let mut rng = Rng::new(9);
        let mut m = Matrix::zeros(n, 2);
        let mut labels = vec![0u32; n];
        for i in 0..n {
            let c = i % 2;
            labels[i] = c as u32;
            let cx = if c == 0 { -5.0 } else { 5.0 };
            m.row_mut(i)[0] = cx + rng.gaussian();
            m.row_mut(i)[1] = rng.gaussian();
        }
        (m, labels)
    }

    #[test]
    fn separated_blobs_score_high() {
        let (m, l) = blobs(400);
        let acc = knn_accuracy(&m, &l, &KnnEvalConfig::default());
        assert!(acc > 0.97, "accuracy {acc}");
    }

    #[test]
    fn random_labels_score_chance() {
        let (m, _) = blobs(400);
        let mut rng = Rng::new(4);
        let labels: Vec<u32> = (0..400).map(|_| rng.below(4) as u32).collect();
        let acc = knn_accuracy(&m, &labels, &KnnEvalConfig { k: 9, ..Default::default() });
        assert!(acc < 0.45, "accuracy {acc} should be near chance 0.25");
    }

    #[test]
    fn sampling_cap_respected() {
        let (m, l) = blobs(1000);
        let acc =
            knn_accuracy(&m, &l, &KnnEvalConfig { sample: 50, ..Default::default() });
        assert!(acc > 0.9);
    }
}
