//! Additional layout-quality metrics beyond the paper's classifier
//! accuracy: K-ary neighborhood preservation (fraction of
//! high-dimensional KNN retained among low-dimensional KNN).

use crate::data::matrix::Matrix;
use crate::knn::bruteforce::exact_knn_for;
use crate::util::rng::Rng;

/// Mean fraction of each sampled point's high-dimensional K nearest
/// neighbors that remain within its low-dimensional K nearest neighbors.
pub fn neighborhood_preservation(
    high: &Matrix,
    low: &Matrix,
    k: usize,
    sample: usize,
    seed: u64,
    threads: usize,
) -> f64 {
    assert_eq!(high.n(), low.n());
    let n = high.n();
    if n < 2 {
        return 1.0;
    }
    let mut rng = Rng::new(seed);
    let queries = rng.sample_indices(n, sample.min(n));
    let hi = exact_knn_for(high, &queries, k, threads);
    let lo = exact_knn_for(low, &queries, k, threads);
    let mut score = 0.0;
    for (h, l) in hi.iter().zip(&lo) {
        let hs: std::collections::HashSet<u32> = h.iter().map(|&(id, _)| id).collect();
        let kept = l.iter().filter(|&&(id, _)| hs.contains(&id)).count();
        score += kept as f64 / hs.len().max(1) as f64;
    }
    score / queries.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_embedding_is_perfect() {
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..200).map(|_| rng.gaussian()).collect();
        let m = Matrix::from_vec(data, 100, 2);
        let s = neighborhood_preservation(&m, &m, 5, 100, 2, 2);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shuffled_embedding_scores_low() {
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..400).map(|_| rng.gaussian()).collect();
        let high = Matrix::from_vec(data.clone(), 200, 2);
        let mut perm: Vec<usize> = (0..200).collect();
        rng.shuffle(&mut perm);
        let low = high.gather_rows(&perm);
        let s = neighborhood_preservation(&high, &low, 5, 200, 4, 2);
        assert!(s < 0.2, "shuffled preservation {s}");
    }
}
