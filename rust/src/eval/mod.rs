//! Quantitative evaluation of layouts.
//!
//! The paper evaluates visualizations by KNN classification accuracy on
//! the 2D coordinates (borrowed from the t-SNE paper): a layout that
//! preserves structure lets a KNN classifier recover the original
//! labels. [`knn_classifier`] implements that metric; [`metrics`] adds
//! a neighborhood-preservation score used by our extended tests.

pub mod knn_classifier;
pub mod metrics;
pub mod kmeans;

pub use kmeans::{kmeans, KMeansConfig};
pub use knn_classifier::{knn_accuracy, KnnEvalConfig};
pub use metrics::neighborhood_preservation;
