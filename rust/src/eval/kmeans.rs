//! K-means (Lloyd's algorithm with k-means++ seeding) — the paper
//! colors its unlabeled figures (WikiWord, CSAuthor; Figs 8–9) by
//! K-means clusters of the *high-dimensional* representations (200
//! clusters). Parallel over points; deterministic under a seed.

use crate::data::matrix::Matrix;
use crate::kernels::{self, sqdist};
use crate::util::pool;
use crate::util::rng::Rng;

/// K-means configuration.
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Max Lloyd iterations.
    pub max_iters: usize,
    /// Stop when fewer than `tol_frac * n` points change cluster.
    pub tol_frac: f64,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Seed for k-means++ init.
    pub seed: u64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig { k: 200, max_iters: 30, tol_frac: 0.001, threads: 0, seed: 0x7e11 }
    }
}

/// K-means result.
pub struct KMeans {
    /// Cluster assignment per point.
    pub assignment: Vec<u32>,
    /// Cluster centroids, `k × d`.
    pub centroids: Matrix,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
    /// Lloyd iterations executed.
    pub iters: usize,
}

/// Run K-means on `data`.
pub fn kmeans(data: &Matrix, cfg: &KMeansConfig) -> KMeans {
    let n = data.n();
    let d = data.d();
    let k = cfg.k.min(n).max(1);
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let mut rng = Rng::new(cfg.seed);

    // k-means++ seeding: first centroid uniform, then ∝ D².
    let mut centroids = Matrix::zeros(k, d);
    centroids.row_mut(0).copy_from_slice(data.row(rng.below(n)));
    let mut d2 = vec![0f64; n];
    for c in 1..k {
        let total: f64 = {
            let prev = centroids.row(c - 1).to_vec();
            let updates = pool::parallel_map(n, threads, |i| {
                let dist = sqdist(data.row(i), &prev) as f64;
                if c == 1 {
                    dist
                } else {
                    dist.min(d2[i])
                }
            });
            d2.copy_from_slice(&updates);
            d2.iter().sum()
        };
        // Sample ∝ d2.
        let mut target = rng.f64() * total.max(1e-300);
        let mut pick = n - 1;
        for (i, &w) in d2.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.row_mut(c).copy_from_slice(data.row(pick));
    }

    // Lloyd iterations.
    let mut assignment = vec![0u32; n];
    let mut inertia = f64::INFINITY;
    let mut iters = 0;
    for iter in 0..cfg.max_iters {
        iters = iter + 1;
        // Assign: every point against the contiguous centroid matrix in
        // one batched SIMD pass (ties keep the lowest cluster id, as
        // the sequential scan did).
        let new_assign: Vec<(u32, f64)> = pool::parallel_map_with(
            n,
            threads,
            |_worker| Vec::<f32>::new(),
            |dist, i| {
                kernels::sqdist_to_all(data.row(i), &centroids, dist);
                let mut best = (0u32, f64::INFINITY);
                for (c, &d) in dist.iter().enumerate() {
                    if (d as f64) < best.1 {
                        best = (c as u32, d as f64);
                    }
                }
                best
            },
        );
        let changed = new_assign
            .iter()
            .zip(&assignment)
            .filter(|((c, _), old)| c != *old)
            .count();
        inertia = new_assign.iter().map(|&(_, d)| d).sum();
        for (slot, &(c, _)) in assignment.iter_mut().zip(&new_assign) {
            *slot = c;
        }
        // Update.
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0usize; k];
        for (i, &c) in assignment.iter().enumerate() {
            counts[c as usize] += 1;
            let row = data.row(i);
            for (s, &x) in sums[c as usize * d..(c as usize + 1) * d].iter_mut().zip(row) {
                *s += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed an empty cluster at a random point.
                centroids.row_mut(c).copy_from_slice(data.row(rng.below(n)));
                continue;
            }
            let crow = centroids.row_mut(c);
            for (slot, &s) in crow.iter_mut().zip(&sums[c * d..(c + 1) * d]) {
                *slot = (s / counts[c] as f64) as f32;
            }
        }
        if (changed as f64) < cfg.tol_frac * n as f64 {
            break;
        }
    }
    KMeans { assignment, centroids, inertia, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;

    #[test]
    fn recovers_separated_clusters() {
        let (m, labels) = gaussian_mixture(600, 10, 4, 0.0, 3);
        let km = kmeans(&m, &KMeansConfig { k: 4, threads: 2, ..Default::default() });
        // Purity: majority true-label share per cluster should be high.
        let mut purity = 0usize;
        for c in 0..4u32 {
            let members: Vec<usize> =
                (0..600).filter(|&i| km.assignment[i] == c).collect();
            if members.is_empty() {
                continue;
            }
            let mut counts = [0usize; 4];
            for &i in &members {
                counts[labels[i] as usize] += 1;
            }
            purity += counts.iter().max().unwrap();
        }
        let score = purity as f64 / 600.0;
        assert!(score > 0.95, "purity {score}");
    }

    #[test]
    fn inertia_decreases_with_k() {
        let (m, _) = gaussian_mixture(300, 8, 3, 0.3, 5);
        let i2 = kmeans(&m, &KMeansConfig { k: 2, threads: 1, ..Default::default() }).inertia;
        let i8 = kmeans(&m, &KMeansConfig { k: 8, threads: 1, ..Default::default() }).inertia;
        assert!(i8 < i2, "inertia k=8 {i8} !< k=2 {i2}");
    }

    #[test]
    fn deterministic_under_seed() {
        let (m, _) = gaussian_mixture(200, 6, 3, 0.2, 7);
        let a = kmeans(&m, &KMeansConfig { k: 5, threads: 1, seed: 9, ..Default::default() });
        let b = kmeans(&m, &KMeansConfig { k: 5, threads: 1, seed: 9, ..Default::default() });
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn k_greater_than_n_clamped() {
        let (m, _) = gaussian_mixture(10, 4, 2, 0.2, 8);
        let km = kmeans(&m, &KMeansConfig { k: 50, threads: 1, ..Default::default() });
        assert!(km.assignment.iter().all(|&c| (c as usize) < 10));
    }
}
