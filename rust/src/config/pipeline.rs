//! Typed end-to-end pipeline configuration, assembled from an INI file
//! and/or CLI overrides.

use crate::config::ini::Ini;
use crate::data::formats::wal::RecoveryPolicy;
use crate::graph::weights::WeightConfig;
use crate::knn::explore::LargeVisKnnConfig;
use crate::knn::rptree::RpForestConfig;
use crate::vis::multilevel::MultilevelConfig;
use crate::vis::{LargeVisConfig, ProbFn};
use anyhow::Result;

/// A pipeline stage boundary — the unit of checkpointing and resume.
///
/// Ordered by execution: `Dataset < Knn < Weights < Layout`. Resuming
/// from stage `S` skips everything before `S` and loads `S`'s inputs
/// from the checkpoint directory (`<out_dir>/checkpoints/`). Only
/// `Weights` and `Layout` are valid resume targets (they are the
/// stages with checkpointed inputs); the coordinator rejects the
/// other two rather than silently recomputing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Dataset ingestion/generation (a full run).
    Dataset,
    /// KNN graph construction.
    Knn,
    /// Perplexity weights + symmetrization (loads the KNN checkpoint).
    Weights,
    /// SGD layout (loads the weighted-graph checkpoint).
    Layout,
}

impl std::str::FromStr for Stage {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "dataset" => Ok(Stage::Dataset),
            "knn" => Ok(Stage::Knn),
            "weights" => Ok(Stage::Weights),
            "layout" => Ok(Stage::Layout),
            other => anyhow::bail!(
                "unknown stage {other:?} (expected dataset|knn|weights|layout)"
            ),
        }
    }
}

/// Layout-stage mode: the paper's flat single-resolution SGD, or the
/// multilevel coarse-to-fine engine (the default — equal-or-better
/// quality in a fraction of the fine-level gradient samples).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayoutMode {
    /// Single-resolution Hogwild SGD on the input graph.
    Flat,
    /// Coarsen → lay out coarsest → prolongate → refine per level.
    Multilevel,
}

impl std::str::FromStr for LayoutMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "flat" => Ok(LayoutMode::Flat),
            "multilevel" | "ml" => Ok(LayoutMode::Multilevel),
            other => anyhow::bail!("unknown layout mode {other:?} (expected flat|multilevel)"),
        }
    }
}

/// Everything the coordinator needs for one run.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Registry dataset name.
    pub dataset: String,
    /// Scale factor applied to the dataset's full N.
    pub scale: f64,
    /// KNN graph: K neighbors (paper: 150).
    pub k: usize,
    /// KNN construction config.
    pub knn: LargeVisKnnConfig,
    /// Edge weighting (perplexity).
    pub weights: WeightConfig,
    /// Layout engine config.
    pub vis: LargeVisConfig,
    /// Layout-stage mode (flat vs multilevel coarse-to-fine).
    pub layout_mode: LayoutMode,
    /// Multilevel schedule knobs (levels, coarsening floor, budget
    /// split, prolongation jitter) — used when `layout_mode` is
    /// [`LayoutMode::Multilevel`].
    pub multilevel: MultilevelConfig,
    /// Use the AOT/XLA batched optimizer instead of Hogwild.
    pub use_xla: bool,
    /// Output directory for layout/SVG/report.
    pub out_dir: std::path::PathBuf,
    /// Seed for dataset generation.
    pub data_seed: u64,
    /// Input points file (LargeVis text or `.lvec` binary). When set it
    /// replaces synthetic generation; `dataset`/`scale` are ignored.
    pub input: Option<std::path::PathBuf>,
    /// Optional `.lbl` label file accompanying `input`.
    pub input_labels: Option<std::path::PathBuf>,
    /// Resume from this stage, loading earlier stages' checkpoints
    /// from `<out_dir>/checkpoints/`. `None` = full run.
    pub resume_from: Option<Stage>,
    /// Write stage checkpoints (KNN graph, weighted graph, labels) into
    /// `<out_dir>/checkpoints/` so later runs can `resume_from`.
    pub save_checkpoints: bool,
    /// Rows per chunk for the streaming dataset readers (bounds parse
    /// memory; 0 = format default).
    pub chunk_rows: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            dataset: "20ng-like".to_string(),
            scale: 1.0,
            k: 150,
            knn: LargeVisKnnConfig::default(),
            weights: WeightConfig::default(),
            vis: LargeVisConfig::default(),
            layout_mode: LayoutMode::Multilevel,
            multilevel: MultilevelConfig::default(),
            use_xla: false,
            out_dir: std::path::PathBuf::from("target/run"),
            data_seed: 0xda7a,
            input: None,
            input_labels: None,
            resume_from: None,
            save_checkpoints: true,
            chunk_rows: 0,
        }
    }
}

/// Configuration for the live layout query server (`largevis serve`).
///
/// How the server answers nearest-neighbor lookups (`/knn`, and the
/// base-neighbor search behind `/embed` and `/insert`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchMode {
    /// Full scan over every base point (`kernels::nearest_k`) — exact,
    /// O(n) per query.
    Exact,
    /// Greedy best-first beam search over the checkpointed KNN graph
    /// (`knn::search`) — sub-linear, with automatic exact fallback
    /// when the walk cannot produce `k` results within budget.
    #[default]
    Graph,
}

impl std::str::FromStr for SearchMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "exact" => Ok(SearchMode::Exact),
            "graph" => Ok(SearchMode::Graph),
            other => Err(format!("unknown search mode {other:?} (expected exact|graph)")),
        }
    }
}

impl std::fmt::Display for SearchMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SearchMode::Exact => write!(f, "exact"),
            SearchMode::Graph => write!(f, "graph"),
        }
    }
}

/// The server loads the checkpoint artifacts (`data.lvec`, `knn.ckpt`,
/// `graph.ckpt`, `layout.lvec`, `labels.lbl`) once at startup, replays
/// the live-insert WAL (`inserts.wal`), and then answers `/embed`,
/// `/knn`, `/insert`, `/insert_batch`, `/viewport`, `/healthz` and
/// `/metrics` from epoch-versioned in-memory snapshots. INI keys live
/// in a `[serve]` section; CLI flags override them.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Checkpoint directory of a finished pipeline run
    /// (`<out_dir>/checkpoints`).
    pub checkpoints: std::path::PathBuf,
    /// Listen address, `host:port` (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads accepting connections (0 = auto).
    pub threads: usize,
    /// Localized-SGD refinement steps per `/embed` point.
    pub embed_samples: usize,
    /// Neighbors per `/embed` point (0 = the checkpointed graph's k).
    pub embed_k: usize,
    /// Spatial-index cells per axis for `/viewport` culling.
    pub grid: usize,
    /// Max points rendered per `/viewport` tile (deterministic
    /// subsample beyond this).
    pub tile_max_points: usize,
    /// Maximum accepted request-body size in bytes.
    pub max_body_bytes: usize,
    /// Refuse `/insert` (403) and skip the WAL entirely.
    pub read_only: bool,
    /// Localized-SGD steps per point inside the `/insert` request
    /// (placement quality vs insert latency; the background refinement
    /// worker adds more afterwards).
    pub insert_samples: usize,
    /// Background refinement: SGD steps per recently-inserted point
    /// per pass (0 disables refinement).
    pub refine_samples: usize,
    /// Background refinement: periodic wake interval in milliseconds
    /// (the worker is also woken by every insert).
    pub refine_interval_ms: u64,
    /// Requests served per connection before the server closes it
    /// (bounds how long one client can pin a worker).
    pub keep_alive_max: usize,
    /// Keep-alive idle timeout in milliseconds: a connection with no
    /// next request within this window is closed.
    pub idle_timeout_ms: u64,
    /// Maximum connections admitted concurrently; arrivals beyond this
    /// are shed with `503` + `Retry-After` (0 = auto: `2×threads + 8`).
    pub max_inflight: usize,
    /// Per-connection socket write timeout in milliseconds — a stalled
    /// client cannot pin a worker forever.
    pub write_timeout_ms: u64,
    /// Rotate the active WAL segment once it exceeds this many bytes
    /// (bounds replay work after a crash).
    pub wal_segment_bytes: u64,
    /// Compact sealed WAL segments into the checkpoints once this many
    /// have accumulated.
    pub wal_max_segments: usize,
    /// What to do when WAL replay hits a corrupt record: fail fast
    /// (default) or truncate to the clean prefix, quarantine the rest,
    /// and count it in `/metrics`.
    pub recovery_policy: RecoveryPolicy,
    /// Nearest-neighbor query strategy: `graph` (default, beam search
    /// over the KNN graph) or `exact` (full scan).
    pub search: SearchMode,
    /// Beam width (`ef`) for graph search — candidate pool size; the
    /// effective width is `max(beam_width, k)` per query.
    pub beam_width: usize,
    /// Entry points kept for graph search (coarse-level centroids, or
    /// grid/stride fallbacks when the hierarchy is degenerate).
    pub search_seeds: usize,
    /// Test hook: expose `GET /__panic` (panics in the handler) so the
    /// per-connection panic containment can be exercised. Never set
    /// from INI/CLI.
    pub debug_panic: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            checkpoints: std::path::PathBuf::from("target/run/checkpoints"),
            addr: "127.0.0.1:7878".to_string(),
            threads: 0,
            embed_samples: 500,
            embed_k: 0,
            grid: 64,
            tile_max_points: 20_000,
            max_body_bytes: 64 << 20,
            read_only: false,
            insert_samples: 500,
            refine_samples: 200,
            refine_interval_ms: 250,
            keep_alive_max: 1000,
            idle_timeout_ms: 5000,
            max_inflight: 0,
            write_timeout_ms: 10_000,
            wal_segment_bytes: 64 << 20,
            wal_max_segments: 4,
            recovery_policy: RecoveryPolicy::FailFast,
            search: SearchMode::Graph,
            beam_width: 64,
            search_seeds: 32,
            debug_panic: false,
        }
    }
}

impl ServeConfig {
    /// Build from an INI document's `[serve]` section (missing keys
    /// keep defaults).
    pub fn from_ini(ini: &Ini) -> Result<Self> {
        let mut cfg = ServeConfig::default();
        if let Some(dir) = ini.get("serve", "checkpoints") {
            cfg.checkpoints = dir.into();
        }
        if let Some(addr) = ini.get("serve", "addr") {
            cfg.addr = addr.to_string();
        }
        cfg.threads = ini.get_or("serve", "threads", cfg.threads)?;
        cfg.embed_samples = ini.get_or("serve", "embed_samples", cfg.embed_samples)?;
        cfg.embed_k = ini.get_or("serve", "embed_k", cfg.embed_k)?;
        cfg.grid = ini.get_or("serve", "grid", cfg.grid)?;
        cfg.tile_max_points = ini.get_or("serve", "tile_max_points", cfg.tile_max_points)?;
        cfg.max_body_bytes = ini.get_or("serve", "max_body_bytes", cfg.max_body_bytes)?;
        cfg.read_only = ini.get_bool_or("serve", "read_only", cfg.read_only)?;
        cfg.insert_samples = ini.get_or("serve", "insert_samples", cfg.insert_samples)?;
        cfg.refine_samples = ini.get_or("serve", "refine_samples", cfg.refine_samples)?;
        cfg.refine_interval_ms =
            ini.get_or("serve", "refine_interval_ms", cfg.refine_interval_ms)?;
        cfg.keep_alive_max = ini.get_or("serve", "keep_alive_max", cfg.keep_alive_max)?;
        cfg.idle_timeout_ms = ini.get_or("serve", "idle_timeout_ms", cfg.idle_timeout_ms)?;
        cfg.max_inflight = ini.get_or("serve", "max_inflight", cfg.max_inflight)?;
        cfg.write_timeout_ms = ini.get_or("serve", "write_timeout_ms", cfg.write_timeout_ms)?;
        cfg.wal_segment_bytes =
            ini.get_or("serve", "wal_segment_bytes", cfg.wal_segment_bytes)?;
        cfg.wal_max_segments =
            ini.get_or("serve", "wal_max_segments", cfg.wal_max_segments)?;
        cfg.recovery_policy =
            ini.get_or("serve", "recovery_policy", cfg.recovery_policy)?;
        cfg.search = ini.get_or("serve", "search", cfg.search)?;
        cfg.beam_width = ini.get_or("serve", "beam_width", cfg.beam_width)?;
        cfg.search_seeds = ini.get_or("serve", "search_seeds", cfg.search_seeds)?;
        Ok(cfg)
    }
}

impl PipelineConfig {
    /// Build from an INI document (missing keys keep defaults).
    pub fn from_ini(ini: &Ini) -> Result<Self> {
        let mut cfg = PipelineConfig::default();
        cfg.dataset = ini.get("", "dataset").unwrap_or(&cfg.dataset).to_string();
        cfg.scale = ini.get_or("", "scale", cfg.scale)?;
        cfg.data_seed = ini.get_or("", "seed", cfg.data_seed)?;
        if let Some(dir) = ini.get("", "out_dir") {
            cfg.out_dir = dir.into();
        }
        if let Some(path) = ini.get("", "input") {
            cfg.input = Some(path.into());
        }
        if let Some(path) = ini.get("", "labels") {
            cfg.input_labels = Some(path.into());
        }
        if let Some(stage) = ini.get("", "resume_from") {
            cfg.resume_from = Some(stage.parse()?);
        }
        cfg.save_checkpoints = ini.get_bool_or("", "checkpoints", cfg.save_checkpoints)?;
        cfg.chunk_rows = ini.get_or("", "chunk_rows", cfg.chunk_rows)?;

        cfg.k = ini.get_or("knn", "k", cfg.k)?;
        cfg.knn.forest = RpForestConfig {
            n_trees: ini.get_or("knn", "trees", cfg.knn.forest.n_trees)?,
            leaf_size: ini.get_or("knn", "leaf_size", cfg.knn.forest.leaf_size)?,
            search_leaves: ini.get_or("knn", "search_leaves", cfg.knn.forest.search_leaves)?,
            threads: ini.get_or("knn", "threads", 0)?,
            seed: ini.get_or("knn", "seed", cfg.knn.forest.seed)?,
        };
        cfg.knn.iters = ini.get_or("knn", "explore_iters", cfg.knn.iters)?;
        cfg.knn.threads = ini.get_or("knn", "threads", 0)?;

        cfg.weights.perplexity = ini.get_or("weights", "perplexity", cfg.weights.perplexity)?;

        cfg.vis.dim = ini.get_or("vis", "dim", cfg.vis.dim)?;
        cfg.vis.samples_per_vertex =
            ini.get_or("vis", "samples_per_vertex", cfg.vis.samples_per_vertex)?;
        cfg.vis.negatives = ini.get_or("vis", "negatives", cfg.vis.negatives)?;
        cfg.vis.gamma = ini.get_or("vis", "gamma", cfg.vis.gamma)?;
        cfg.vis.rho0 = ini.get_or("vis", "rho0", cfg.vis.rho0)?;
        cfg.vis.threads = ini.get_or("vis", "threads", 0)?;
        cfg.vis.seed = ini.get_or("vis", "seed", cfg.vis.seed)?;
        let a = ini.get_or("vis", "prob_a", 1.0f32)?;
        cfg.vis.prob_fn = match ini.get("vis", "prob_fn").unwrap_or("invquad") {
            "invquad" => ProbFn::InvQuad { a },
            "sigmoid" => ProbFn::SigmoidSq,
            other => anyhow::bail!("[vis] prob_fn: unknown function {other:?}"),
        };
        cfg.use_xla = ini.get_bool_or("vis", "use_xla", cfg.use_xla)?;
        if let Some(mode) = ini.get("vis", "layout") {
            cfg.layout_mode = mode.parse()?;
        }

        cfg.multilevel.coarsen.max_levels =
            ini.get_or("multilevel", "levels", cfg.multilevel.coarsen.max_levels)?;
        cfg.multilevel.coarsen.min_coarse_size =
            ini.get_or("multilevel", "min_coarse_size", cfg.multilevel.coarsen.min_coarse_size)?;
        cfg.multilevel.coarse_samples_multiplier = ini.get_or(
            "multilevel",
            "coarse_samples",
            cfg.multilevel.coarse_samples_multiplier,
        )?;
        cfg.multilevel.jitter = ini.get_or("multilevel", "jitter", cfg.multilevel.jitter)?;
        cfg.multilevel.level_rho_decay =
            ini.get_or("multilevel", "rho_decay", cfg.multilevel.level_rho_decay)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = PipelineConfig::default();
        assert_eq!(c.k, 150);
        assert_eq!(c.weights.perplexity, 50.0);
        assert_eq!(c.vis.negatives, 5);
        assert_eq!(c.vis.gamma, 7.0);
        assert_eq!(c.vis.rho0, 1.0);
        assert_eq!(c.vis.prob_fn, ProbFn::InvQuad { a: 1.0 });
    }

    #[test]
    fn ini_overrides() {
        let ini = Ini::parse(
            "dataset = mnist-like\nscale = 0.5\n[knn]\nk = 30\ntrees = 2\n[vis]\nprob_fn = sigmoid\ngamma = 3.5",
        )
        .unwrap();
        let c = PipelineConfig::from_ini(&ini).unwrap();
        assert_eq!(c.dataset, "mnist-like");
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.k, 30);
        assert_eq!(c.knn.forest.n_trees, 2);
        assert_eq!(c.vis.prob_fn, ProbFn::SigmoidSq);
        assert_eq!(c.vis.gamma, 3.5);
    }

    #[test]
    fn bad_prob_fn_rejected() {
        let ini = Ini::parse("[vis]\nprob_fn = cosine").unwrap();
        assert!(PipelineConfig::from_ini(&ini).is_err());
    }

    #[test]
    fn ingestion_and_resume_keys() {
        let ini = Ini::parse(
            "input = data/points.lvec\nlabels = data/points.lbl\nresume_from = weights\ncheckpoints = no\nchunk_rows = 4096",
        )
        .unwrap();
        let c = PipelineConfig::from_ini(&ini).unwrap();
        assert_eq!(c.input.as_deref(), Some(std::path::Path::new("data/points.lvec")));
        assert_eq!(c.input_labels.as_deref(), Some(std::path::Path::new("data/points.lbl")));
        assert_eq!(c.resume_from, Some(Stage::Weights));
        assert!(!c.save_checkpoints);
        assert_eq!(c.chunk_rows, 4096);
    }

    #[test]
    fn layout_mode_and_multilevel_keys() {
        let c = PipelineConfig::default();
        assert_eq!(c.layout_mode, LayoutMode::Multilevel);
        let ini = Ini::parse(
            "[vis]\nlayout = flat\n[multilevel]\nlevels = 5\nmin_coarse_size = 2000\ncoarse_samples = 2.5\njitter = 0.1\nrho_decay = 0.9",
        )
        .unwrap();
        let c = PipelineConfig::from_ini(&ini).unwrap();
        assert_eq!(c.layout_mode, LayoutMode::Flat);
        assert_eq!(c.multilevel.coarsen.max_levels, 5);
        assert_eq!(c.multilevel.coarsen.min_coarse_size, 2000);
        assert_eq!(c.multilevel.coarse_samples_multiplier, 2.5);
        assert_eq!(c.multilevel.jitter, 0.1);
        assert_eq!(c.multilevel.level_rho_decay, 0.9);
        assert_eq!("ml".parse::<LayoutMode>().unwrap(), LayoutMode::Multilevel);
        assert!("pyramid".parse::<LayoutMode>().is_err());
        let bad = Ini::parse("[vis]\nlayout = pyramid").unwrap();
        assert!(PipelineConfig::from_ini(&bad).is_err());
    }

    #[test]
    fn stage_parse_and_order() {
        assert!(Stage::Dataset < Stage::Knn);
        assert!(Stage::Knn < Stage::Weights);
        assert!(Stage::Weights < Stage::Layout);
        assert_eq!("layout".parse::<Stage>().unwrap(), Stage::Layout);
        assert!("nope".parse::<Stage>().is_err());
    }

    #[test]
    fn serve_section_keys() {
        let c = ServeConfig::default();
        assert_eq!(c.addr, "127.0.0.1:7878");
        assert_eq!(c.embed_k, 0);
        assert!(!c.read_only);
        assert!(c.keep_alive_max > 1);
        assert_eq!(c.max_inflight, 0);
        assert_eq!(c.write_timeout_ms, 10_000);
        assert_eq!(c.wal_segment_bytes, 64 << 20);
        assert_eq!(c.wal_max_segments, 4);
        assert_eq!(c.recovery_policy, RecoveryPolicy::FailFast);
        assert_eq!(c.search, SearchMode::Graph);
        assert_eq!(c.beam_width, 64);
        assert_eq!(c.search_seeds, 32);
        assert!(!c.debug_panic);
        let ini = Ini::parse(
            "[serve]\ncheckpoints = target/mnist/checkpoints\naddr = 0.0.0.0:9000\nthreads = 8\nembed_samples = 250\nembed_k = 20\ngrid = 128\ntile_max_points = 5000\nread_only = yes\ninsert_samples = 300\nrefine_samples = 100\nrefine_interval_ms = 500\nkeep_alive_max = 64\nidle_timeout_ms = 2500\nmax_inflight = 32\nwrite_timeout_ms = 1500\nwal_segment_bytes = 1048576\nwal_max_segments = 2\nrecovery_policy = truncate\nsearch = exact\nbeam_width = 96\nsearch_seeds = 48",
        )
        .unwrap();
        let c = ServeConfig::from_ini(&ini).unwrap();
        assert_eq!(
            c.checkpoints,
            std::path::PathBuf::from("target/mnist/checkpoints")
        );
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.threads, 8);
        assert_eq!(c.embed_samples, 250);
        assert_eq!(c.embed_k, 20);
        assert_eq!(c.grid, 128);
        assert_eq!(c.tile_max_points, 5000);
        assert!(c.read_only);
        assert_eq!(c.insert_samples, 300);
        assert_eq!(c.refine_samples, 100);
        assert_eq!(c.refine_interval_ms, 500);
        assert_eq!(c.keep_alive_max, 64);
        assert_eq!(c.idle_timeout_ms, 2500);
        assert_eq!(c.max_inflight, 32);
        assert_eq!(c.write_timeout_ms, 1500);
        assert_eq!(c.wal_segment_bytes, 1_048_576);
        assert_eq!(c.wal_max_segments, 2);
        assert_eq!(c.recovery_policy, RecoveryPolicy::Truncate);
        assert_eq!(c.search, SearchMode::Exact);
        assert_eq!(c.beam_width, 96);
        assert_eq!(c.search_seeds, 48);
        let bad = Ini::parse("[serve]\nrecovery_policy = maybe").unwrap();
        assert!(ServeConfig::from_ini(&bad).is_err());
        let bad = Ini::parse("[serve]\nsearch = maybe").unwrap();
        assert!(ServeConfig::from_ini(&bad).is_err());
        assert_eq!(SearchMode::Graph.to_string(), "graph");
        assert_eq!("EXACT".parse::<SearchMode>().unwrap(), SearchMode::Exact);
    }

    #[test]
    fn bad_resume_stage_rejected() {
        let ini = Ini::parse("resume_from = everything").unwrap();
        assert!(PipelineConfig::from_ini(&ini).is_err());
    }
}
