//! Typed end-to-end pipeline configuration, assembled from an INI file
//! and/or CLI overrides.

use crate::config::ini::Ini;
use crate::graph::weights::WeightConfig;
use crate::knn::explore::LargeVisKnnConfig;
use crate::knn::rptree::RpForestConfig;
use crate::vis::{LargeVisConfig, ProbFn};
use anyhow::Result;

/// Everything the coordinator needs for one run.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Registry dataset name.
    pub dataset: String,
    /// Scale factor applied to the dataset's full N.
    pub scale: f64,
    /// KNN graph: K neighbors (paper: 150).
    pub k: usize,
    /// KNN construction config.
    pub knn: LargeVisKnnConfig,
    /// Edge weighting (perplexity).
    pub weights: WeightConfig,
    /// Layout engine config.
    pub vis: LargeVisConfig,
    /// Use the AOT/XLA batched optimizer instead of Hogwild.
    pub use_xla: bool,
    /// Output directory for layout/SVG/report.
    pub out_dir: std::path::PathBuf,
    /// Seed for dataset generation.
    pub data_seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            dataset: "20ng-like".to_string(),
            scale: 1.0,
            k: 150,
            knn: LargeVisKnnConfig::default(),
            weights: WeightConfig::default(),
            vis: LargeVisConfig::default(),
            use_xla: false,
            out_dir: std::path::PathBuf::from("target/run"),
            data_seed: 0xda7a,
        }
    }
}

impl PipelineConfig {
    /// Build from an INI document (missing keys keep defaults).
    pub fn from_ini(ini: &Ini) -> Result<Self> {
        let mut cfg = PipelineConfig::default();
        cfg.dataset = ini.get("", "dataset").unwrap_or(&cfg.dataset).to_string();
        cfg.scale = ini.get_or("", "scale", cfg.scale)?;
        cfg.data_seed = ini.get_or("", "seed", cfg.data_seed)?;
        if let Some(dir) = ini.get("", "out_dir") {
            cfg.out_dir = dir.into();
        }

        cfg.k = ini.get_or("knn", "k", cfg.k)?;
        cfg.knn.forest = RpForestConfig {
            n_trees: ini.get_or("knn", "trees", cfg.knn.forest.n_trees)?,
            leaf_size: ini.get_or("knn", "leaf_size", cfg.knn.forest.leaf_size)?,
            search_leaves: ini.get_or("knn", "search_leaves", cfg.knn.forest.search_leaves)?,
            threads: ini.get_or("knn", "threads", 0)?,
            seed: ini.get_or("knn", "seed", cfg.knn.forest.seed)?,
        };
        cfg.knn.iters = ini.get_or("knn", "explore_iters", cfg.knn.iters)?;
        cfg.knn.threads = ini.get_or("knn", "threads", 0)?;

        cfg.weights.perplexity = ini.get_or("weights", "perplexity", cfg.weights.perplexity)?;

        cfg.vis.dim = ini.get_or("vis", "dim", cfg.vis.dim)?;
        cfg.vis.samples_per_vertex =
            ini.get_or("vis", "samples_per_vertex", cfg.vis.samples_per_vertex)?;
        cfg.vis.negatives = ini.get_or("vis", "negatives", cfg.vis.negatives)?;
        cfg.vis.gamma = ini.get_or("vis", "gamma", cfg.vis.gamma)?;
        cfg.vis.rho0 = ini.get_or("vis", "rho0", cfg.vis.rho0)?;
        cfg.vis.threads = ini.get_or("vis", "threads", 0)?;
        cfg.vis.seed = ini.get_or("vis", "seed", cfg.vis.seed)?;
        let a = ini.get_or("vis", "prob_a", 1.0f32)?;
        cfg.vis.prob_fn = match ini.get("vis", "prob_fn").unwrap_or("invquad") {
            "invquad" => ProbFn::InvQuad { a },
            "sigmoid" => ProbFn::SigmoidSq,
            other => anyhow::bail!("[vis] prob_fn: unknown function {other:?}"),
        };
        cfg.use_xla = ini.get_bool_or("vis", "use_xla", cfg.use_xla)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = PipelineConfig::default();
        assert_eq!(c.k, 150);
        assert_eq!(c.weights.perplexity, 50.0);
        assert_eq!(c.vis.negatives, 5);
        assert_eq!(c.vis.gamma, 7.0);
        assert_eq!(c.vis.rho0, 1.0);
        assert_eq!(c.vis.prob_fn, ProbFn::InvQuad { a: 1.0 });
    }

    #[test]
    fn ini_overrides() {
        let ini = Ini::parse(
            "dataset = mnist-like\nscale = 0.5\n[knn]\nk = 30\ntrees = 2\n[vis]\nprob_fn = sigmoid\ngamma = 3.5",
        )
        .unwrap();
        let c = PipelineConfig::from_ini(&ini).unwrap();
        assert_eq!(c.dataset, "mnist-like");
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.k, 30);
        assert_eq!(c.knn.forest.n_trees, 2);
        assert_eq!(c.vis.prob_fn, ProbFn::SigmoidSq);
        assert_eq!(c.vis.gamma, 3.5);
    }

    #[test]
    fn bad_prob_fn_rejected() {
        let ini = Ini::parse("[vis]\nprob_fn = cosine").unwrap();
        assert!(PipelineConfig::from_ini(&ini).is_err());
    }
}
