//! INI-subset parser: sections, `key = value`, comments, blank lines.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed INI document: `section -> key -> value` (root section = "").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Ini {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Ini {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Ini> {
        let mut ini = Ini::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                ini.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {line:?}", lineno + 1);
            };
            ini.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(ini)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Ini> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Ini::parse(&text)
    }

    /// Raw string lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T> {
        match self.get(section, key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|_| anyhow::anyhow!("[{section}] {key}: cannot parse {raw:?}")),
        }
    }

    /// Boolean lookup accepting true/false/1/0/yes/no.
    pub fn get_bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(raw) => match raw.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => bail!("[{section}] {key}: not a boolean: {other:?}"),
            },
        }
    }

    /// Section names present in the document.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# pipeline config
dataset = 20ng-like

[knn]
k = 150
trees = 4

[vis]
gamma = 7.0
use_xla = yes
"#;

    #[test]
    fn parses_sections_and_values() {
        let ini = Ini::parse(SAMPLE).unwrap();
        assert_eq!(ini.get("", "dataset"), Some("20ng-like"));
        assert_eq!(ini.get_or::<usize>("knn", "k", 0).unwrap(), 150);
        assert_eq!(ini.get_or::<f32>("vis", "gamma", 0.0).unwrap(), 7.0);
        assert!(ini.get_bool_or("vis", "use_xla", false).unwrap());
    }

    #[test]
    fn defaults_apply() {
        let ini = Ini::parse("").unwrap();
        assert_eq!(ini.get_or::<usize>("knn", "k", 150).unwrap(), 150);
        assert!(!ini.get_bool_or("x", "y", false).unwrap());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Ini::parse("not a kv line").is_err());
        assert!(Ini::parse("[unterminated").is_err());
        let ini = Ini::parse("k = notanumber").unwrap();
        assert!(ini.get_or::<usize>("", "k", 1).is_err());
        assert!(ini.get_bool_or("", "k", true).is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let ini = Ini::parse("  key   =   spaced value  ").unwrap();
        assert_eq!(ini.get("", "key"), Some("spaced value"));
    }
}
