//! Configuration system (no `serde` offline): a small INI-style parser
//! (`[section]`, `key = value`, `#`/`;` comments) with typed getters,
//! plus the typed [`PipelineConfig`] used by the coordinator and CLI.

pub mod ini;
pub mod pipeline;

pub use ini::Ini;
pub use pipeline::{LayoutMode, PipelineConfig, SearchMode, ServeConfig, Stage};
