//! Bit-exact binary checkpoints for the pipeline's expensive
//! intermediates.
//!
//! The KNN stage dominates pipeline runtime (paper Fig 2 / Table 2), so
//! it should be paid once per dataset, not once per layout experiment.
//! These checkpoints make the stage boundary durable:
//!
//! * `.knn` (magic `LVKN`) — a [`KnnGraph`]: `u64 n`, `u64 k`, then per
//!   row `u32 len` + `len × (u32 id, f32 sqdist)`.
//! * `.csr` (magic `LVCS`) — a [`CsrGraph`]: `u64 n`, `u64 m` (directed
//!   edge count), offsets `(n+1) × u64`, cols `m × u32`, weights
//!   `m × f64`.
//!
//! All values little-endian; floats are serialized by bit pattern, so a
//! round-trip is bit-identical (property-tested in
//! `rust/tests/checkpoint_roundtrip.rs`). Reads validate magic,
//! version, and structural invariants so a corrupt or truncated
//! checkpoint fails with a message instead of a garbage graph.

use crate::data::formats::binary::{
    check_magic, dec_u32, dec_u64, read_array, read_u32, read_u64, write_array,
};
use crate::data::formats::UNTRUSTED_CAPACITY_HINT;
use crate::graph::sparse::CsrGraph;
use crate::knn::{KnnGraph, NeighborStore};
use crate::util::faultio::{DurableFile, RealStorage, Storage};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const KNN_MAGIC: &[u8; 4] = b"LVKN";
const CSR_MAGIC: &[u8; 4] = b"LVCS";
const VERSION: u32 = 1;

fn open_writer(
    storage: &dyn Storage,
    path: &Path,
    magic: &[u8; 4],
) -> Result<BufWriter<Box<dyn DurableFile>>> {
    let f = storage
        .create_durable(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(magic)?;
    w.write_all(&VERSION.to_le_bytes())?;
    Ok(w)
}

/// Flush a checkpoint writer and fsync its contents — only then is the
/// checkpoint durable (compaction renames it into place afterwards).
fn finish_writer(mut w: BufWriter<Box<dyn DurableFile>>, path: &Path) -> Result<()> {
    w.flush()?;
    let mut f = w.into_inner().map_err(|e| anyhow::anyhow!("flush: {e}"))?;
    f.sync_data()
        .with_context(|| format!("sync {}", path.display()))?;
    Ok(())
}

fn open_reader(path: &Path, magic: &[u8; 4]) -> Result<BufReader<std::fs::File>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    check_magic(&mut r, magic, VERSION, path)?;
    Ok(r)
}

/// Write a KNN graph checkpoint. Generic over [`NeighborStore`]: the
/// format is written row by row, so the flat [`KnnGraph`] and the
/// serving path's chunked store produce byte-identical files.
pub fn write_knn(path: &Path, g: &impl NeighborStore) -> Result<()> {
    write_knn_with(&RealStorage, path, g)
}

/// [`write_knn`] through an explicit [`Storage`] — the durable
/// (fault-injectable) path WAL compaction uses.
pub fn write_knn_with(storage: &dyn Storage, path: &Path, g: &impl NeighborStore) -> Result<()> {
    let mut w = open_writer(storage, path, KNN_MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.k() as u64).to_le_bytes())?;
    let mut buf: Vec<u8> = Vec::new();
    for i in 0..g.n() {
        let row = g.row(i);
        buf.clear();
        buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
        for &(id, dist) in row {
            buf.extend_from_slice(&id.to_le_bytes());
            buf.extend_from_slice(&dist.to_bits().to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    finish_writer(w, path)
}

/// Read a KNN graph checkpoint (bit-identical to what was written).
pub fn read_knn(path: &Path) -> Result<KnnGraph> {
    let mut r = open_reader(path, KNN_MAGIC)?;
    let n = read_u64(&mut r)? as usize;
    let k = read_u64(&mut r)? as usize;
    if n > (1usize << 40) || k > (1usize << 32) {
        bail!("{}: implausible knn checkpoint shape n={n} k={k}", path.display());
    }
    // Capacity hints are clamped: a corrupt header must not drive a
    // huge allocation before the reads themselves fail.
    let mut neighbors = Vec::with_capacity(n.min(UNTRUSTED_CAPACITY_HINT));
    let mut buf: Vec<u8> = Vec::new();
    for i in 0..n {
        let len = read_u32(&mut r)? as usize;
        if len > n || len > (1 << 24) {
            bail!("{}: row {i} has implausible length {len} (n={n})", path.display());
        }
        buf.clear();
        buf.resize(len * 8, 0);
        r.read_exact(&mut buf)
            .with_context(|| format!("{}: truncated at row {i}", path.display()))?;
        let mut row = Vec::with_capacity(len);
        for pair in buf.chunks_exact(8) {
            let id = dec_u32(&pair[..4]);
            if id as usize >= n || id as usize == i {
                bail!("{}: row {i}: invalid neighbor id {id} (n={n})", path.display());
            }
            row.push((id, f32::from_bits(dec_u32(&pair[4..]))));
        }
        neighbors.push(row);
    }
    Ok(KnnGraph { neighbors, k })
}

/// Write a CSR graph checkpoint.
pub fn write_csr(path: &Path, g: &CsrGraph) -> Result<()> {
    write_csr_with(&RealStorage, path, g)
}

/// [`write_csr`] through an explicit [`Storage`].
pub fn write_csr_with(storage: &dyn Storage, path: &Path, g: &CsrGraph) -> Result<()> {
    let mut w = open_writer(storage, path, CSR_MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.cols().len() as u64).to_le_bytes())?;
    let mut buf: Vec<u8> = Vec::new();
    write_array(&mut w, g.offsets(), &mut buf, |o: u64| o.to_le_bytes())?;
    write_array(&mut w, g.cols(), &mut buf, |c: u32| c.to_le_bytes())?;
    write_array(&mut w, g.weights(), &mut buf, |x: f64| x.to_bits().to_le_bytes())?;
    finish_writer(w, path)
}

/// Read a CSR graph checkpoint; structure is re-validated via
/// [`CsrGraph::from_raw_parts`], and the flat edge list is rebuilt
/// deterministically.
pub fn read_csr(path: &Path) -> Result<CsrGraph> {
    let mut r = open_reader(path, CSR_MAGIC)?;
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    if n > (1usize << 40) || m > (1usize << 40) {
        bail!("{}: implausible csr checkpoint shape n={n} m={m}", path.display());
    }
    // Capacity hints clamped; `read_array` grows with the data actually
    // present, so a lying header hits a read error, not a huge alloc.
    let mut offsets: Vec<u64> = Vec::with_capacity((n + 1).min(UNTRUSTED_CAPACITY_HINT));
    read_array(&mut r, n + 1, 8, &mut offsets, dec_u64)
        .with_context(|| format!("{}: truncated offsets", path.display()))?;
    let mut cols: Vec<u32> = Vec::with_capacity(m.min(UNTRUSTED_CAPACITY_HINT));
    read_array(&mut r, m, 4, &mut cols, dec_u32)
        .with_context(|| format!("{}: truncated cols", path.display()))?;
    let mut weights: Vec<f64> = Vec::with_capacity(m.min(UNTRUSTED_CAPACITY_HINT));
    read_array(&mut r, m, 8, &mut weights, |b: &[u8]| f64::from_bits(dec_u64(b)))
        .with_context(|| format!("{}: truncated weights", path.display()))?;
    CsrGraph::from_raw_parts(offsets, cols, weights)
        .map_err(|e| anyhow::anyhow!("{}: corrupt checkpoint: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn knn_roundtrip_with_empty_rows() {
        let mut g = KnnGraph::empty(4, 3);
        g.neighbors[0] = vec![(1, 0.25), (2, 0.5), (3, 1.0)];
        g.neighbors[2] = vec![(0, 0.5)];
        // rows 1 and 3 stay empty
        let p = tmp("g.knn");
        write_knn(&p, &g).unwrap();
        let back = read_knn(&p).unwrap();
        assert_eq!(back.k, 3);
        assert_eq!(back.n(), 4);
        for (a, b) in g.neighbors.iter().zip(&back.neighbors) {
            assert_eq!(a.len(), b.len());
            for (&(ia, da), &(ib, db)) in a.iter().zip(b) {
                assert_eq!(ia, ib);
                assert_eq!(da.to_bits(), db.to_bits());
            }
        }
    }

    #[test]
    fn csr_roundtrip_identical() {
        let g = CsrGraph::from_undirected(5, &[(0, 1, 0.125), (1, 2, 1e-300), (3, 4, 7.5)]);
        let p = tmp("g.csr");
        write_csr(&p, &g).unwrap();
        let back = read_csr(&p).unwrap();
        assert_eq!(g, back);
        assert_eq!(g.edges(), back.edges());
    }

    #[test]
    fn cross_format_reads_rejected() {
        let g = CsrGraph::from_undirected(3, &[(0, 1, 1.0)]);
        let p = tmp("cross.csr");
        write_csr(&p, &g).unwrap();
        assert!(read_knn(&p).is_err(), "knn reader must reject csr magic");
        let mut k = KnnGraph::empty(2, 1);
        k.neighbors[0] = vec![(1, 1.0)];
        let p2 = tmp("cross.knn");
        write_knn(&p2, &k).unwrap();
        assert!(read_csr(&p2).is_err(), "csr reader must reject knn magic");
    }

    #[test]
    fn truncated_checkpoint_rejected() {
        let g = CsrGraph::from_undirected(4, &[(0, 1, 1.0), (2, 3, 2.0)]);
        let p = tmp("trunc.csr");
        write_csr(&p, &g).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(read_csr(&p).is_err());
    }

    #[test]
    fn out_of_range_neighbor_rejected() {
        let mut g = KnnGraph::empty(2, 1);
        g.neighbors[0] = vec![(1, 1.0)];
        let p = tmp("oor.knn");
        write_knn(&p, &g).unwrap();
        // Patch the neighbor id to 9 (out of range for n=2).
        let mut bytes = std::fs::read(&p).unwrap();
        let row0_id_off = 4 + 4 + 8 + 8 + 4; // magic+ver+n+k+len
        bytes[row0_id_off..row0_id_off + 4].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_knn(&p).is_err());
    }
}
