//! Append-only write-ahead log for live point insertion (`LVWL`).
//!
//! The live query server accepts `POST /insert` while running; those
//! points must survive a restart without rewriting the (potentially
//! huge) base checkpoints on every request. Each accepted batch is
//! appended to the active log *before* it is applied to the in-memory
//! state, and replayed in order at startup — the recovered dataset is
//! bit-identical to the pre-restart one.
//!
//! # Record format (version 2)
//!
//! File header: 4-byte magic `LVWL`, `u32` version (LE, like every
//! other on-disk format here), `u32 d` — the point dimensionality the
//! log is bound to (a WAL can never be replayed against a base of a
//! different width) — then `u64 base_seq`, the absolute sequence
//! number of the file's first record (segments after the first start
//! above zero). Records follow back to back:
//!
//! ```text
//! u64 seq        absolute batch sequence number (strictly increasing)
//! u32 rows       points in this batch (1 ..= MAX_WAL_BATCH_ROWS)
//! rows × d × f32 row-major point payload (bit patterns)
//! u32 checksum   FNV-1a over seq ‖ rows ‖ payload (v1: payload only)
//! ```
//!
//! Version 1 files (12-byte header, implicit `base_seq = 0`, checksum
//! over the payload only) are still read, and a writer resuming a v1
//! file keeps appending v1 records so the file stays self-consistent.
//! Version 2 exists because the v1 checksum left the `seq`/`rows`
//! fields unprotected: a bit flip there was misdiagnosed as a torn
//! tail.
//!
//! # Tails, corruption, and [`RecoveryPolicy`]
//!
//! A crash mid-append leaves a *torn tail*: a prefix of the true final
//! record. Replay detects it as a short read (or a checksum mismatch
//! on the final record), truncates it, and continues — that is normal
//! WAL recovery, not data loss, because a torn record was by
//! definition never acknowledged. Anything else — a record whose
//! fully-readable header fields are invalid, a checksum mismatch with
//! more log after it, a sealed segment that does not end cleanly — is
//! *corruption*: acknowledged data is at risk, and the configured
//! [`RecoveryPolicy`] decides between failing fast and salvaging the
//! surviving prefix (counted, so operators can alert on it).
//!
//! # Segments
//!
//! [`WalSet`] manages the active log plus its sealed, read-only
//! predecessors (`inserts.wal.0`, `inserts.wal.1`, …). Sealing is one
//! atomic rename; compaction absorbs every logged batch into the base
//! checkpoints and resets the set to a single empty segment (see the
//! server's checkpoint compaction), which is what keeps replay time
//! bounded by the segment budget instead of total insert history.
//!
//! All file I/O goes through [`crate::util::faultio::Storage`], so the
//! crash-recovery torture tests can inject short writes, fsync
//! failures, ENOSPC, and torn writes at every point of this module.

use crate::data::matrix::Matrix;
use crate::util::faultio::{RealStorage, Storage};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, Read, SeekFrom};
use std::path::{Path, PathBuf};
use crate::util::sync::Arc;

/// WAL file magic.
pub const MAGIC: &[u8; 4] = b"LVWL";
/// WAL format version written to fresh files.
pub const VERSION: u32 = 2;
/// Cap on rows per WAL record (a lying length prefix must not drive an
/// unbounded allocation; the server's per-request insert cap is far
/// smaller).
pub const MAX_WAL_BATCH_ROWS: usize = 1 << 20;

/// What replay does when it finds *corruption* (as opposed to an
/// ordinary torn tail, which is always truncated silently).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Refuse to start: surface the corruption to the operator rather
    /// than silently dropping acknowledged data. The safe default.
    #[default]
    FailFast,
    /// Salvage the longest clean prefix, quarantine the rest, and
    /// count what was dropped (`serve.wal_corrupt_segments`).
    Truncate,
}

impl std::str::FromStr for RecoveryPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fail_fast" | "fail-fast" | "failfast" => Ok(RecoveryPolicy::FailFast),
            "truncate" | "skip_corrupt" | "skip-corrupt" => Ok(RecoveryPolicy::Truncate),
            other => Err(format!("unknown recovery policy '{other}' (fail_fast | truncate)")),
        }
    }
}

impl std::fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecoveryPolicy::FailFast => write!(f, "fail_fast"),
            RecoveryPolicy::Truncate => write!(f, "truncate"),
        }
    }
}

fn fnv1a_update(mut h: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// FNV-1a over `bytes` — cheap, dependency-free corruption detection
/// for the torn-tail case (not an integrity MAC).
pub fn fnv1a(bytes: &[u8]) -> u32 {
    fnv1a_update(0x811c9dc5, bytes)
}

/// The checksum a record carries, by format version: v2 covers the
/// record header (`seq`, `rows`) and the payload; v1 covered only the
/// payload.
pub fn record_checksum(version: u32, seq: u64, rows: u32, payload: &[u8]) -> u32 {
    if version >= 2 {
        let mut h = fnv1a_update(0x811c9dc5, &seq.to_le_bytes());
        h = fnv1a_update(h, &rows.to_le_bytes());
        fnv1a_update(h, payload)
    } else {
        fnv1a(payload)
    }
}

/// Bytes of the fixed file header for `version`.
pub fn header_bytes(version: u32) -> u64 {
    if version >= 2 {
        4 + 4 + 4 + 8
    } else {
        4 + 4 + 4
    }
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// `read_exact` that reports EOF-before-fill as `Ok(false)` instead of
/// an error — replay needs to tell "file ended" apart from real I/O
/// failures.
fn try_read_exact(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Ok(false),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// The surviving content of one WAL file: complete batches only.
#[derive(Clone, Debug, Default)]
pub struct WalContents {
    /// Replayable batches, in append order; every row has the log's
    /// declared dimensionality.
    pub batches: Vec<Matrix>,
    /// Total rows across `batches`.
    pub rows: usize,
    /// Byte offset just past the last complete record — the append
    /// position for a writer resuming this log.
    pub valid_bytes: u64,
    /// True when a torn/corrupt tail was detected (and ignored).
    pub torn_tail: bool,
    /// True when the tail was *corruption* (not a plain torn record)
    /// and [`RecoveryPolicy::Truncate`] dropped it.
    pub corrupt: bool,
    /// Format version from the file header (0 when headerless).
    pub version: u32,
    /// Absolute sequence number of the file's first record.
    pub base_seq: u64,
    /// False when the file does not exist.
    pub present: bool,
    /// True when a complete, valid file header was read.
    pub has_header: bool,
}

fn fail_corrupt(
    path: &Path,
    policy: RecoveryPolicy,
    mut out: WalContents,
    pos: u64,
    why: &str,
) -> Result<WalContents> {
    match policy {
        RecoveryPolicy::FailFast => bail!(
            "{}: corrupt WAL record at byte {pos}: {why} \
             (recovery_policy=truncate salvages the clean prefix)",
            path.display()
        ),
        RecoveryPolicy::Truncate => {
            out.corrupt = true;
            out.torn_tail = true;
            Ok(out)
        }
    }
}

/// Read every complete batch from the single WAL file at `path`,
/// validating sequence numbers, shapes and checksums. `d` is the
/// dimensionality the caller's base data has; a WAL header disagreeing
/// with it fails loudly under either policy (stale checkpoint
/// directory, not corruption). A missing file is an empty log.
pub fn read_wal_file(path: &Path, d: usize, policy: RecoveryPolicy) -> Result<WalContents> {
    let f = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(WalContents::default()),
        Err(e) => return Err(e).with_context(|| format!("open {}", path.display())),
    };
    let flen = f
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut out = WalContents { present: true, ..Default::default() };
    if flen == 0 {
        return Ok(out);
    }
    let mut r = BufReader::new(f);

    // Header. A crash between create and header sync leaves a short
    // file; that is a torn (empty) log, not a parse error.
    let mut head = [0u8; 12];
    if !try_read_exact(&mut r, &mut head)? {
        out.torn_tail = true;
        return Ok(out);
    }
    if &head[..4] != MAGIC {
        return fail_corrupt(path, policy, out, 0, "bad magic");
    }
    let version = le_u32(&head[4..8]);
    if version == 0 || version > VERSION {
        return fail_corrupt(path, policy, out, 4, "unsupported LVWL version");
    }
    let wal_d = le_u32(&head[8..12]) as usize;
    if wal_d != d {
        bail!(
            "{}: WAL holds {wal_d}-dimensional points, base data is {d}-dimensional — \
             stale checkpoint directory?",
            path.display()
        );
    }
    let mut base_seq = 0u64;
    if version >= 2 {
        let mut b = [0u8; 8];
        if !try_read_exact(&mut r, &mut b)? {
            out.torn_tail = true;
            return Ok(out);
        }
        base_seq = le_u64(&b);
    }
    out.version = version;
    out.base_seq = base_seq;
    out.has_header = true;
    out.valid_bytes = header_bytes(version);

    let mut pos = out.valid_bytes;
    let mut payload: Vec<u8> = Vec::new();
    loop {
        let mut rec_head = [0u8; 12];
        if !try_read_exact(&mut r, &mut rec_head)? {
            out.torn_tail = pos < flen;
            break;
        }
        let seq = le_u64(&rec_head[0..8]);
        let rows_u = le_u32(&rec_head[8..12]);
        let rows = rows_u as usize;
        let expected = base_seq + out.batches.len() as u64;
        if seq != expected || rows == 0 || rows > MAX_WAL_BATCH_ROWS {
            // A torn write keeps a *prefix* of the true record, so a
            // fully-readable head with wrong fields is corruption (the
            // exact case the v1 payload-only checksum misdiagnosed).
            let why = format!("invalid record head (seq {seq}, expected {expected}, rows {rows_u})");
            return fail_corrupt(path, policy, out, pos, &why);
        }
        payload.clear();
        payload.resize(rows * d * 4, 0);
        if !try_read_exact(&mut r, &mut payload)? {
            out.torn_tail = true;
            break;
        }
        let mut sum = [0u8; 4];
        if !try_read_exact(&mut r, &mut sum)? {
            out.torn_tail = true;
            break;
        }
        let rec_end = pos + 12 + payload.len() as u64 + 4;
        if record_checksum(version, seq, rows_u, &payload) != le_u32(&sum) {
            if rec_end < flen {
                return fail_corrupt(path, policy, out, pos, "record checksum mismatch mid-log");
            }
            // Mismatch on the final record: crash garbage, a torn tail.
            out.torn_tail = true;
            break;
        }
        let vals: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_bits(le_u32(b)))
            .collect();
        out.rows += rows;
        out.batches.push(Matrix::from_vec(vals, rows, d));
        pos = rec_end;
        out.valid_bytes = pos;
    }
    Ok(out)
}

/// [`read_wal_file`] under the fail-fast policy — the historical
/// single-file entry point.
pub fn read_wal(path: &Path, d: usize) -> Result<WalContents> {
    read_wal_file(path, d, RecoveryPolicy::FailFast)
}

/// Path of sealed segment `idx` for the active log at `active`
/// (`inserts.wal` → `inserts.wal.3`).
pub fn segment_path(active: &Path, idx: u64) -> PathBuf {
    let mut name = active.as_os_str().to_os_string();
    name.push(format!(".{idx}"));
    PathBuf::from(name)
}

/// Sealed segments next to `active`, sorted by segment index. Files
/// whose suffix is not a plain integer (e.g. quarantined segments) are
/// ignored.
pub fn sealed_segments(active: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out: Vec<(u64, PathBuf)> = Vec::new();
    let Some(stem) = active.file_name().and_then(|n| n.to_str()) else {
        return Ok(out);
    };
    let dir = match active.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e).with_context(|| format!("list {}", dir.display())),
    };
    let prefix = format!("{stem}.");
    for entry in entries {
        let entry = entry.with_context(|| format!("list {}", dir.display()))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(sfx) = name.strip_prefix(&prefix) {
            if let Ok(idx) = sfx.parse::<u64>() {
                out.push((idx, dir.join(name)));
            }
        }
    }
    out.sort_by_key(|&(i, _)| i);
    Ok(out)
}

/// Everything recovered from a WAL set (sealed segments + active log).
#[derive(Clone, Debug, Default)]
pub struct WalRecovery {
    /// Replayable batches across all segments, in append order.
    pub batches: Vec<Matrix>,
    /// Total rows across `batches`.
    pub rows: usize,
    /// Absolute sequence number the next append will receive.
    pub next_seq: u64,
    /// True when a torn tail was truncated from the final segment.
    pub torn_tail: bool,
    /// Segments dropped (in whole or in part) as corrupt under
    /// [`RecoveryPolicy::Truncate`].
    pub corrupt_segments: usize,
    /// Segment files inspected (sealed + active, when present).
    pub segments: usize,
}

/// How [`WalSet::open`] must treat the on-disk files after a scan.
struct SetScan {
    /// Sealed segments that replayed cleanly, in order.
    good_sealed: Vec<PathBuf>,
    /// Segment files to quarantine (rename aside) before writing.
    quarantine: Vec<PathBuf>,
    /// Whether the active file can be resumed in place.
    resume_active: bool,
    /// `base_seq` a recreated active segment must carry.
    active_base: u64,
}

fn scan_wal_set(active: &Path, d: usize, policy: RecoveryPolicy) -> Result<(WalRecovery, SetScan)> {
    let sealed = sealed_segments(active)?;
    let mut rec = WalRecovery::default();
    let mut scan = SetScan {
        good_sealed: Vec::new(),
        quarantine: Vec::new(),
        resume_active: false,
        active_base: 0,
    };
    let mut next_seq = 0u64;
    let mut have_prior = false; // any clean segment read yet
    let mut stopped = false; // Truncate: corruption found, discard the rest

    for (slot, (idx, p)) in sealed.iter().enumerate() {
        if stopped {
            scan.quarantine.push(p.clone());
            continue;
        }
        let broken: Option<String> = if *idx != slot as u64 {
            Some(format!("segment numbering gap: found index {idx} at position {slot}"))
        } else {
            let c = read_wal_file(p, d, policy)?;
            rec.segments += 1;
            if c.torn_tail || c.corrupt || !c.has_header {
                Some("sealed WAL segment does not end cleanly".to_string())
            } else if have_prior && c.base_seq != next_seq {
                Some(format!(
                    "sealed segment base_seq {} does not continue the log at {next_seq}",
                    c.base_seq
                ))
            } else {
                next_seq = c.base_seq + c.batches.len() as u64;
                have_prior = true;
                rec.rows += c.rows;
                rec.batches.extend(c.batches);
                scan.good_sealed.push(p.clone());
                None
            }
        };
        if let Some(why) = broken {
            match policy {
                RecoveryPolicy::FailFast => {
                    bail!("{}: {why} (recovery_policy=truncate quarantines it)", p.display())
                }
                RecoveryPolicy::Truncate => {
                    rec.corrupt_segments += 1;
                    scan.quarantine.push(p.clone());
                    stopped = true;
                }
            }
        }
    }

    if stopped {
        // Orphaned active log: its sequences no longer follow what we
        // replayed, so it gets quarantined alongside the bad segment.
        if active.exists() {
            scan.quarantine.push(active.to_path_buf());
        }
        scan.active_base = next_seq;
        rec.next_seq = next_seq;
        return Ok((rec, scan));
    }

    let c = read_wal_file(active, d, policy)?;
    if c.present {
        rec.segments += 1;
    }
    if c.has_header && have_prior && c.base_seq != next_seq {
        match policy {
            RecoveryPolicy::FailFast => bail!(
                "{}: active WAL base_seq {} does not continue the sealed segments at {next_seq} \
                 (recovery_policy=truncate quarantines it)",
                active.display(),
                c.base_seq
            ),
            RecoveryPolicy::Truncate => {
                rec.corrupt_segments += 1;
                scan.quarantine.push(active.to_path_buf());
                scan.active_base = next_seq;
                rec.next_seq = next_seq;
                return Ok((rec, scan));
            }
        }
    }
    if c.has_header {
        next_seq = c.base_seq + c.batches.len() as u64;
    }
    rec.torn_tail = c.torn_tail;
    rec.corrupt_segments += c.corrupt as usize;
    rec.rows += c.rows;
    rec.batches.extend(c.batches);
    rec.next_seq = next_seq;
    scan.resume_active = true;
    scan.active_base = next_seq;
    Ok((rec, scan))
}

/// Read-only replay of a whole WAL set (sealed segments + active log),
/// without touching any file — the read-only server path and the
/// bounded-replay assertions use this.
pub fn read_wal_set(active: &Path, d: usize, policy: RecoveryPolicy) -> Result<WalRecovery> {
    let (rec, _) = scan_wal_set(active, d, policy)?;
    Ok(rec)
}

/// Reset a WAL set on disk to a single fresh, empty active segment
/// whose sequence numbering starts at `absorbed_seq` — the compaction
/// roll-forward path, where no live writer exists.
pub fn reset_wal_set(
    storage: &dyn Storage,
    active: &Path,
    d: usize,
    absorbed_seq: u64,
) -> Result<()> {
    for (_, p) in sealed_segments(active)? {
        storage
            .remove(&p)
            .with_context(|| format!("remove absorbed WAL segment {}", p.display()))?;
    }
    WalWriter::create(storage, active, d, absorbed_seq)?;
    Ok(())
}

/// Appending writer over one WAL file. [`WalWriter::append`] durably
/// records one batch per call — the whole record is written with one
/// `write_all` and `sync_data` **must succeed before the append
/// returns `Ok`**, so an acknowledged insert survives a process kill
/// or power loss.
///
/// A *failed* append rolls the file back to the end of the last
/// complete record before returning the error: a transient I/O failure
/// (e.g. `ENOSPC` mid-write) must not leave partial bytes that would
/// make replay stop early and silently drop *later, acknowledged*
/// records. If even the rollback fails, the writer poisons itself and
/// refuses further appends instead of corrupting the log.
pub struct WalWriter {
    f: Box<dyn crate::util::faultio::DurableFile>,
    path: PathBuf,
    d: usize,
    version: u32,
    base_seq: u64,
    next_seq: u64,
    /// Byte offset just past the last durably recorded record.
    valid_bytes: u64,
    /// Set when a failed append could not be rolled back; the log tail
    /// state is unknown, so appending more would risk corruption.
    poisoned: bool,
}

impl WalWriter {
    /// Create (truncating) a fresh log at `path` for `d`-dimensional
    /// points whose first record will carry absolute sequence number
    /// `base_seq`. The header is fsync'd before returning.
    pub fn create(storage: &dyn Storage, path: &Path, d: usize, base_seq: u64) -> Result<WalWriter> {
        let mut f = storage
            .create_durable(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut header = Vec::with_capacity(header_bytes(VERSION) as usize);
        header.extend_from_slice(MAGIC);
        header.extend_from_slice(&VERSION.to_le_bytes());
        header.extend_from_slice(&(d as u32).to_le_bytes());
        header.extend_from_slice(&base_seq.to_le_bytes());
        f.write_all(&header)
            .and_then(|_| f.sync_data())
            .with_context(|| format!("write WAL header {}", path.display()))?;
        Ok(WalWriter {
            f,
            path: path.to_path_buf(),
            d,
            version: VERSION,
            base_seq,
            next_seq: base_seq,
            valid_bytes: header_bytes(VERSION),
            poisoned: false,
        })
    }

    /// Open the log at `path` for appending: replay/validate the
    /// existing content under `policy`, truncate away a torn tail, and
    /// position at the end. A missing or headerless file is started
    /// fresh with `fresh_base_seq`. Returns the writer plus the
    /// surviving contents (the caller replays them into its state).
    pub fn resume(
        storage: &dyn Storage,
        path: &Path,
        d: usize,
        policy: RecoveryPolicy,
        fresh_base_seq: u64,
    ) -> Result<(WalWriter, WalContents)> {
        let contents = read_wal_file(path, d, policy)?;
        if !contents.has_header {
            let w = WalWriter::create(storage, path, d, fresh_base_seq)?;
            return Ok((w, contents));
        }
        let mut f = storage
            .open_durable(path)
            .with_context(|| format!("open {}", path.display()))?;
        // Drop any torn tail so the resumed log is a clean prefix.
        f.set_len(contents.valid_bytes)
            .with_context(|| format!("truncate {}", path.display()))?;
        f.seek(SeekFrom::End(0))
            .with_context(|| format!("seek {}", path.display()))?;
        let w = WalWriter {
            f,
            path: path.to_path_buf(),
            d,
            version: contents.version,
            base_seq: contents.base_seq,
            next_seq: contents.base_seq + contents.batches.len() as u64,
            valid_bytes: contents.valid_bytes,
            poisoned: false,
        };
        Ok((w, contents))
    }

    /// Open (or create) the WAL at `path` on the real filesystem with
    /// fail-fast recovery — the historical single-file entry point.
    pub fn open(path: &Path, d: usize) -> Result<(WalWriter, WalContents)> {
        WalWriter::resume(&RealStorage, path, d, RecoveryPolicy::FailFast, 0)
    }

    /// Durably append one batch of points (shape-checked against the
    /// log's dimensionality). Returns the record's absolute sequence
    /// number only after the record is written **and** fsync'd; on
    /// failure the file is rolled back to the previous record boundary.
    pub fn append(&mut self, batch: &Matrix) -> Result<u64> {
        if self.poisoned {
            bail!(
                "{}: WAL writer disabled by an earlier unrecoverable I/O error",
                self.path.display()
            );
        }
        if batch.d() != self.d {
            bail!(
                "{}: appending {}-dimensional rows to a {}-dimensional WAL",
                self.path.display(),
                batch.d(),
                self.d
            );
        }
        if batch.n() == 0 || batch.n() > MAX_WAL_BATCH_ROWS {
            bail!("{}: WAL batch of {} rows out of range", self.path.display(), batch.n());
        }
        let seq = self.next_seq;
        let rows = batch.n() as u32;
        // Serialize the whole record up front so it hits the file in a
        // single write_all — no partial-record state to manage in the
        // common path.
        let mut record: Vec<u8> = Vec::with_capacity(16 + batch.n() * self.d * 4);
        record.extend_from_slice(&seq.to_le_bytes());
        record.extend_from_slice(&rows.to_le_bytes());
        let payload_start = record.len();
        for &v in batch.as_slice() {
            record.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let checksum = record_checksum(self.version, seq, rows, &record[payload_start..]);
        record.extend_from_slice(&checksum.to_le_bytes());

        let wrote = self.f.write_all(&record).and_then(|_| self.f.sync_data());
        match wrote {
            Ok(()) => {
                self.valid_bytes += record.len() as u64;
                self.next_seq += 1;
                Ok(seq)
            }
            Err(e) => {
                // Roll back to the last complete record so this failure
                // cannot make replay drop later successful appends.
                #[cfg(not(modelcheck_mutant_wal_no_rollback))]
                let rolled = self
                    .f
                    .set_len(self.valid_bytes)
                    .and_then(|_| self.f.seek(SeekFrom::End(0)));
                // Seeded durability bug for the mutation corpus: leave
                // the torn tail in place after a failed append. A later
                // successful append then lands *after* garbage bytes,
                // so replay truncates at the tear and silently drops an
                // acked record — exactly the acked-prefix violation the
                // WAL model test pins. The checker must catch this.
                #[cfg(modelcheck_mutant_wal_no_rollback)]
                let rolled = self.f.seek(SeekFrom::End(0));
                if rolled.is_err() {
                    self.poisoned = true;
                }
                Err(e).with_context(|| {
                    format!(
                        "{}: WAL append of batch {seq} failed{}",
                        self.path.display(),
                        if self.poisoned { " (writer disabled: rollback also failed)" } else { "" }
                    )
                })
            }
        }
    }

    /// Records durably held by this file (surviving prefix + appends).
    pub fn batches(&self) -> u64 {
        self.next_seq - self.base_seq
    }

    /// Absolute sequence number the next append will receive.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Byte offset just past the last durable record.
    pub fn valid_bytes(&self) -> u64 {
        self.valid_bytes
    }

    /// Re-fsync the file — a no-op after a clean append (every append
    /// syncs), kept for the server's drain path.
    pub fn sync(&mut self) -> Result<()> {
        if self.poisoned {
            return Ok(());
        }
        self.f
            .sync_data()
            .with_context(|| format!("sync {}", self.path.display()))
    }
}

/// The active WAL plus its sealed segments, as one appendable log with
/// rotation and compaction-reset.
pub struct WalSet {
    storage: Arc<dyn Storage>,
    active: PathBuf,
    d: usize,
    writer: WalWriter,
    sealed: Vec<PathBuf>,
    /// Set when a rotation died half-way; the in-memory picture of the
    /// segment files is unreliable, so appends are refused until the
    /// set is reopened (recovery sorts the files out).
    failed: bool,
}

impl WalSet {
    /// Open the WAL set rooted at the active path: replay sealed
    /// segments in order, then the active log, validating sequence
    /// continuity across files. Corruption is handled per `policy`
    /// (fail fast, or quarantine the corrupt suffix and keep going).
    pub fn open(
        storage: Arc<dyn Storage>,
        active: &Path,
        d: usize,
        policy: RecoveryPolicy,
    ) -> Result<(WalSet, WalRecovery)> {
        let (rec, scan) = scan_wal_set(active, d, policy)?;
        for p in &scan.quarantine {
            let mut q = p.as_os_str().to_os_string();
            q.push(".quarantined");
            storage
                .persist(p, Path::new(&q))
                .with_context(|| format!("quarantine corrupt WAL segment {}", p.display()))?;
        }
        let writer = if scan.resume_active {
            WalWriter::resume(storage.as_ref(), active, d, policy, scan.active_base)?.0
        } else {
            WalWriter::create(storage.as_ref(), active, d, scan.active_base)?
        };
        let set = WalSet {
            storage,
            active: active.to_path_buf(),
            d,
            writer,
            sealed: scan.good_sealed,
            failed: false,
        };
        Ok((set, rec))
    }

    /// Durably append one batch (see [`WalWriter::append`]).
    pub fn append(&mut self, batch: &Matrix) -> Result<u64> {
        if self.failed {
            bail!("{}: WAL set disabled after a failed rotation", self.active.display());
        }
        self.writer.append(batch)
    }

    /// Bytes of durable records in the active segment.
    pub fn active_bytes(&self) -> u64 {
        self.writer.valid_bytes()
    }

    /// Sealed (rotated, read-only) segments currently on disk.
    pub fn sealed_count(&self) -> usize {
        self.sealed.len()
    }

    /// Absolute sequence number the next append will receive.
    pub fn next_seq(&self) -> u64 {
        self.writer.next_seq()
    }

    /// Seal the active segment (atomic rename to the next `.N` name)
    /// and start a fresh active log continuing the sequence numbers.
    pub fn rotate(&mut self) -> Result<()> {
        if self.failed {
            bail!("{}: WAL set disabled after a failed rotation", self.active.display());
        }
        let sealed_path = segment_path(&self.active, self.sealed.len() as u64);
        let next_base = self.writer.next_seq();
        self.storage
            .persist(&self.active, &sealed_path)
            .with_context(|| format!("seal WAL segment {}", sealed_path.display()))?;
        self.sealed.push(sealed_path);
        match WalWriter::create(self.storage.as_ref(), &self.active, self.d, next_base) {
            Ok(w) => {
                self.writer = w;
                Ok(())
            }
            Err(e) => {
                // The old handle now points at the sealed file; writing
                // further records there would confuse the next rotation,
                // so the set refuses appends until reopened.
                self.failed = true;
                Err(e.context("start fresh WAL segment after sealing"))
            }
        }
    }

    /// After compaction durably absorbed every batch below absolute
    /// sequence `absorbed_seq` into the base checkpoints: delete the
    /// sealed segments and restart the active log empty at that
    /// sequence. Idempotent on retry (removes tolerate absence).
    pub fn reset_absorbed(&mut self, absorbed_seq: u64) -> Result<()> {
        for p in &self.sealed {
            self.storage
                .remove(p)
                .with_context(|| format!("remove absorbed WAL segment {}", p.display()))?;
        }
        self.sealed.clear();
        self.writer = WalWriter::create(self.storage.as_ref(), &self.active, self.d, absorbed_seq)?;
        self.failed = false;
        Ok(())
    }

    /// Final fsync of the active log (the server's shutdown drain).
    pub fn sync(&mut self) -> Result<()> {
        self.writer.sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_wal_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn batch(vals: &[f32], d: usize) -> Matrix {
        Matrix::from_vec(vals.to_vec(), vals.len() / d, d)
    }

    #[test]
    fn roundtrip_bit_identical() {
        let p = tmp("rt.wal");
        std::fs::remove_file(&p).ok();
        let b1 = batch(&[1.0, -2.5, 3.25, f32::MIN_POSITIVE, 0.0, -0.0], 3);
        let b2 = batch(&[9.0, 8.0, 7.0], 3);
        {
            let (mut w, prior) = WalWriter::open(&p, 3).unwrap();
            assert_eq!(prior.batches.len(), 0);
            assert_eq!(w.append(&b1).unwrap(), 0);
            assert_eq!(w.append(&b2).unwrap(), 1);
        }
        let back = read_wal(&p, 3).unwrap();
        assert!(!back.torn_tail);
        assert_eq!(back.version, VERSION);
        assert_eq!(back.batches.len(), 2);
        assert_eq!(back.rows, 3);
        // Bit-identical payloads (−0.0 and subnormals preserved).
        for (a, b) in [(&b1, &back.batches[0]), (&b2, &back.batches[1])] {
            assert_eq!(a.n(), b.n());
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn missing_file_is_empty_log() {
        let c = read_wal(&tmp("nope.wal"), 4).unwrap();
        assert_eq!(c.batches.len(), 0);
        assert!(!c.torn_tail);
        assert!(!c.present);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let p = tmp("dim.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
            assert!(w.append(&batch(&[1.0, 2.0, 3.0], 3)).is_err());
        }
        let err = format!("{:#}", read_wal(&p, 3).unwrap_err());
        assert!(err.contains("2-dimensional"), "{err}");
    }

    #[test]
    fn torn_tail_ignored_and_truncated_on_reopen() {
        let p = tmp("torn.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
            w.append(&batch(&[3.0, 4.0, 5.0, 6.0], 2)).unwrap();
        }
        let full = std::fs::metadata(&p).unwrap().len();
        // Chop mid-record: the second batch loses its checksum.
        std::fs::OpenOptions::new()
            .write(true)
            .open(&p)
            .unwrap()
            .set_len(full - 3)
            .unwrap();
        let c = read_wal(&p, 2).unwrap();
        assert!(c.torn_tail);
        assert!(!c.corrupt);
        assert_eq!(c.batches.len(), 1);
        assert_eq!(c.rows, 1);
        // Reopening truncates the torn tail and appends after it with
        // the right sequence number.
        {
            let (mut w, prior) = WalWriter::open(&p, 2).unwrap();
            assert_eq!(prior.batches.len(), 1);
            assert_eq!(w.append(&batch(&[7.0, 8.0], 2)).unwrap(), 1);
        }
        let c = read_wal(&p, 2).unwrap();
        assert!(!c.torn_tail);
        assert_eq!(c.batches.len(), 2);
        assert_eq!(c.batches[1].row(0), &[7.0, 8.0]);
    }

    #[test]
    fn corrupt_payload_detected() {
        let p = tmp("crc.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip a payload bit (first value's low byte, after the
        // 20-byte v2 header + 8-byte seq + 4-byte row count).
        let off = header_bytes(VERSION) as usize + 8 + 4;
        bytes[off] ^= 1;
        std::fs::write(&p, &bytes).unwrap();
        // The flipped record is the log's final one, so this reads as a
        // (checksum-caught) torn tail and replay salvages the prefix.
        let c = read_wal(&p, 2).unwrap();
        assert!(c.torn_tail, "bit flip not caught by checksum");
        assert_eq!(c.batches.len(), 0);
    }

    #[test]
    fn corrupt_record_head_mid_log_is_not_a_torn_tail() {
        let p = tmp("head.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
            w.append(&batch(&[3.0, 4.0], 2)).unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip the low bit of record 0's `rows` field: the v1 checksum
        // (payload-only) would never notice.
        let off = header_bytes(VERSION) as usize + 8;
        bytes[off] ^= 1;
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", read_wal(&p, 2).unwrap_err());
        assert!(err.contains("corrupt WAL record"), "{err}");
        // The explicit salvage policy recovers nothing before the flip
        // but reports the corruption instead of failing.
        let c = read_wal_file(&p, 2, RecoveryPolicy::Truncate).unwrap();
        assert!(c.corrupt);
        assert_eq!(c.batches.len(), 0);
    }

    #[test]
    fn v1_logs_still_read_and_resume() {
        let p = tmp("v1.wal");
        std::fs::remove_file(&p).ok();
        // Hand-build a version-1 file: 12-byte header, one record with
        // a payload-only checksum.
        let payload: Vec<u8> = [1.5f32, -2.0]
            .iter()
            .flat_map(|v| v.to_bits().to_le_bytes())
            .collect();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes()); // seq
        bytes.extend_from_slice(&1u32.to_le_bytes()); // rows
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();

        let c = read_wal(&p, 2).unwrap();
        assert_eq!(c.version, 1);
        assert_eq!(c.batches.len(), 1);
        assert_eq!(c.batches[0].row(0), &[1.5, -2.0]);

        // A writer resuming a v1 file keeps appending v1 records so the
        // file stays self-consistent.
        {
            let (mut w, prior) = WalWriter::open(&p, 2).unwrap();
            assert_eq!(prior.version, 1);
            assert_eq!(w.append(&batch(&[7.0, 8.0], 2)).unwrap(), 1);
        }
        let c = read_wal(&p, 2).unwrap();
        assert_eq!(c.version, 1);
        assert!(!c.torn_tail);
        assert_eq!(c.batches.len(), 2);
        assert_eq!(c.batches[1].row(0), &[7.0, 8.0]);
    }

    #[test]
    fn rotation_and_set_recovery() {
        let dir = tmp("set");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let active = dir.join("inserts.wal");
        let storage: Arc<dyn Storage> = Arc::new(RealStorage);
        {
            let (mut set, rec) =
                WalSet::open(storage.clone(), &active, 2, RecoveryPolicy::FailFast).unwrap();
            assert_eq!(rec.batches.len(), 0);
            assert_eq!(set.append(&batch(&[0.0, 0.5], 2)).unwrap(), 0);
            set.rotate().unwrap();
            assert_eq!(set.sealed_count(), 1);
            assert_eq!(set.append(&batch(&[1.0, 1.5], 2)).unwrap(), 1);
            set.rotate().unwrap();
            assert_eq!(set.append(&batch(&[2.0, 2.5], 2)).unwrap(), 2);
        }
        assert!(segment_path(&active, 0).exists());
        assert!(segment_path(&active, 1).exists());
        let rec = read_wal_set(&active, 2, RecoveryPolicy::FailFast).unwrap();
        assert_eq!(rec.batches.len(), 3);
        assert_eq!(rec.next_seq, 3);
        for (i, b) in rec.batches.iter().enumerate() {
            assert_eq!(b.row(0)[0], i as f32, "batch order scrambled");
        }
        // Reopen: same recovery, sequence numbering continues.
        let (mut set, rec) =
            WalSet::open(storage.clone(), &active, 2, RecoveryPolicy::FailFast).unwrap();
        assert_eq!(rec.batches.len(), 3);
        assert_eq!(set.sealed_count(), 2);
        assert_eq!(set.append(&batch(&[3.0, 3.5], 2)).unwrap(), 3);

        // Compaction reset: sealed segments vanish, numbering holds.
        set.reset_absorbed(4).unwrap();
        assert_eq!(set.sealed_count(), 0);
        assert!(!segment_path(&active, 0).exists());
        assert_eq!(set.append(&batch(&[4.0, 4.5], 2)).unwrap(), 4);
        drop(set);
        let rec = read_wal_set(&active, 2, RecoveryPolicy::FailFast).unwrap();
        assert_eq!(rec.batches.len(), 1);
        assert_eq!(rec.next_seq, 5);
    }

    #[test]
    fn corrupt_sealed_segment_policies() {
        let dir = tmp("corrupt_set");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let active = dir.join("inserts.wal");
        let storage: Arc<dyn Storage> = Arc::new(RealStorage);
        {
            let (mut set, _) =
                WalSet::open(storage.clone(), &active, 2, RecoveryPolicy::FailFast).unwrap();
            set.append(&batch(&[0.0, 0.5], 2)).unwrap();
            set.rotate().unwrap();
            set.append(&batch(&[1.0, 1.5], 2)).unwrap();
            set.rotate().unwrap();
            set.append(&batch(&[2.0, 2.5], 2)).unwrap();
        }
        // Corrupt sealed segment 1 mid-record.
        let seg1 = segment_path(&active, 1);
        let mut bytes = std::fs::read(&seg1).unwrap();
        let off = header_bytes(VERSION) as usize + 8 + 4;
        bytes[off] ^= 0xff;
        std::fs::write(&seg1, &bytes).unwrap();

        let err = format!("{:#}", read_wal_set(&active, 2, RecoveryPolicy::FailFast).unwrap_err());
        assert!(err.contains("does not end cleanly"), "{err}");

        // Truncate policy: salvage segment 0, quarantine the rest, and
        // keep an appendable set whose numbering continues at 1.
        let (mut set, rec) =
            WalSet::open(storage.clone(), &active, 2, RecoveryPolicy::Truncate).unwrap();
        assert_eq!(rec.batches.len(), 1);
        assert_eq!(rec.corrupt_segments, 1);
        assert_eq!(rec.next_seq, 1);
        assert!(!seg1.exists(), "corrupt segment must be quarantined");
        assert_eq!(set.append(&batch(&[9.0, 9.5], 2)).unwrap(), 1);
        drop(set);
        let rec = read_wal_set(&active, 2, RecoveryPolicy::FailFast).unwrap();
        assert_eq!(rec.batches.len(), 2);
    }
}
