//! Append-only write-ahead log for live point insertion (`LVWL`).
//!
//! The live query server accepts `POST /insert` while running; those
//! points must survive a restart without rewriting the (potentially
//! huge) base checkpoints on every request. Each accepted batch is
//! appended to `inserts.wal` in the checkpoint directory *before* it is
//! applied to the in-memory state, and replayed in order at startup —
//! the recovered dataset is bit-identical to the pre-restart one.
//!
//! # Record format
//!
//! File header: 4-byte magic `LVWL`, `u32` version (LE, like every
//! other on-disk format here), then `u32 d` — the point dimensionality
//! the log is bound to (a WAL can never be replayed against a base of
//! a different width). Records follow back to back:
//!
//! ```text
//! u64 seq        batch sequence number (0-based, strictly increasing)
//! u32 rows       points in this batch (1 ..= MAX_WAL_BATCH_ROWS)
//! rows × d × f32 row-major point payload (bit patterns)
//! u32 checksum   FNV-1a over the payload bytes
//! ```
//!
//! A crash mid-append leaves a torn tail; replay stops at the first
//! short read, sequence gap, or checksum mismatch and reports how many
//! complete batches survived — standard WAL semantics. The writer
//! then continues appending *after* the surviving prefix (the file is
//! truncated to it on open), so one torn record never poisons the log;
//! a *failed* append likewise rolls the file back to the last complete
//! record before surfacing the error (see [`WalWriter::append`]).

use crate::data::formats::binary::{check_magic, read_u32, read_u64};
use crate::data::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// WAL file magic.
pub const MAGIC: &[u8; 4] = b"LVWL";
/// WAL format version.
pub const VERSION: u32 = 1;
/// Cap on rows per WAL record (a lying length prefix must not drive an
/// unbounded allocation; the server's per-request insert cap is far
/// smaller).
pub const MAX_WAL_BATCH_ROWS: usize = 1 << 20;

/// FNV-1a over `bytes` — cheap, dependency-free corruption detection
/// for the torn-tail case (not an integrity MAC).
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// The surviving content of a WAL file: complete batches only.
#[derive(Clone, Debug, Default)]
pub struct WalContents {
    /// Replayable batches, in append order; every row has the log's
    /// declared dimensionality.
    pub batches: Vec<Matrix>,
    /// Total rows across `batches`.
    pub rows: usize,
    /// Byte offset just past the last complete record — the append
    /// position for a writer resuming this log.
    pub valid_bytes: u64,
    /// True when a torn/corrupt tail was detected (and ignored).
    pub torn_tail: bool,
}

/// Read every complete batch from the WAL at `path`, validating
/// sequence numbers, shapes and checksums. `d` is the dimensionality
/// the caller's base data has; a WAL header disagreeing with it fails
/// loudly (stale checkpoint directory). A missing file is an empty log.
pub fn read_wal(path: &Path, d: usize) -> Result<WalContents> {
    let f = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalContents { valid_bytes: 0, ..Default::default() })
        }
        Err(e) => return Err(e).with_context(|| format!("open {}", path.display())),
    };
    // A crash between create and header write leaves a short file;
    // treat it as an empty (torn) log rather than a parse error.
    let len = f.metadata().map(|m| m.len()).unwrap_or(0);
    if len < header_bytes() {
        return Ok(WalContents { valid_bytes: 0, torn_tail: len > 0, ..Default::default() });
    }
    let mut r = BufReader::new(f);
    check_magic(&mut r, MAGIC, VERSION, path)?;
    let wal_d = read_u32(&mut r)? as usize;
    if wal_d != d {
        bail!(
            "{}: WAL holds {wal_d}-dimensional points, base data is {d}-dimensional — \
             stale checkpoint directory?",
            path.display()
        );
    }
    let mut out = WalContents { valid_bytes: header_bytes(), ..Default::default() };
    let mut payload: Vec<u8> = Vec::new();
    loop {
        // Each field read is allowed to hit EOF (torn tail) — only a
        // *complete* record advances `valid_bytes`.
        let Ok(seq) = read_u64(&mut r) else {
            break;
        };
        let Ok(rows) = read_u32(&mut r) else {
            out.torn_tail = true;
            break;
        };
        let rows = rows as usize;
        if seq != out.batches.len() as u64 || rows == 0 || rows > MAX_WAL_BATCH_ROWS {
            out.torn_tail = true;
            break;
        }
        payload.clear();
        payload.resize(rows * d * 4, 0);
        if r.read_exact(&mut payload).is_err() {
            out.torn_tail = true;
            break;
        }
        let Ok(want_sum) = read_u32(&mut r) else {
            out.torn_tail = true;
            break;
        };
        if fnv1a(&payload) != want_sum {
            out.torn_tail = true;
            break;
        }
        let vals: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
            .collect();
        out.rows += rows;
        out.batches.push(Matrix::from_vec(vals, rows, d));
        out.valid_bytes += 8 + 4 + rows as u64 * d as u64 * 4 + 4;
    }
    Ok(out)
}

/// Bytes of the fixed WAL header (magic + version + dimensionality).
fn header_bytes() -> u64 {
    4 + 4 + 4
}

/// Appending writer over a WAL file. Opening replays/validates the
/// existing log (if any), truncates away a torn tail, and positions at
/// the end; [`WalWriter::append`] then durably records one batch per
/// call — the whole record is written with one `write_all` and
/// `sync_data` **must succeed before the append returns `Ok`**, so an
/// acknowledged insert survives a process kill or power loss.
///
/// A *failed* append rolls the file back to the end of the last
/// complete record before returning the error: a transient I/O failure
/// (e.g. `ENOSPC` mid-write) must not leave partial bytes that would
/// make replay stop early and silently drop *later, acknowledged*
/// records. If even the rollback fails, the writer poisons itself and
/// refuses further appends instead of corrupting the log.
pub struct WalWriter {
    f: std::fs::File,
    path: PathBuf,
    d: usize,
    next_seq: u64,
    /// Byte offset just past the last durably recorded record.
    valid_bytes: u64,
    /// Set when a failed append could not be rolled back; the log tail
    /// state is unknown, so appending more would risk corruption.
    poisoned: bool,
}

impl WalWriter {
    /// Open (or create) the WAL at `path` for `d`-dimensional points.
    /// Returns the writer positioned after the surviving prefix plus
    /// that prefix's contents (the caller replays them into its state).
    pub fn open(path: &Path, d: usize) -> Result<(WalWriter, WalContents)> {
        let contents = read_wal(path, d)?;
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let valid_bytes = if contents.valid_bytes < header_bytes() {
            // Fresh (or header-torn) log: start it over.
            f.set_len(0).with_context(|| format!("truncate {}", path.display()))?;
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&(d as u32).to_le_bytes())?;
            f.sync_data()
                .with_context(|| format!("sync WAL header {}", path.display()))?;
            header_bytes()
        } else {
            // Drop any torn tail so the resumed log is a clean prefix.
            f.set_len(contents.valid_bytes)
                .with_context(|| format!("truncate {}", path.display()))?;
            contents.valid_bytes
        };
        f.seek(SeekFrom::End(0))?;
        let next_seq = contents.batches.len() as u64;
        Ok((
            WalWriter {
                f,
                path: path.to_path_buf(),
                d,
                next_seq,
                valid_bytes,
                poisoned: false,
            },
            contents,
        ))
    }

    /// Durably append one batch of points (shape-checked against the
    /// log's dimensionality). Returns the record's sequence number
    /// only after the record is written **and** fsync'd; on failure
    /// the file is rolled back to the previous record boundary.
    pub fn append(&mut self, batch: &Matrix) -> Result<u64> {
        if self.poisoned {
            bail!(
                "{}: WAL writer disabled by an earlier unrecoverable I/O error",
                self.path.display()
            );
        }
        if batch.d() != self.d {
            bail!(
                "{}: appending {}-dimensional rows to a {}-dimensional WAL",
                self.path.display(),
                batch.d(),
                self.d
            );
        }
        if batch.n() == 0 || batch.n() > MAX_WAL_BATCH_ROWS {
            bail!("{}: WAL batch of {} rows out of range", self.path.display(), batch.n());
        }
        let seq = self.next_seq;
        // Serialize the whole record up front so it hits the file in a
        // single write_all — no partial-record state to manage in the
        // common path.
        let mut record: Vec<u8> = Vec::with_capacity(16 + batch.n() * self.d * 4);
        record.extend_from_slice(&seq.to_le_bytes());
        record.extend_from_slice(&(batch.n() as u32).to_le_bytes());
        let payload_start = record.len();
        for &v in batch.as_slice() {
            record.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let checksum = fnv1a(&record[payload_start..]);
        record.extend_from_slice(&checksum.to_le_bytes());

        let wrote = self.f.write_all(&record).and_then(|_| self.f.sync_data());
        match wrote {
            Ok(()) => {
                self.valid_bytes += record.len() as u64;
                self.next_seq += 1;
                Ok(seq)
            }
            Err(e) => {
                // Roll back to the last complete record so this failure
                // cannot make replay drop later successful appends.
                let rolled = self
                    .f
                    .set_len(self.valid_bytes)
                    .and_then(|_| self.f.seek(SeekFrom::End(0)));
                if rolled.is_err() {
                    self.poisoned = true;
                }
                Err(e).with_context(|| {
                    format!(
                        "{}: WAL append of batch {seq} failed{}",
                        self.path.display(),
                        if self.poisoned { " (writer disabled: rollback also failed)" } else { "" }
                    )
                })
            }
        }
    }

    /// Batches durably recorded so far (surviving prefix + appends).
    pub fn batches(&self) -> u64 {
        self.next_seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_wal_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn batch(vals: &[f32], d: usize) -> Matrix {
        Matrix::from_vec(vals.to_vec(), vals.len() / d, d)
    }

    #[test]
    fn roundtrip_bit_identical() {
        let p = tmp("rt.wal");
        std::fs::remove_file(&p).ok();
        let b1 = batch(&[1.0, -2.5, 3.25, f32::MIN_POSITIVE, 0.0, -0.0], 3);
        let b2 = batch(&[9.0, 8.0, 7.0], 3);
        {
            let (mut w, prior) = WalWriter::open(&p, 3).unwrap();
            assert_eq!(prior.batches.len(), 0);
            assert_eq!(w.append(&b1).unwrap(), 0);
            assert_eq!(w.append(&b2).unwrap(), 1);
        }
        let back = read_wal(&p, 3).unwrap();
        assert!(!back.torn_tail);
        assert_eq!(back.batches.len(), 2);
        assert_eq!(back.rows, 3);
        // Bit-identical payloads (−0.0 and subnormals preserved).
        for (a, b) in [(&b1, &back.batches[0]), (&b2, &back.batches[1])] {
            assert_eq!(a.n(), b.n());
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn missing_file_is_empty_log() {
        let c = read_wal(&tmp("nope.wal"), 4).unwrap();
        assert_eq!(c.batches.len(), 0);
        assert!(!c.torn_tail);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let p = tmp("dim.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
            assert!(w.append(&batch(&[1.0, 2.0, 3.0], 3)).is_err());
        }
        let err = format!("{:#}", read_wal(&p, 3).unwrap_err());
        assert!(err.contains("2-dimensional"), "{err}");
    }

    #[test]
    fn torn_tail_ignored_and_truncated_on_reopen() {
        let p = tmp("torn.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
            w.append(&batch(&[3.0, 4.0, 5.0, 6.0], 2)).unwrap();
        }
        let full = std::fs::metadata(&p).unwrap().len();
        // Chop mid-record: the second batch loses its checksum.
        std::fs::OpenOptions::new()
            .write(true)
            .open(&p)
            .unwrap()
            .set_len(full - 3)
            .unwrap();
        let c = read_wal(&p, 2).unwrap();
        assert!(c.torn_tail);
        assert_eq!(c.batches.len(), 1);
        assert_eq!(c.rows, 1);
        // Reopening truncates the torn tail and appends after it with
        // the right sequence number.
        {
            let (mut w, prior) = WalWriter::open(&p, 2).unwrap();
            assert_eq!(prior.batches.len(), 1);
            assert_eq!(w.append(&batch(&[7.0, 8.0], 2)).unwrap(), 1);
        }
        let c = read_wal(&p, 2).unwrap();
        assert!(!c.torn_tail);
        assert_eq!(c.batches.len(), 2);
        assert_eq!(c.batches[1].row(0), &[7.0, 8.0]);
    }

    #[test]
    fn corrupt_payload_detected() {
        let p = tmp("crc.wal");
        std::fs::remove_file(&p).ok();
        {
            let (mut w, _) = WalWriter::open(&p, 2).unwrap();
            w.append(&batch(&[1.0, 2.0], 2)).unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip a payload bit (first value's low byte, after the
        // 12-byte header + 8-byte seq + 4-byte row count).
        let off = 12 + 8 + 4;
        bytes[off] ^= 1;
        std::fs::write(&p, &bytes).unwrap();
        let c = read_wal(&p, 2).unwrap();
        assert!(c.torn_tail, "bit flip not caught by checksum");
        assert_eq!(c.batches.len(), 0);
    }
}
