//! Little-endian binary matrix format (`.lvec`) with streaming access.
//!
//! Layout: magic `LVEC`, `u32` version, `u64 n`, `u64 d`, then `n*d`
//! `f32` values row-major. The format is identical to the one
//! `data::io` has always written, so existing files stay readable; this
//! module adds the pieces out-of-core ingestion needs:
//!
//! * [`ChunkedMatrixReader`] — pulls `chunk_rows` rows at a time into a
//!   reused bounded buffer, so parsing a 10M-point file holds
//!   `chunk_rows * d` floats, not `n * d`. The reader exposes its
//!   buffer capacities so tests can *assert* the memory bound.
//! * [`MatrixWriter`] — append rows without knowing `n` up front; the
//!   header's count is patched on [`MatrixWriter::finish`].

use crate::data::formats::{DEFAULT_CHUNK_ROWS, UNTRUSTED_CAPACITY_HINT};
use crate::data::matrix::{Matrix, RowStore};
use crate::util::faultio::{DurableFile, RealStorage, Storage};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, SeekFrom, Write};
use std::path::Path;

/// File magic for binary matrices.
pub const MAGIC: &[u8; 4] = b"LVEC";
/// Current format version.
pub const VERSION: u32 = 1;
/// Byte offset of the `n` field in the header (after magic + version).
const N_OFFSET: u64 = 4 + 4;

pub(crate) fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Values per bulk-I/O block: large arrays are encoded/decoded through
/// bounded reusable byte blocks instead of one `read_exact`/`write_all`
/// per value (at target scale the arrays hold 10⁸+ entries).
pub(crate) const IO_CHUNK: usize = 65_536;

/// Encode `vals` little-endian into `w` through the reusable scratch
/// `buf`, `IO_CHUNK` values per block. `WIDTH` is one value's byte
/// width, inferred from `enc`'s return type.
pub(crate) fn write_array<T: Copy, const WIDTH: usize>(
    w: &mut impl Write,
    vals: &[T],
    buf: &mut Vec<u8>,
    enc: impl Fn(T) -> [u8; WIDTH],
) -> Result<()> {
    for chunk in vals.chunks(IO_CHUNK) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&enc(v));
        }
        w.write_all(buf)?;
    }
    Ok(())
}

/// Read `n` little-endian values of `width` bytes each through a
/// bounded reusable byte block, appending to `out`. `n` is untrusted:
/// allocation grows with the data actually read, never with the
/// header's claim.
pub(crate) fn read_array<T>(
    r: &mut impl Read,
    n: usize,
    width: usize,
    out: &mut Vec<T>,
    dec: impl Fn(&[u8]) -> T,
) -> Result<()> {
    let mut buf = vec![0u8; n.min(IO_CHUNK).max(1) * width];
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(IO_CHUNK);
        let block = &mut buf[..take * width];
        r.read_exact(block)?;
        out.extend(block.chunks_exact(width).map(&dec));
        remaining -= take;
    }
    Ok(())
}

pub(crate) fn dec_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

pub(crate) fn dec_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Validate a 4-byte magic + `u32` version header. Shared by every
/// on-disk format in the system (matrices, labels, checkpoints) so the
/// header convention is implemented exactly once.
pub(crate) fn check_magic(
    r: &mut impl Read,
    want: &[u8; 4],
    want_version: u32,
    path: &Path,
) -> Result<()> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated header", path.display()))?;
    if &magic != want {
        bail!(
            "{}: bad magic {:?} (expected {:?})",
            path.display(),
            String::from_utf8_lossy(&magic),
            String::from_utf8_lossy(want)
        );
    }
    let version = read_u32(r)?;
    if version != want_version {
        bail!("{}: unsupported version {version}", path.display());
    }
    Ok(())
}

/// Streaming reader: `chunk_rows` rows per [`ChunkedMatrixReader::next_chunk`]
/// call, into one reused buffer.
pub struct ChunkedMatrixReader {
    r: BufReader<std::fs::File>,
    n: usize,
    d: usize,
    chunk_rows: usize,
    rows_read: usize,
    /// Reused decoded-value buffer (≤ chunk_rows * d floats).
    buf: Vec<f32>,
    /// Reused raw-byte buffer (≤ chunk_rows * d * 4 bytes).
    bytes: Vec<u8>,
}

impl ChunkedMatrixReader {
    /// Open `path` and parse the header; rows are not read yet.
    pub fn open(path: &Path, chunk_rows: usize) -> Result<Self> {
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        check_magic(&mut r, MAGIC, VERSION, path)?;
        let n = read_u64(&mut r)? as usize;
        let d = read_u64(&mut r)? as usize;
        crate::data::formats::check_shape(path, n, d)?;
        Ok(ChunkedMatrixReader {
            r,
            n,
            d,
            chunk_rows: chunk_rows.max(1),
            rows_read: 0,
            buf: Vec::new(),
            bytes: Vec::new(),
        })
    }

    /// Total rows per the header.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Row dimensionality per the header.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows consumed so far.
    pub fn rows_read(&self) -> usize {
        self.rows_read
    }

    /// Peak size of the parse buffers in bytes — what tests assert the
    /// memory bound on. Never exceeds `chunk_rows * d * 8` (4 bytes raw
    /// + 4 bytes decoded per value).
    pub fn parse_buffer_bytes(&self) -> usize {
        self.buf.capacity() * 4 + self.bytes.capacity()
    }

    /// Read the next ≤ `chunk_rows` rows; `None` once all `n` rows are
    /// consumed. The returned slice (`rows * d` values) aliases the
    /// internal buffer and is valid until the next call. Chunks are
    /// additionally capped so `chunk_rows × d` from an untrusted header
    /// cannot drive a giant buffer allocation (a chunk always holds at
    /// least one row).
    pub fn next_chunk(&mut self) -> Result<Option<&[f32]>> {
        let remaining = self.n - self.rows_read;
        if remaining == 0 {
            return Ok(None);
        }
        let row_cap = (UNTRUSTED_CAPACITY_HINT / self.d.max(1)).max(1);
        let rows = remaining.min(self.chunk_rows).min(row_cap);
        let values = rows * self.d;
        self.bytes.resize(values * 4, 0);
        let (lo, hi) = (self.rows_read, self.rows_read + rows);
        self.r
            .read_exact(&mut self.bytes)
            .with_context(|| format!("truncated matrix: failed reading rows {lo}..{hi}"))?;
        self.buf.clear();
        self.buf.reserve(values);
        for c in self.bytes.chunks_exact(4) {
            self.buf.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        self.rows_read += rows;
        Ok(Some(&self.buf))
    }
}

/// Read a whole binary matrix through the chunked reader (bounded parse
/// buffers; one final `n × d` allocation for the result).
pub fn read_binary(path: &Path) -> Result<Matrix> {
    let mut r = ChunkedMatrixReader::open(path, DEFAULT_CHUNK_ROWS)?;
    let (n, d) = (r.n(), r.d());
    // Capacity hint clamped: a lying header must hit a read error, not
    // drive a terabyte reservation up front.
    let mut data: Vec<f32> = Vec::with_capacity((n * d).min(UNTRUSTED_CAPACITY_HINT));
    while let Some(chunk) = r.next_chunk()? {
        data.extend_from_slice(chunk);
    }
    Ok(Matrix::from_vec(data, n, d))
}

/// Write a whole matrix to `path` in `.lvec` format. Generic over
/// [`RowStore`], so both the flat [`Matrix`] and the serving path's
/// chunked store serialize through the same code — the bytes written
/// depend only on the row values, never on the chunk layout.
pub fn write_binary(path: &Path, m: &impl RowStore) -> Result<()> {
    write_binary_with(&RealStorage, path, m)
}

/// [`write_binary`] through an explicit [`Storage`] — the durable
/// (fault-injectable) path WAL compaction uses. Streams one
/// [`RowStore::row_block`] at a time, so a chunked store is written
/// without materializing a contiguous copy.
pub fn write_binary_with(storage: &dyn Storage, path: &Path, m: &impl RowStore) -> Result<()> {
    let (n, d) = (m.n(), m.d());
    let mut w = MatrixWriter::create_with(storage, path, d)?;
    let mut i = 0;
    while i < n {
        let (block, rows) = m.row_block(i);
        w.write_values(&block[..rows * d])?;
        i += rows;
    }
    let written = w.finish()?;
    debug_assert_eq!(written, n);
    Ok(())
}

/// Append-only streaming writer; the header's `n` is patched at
/// [`MatrixWriter::finish`], so callers can stream without knowing the
/// row count up front. All I/O goes through a [`DurableFile`], and
/// `finish` syncs file contents before returning, so a completed write
/// survives a crash.
pub struct MatrixWriter {
    w: BufWriter<Box<dyn DurableFile>>,
    d: usize,
    rows: usize,
    partial: usize,
    /// Reusable encode scratch for [`write_array`].
    buf: Vec<u8>,
    path: std::path::PathBuf,
}

impl MatrixWriter {
    /// Create `path` on the real filesystem, writing a header with a
    /// placeholder row count.
    pub fn create(path: &Path, d: usize) -> Result<Self> {
        MatrixWriter::create_with(&RealStorage, path, d)
    }

    /// [`MatrixWriter::create`] through an explicit [`Storage`].
    pub fn create_with(storage: &dyn Storage, path: &Path, d: usize) -> Result<Self> {
        let f = storage
            .create_durable(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&0u64.to_le_bytes())?; // n, patched in finish()
        w.write_all(&(d as u64).to_le_bytes())?;
        Ok(MatrixWriter { w, d, rows: 0, partial: 0, buf: Vec::new(), path: path.to_path_buf() })
    }

    /// Append one `d`-length row.
    pub fn write_row(&mut self, row: &[f32]) -> Result<()> {
        if row.len() != self.d {
            bail!("{}: row of {} values, expected {}", self.path.display(), row.len(), self.d);
        }
        self.write_values(row)
    }

    /// Append raw values (any multiple of rows; partial rows are
    /// tracked and rejected at finish). Values are block-encoded
    /// through the reusable scratch buffer, not written one at a time.
    pub fn write_values(&mut self, values: &[f32]) -> Result<()> {
        write_array(&mut self.w, values, &mut self.buf, |v: f32| v.to_le_bytes())?;
        if self.d > 0 {
            let total = self.partial + values.len();
            self.rows += total / self.d;
            self.partial = total % self.d;
        }
        Ok(())
    }

    /// Rows fully written so far.
    pub fn rows_written(&self) -> usize {
        self.rows
    }

    /// Flush, patch the header's row count, fsync, and return the
    /// count. Only after `finish` returns `Ok` is the file durable.
    pub fn finish(mut self) -> Result<usize> {
        if self.partial != 0 {
            bail!(
                "{}: {} trailing values do not form a full {}-d row",
                self.path.display(),
                self.partial,
                self.d
            );
        }
        self.w.flush()?;
        let mut f = self.w.into_inner().map_err(|e| anyhow::anyhow!("flush: {e}"))?;
        f.seek(SeekFrom::Start(N_OFFSET))?;
        f.write_all(&(self.rows as u64).to_le_bytes())?;
        f.sync_data()
            .with_context(|| format!("sync {}", self.path.display()))?;
        Ok(self.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_binary_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_bits() {
        let m = Matrix::from_vec(
            vec![0.0, -0.0, 1.5, f32::MIN_POSITIVE, -1e30, 3.25, 7.0, -2.5],
            4,
            2,
        );
        let p = tmp("rt.lvec");
        write_binary(&p, &m).unwrap();
        let back = read_binary(&p).unwrap();
        for (a, b) in m.as_slice().iter().zip(back.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn chunked_reader_bounded_and_complete() {
        let m = Matrix::from_vec((0..70).map(|x| x as f32 * 0.5).collect(), 10, 7);
        let p = tmp("chunks.lvec");
        write_binary(&p, &m).unwrap();
        let mut r = ChunkedMatrixReader::open(&p, 3).unwrap();
        assert_eq!((r.n(), r.d()), (10, 7));
        let mut all = Vec::new();
        while let Some(c) = r.next_chunk().unwrap() {
            assert!(c.len() <= 3 * 7);
            all.extend_from_slice(c);
            assert!(r.parse_buffer_bytes() <= 3 * 7 * 8, "buffer grew past bound");
        }
        assert_eq!(all, m.as_slice());
        assert_eq!(r.rows_read(), 10);
        assert!(r.next_chunk().unwrap().is_none());
    }

    #[test]
    fn streaming_writer_patches_count() {
        let p = tmp("stream.lvec");
        let mut w = MatrixWriter::create(&p, 3).unwrap();
        for i in 0..5 {
            w.write_row(&[i as f32, 0.5, -1.0]).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 5);
        let m = read_binary(&p).unwrap();
        assert_eq!((m.n(), m.d()), (5, 3));
        assert_eq!(m.row(4)[0], 4.0);
    }

    #[test]
    fn truncated_file_rejected() {
        let m = Matrix::from_vec(vec![1.0; 12], 4, 3);
        let p = tmp("trunc.lvec");
        write_binary(&p, &m).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 5]).unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn partial_row_rejected_at_finish() {
        let p = tmp("partial.lvec");
        let mut w = MatrixWriter::create(&p, 3).unwrap();
        w.write_values(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!(w.finish().is_err());
    }

    #[test]
    fn bad_magic_and_version() {
        let p = tmp("magic.lvec");
        std::fs::write(&p, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(read_binary(&p).is_err());
        let mut good = Vec::new();
        good.extend_from_slice(MAGIC);
        good.extend_from_slice(&99u32.to_le_bytes());
        good.extend_from_slice(&0u64.to_le_bytes());
        good.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &good).unwrap();
        assert!(read_binary(&p).is_err());
    }
}
