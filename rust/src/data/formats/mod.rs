//! On-disk dataset and checkpoint formats — the ingestion layer that
//! replaces "generation stands in for I/O" with real files.
//!
//! Three formats live here:
//!
//! * [`text`] — the original LargeVis text format (`n d` header, then
//!   `n` whitespace-separated rows), parsed with a bounded row buffer.
//! * [`binary`] — the little-endian `.lvec` binary matrix format with
//!   a streaming chunked reader ([`binary::ChunkedMatrixReader`]) and
//!   an append-only writer ([`binary::MatrixWriter`]), so a dataset
//!   never needs to fit in one allocation during parse.
//! * [`checkpoint`] — bit-exact serialization of the pipeline's two
//!   expensive intermediates ([`crate::knn::KnnGraph`] and
//!   [`crate::graph::CsrGraph`]), the substrate for
//!   `--resume-from <stage>`.
//! * [`wal`] — the append-only insert log (`inserts.wal`) the live
//!   query server writes before applying `POST /insert` batches, and
//!   replays at startup to recover them bit-identically.
//!
//! All integers and floats are little-endian; every format starts with
//! a 4-byte magic and a `u32` version so corruption and accidental
//! cross-format reads fail loudly instead of mis-parsing.

pub mod binary;
pub mod checkpoint;
pub mod text;
pub mod wal;

use crate::data::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Default rows per parse chunk for every streaming reader (at d=100
/// this is ~25 MB of parse buffer).
pub const DEFAULT_CHUNK_ROWS: usize = 65_536;

/// Cap for capacity *hints* derived from untrusted file headers: the
/// vectors still grow to the real data size, but a lying header can
/// only pre-reserve this much before the reads themselves fail.
pub(crate) const UNTRUSTED_CAPACITY_HINT: usize = 1 << 20;

/// A recognized input-matrix file format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputFormat {
    /// LargeVis text: `n d` header + whitespace rows.
    LargeVisText,
    /// `.lvec` little-endian binary matrix.
    Binary,
}

/// Detect the format of `path` by sniffing the first bytes: the binary
/// magic wins, anything else is treated as text.
pub fn detect_format(path: &Path) -> Result<InputFormat> {
    use std::io::Read;
    let mut f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut head = [0u8; 4];
    match f.read_exact(&mut head) {
        Ok(()) if &head == binary::MAGIC => Ok(InputFormat::Binary),
        _ => Ok(InputFormat::LargeVisText),
    }
}

/// Read a matrix from `path` in whichever supported format it is in.
///
/// Binary files go through the chunked reader (bounded parse buffer);
/// text files go through the line parser. The returned [`Matrix`] is of
/// course one allocation — the bound applies to the *parse* buffers.
pub fn read_any(path: &Path) -> Result<Matrix> {
    match detect_format(path)? {
        InputFormat::Binary => binary::read_binary(path),
        InputFormat::LargeVisText => text::read_text(path),
    }
}

/// Stream a matrix from `path` chunk-by-chunk into `sink(rows, n_rows)`
/// without materializing it; returns `(n, d)`. `chunk_rows` bounds the
/// parse buffer for both formats.
pub fn stream_any(
    path: &Path,
    chunk_rows: usize,
    mut sink: impl FnMut(&[f32], usize) -> Result<()>,
) -> Result<(usize, usize)> {
    match detect_format(path)? {
        InputFormat::Binary => {
            let mut r = binary::ChunkedMatrixReader::open(path, chunk_rows)?;
            let (n, d) = (r.n(), r.d());
            while let Some(chunk) = r.next_chunk()? {
                let rows = chunk.len() / d.max(1);
                sink(chunk, rows)?;
            }
            Ok((n, d))
        }
        InputFormat::LargeVisText => text::stream_text(path, chunk_rows, sink),
    }
}

/// Convert between the two input formats by extension of `dst`
/// (`.txt`/`.tsv` → text, anything else → binary), streaming through a
/// bounded buffer in both directions.
pub fn convert(src: &Path, dst: &Path, chunk_rows: usize) -> Result<(usize, usize)> {
    // Creating the destination truncates it — converting a file onto
    // itself (directly or via a symlink) would destroy the input
    // before it is ever read.
    if let (Ok(a), Ok(b)) = (src.canonicalize(), dst.canonicalize()) {
        if a == b {
            bail!("{}: source and destination are the same file", src.display());
        }
    }
    let to_text = matches!(
        dst.extension().and_then(|e| e.to_str()),
        Some("txt") | Some("tsv") | Some("text")
    );
    if to_text {
        // Text needs n in the header before any row, so probe the
        // source header first (cheap for both formats). The file could
        // change between this open and the streaming one, so every
        // chunk re-checks the row width instead of trusting the peek.
        let (n, d) = peek_shape(src)?;
        let mut w = text::TextMatrixWriter::create(dst, n, d)?;
        stream_any(src, chunk_rows, |rows, n_rows| {
            let dd = if n_rows > 0 { rows.len() / n_rows } else { d };
            if dd != d {
                bail!("{}: dimensionality changed during read ({d} -> {dd})", src.display());
            }
            for r in 0..n_rows {
                w.write_row(&rows[r * d..(r + 1) * d])?;
            }
            Ok(())
        })?;
        w.finish()?;
        Ok((n, d))
    } else {
        let (_, d) = peek_shape(src)?;
        let mut w = binary::MatrixWriter::create(dst, d)?;
        let shape = stream_any(src, chunk_rows, |rows, n_rows| {
            let dd = if n_rows > 0 { rows.len() / n_rows } else { d };
            if dd != d {
                bail!("{}: dimensionality changed during read ({d} -> {dd})", src.display());
            }
            w.write_values(rows)
        })?;
        w.finish()?;
        Ok(shape)
    }
}

/// Read just the `(n, d)` shape of a matrix file (either format).
pub fn peek_shape(path: &Path) -> Result<(usize, usize)> {
    match detect_format(path)? {
        InputFormat::Binary => {
            let r = binary::ChunkedMatrixReader::open(path, 1)?;
            Ok((r.n(), r.d()))
        }
        InputFormat::LargeVisText => text::read_header(path),
    }
}

/// Guard against absurd headers before allocating (`n*d` must fit and
/// stay under a sanity cap of 2^40 values).
pub(crate) fn check_shape(path: &Path, n: usize, d: usize) -> Result<usize> {
    let total = n.checked_mul(d).with_context(|| format!("{}: n*d overflow", path.display()))?;
    if d == 0 && n > 0 {
        bail!("{}: zero-dimensional rows", path.display());
    }
    if total > (1usize << 40) {
        bail!("{}: implausible shape {n}x{d}", path.display());
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_formats_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> Matrix {
        Matrix::from_vec((0..40).map(|x| x as f32 * 0.25 - 3.0).collect(), 8, 5)
    }

    #[test]
    fn detect_by_magic() {
        let m = sample();
        let pb = tmp("detect.lvec");
        binary::write_binary(&pb, &m).unwrap();
        assert_eq!(detect_format(&pb).unwrap(), InputFormat::Binary);
        let pt = tmp("detect.txt");
        text::write_text(&pt, &m).unwrap();
        assert_eq!(detect_format(&pt).unwrap(), InputFormat::LargeVisText);
    }

    #[test]
    fn read_any_both_formats() {
        let m = sample();
        let pb = tmp("any.lvec");
        binary::write_binary(&pb, &m).unwrap();
        assert_eq!(read_any(&pb).unwrap(), m);
        let pt = tmp("any.txt");
        text::write_text(&pt, &m).unwrap();
        assert_eq!(read_any(&pt).unwrap(), m);
    }

    #[test]
    fn convert_roundtrip_both_ways() {
        let m = sample();
        let a = tmp("conv_a.lvec");
        binary::write_binary(&a, &m).unwrap();
        let b = tmp("conv_b.txt");
        assert_eq!(convert(&a, &b, 3).unwrap(), (8, 5));
        let c = tmp("conv_c.lvec");
        assert_eq!(convert(&b, &c, 3).unwrap(), (8, 5));
        assert_eq!(read_any(&c).unwrap(), m);
    }

    #[test]
    fn convert_refuses_same_file() {
        let m = sample();
        let p = tmp("same.lvec");
        binary::write_binary(&p, &m).unwrap();
        assert!(convert(&p, &p, 4).is_err());
        // The input must be untouched.
        assert_eq!(read_any(&p).unwrap(), m);
    }

    #[test]
    fn stream_any_bounded_chunks() {
        let m = sample();
        let p = tmp("stream.lvec");
        binary::write_binary(&p, &m).unwrap();
        let mut collected = Vec::new();
        let (n, d) = stream_any(&p, 3, |rows, n_rows| {
            assert!(n_rows <= 3);
            assert_eq!(rows.len(), n_rows * 5);
            collected.extend_from_slice(rows);
            Ok(())
        })
        .unwrap();
        assert_eq!((n, d), (8, 5));
        assert_eq!(collected, m.as_slice());
    }
}
