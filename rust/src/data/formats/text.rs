//! The original LargeVis text input format.
//!
//! Line 1: `n d` (point count, dimensionality). Then exactly `n` data
//! rows of `d` whitespace-separated floats. Accepted liberally on the
//! way in: CRLF or LF line endings, runs of spaces/tabs, scientific
//! notation (`1e-3`, `-2.5E2`, `+1.5e+2`), and blank lines (skipped).
//! Rejected loudly: ragged rows (wrong value count), unparsable or
//! non-finite floats (`nan`/`inf` would silently poison every distance
//! downstream), and a row count that disagrees with the header — each
//! with a 1-based line number so multi-gigabyte files are debuggable.
//!
//! Parsing is streaming: rows are accumulated into a bounded
//! `chunk_rows × d` buffer and flushed to the caller's sink, so the
//! parse never holds more than one chunk regardless of file size.

use crate::data::formats::{DEFAULT_CHUNK_ROWS, UNTRUSTED_CAPACITY_HINT};
use crate::data::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Read just the `n d` header of a LargeVis text file.
pub fn read_header(path: &Path) -> Result<(usize, usize)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut line = String::new();
    r.read_line(&mut line).with_context(|| format!("read {}", path.display()))?;
    parse_header(path, &line)
}

fn parse_header(path: &Path, line: &str) -> Result<(usize, usize)> {
    let mut it = line.split_ascii_whitespace();
    let (Some(ns), Some(ds), None) = (it.next(), it.next(), it.next()) else {
        bail!("{}:1: header must be exactly `n d`, got {:?}", path.display(), line.trim_end());
    };
    let n: usize = ns
        .parse()
        .map_err(|_| anyhow::anyhow!("{}:1: bad point count {ns:?}", path.display()))?;
    let d: usize = ds
        .parse()
        .map_err(|_| anyhow::anyhow!("{}:1: bad dimensionality {ds:?}", path.display()))?;
    crate::data::formats::check_shape(path, n, d)?;
    Ok((n, d))
}

/// Stream-parse `path`, delivering rows to `sink(values, n_rows)` in
/// chunks of at most `chunk_rows` rows (`values.len() == n_rows * d`).
/// Returns `(n, d)` from the header. The parse buffer is bounded by
/// `chunk_rows * d` floats.
pub fn stream_text(
    path: &Path,
    chunk_rows: usize,
    mut sink: impl FnMut(&[f32], usize) -> Result<()>,
) -> Result<(usize, usize)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut line = String::new();
    r.read_line(&mut line).with_context(|| format!("read {}", path.display()))?;
    let (n, d) = parse_header(path, &line)?;

    let chunk_rows = chunk_rows.max(1);
    let hint = (chunk_rows.min(n.max(1)) * d).min(UNTRUSTED_CAPACITY_HINT);
    let mut buf: Vec<f32> = Vec::with_capacity(hint);
    let mut rows_in_buf = 0usize;
    let mut rows_seen = 0usize;
    let mut line_no = 1usize; // header was line 1
    loop {
        line.clear();
        let bytes = r.read_line(&mut line).with_context(|| format!("read {}", path.display()))?;
        if bytes == 0 {
            break;
        }
        line_no += 1;
        // `split_ascii_whitespace` treats `\r` as whitespace, so CRLF
        // endings need no special casing.
        let mut count = 0usize;
        for tok in line.split_ascii_whitespace() {
            let v: f32 = tok.parse().map_err(|_| {
                anyhow::anyhow!("{}:{line_no}: unparsable value {tok:?}", path.display())
            })?;
            if !v.is_finite() {
                bail!("{}:{line_no}: non-finite value {tok:?}", path.display());
            }
            buf.push(v);
            count += 1;
        }
        if count == 0 {
            continue; // blank line
        }
        if count != d {
            bail!(
                "{}:{line_no}: ragged row — {count} values, expected {d}",
                path.display()
            );
        }
        rows_seen += 1;
        if rows_seen > n {
            bail!(
                "{}:{line_no}: more data rows than the header's n={n}",
                path.display()
            );
        }
        rows_in_buf += 1;
        if rows_in_buf == chunk_rows {
            sink(&buf, rows_in_buf)?;
            buf.clear();
            rows_in_buf = 0;
        }
    }
    if rows_in_buf > 0 {
        sink(&buf, rows_in_buf)?;
    }
    if rows_seen != n {
        bail!("{}: {rows_seen} data rows, header says n={n}", path.display());
    }
    Ok((n, d))
}

/// Read a whole LargeVis text file into a [`Matrix`] (streamed through
/// the chunked parser into one preallocated buffer).
pub fn read_text(path: &Path) -> Result<Matrix> {
    let (n, d) = read_header(path)?;
    // Capacity hint clamped: the header is untrusted input.
    let mut data: Vec<f32> = Vec::with_capacity((n * d).min(UNTRUSTED_CAPACITY_HINT));
    stream_text(path, DEFAULT_CHUNK_ROWS, |vals, _| {
        data.extend_from_slice(vals);
        Ok(())
    })?;
    Ok(Matrix::from_vec(data, n, d))
}

/// Write a matrix in LargeVis text format. Values are printed with
/// Rust's shortest-roundtrip float formatting, so text output parses
/// back bit-identically.
pub fn write_text(path: &Path, m: &Matrix) -> Result<()> {
    let mut w = TextMatrixWriter::create(path, m.n(), m.d())?;
    for i in 0..m.n() {
        w.write_row(m.row(i))?;
    }
    w.finish()
}

/// Streaming row-by-row text writer (header first, so `n` must be
/// known up front — use the binary format when it is not).
pub struct TextMatrixWriter {
    w: BufWriter<std::fs::File>,
    n: usize,
    d: usize,
    written: usize,
    path: std::path::PathBuf,
}

impl TextMatrixWriter {
    /// Create `path` and write the `n d` header.
    pub fn create(path: &Path, n: usize, d: usize) -> Result<Self> {
        let f =
            std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{n} {d}")?;
        Ok(TextMatrixWriter { w, n, d, written: 0, path: path.to_path_buf() })
    }

    /// Append one row (must be called exactly `n` times).
    pub fn write_row(&mut self, row: &[f32]) -> Result<()> {
        if row.len() != self.d {
            bail!("{}: row of {} values, expected {}", self.path.display(), row.len(), self.d);
        }
        for (i, v) in row.iter().enumerate() {
            if i > 0 {
                self.w.write_all(b" ")?;
            }
            write!(self.w, "{v}")?;
        }
        self.w.write_all(b"\n")?;
        self.written += 1;
        Ok(())
    }

    /// Flush and verify the row count matches the header.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush()?;
        if self.written != self.n {
            bail!("{}: wrote {} rows, header says {}", self.path.display(), self.written, self.n);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_text_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_exact() {
        let m = Matrix::from_vec(
            vec![0.1, -2.5e-8, 3.0, f32::MIN_POSITIVE, 1e30, -0.0, 7.25, 42.0],
            4,
            2,
        );
        let p = tmp("rt.txt");
        write_text(&p, &m).unwrap();
        let back = read_text(&p).unwrap();
        assert_eq!(m.n(), back.n());
        for (a, b) in m.as_slice().iter().zip(back.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn header_errors() {
        let p = tmp("hdr.txt");
        std::fs::write(&p, "3\n1 2 3\n").unwrap();
        assert!(read_text(&p).is_err());
        std::fs::write(&p, "a b\n").unwrap();
        assert!(read_text(&p).is_err());
        std::fs::write(&p, "2 3 4\n").unwrap();
        assert!(read_text(&p).is_err());
    }

    #[test]
    fn row_count_mismatch_detected() {
        let p = tmp("count.txt");
        std::fs::write(&p, "3 2\n1 2\n3 4\n").unwrap();
        let err = read_text(&p).unwrap_err().to_string();
        assert!(err.contains("header says n=3"), "{err}");
        std::fs::write(&p, "1 2\n1 2\n3 4\n").unwrap();
        let err = read_text(&p).unwrap_err().to_string();
        assert!(err.contains("more data rows"), "{err}");
    }

    #[test]
    fn blank_lines_skipped() {
        let p = tmp("blank.txt");
        std::fs::write(&p, "2 2\n\n1 2\n\n3 4\n\n").unwrap();
        let m = read_text(&p).unwrap();
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn non_finite_values_rejected() {
        let p = tmp("nonfinite.txt");
        for bad in ["nan", "NaN", "inf", "-inf", "1e9999"] {
            std::fs::write(&p, format!("1 2\n0.5 {bad}\n")).unwrap();
            let err = read_text(&p).unwrap_err().to_string();
            assert!(err.contains(":2:"), "{bad}: {err}");
        }
    }

    #[test]
    fn chunked_stream_bounded() {
        let p = tmp("chunk.txt");
        let m = Matrix::from_vec((0..30).map(|x| x as f32).collect(), 10, 3);
        write_text(&p, &m).unwrap();
        let mut all = Vec::new();
        let mut chunks = 0;
        stream_text(&p, 4, |vals, rows| {
            assert!(rows <= 4);
            assert_eq!(vals.len(), rows * 3);
            all.extend_from_slice(vals);
            chunks += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(chunks, 3); // 4 + 4 + 2
        assert_eq!(all, m.as_slice());
    }

    #[test]
    fn writer_checks_shape() {
        let p = tmp("shape.txt");
        let mut w = TextMatrixWriter::create(&p, 2, 3).unwrap();
        assert!(w.write_row(&[1.0, 2.0]).is_err());
        w.write_row(&[1.0, 2.0, 3.0]).unwrap();
        assert!(w.finish().is_err()); // only 1 of 2 rows written
    }
}
