//! Dense row-major `f32` matrix — the in-memory format for both the
//! high-dimensional input points and the low-dimensional layout.

/// Dense row-major matrix of `n` rows × `d` columns.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

impl Matrix {
    /// Zero-filled `n × d` matrix.
    pub fn zeros(n: usize, d: usize) -> Self {
        Matrix { data: vec![0.0; n * d], n, d }
    }

    /// Wrap an existing buffer; `data.len()` must equal `n * d`.
    pub fn from_vec(data: Vec<f32>, n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d, "buffer length {} != {}x{}", data.len(), n, d);
        Matrix { data, n, d }
    }

    /// Number of rows (points).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of columns (dimensions).
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// The full backing buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable backing buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f32 {
        sqdist(self.row(i), self.row(j))
    }

    /// Copy a subset of rows into a new matrix (preserving order).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.d);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Normalize every row to unit L2 norm (zero rows left untouched).
    pub fn normalize_rows(&mut self) {
        for i in 0..self.n {
            let row = self.row_mut(i);
            let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                for x in row.iter_mut() {
                    *x /= norm;
                }
            }
        }
    }

    /// Append a row (used by the incremental/dynamic-data extension).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "row length {} != d {}", row.len(), self.d);
        self.data.extend_from_slice(row);
        self.n += 1;
    }

    /// Per-column mean.
    pub fn col_means(&self) -> Vec<f32> {
        let mut means = vec![0f64; self.d];
        for i in 0..self.n {
            for (m, &x) in means.iter_mut().zip(self.row(i)) {
                *m += x as f64;
            }
        }
        means.iter().map(|&m| (m / self.n.max(1) as f64) as f32).collect()
    }
}

/// Read-only row access shared by the flat [`Matrix`] and the serving
/// path's chunked copy-on-write store
/// ([`ChunkedMatrix`](crate::data::chunked::ChunkedMatrix)). Rows never
/// straddle chunk boundaries, so `row` keeps the familiar slice shape;
/// block-oriented consumers (the batched distance kernels, checkpoint
/// writers) iterate [`RowStore::row_block`] instead of assuming one
/// contiguous buffer.
pub trait RowStore {
    /// Number of rows.
    fn n(&self) -> usize;
    /// Number of columns.
    fn d(&self) -> usize;
    /// Row `i` as a slice.
    fn row(&self, i: usize) -> &[f32];
    /// Longest contiguous block starting at row `i`: the backing slice
    /// (at least `rows * d` values) and `rows`, the number of full rows
    /// it holds. Iterating `i += rows` visits every row exactly once.
    fn row_block(&self, i: usize) -> (&[f32], usize);
}

impl RowStore for Matrix {
    fn n(&self) -> usize {
        self.n
    }
    fn d(&self) -> usize {
        self.d
    }
    fn row(&self, i: usize) -> &[f32] {
        Matrix::row(self, i)
    }
    fn row_block(&self, i: usize) -> (&[f32], usize) {
        (&self.data[i * self.d..], self.n - i)
    }
}

// The distance kernels moved to the runtime-dispatched SIMD subsystem
// in `crate::kernels` (scalar reference lives in `kernels::scalar`).
// Re-exported here so `data::matrix::{sqdist, sqdist_bounded, dot}`
// remains the stable path every consumer already imports.
pub use crate::kernels::{dot, sqdist, sqdist_bounded};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_rows() {
        let mut m = Matrix::zeros(3, 4);
        assert_eq!((m.n(), m.d()), (3, 4));
        m.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(0), &[0.0; 4]);
    }

    #[test]
    fn sqdist_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32 * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((sqdist(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn sqdist_bounded_exact_below_bound() {
        let a: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..100).map(|i| (i as f32).cos()).collect();
        let exact = sqdist(&a, &b);
        assert!((sqdist_bounded(&a, &b, f32::INFINITY) - exact).abs() < 1e-4);
        // With a bound above the true value the result is still exact.
        assert!((sqdist_bounded(&a, &b, exact * 1.01) - exact).abs() < 1e-4);
        // With a tiny bound the result merely exceeds the bound.
        assert!(sqdist_bounded(&a, &b, 0.001) > 0.001);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..9).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), naive);
    }

    #[test]
    fn gather_rows_preserves_order() {
        let m = Matrix::from_vec((0..12).map(|x| x as f32).collect(), 4, 3);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), m.row(2));
        assert_eq!(g.row(1), m.row(0));
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut m = Matrix::from_vec(vec![3.0, 4.0, 0.0, 0.0], 2, 2);
        m.normalize_rows();
        assert!((m.row(0)[0] - 0.6).abs() < 1e-6);
        assert!((m.row(0)[1] - 0.8).abs() < 1e-6);
        assert_eq!(m.row(1), &[0.0, 0.0]); // zero row untouched
    }

    #[test]
    fn col_means() {
        let m = Matrix::from_vec(vec![1.0, 10.0, 3.0, 30.0], 2, 2);
        assert_eq!(m.col_means(), vec![2.0, 20.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Matrix::from_vec(vec![0.0; 5], 2, 3);
    }
}
