//! Chunked copy-on-write storage for the serving snapshot path.
//!
//! The epoch-swap serving design (see `serve/state.rs`) publishes a
//! fresh immutable [`Snapshot`] per insert batch. With flat matrices a
//! publish is an O(N) memcpy of every row, so insert throughput decays
//! with base size. The types here split each store into fixed-size
//! immutable chunks behind [`Arc`]s:
//!
//! - [`ChunkedMatrix`] — row-major `f32` rows (data and layout),
//!   [`MATRIX_CHUNK_ROWS`] rows per chunk;
//! - [`ChunkedKnn`] — per-point sorted neighbor lists,
//!   [`KNN_CHUNK_ROWS`] rows per chunk (smaller, because the insert
//!   path splices in-edges into *scattered* base rows);
//! - [`ChunkedLabels`] — class labels, [`LABEL_CHUNK_LEN`] per chunk.
//!
//! `Clone` on any of them copies only the chunk *pointers*; mutation
//! goes through copy-on-write handles ([`ChunkedMatrix::row_mut`],
//! [`ChunkedKnn::row_mut`], `push_*`) that clone a chunk's payload only
//! when it is still shared with an older epoch. A publish therefore
//! copies O(batch · chunk_size) bytes, independent of N, and a reader
//! holding an old snapshot keeps bit-identical rows forever.
//!
//! The chunk layout is a pure function of `(len, chunk_size)` — chunk
//! `c` always holds rows `[c·chunk_size, min((c+1)·chunk_size, len))`
//! — so WAL replay reproduces the exact same structure and the
//! checkpoint writers can stream chunk blocks without changing the
//! on-disk bytes.
//!
//! Every payload byte copied by a copy-on-write clone is added to a
//! process-global counter ([`copied_bytes`]); the publish-cost
//! regression harness (`rust/tests/publish_cost.rs`) reads it to prove
//! publishes stay O(batch) as the base grows.
//!
//! [`Snapshot`]: crate::serve::state::Snapshot

use crate::data::matrix::{Matrix, RowStore};
use crate::knn::{KnnGraph, NeighborStore};
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Arc;

/// Rows per [`ChunkedMatrix`] chunk. 1024 rows × d=100 floats is
/// ~400 KiB — big enough that the pointer vector stays tiny, small
/// enough that one touched row costs a bounded copy.
pub const MATRIX_CHUNK_ROWS: usize = 1024;

/// Rows per [`ChunkedKnn`] chunk. Kept small because an insert splices
/// in-edges into up to `k+1` *scattered* base rows, each dirtying its
/// whole chunk; 32 rows bounds that collateral copying.
pub const KNN_CHUNK_ROWS: usize = 32;

/// Labels per [`ChunkedLabels`] chunk (labels are 4 bytes each, so the
/// append path touches one small tail chunk per batch).
pub const LABEL_CHUNK_LEN: usize = 4096;

/// Process-global count of payload bytes copied by copy-on-write chunk
/// clones (monotone; never reset). Construction and explicit
/// conversions do not count — only clones forced by mutating a chunk
/// still shared with another epoch, plus the grid's bounded
/// overflow-list copy per snapshot clone.
static COPIED_BYTES: AtomicU64 = AtomicU64::new(0);

/// Total copy-on-write bytes copied so far in this process (see
/// [`COPIED_BYTES`] for what is counted). The publish-cost harness
/// samples this before/after an insert to measure bytes per publish.
pub fn copied_bytes() -> u64 {
    // ordering: Relaxed — standalone statistics counter; readers only
    // need an eventually-consistent total, no happens-before edges.
    COPIED_BYTES.load(Ordering::Relaxed)
}

/// Record `bytes` of copy-on-write copying (also used by
/// `render::grid` for its per-clone overflow-list copy).
pub(crate) fn count_copied(bytes: usize) {
    // ordering: Relaxed — standalone statistics counter; no
    // happens-before needed, torn totals are impossible on u64 RMW.
    COPIED_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Row-major `f32` matrix stored as fixed-size immutable chunks shared
/// between epochs via [`Arc`]. `Clone` is O(chunk count) pointer
/// copies; mutation copies only the touched chunk, and only if shared.
#[derive(Clone, Debug)]
pub struct ChunkedMatrix {
    /// Chunk `c` holds rows `[c*chunk_rows, min((c+1)*chunk_rows, n))`,
    /// each chunk vector exactly `rows_in_chunk * d` floats.
    chunks: Vec<Arc<Vec<f32>>>,
    chunk_rows: usize,
    n: usize,
    d: usize,
}

impl ChunkedMatrix {
    /// Chunk a flat matrix (`chunk_rows` must be non-zero). The
    /// conversion copy is construction, not COW, and is not counted.
    pub fn from_matrix(m: &Matrix, chunk_rows: usize) -> Self {
        assert!(chunk_rows > 0, "chunk_rows must be non-zero");
        let (n, d) = (m.n(), m.d());
        let mut chunks = Vec::with_capacity(n.div_ceil(chunk_rows));
        let mut i = 0;
        while i < n {
            let hi = (i + chunk_rows).min(n);
            chunks.push(Arc::new(m.as_slice()[i * d..hi * d].to_vec()));
            i = hi;
        }
        ChunkedMatrix { chunks, chunk_rows, n, d }
    }

    /// Flatten back into a contiguous [`Matrix`] (O(N) copy; used by
    /// rarely-run full rebuilds, not the serving hot path).
    pub fn to_matrix(&self) -> Matrix {
        let mut data = Vec::with_capacity(self.n * self.d);
        for c in &self.chunks {
            data.extend_from_slice(c);
        }
        Matrix::from_vec(data, self.n, self.d)
    }

    /// Number of rows (points).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of columns (dimensions).
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Row `i` as a slice — rows never straddle a chunk boundary, so
    /// this has the same shape as [`Matrix::row`].
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.n, "row {i} out of bounds (n={})", self.n);
        let (ci, ri) = (i / self.chunk_rows, i % self.chunk_rows);
        &self.chunks[ci][ri * self.d..(ri + 1) * self.d]
    }

    /// Copy-on-write handle for chunk `ci`: clones the payload (and
    /// counts the bytes) only if the chunk is still shared.
    fn chunk_mut(&mut self, ci: usize) -> &mut Vec<f32> {
        let arc = &mut self.chunks[ci];
        if Arc::get_mut(arc).is_none() {
            count_copied(arc.len() * std::mem::size_of::<f32>());
        }
        Arc::make_mut(arc)
    }

    /// Row `i` as a mutable slice, copy-on-write: dirties (at most)
    /// one chunk.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert!(i < self.n, "row {i} out of bounds (n={})", self.n);
        let (ci, ri) = (i / self.chunk_rows, i % self.chunk_rows);
        let d = self.d;
        &mut self.chunk_mut(ci)[ri * d..(ri + 1) * d]
    }

    /// Append a row, copy-on-write on the tail chunk (a fresh chunk is
    /// started whenever the previous one is full, so the layout
    /// invariant is preserved).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "row length {} != d {}", row.len(), self.d);
        if self.n % self.chunk_rows == 0 {
            self.chunks.push(Arc::new(Vec::with_capacity(self.chunk_rows * self.d)));
        }
        let ci = self.n / self.chunk_rows;
        self.chunk_mut(ci).extend_from_slice(row);
        self.n += 1;
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f32 {
        crate::kernels::sqdist(self.row(i), self.row(j))
    }

    /// All values in row-major order (chunk-aware; used by tests and
    /// finiteness sweeps instead of `as_slice`).
    pub fn values(&self) -> impl Iterator<Item = f32> + '_ {
        self.chunks.iter().flat_map(|c| c.iter().copied())
    }

    /// Number of chunks currently backing the matrix.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Whether chunk `ci` of `a` and `b` is the *same* shared
    /// allocation (`Arc::ptr_eq`) — the sharing probe used by the
    /// chunk-sharing property tests.
    pub fn chunk_shared(a: &ChunkedMatrix, b: &ChunkedMatrix, ci: usize) -> bool {
        Arc::ptr_eq(&a.chunks[ci], &b.chunks[ci])
    }

    /// Rows per chunk this matrix was built with.
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }
}

/// Bitwise row equality (`f32::to_bits`), so replay/restart identity
/// checks are exact and NaN-proof regardless of chunk boundaries.
impl PartialEq for ChunkedMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.d == other.d
            && (0..self.n).all(|i| {
                self.row(i).iter().zip(other.row(i)).all(|(a, b)| a.to_bits() == b.to_bits())
            })
    }
}

impl RowStore for ChunkedMatrix {
    fn n(&self) -> usize {
        self.n
    }
    fn d(&self) -> usize {
        self.d
    }
    fn row(&self, i: usize) -> &[f32] {
        ChunkedMatrix::row(self, i)
    }
    fn row_block(&self, i: usize) -> (&[f32], usize) {
        assert!(i < self.n, "row {i} out of bounds (n={})", self.n);
        let (ci, ri) = (i / self.chunk_rows, i % self.chunk_rows);
        let hi = ((ci + 1) * self.chunk_rows).min(self.n);
        (&self.chunks[ci][ri * self.d..], hi - i)
    }
}

/// K-nearest-neighbor lists stored as fixed-size immutable chunks of
/// rows shared between epochs via [`Arc`]. Mirrors
/// [`KnnGraph`]'s invariants (sorted, distinct, no self-loops, ≤ k).
#[derive(Clone, Debug)]
pub struct ChunkedKnn {
    /// Chunk `c` holds rows `[c*chunk_rows, min((c+1)*chunk_rows, n))`.
    chunks: Vec<Arc<Vec<Vec<(u32, f32)>>>>,
    chunk_rows: usize,
    n: usize,
    /// Requested K (public for parity with [`KnnGraph::k`]).
    pub k: usize,
}

impl ChunkedKnn {
    /// Chunk a flat graph (`chunk_rows` must be non-zero); the
    /// conversion copy is not counted as COW.
    pub fn from_graph(g: &KnnGraph, chunk_rows: usize) -> Self {
        assert!(chunk_rows > 0, "chunk_rows must be non-zero");
        let n = g.n();
        let mut chunks = Vec::with_capacity(n.div_ceil(chunk_rows));
        let mut i = 0;
        while i < n {
            let hi = (i + chunk_rows).min(n);
            chunks.push(Arc::new(g.neighbors[i..hi].to_vec()));
            i = hi;
        }
        ChunkedKnn { chunks, chunk_rows, n, k: g.k }
    }

    /// Flatten back into a [`KnnGraph`] (O(N) copy; full-rebuild path
    /// only).
    pub fn to_graph(&self) -> KnnGraph {
        let mut neighbors = Vec::with_capacity(self.n);
        for c in &self.chunks {
            neighbors.extend(c.iter().cloned());
        }
        KnnGraph { neighbors, k: self.k }
    }

    /// Number of points.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Neighbor list of point `i`: sorted `(id, sqdist)` pairs.
    #[inline]
    pub fn row(&self, i: usize) -> &[(u32, f32)] {
        debug_assert!(i < self.n, "row {i} out of bounds (n={})", self.n);
        let (ci, ri) = (i / self.chunk_rows, i % self.chunk_rows);
        &self.chunks[ci][ri]
    }

    /// Copy-on-write handle for chunk `ci`, counting the payload bytes
    /// of all lists in the chunk when a shared chunk must be cloned.
    fn chunk_mut(&mut self, ci: usize) -> &mut Vec<Vec<(u32, f32)>> {
        let arc = &mut self.chunks[ci];
        if Arc::get_mut(arc).is_none() {
            let bytes: usize =
                arc.iter().map(|r| r.len() * std::mem::size_of::<(u32, f32)>()).sum();
            count_copied(bytes);
        }
        Arc::make_mut(arc)
    }

    /// Mutable neighbor list of point `i`, copy-on-write: dirties (at
    /// most) one chunk. The insert path splices in-edges through this.
    pub fn row_mut(&mut self, i: usize) -> &mut Vec<(u32, f32)> {
        assert!(i < self.n, "row {i} out of bounds (n={})", self.n);
        let (ci, ri) = (i / self.chunk_rows, i % self.chunk_rows);
        &mut self.chunk_mut(ci)[ri]
    }

    /// Append a point's neighbor list, copy-on-write on the tail chunk.
    pub fn push_row(&mut self, row: Vec<(u32, f32)>) {
        if self.n % self.chunk_rows == 0 {
            self.chunks.push(Arc::new(Vec::with_capacity(self.chunk_rows)));
        }
        let ci = self.n / self.chunk_rows;
        self.chunk_mut(ci).push(row);
        self.n += 1;
    }

    /// Validate the same structural invariants as
    /// [`KnnGraph::check_invariants`] (no self-loops, sorted, distinct,
    /// finite, ≤ K entries).
    pub fn check_invariants(&self) -> Result<(), String> {
        for i in 0..self.n {
            let nb = self.row(i);
            if nb.len() > self.k {
                return Err(format!("node {i}: {} neighbors > k={}", nb.len(), self.k));
            }
            let mut seen = std::collections::HashSet::new();
            let mut last = f32::NEG_INFINITY;
            for &(id, d) in nb {
                if id as usize == i {
                    return Err(format!("node {i}: self-loop"));
                }
                if !seen.insert(id) {
                    return Err(format!("node {i}: duplicate neighbor {id}"));
                }
                if d < last {
                    return Err(format!("node {i}: distances not sorted"));
                }
                if !d.is_finite() {
                    return Err(format!("node {i}: non-finite distance"));
                }
                last = d;
            }
        }
        Ok(())
    }

    /// Number of chunks currently backing the graph.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Whether chunk `ci` of `a` and `b` is the same shared allocation.
    pub fn chunk_shared(a: &ChunkedKnn, b: &ChunkedKnn, ci: usize) -> bool {
        Arc::ptr_eq(&a.chunks[ci], &b.chunks[ci])
    }

    /// Rows per chunk this graph was built with.
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }
}

/// Bitwise equality of every neighbor list (ids and distance bits).
impl PartialEq for ChunkedKnn {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.k == other.k
            && (0..self.n).all(|i| {
                let (a, b) = (self.row(i), other.row(i));
                a.len() == b.len()
                    && a.iter()
                        .zip(b)
                        .all(|(&(ia, da), &(ib, db))| ia == ib && da.to_bits() == db.to_bits())
            })
    }
}

impl NeighborStore for ChunkedKnn {
    fn n(&self) -> usize {
        self.n
    }
    fn k(&self) -> usize {
        self.k
    }
    fn row(&self, i: usize) -> &[(u32, f32)] {
        ChunkedKnn::row(self, i)
    }
}

/// Class labels stored as fixed-size immutable chunks shared between
/// epochs via [`Arc`]; the insert path only ever appends.
#[derive(Clone, Debug)]
pub struct ChunkedLabels {
    /// Chunk `c` holds labels `[c*chunk_len, min((c+1)*chunk_len, len))`.
    chunks: Vec<Arc<Vec<u32>>>,
    chunk_len: usize,
    len: usize,
}

impl ChunkedLabels {
    /// Chunk a flat label array (`chunk_len` must be non-zero).
    pub fn from_slice(labels: &[u32], chunk_len: usize) -> Self {
        assert!(chunk_len > 0, "chunk_len must be non-zero");
        let chunks =
            labels.chunks(chunk_len).map(|c| Arc::new(c.to_vec())).collect::<Vec<_>>();
        ChunkedLabels { chunks, chunk_len, len: labels.len() }
    }

    /// Number of labels.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether there are no labels.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Label of point `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len, "label {i} out of bounds (len={})", self.len);
        self.chunks[i / self.chunk_len][i % self.chunk_len]
    }

    /// Append a label, copy-on-write on the tail chunk.
    pub fn push(&mut self, v: u32) {
        if self.len % self.chunk_len == 0 {
            self.chunks.push(Arc::new(Vec::with_capacity(self.chunk_len)));
        }
        let ci = self.len / self.chunk_len;
        let arc = &mut self.chunks[ci];
        if Arc::get_mut(arc).is_none() {
            count_copied(arc.len() * std::mem::size_of::<u32>());
        }
        Arc::make_mut(arc).push(v);
        self.len += 1;
    }

    /// Flatten into a contiguous vector (compaction path only).
    pub fn to_vec(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.len);
        for c in &self.chunks {
            out.extend_from_slice(c);
        }
        out
    }
}

/// Value equality regardless of chunk boundaries.
impl PartialEq for ChunkedLabels {
    fn eq(&self, other: &Self) -> bool {
        self.len == other.len && (0..self.len).all(|i| self.get(i) == other.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_matrix(n: usize, d: usize) -> Matrix {
        Matrix::from_vec((0..n * d).map(|x| x as f32).collect(), n, d)
    }

    #[test]
    fn roundtrip_and_row_access() {
        let m = seq_matrix(10, 3);
        let c = ChunkedMatrix::from_matrix(&m, 4);
        assert_eq!((c.n(), c.d(), c.n_chunks()), (10, 3, 3));
        for i in 0..10 {
            assert_eq!(c.row(i), m.row(i));
        }
        assert_eq!(c.to_matrix(), m);
        assert_eq!(c.values().collect::<Vec<_>>(), m.as_slice());
        assert_eq!(c.sqdist(0, 1), m.sqdist(0, 1));
    }

    #[test]
    fn row_block_covers_matrix_in_chunk_steps() {
        let m = seq_matrix(11, 2);
        let c = ChunkedMatrix::from_matrix(&m, 4);
        let mut i = 0;
        let mut flat = Vec::new();
        while i < RowStore::n(&c) {
            let (block, rows) = c.row_block(i);
            assert!(rows > 0 && block.len() >= rows * 2);
            flat.extend_from_slice(&block[..rows * 2]);
            i += rows;
        }
        assert_eq!(flat, m.as_slice());
        // The flat Matrix's row_block is the whole remainder.
        let (block, rows) = m.row_block(3);
        assert_eq!((block.len(), rows), (16, 8));
    }

    #[test]
    fn clone_shares_and_cow_unshares_one_chunk() {
        let m = seq_matrix(8, 2);
        let mut a = ChunkedMatrix::from_matrix(&m, 4);
        let b = a.clone();
        assert!(ChunkedMatrix::chunk_shared(&a, &b, 0));
        assert!(ChunkedMatrix::chunk_shared(&a, &b, 1));
        let before = copied_bytes();
        a.row_mut(1)[0] = 99.0;
        // The shared chunk was cloned (4 rows × 2 floats × 4 bytes)...
        assert!(copied_bytes() - before >= 32);
        assert!(!ChunkedMatrix::chunk_shared(&a, &b, 0));
        // ...the untouched chunk is still the same allocation...
        assert!(ChunkedMatrix::chunk_shared(&a, &b, 1));
        // ...and the old epoch still sees the original bits.
        assert_eq!(b.row(1), m.row(1));
        assert_eq!(a.row(1)[0], 99.0);
        // Mutating an unshared chunk copies nothing further.
        let before = copied_bytes();
        a.row_mut(1)[1] = 7.0;
        assert_eq!(copied_bytes(), before);
    }

    #[test]
    fn push_row_extends_tail_and_starts_new_chunks() {
        let m = seq_matrix(3, 2);
        let mut c = ChunkedMatrix::from_matrix(&m, 4);
        let old = c.clone();
        c.push_row(&[50.0, 51.0]);
        c.push_row(&[52.0, 53.0]);
        assert_eq!((c.n(), c.n_chunks()), (5, 2));
        assert_eq!(c.row(3), &[50.0, 51.0]);
        assert_eq!(c.row(4), &[52.0, 53.0]);
        // The old epoch still has exactly its 3 rows, bit-identical.
        assert_eq!((old.n(), old.n_chunks()), (3, 1));
        assert_eq!(old.row(2), m.row(2));
        // Layout matches a fresh conversion of the flattened result.
        let rebuilt = ChunkedMatrix::from_matrix(&c.to_matrix(), 4);
        assert_eq!(rebuilt, c);
        assert_eq!(rebuilt.n_chunks(), c.n_chunks());
    }

    #[test]
    fn bitwise_equality_is_nan_aware() {
        let m = Matrix::from_vec(vec![f32::NAN, 1.0], 1, 2);
        let a = ChunkedMatrix::from_matrix(&m, 4);
        let b = a.clone();
        assert_eq!(a, b); // NaN bits equal => equal
        let flat = Matrix::from_vec(vec![f32::NAN, 2.0], 1, 2);
        assert_ne!(a, ChunkedMatrix::from_matrix(&flat, 4));
    }

    fn ring_graph(n: usize) -> KnnGraph {
        let mut g = KnnGraph::empty(n, 2);
        for i in 0..n {
            g.neighbors[i] = vec![(((i + 1) % n) as u32, 1.0)];
        }
        g
    }

    #[test]
    fn knn_roundtrip_cow_and_invariants() {
        let g = ring_graph(10);
        let mut a = ChunkedKnn::from_graph(&g, 4);
        assert_eq!((a.n(), a.k, a.n_chunks()), (10, 2, 3));
        assert!(a.check_invariants().is_ok());
        let b = a.clone();
        let before = copied_bytes();
        a.row_mut(0).push((5, 2.0));
        assert!(copied_bytes() > before);
        assert!(!ChunkedKnn::chunk_shared(&a, &b, 0));
        assert!(ChunkedKnn::chunk_shared(&a, &b, 1));
        assert_eq!(b.row(0), g.neighbors[0].as_slice());
        assert_eq!(a.row(0).len(), 2);
        // Append keeps the old epoch intact and the flat roundtrip exact.
        a.push_row(vec![(0, 3.0)]);
        assert_eq!(a.n(), 11);
        assert_eq!(b.n(), 10);
        let flat = a.to_graph();
        assert_eq!(ChunkedKnn::from_graph(&flat, 4), a);
    }

    #[test]
    fn labels_append_only_sharing() {
        let mut a = ChunkedLabels::from_slice(&[1, 2, 3], 4);
        let b = a.clone();
        a.push(9);
        assert_eq!((a.len(), b.len()), (4, 3));
        assert_eq!(a.get(3), 9);
        assert_eq!(b.to_vec(), vec![1, 2, 3]);
        assert_eq!(a.to_vec(), vec![1, 2, 3, 9]);
        // Chunk boundary: pushing past chunk_len opens a new chunk.
        let mut c = ChunkedLabels::from_slice(&[0; 4], 4);
        c.push(7);
        assert_eq!((c.len(), c.get(4)), (5, 7));
        assert_eq!(ChunkedLabels::from_slice(&c.to_vec(), 4), c);
        assert!(ChunkedLabels::from_slice(&[], 4).is_empty());
    }

    #[test]
    fn copied_bytes_is_monotone() {
        let before = copied_bytes();
        let m = seq_matrix(4, 2);
        let mut a = ChunkedMatrix::from_matrix(&m, 4);
        let _keep = a.clone();
        a.row_mut(0)[0] = 1.0;
        assert!(copied_bytes() >= before);
    }
}
