//! Anisotropic Gaussian mixture — the `20ng-like` analog.
//!
//! 20 newsgroups has ~20 topical classes at 100-d with substantial
//! pairwise overlap (e.g. comp.* groups). We mimic that by drawing
//! cluster centers on a sphere, giving each cluster an anisotropic
//! per-dimension scale, and pulling designated *confusable pairs* of
//! centers close together.

use crate::data::matrix::Matrix;
use crate::util::rng::Rng;

/// Generate `n` points in `d` dims from `k` anisotropic Gaussian
/// clusters; `overlap ∈ [0,1]` controls how close confusable pairs sit.
///
/// Returns `(points, labels)` with labels in `0..k`.
pub fn gaussian_mixture(n: usize, d: usize, k: usize, overlap: f32, seed: u64) -> (Matrix, Vec<u32>) {
    assert!(k >= 1 && d >= 1 && n >= k);
    let mut rng = Rng::new(seed);

    // Cluster centers: random gaussian directions, radius ~ sqrt(d) so
    // between-cluster distance dominates within-cluster variance.
    let radius = (d as f32).sqrt() * 2.0;
    let mut centers = Matrix::zeros(k, d);
    for c in 0..k {
        let row = centers.row_mut(c);
        for x in row.iter_mut() {
            *x = rng.gaussian();
        }
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        for x in row.iter_mut() {
            *x *= radius / norm;
        }
    }
    // Confusable pairs: centers (2i, 2i+1) are pulled together.
    for pair in 0..k / 2 {
        let (a, b) = (2 * pair, 2 * pair + 1);
        if rng.f32() < 0.5 {
            // Only half of the pairs confusable, like real topic sets.
            continue;
        }
        let mix = overlap.clamp(0.0, 1.0);
        let ca: Vec<f32> = centers.row(a).to_vec();
        for (xb, &xa) in centers.row_mut(b).iter_mut().zip(&ca) {
            *xb = *xb * (1.0 - mix) + xa * mix;
        }
    }
    // Per-cluster anisotropic scales in [0.5, 1.5].
    let scales: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.range_f32(0.5, 1.5)).collect())
        .collect();

    let mut points = Matrix::zeros(n, d);
    let mut labels = vec![0u32; n];
    for i in 0..n {
        let c = i % k; // balanced classes
        labels[i] = c as u32;
        let center = centers.row(c).to_vec();
        let row = points.row_mut(i);
        for ((x, &mu), &s) in row.iter_mut().zip(&center).zip(&scales[c]) {
            *x = mu + s * rng.gaussian();
        }
    }
    (points, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::sqdist;

    #[test]
    fn shapes_and_labels() {
        let (m, l) = gaussian_mixture(200, 10, 5, 0.3, 1);
        assert_eq!((m.n(), m.d()), (200, 10));
        assert_eq!(l.len(), 200);
        assert!(l.iter().all(|&c| c < 5));
        // balanced
        for c in 0..5u32 {
            assert_eq!(l.iter().filter(|&&x| x == c).count(), 40);
        }
    }

    #[test]
    fn deterministic() {
        let (a, _) = gaussian_mixture(50, 8, 4, 0.2, 9);
        let (b, _) = gaussian_mixture(50, 8, 4, 0.2, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn clusters_separated() {
        // Same-class mean distance should be well below cross-class.
        let (m, l) = gaussian_mixture(400, 50, 4, 0.0, 3);
        let (mut within, mut across) = (vec![], vec![]);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d = sqdist(m.row(i), m.row(j)) as f64;
                if l[i] == l[j] {
                    within.push(d);
                } else {
                    across.push(d);
                }
            }
        }
        let mw = within.iter().sum::<f64>() / within.len() as f64;
        let ma = across.iter().sum::<f64>() / across.len() as f64;
        assert!(ma > 1.5 * mw, "within={mw} across={ma}");
    }
}
