//! Stochastic block model graph generators — the network-dataset
//! analogs (`livejournal-like`, `csauthor-like`, `dblp-like`).
//!
//! The paper embeds its network datasets to 100-d with LINE before
//! visualization; we generate community-structured graphs here and run
//! them through our own LINE substrate ([`crate::embed::line`]),
//! exercising the identical preprocessing pipeline.
//!
//! Two variants: a plain SBM with balanced communities, and a power-law
//! degree-corrected SBM (LiveJournal's degree skew is what stresses the
//! `deg^0.75` negative-sampling table).

use crate::util::rng::Rng;

/// An undirected graph with ground-truth community labels.
#[derive(Clone, Debug)]
pub struct SbmGraph {
    /// Number of vertices.
    pub n: usize,
    /// Undirected edges (i < j), deduplicated.
    pub edges: Vec<(u32, u32)>,
    /// Ground-truth community of each vertex.
    pub communities: Vec<u32>,
}

impl SbmGraph {
    /// Vertex degrees.
    pub fn degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n];
        for &(a, b) in &self.edges {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        deg
    }
}

/// Balanced stochastic block model: `k` communities over `n` vertices,
/// expected within-community degree `deg_in` and cross degree `deg_out`.
pub fn sbm(n: usize, k: usize, deg_in: f64, deg_out: f64, seed: u64) -> SbmGraph {
    degree_corrected_sbm(n, k, deg_in, deg_out, 0.0, seed)
}

/// Power-law degree-corrected SBM: vertex propensities ~ Zipf(`skew`);
/// `skew = 0` reduces to the plain SBM.
pub fn power_law_sbm(n: usize, k: usize, deg_in: f64, deg_out: f64, seed: u64) -> SbmGraph {
    degree_corrected_sbm(n, k, deg_in, deg_out, 0.9, seed)
}

fn degree_corrected_sbm(
    n: usize,
    k: usize,
    deg_in: f64,
    deg_out: f64,
    skew: f64,
    seed: u64,
) -> SbmGraph {
    assert!(k >= 1 && n >= 2 * k);
    let mut rng = Rng::new(seed);
    let communities: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
    // Membership lists.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &c) in communities.iter().enumerate() {
        members[c as usize].push(v as u32);
    }
    // Degree propensities.
    let theta: Vec<f64> = if skew > 0.0 {
        (0..n).map(|i| 1.0 / (1.0 + (i / k) as f64).powf(skew)).collect()
    } else {
        vec![1.0; n]
    };

    let mut edges = std::collections::HashSet::<(u32, u32)>::new();
    let comm_size = n as f64 / k as f64;

    // Within-community edges: expected count per community =
    // comm_size * deg_in / 2, placed by propensity-weighted endpoint draws.
    for c in 0..k {
        let ms = &members[c];
        let weights: Vec<f64> = ms.iter().map(|&v| theta[v as usize]).collect();
        let table = crate::util::alias::AliasTable::new(&weights);
        let target = (comm_size * deg_in / 2.0).round() as usize;
        let mut placed = 0;
        let mut attempts = 0;
        while placed < target && attempts < target * 20 {
            attempts += 1;
            let a = ms[table.sample(&mut rng)];
            let b = ms[table.sample(&mut rng)];
            if a == b {
                continue;
            }
            let e = (a.min(b), a.max(b));
            if edges.insert(e) {
                placed += 1;
            }
        }
    }
    // Cross-community edges.
    {
        let table = crate::util::alias::AliasTable::new(&theta);
        let target = (n as f64 * deg_out / 2.0).round() as usize;
        let mut placed = 0;
        let mut attempts = 0;
        while placed < target && attempts < target * 20 {
            attempts += 1;
            let a = table.sample(&mut rng) as u32;
            let b = table.sample(&mut rng) as u32;
            if a == b || communities[a as usize] == communities[b as usize] {
                continue;
            }
            let e = (a.min(b), a.max(b));
            if edges.insert(e) {
                placed += 1;
            }
        }
    }
    let mut edges: Vec<(u32, u32)> = edges.into_iter().collect();
    edges.sort_unstable();
    SbmGraph { n, edges, communities }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_edges_dominate() {
        let g = sbm(1000, 5, 10.0, 1.0, 1);
        let within = g
            .edges
            .iter()
            .filter(|&&(a, b)| g.communities[a as usize] == g.communities[b as usize])
            .count();
        let across = g.edges.len() - within;
        assert!(within > 4 * across, "within={within} across={across}");
    }

    #[test]
    fn expected_degree_close() {
        let g = sbm(2000, 4, 8.0, 2.0, 2);
        let mean_deg = 2.0 * g.edges.len() as f64 / g.n as f64;
        assert!((mean_deg - 10.0).abs() < 2.0, "mean degree {mean_deg}");
    }

    #[test]
    fn power_law_skews_degrees() {
        let g = power_law_sbm(3000, 6, 10.0, 2.0, 3);
        let mut deg = g.degrees();
        deg.sort_unstable_by(|a, b| b.cmp(a));
        // Top-decile degree should far exceed the median.
        let top = deg[g.n / 10] as f64;
        let med = deg[g.n / 2].max(1) as f64;
        assert!(top >= 2.0 * med, "top={top} med={med}");
    }

    #[test]
    fn edges_are_canonical_and_unique() {
        let g = sbm(500, 3, 6.0, 1.0, 4);
        let set: std::collections::HashSet<_> = g.edges.iter().collect();
        assert_eq!(set.len(), g.edges.len());
        assert!(g.edges.iter().all(|&(a, b)| a < b && (b as usize) < g.n));
    }

    #[test]
    fn deterministic() {
        let a = sbm(300, 3, 5.0, 1.0, 7);
        let b = sbm(300, 3, 5.0, 1.0, 7);
        assert_eq!(a.edges, b.edges);
    }
}
