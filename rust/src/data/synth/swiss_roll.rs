//! Classic swiss-roll manifold, used by the quickstart example and by
//! tests that need a known non-linear structure (a linear method cannot
//! unroll it; LargeVis should).

use crate::data::matrix::Matrix;
use crate::util::rng::Rng;

/// Generate a swiss roll with `n` points embedded in `d >= 3` dims
/// (extra dims are small noise). Labels quantize the roll parameter
/// into `bands` segments. Returns `(points, labels)`.
pub fn swiss_roll(n: usize, d: usize, bands: usize, seed: u64) -> (Matrix, Vec<u32>) {
    assert!(d >= 3 && bands >= 1);
    let mut rng = Rng::new(seed);
    let mut points = Matrix::zeros(n, d);
    let mut labels = vec![0u32; n];
    for i in 0..n {
        let t = 1.5 * std::f32::consts::PI * (1.0 + 2.0 * rng.f32()); // roll parameter
        let h = 21.0 * rng.f32(); // height
        let row = points.row_mut(i);
        row[0] = t * t.cos();
        row[1] = h;
        row[2] = t * t.sin();
        for x in row.iter_mut().skip(3) {
            *x = 0.05 * rng.gaussian();
        }
        let t_min = 1.5 * std::f32::consts::PI;
        let t_max = 4.5 * std::f32::consts::PI;
        let band = (((t - t_min) / (t_max - t_min)) * bands as f32) as usize;
        labels[i] = band.min(bands - 1) as u32;
    }
    (points, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_band_range() {
        let (m, l) = swiss_roll(500, 5, 8, 1);
        assert_eq!((m.n(), m.d()), (500, 5));
        assert!(l.iter().all(|&b| b < 8));
        let distinct: std::collections::HashSet<_> = l.iter().collect();
        assert!(distinct.len() >= 6);
    }

    #[test]
    fn radius_grows_with_band() {
        let (m, l) = swiss_roll(2000, 3, 4, 2);
        let mut mean_r = vec![0f64; 4];
        let mut cnt = vec![0usize; 4];
        for i in 0..2000 {
            let row = m.row(i);
            let r = (row[0] * row[0] + row[2] * row[2]).sqrt() as f64;
            mean_r[l[i] as usize] += r;
            cnt[l[i] as usize] += 1;
        }
        for b in 0..4 {
            mean_r[b] /= cnt[b] as f64;
        }
        assert!(mean_r[3] > mean_r[0]);
    }
}
