//! Two-level hierarchical Gaussian mixture — the `wikidoc-like` analog.
//!
//! Wikipedia articles carry ~1000 categories with a clear topical
//! hierarchy (a few dozen broad topics, each with many subcategories).
//! We sample `super_k` top-level topic centers, then `k` subtopic
//! centers around them; leaf labels are subtopic ids. This produces the
//! multi-scale cluster structure that distinguishes a good layout from
//! a bad one at millions of points.

use crate::data::matrix::Matrix;
use crate::util::rng::Rng;

/// Generate a 2-level mixture: `k` leaf classes nested under `super_k`
/// topics. Returns `(points, leaf_labels)`.
pub fn hierarchical_mixture(
    n: usize,
    d: usize,
    super_k: usize,
    k: usize,
    seed: u64,
) -> (Matrix, Vec<u32>) {
    assert!(super_k >= 1 && k >= super_k && n >= k);
    let mut rng = Rng::new(seed);
    let top_radius = (d as f32).sqrt() * 3.0;
    let sub_radius = (d as f32).sqrt() * 0.8;

    let mut top = Matrix::zeros(super_k, d);
    for c in 0..super_k {
        let row = top.row_mut(c);
        for x in row.iter_mut() {
            *x = rng.gaussian();
        }
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        for x in row.iter_mut() {
            *x *= top_radius / norm;
        }
    }
    // Subtopic centers: parent center + small offset. Subtopic c belongs
    // to parent c % super_k so classes are spread across topics.
    let mut sub = Matrix::zeros(k, d);
    let mut parent = vec![0usize; k];
    for c in 0..k {
        let p = c % super_k;
        parent[c] = p;
        let prow = top.row(p).to_vec();
        let row = sub.row_mut(c);
        for (x, &mu) in row.iter_mut().zip(&prow) {
            *x = mu + sub_radius / (d as f32).sqrt() * rng.gaussian() * (d as f32).powf(0.25);
        }
    }
    // Cluster sizes ~ Zipf, mirroring category popularity skew; points
    // assigned round-robin over a Zipf-weighted alias-ish scheme.
    let weights: Vec<f64> = (0..k).map(|c| 1.0 / (1.0 + c as f64).powf(0.8)).collect();
    let table = crate::util::alias::AliasTable::new(&weights);

    let mut points = Matrix::zeros(n, d);
    let mut labels = vec![0u32; n];
    for i in 0..n {
        // Guarantee every class is populated, then go Zipf.
        let c = if i < k { i } else { table.sample(&mut rng) };
        labels[i] = c as u32;
        let center = sub.row(c).to_vec();
        let row = points.row_mut(i);
        for (x, &mu) in row.iter_mut().zip(&center) {
            *x = mu + 0.7 * rng.gaussian();
        }
    }
    (points, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_classes_populated() {
        let (_, l) = hierarchical_mixture(500, 20, 5, 40, 2);
        let distinct: std::collections::HashSet<_> = l.iter().collect();
        assert_eq!(distinct.len(), 40);
    }

    #[test]
    fn zipf_skew_present() {
        let (_, l) = hierarchical_mixture(5000, 10, 4, 50, 3);
        let mut counts = vec![0usize; 50];
        for &c in &l {
            counts[c as usize] += 1;
        }
        assert!(counts[0] > counts[30], "head class should dominate: {counts:?}");
    }

    #[test]
    fn deterministic() {
        let (a, la) = hierarchical_mixture(100, 16, 3, 10, 7);
        let (b, lb) = hierarchical_mixture(100, 16, 3, 10, 7);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }
}
