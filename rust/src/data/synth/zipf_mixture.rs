//! Zipf-weighted mixture with heavy-tailed density — the
//! `wikiword-like` analog.
//!
//! Word-embedding spaces have no class labels but a very skewed density:
//! a dense core of frequent words and a long sparse tail. We sample
//! cluster assignment Zipf-style and scale cluster spread with rank, so
//! head clusters are dense/tight and tail clusters diffuse.

use crate::data::matrix::Matrix;
use crate::util::rng::Rng;

/// Generate `n` points in `d` dims from `k` Zipf-weighted clusters.
/// Returns `(points, cluster_ids)` — ids are *not* semantic labels (the
/// paper's WikiWord has none) but are handy for coloring.
pub fn zipf_mixture(n: usize, d: usize, k: usize, seed: u64) -> (Matrix, Vec<u32>) {
    assert!(k >= 1 && n >= 1);
    let mut rng = Rng::new(seed);
    let radius = (d as f32).sqrt() * 1.8;
    let mut centers = Matrix::zeros(k, d);
    for c in 0..k {
        let row = centers.row_mut(c);
        for x in row.iter_mut() {
            *x = rng.gaussian();
        }
        // Head clusters near the origin, tail clusters farther out.
        let shell = radius * (0.4 + 0.6 * (c as f32 / k as f32));
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        for x in row.iter_mut() {
            *x *= shell / norm;
        }
    }
    let mut points = Matrix::zeros(n, d);
    let mut ids = vec![0u32; n];
    for i in 0..n {
        let c = rng.zipf(k, 1.1);
        ids[i] = c as u32;
        let spread = 0.5 + 1.2 * (c as f32 / k as f32); // tail is diffuse
        let center = centers.row(c).to_vec();
        let row = points.row_mut(i);
        for (x, &mu) in row.iter_mut().zip(&center) {
            *x = mu + spread * rng.gaussian();
        }
    }
    (points, ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_cluster_largest() {
        let (_, ids) = zipf_mixture(20_000, 10, 50, 1);
        let mut counts = vec![0usize; 50];
        for &c in &ids {
            counts[c as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert_eq!(counts[0], max);
        assert!(counts[0] > 5 * counts[30].max(1));
    }

    #[test]
    fn deterministic() {
        let (a, _) = zipf_mixture(100, 8, 10, 5);
        let (b, _) = zipf_mixture(100, 8, 10, 5);
        assert_eq!(a, b);
    }
}
