//! Synthetic dataset generators — the offline analogs of the paper's
//! corpora (DESIGN.md §Data-substitutions).
//!
//! Each generator is seeded and deterministic. Vector generators return
//! `(Matrix, labels)`; graph generators return an edge list plus ground-
//! truth communities (embedded to 100-d via [`crate::embed::line`], the
//! same preprocessing the paper applies to its network datasets).

pub mod gaussian_mixture;
pub mod hierarchical;
pub mod manifold;
pub mod swiss_roll;
pub mod zipf_mixture;
pub mod sbm;

pub use gaussian_mixture::gaussian_mixture;
pub use hierarchical::hierarchical_mixture;
pub use manifold::manifold_clusters;
pub use sbm::{power_law_sbm, sbm, SbmGraph};
pub use swiss_roll::swiss_roll;
pub use zipf_mixture::zipf_mixture;
