//! Low-rank manifold clusters in a high ambient dimension — the
//! `mnist-like` analog.
//!
//! MNIST lives in 784-d pixel space but has intrinsic dimensionality of
//! a few dozen; that gap is exactly the regime where vantage-point
//! trees degrade and random projection trees shine (the paper's Fig 2
//! MNIST panel). Each class is a random affine `r`-dimensional subspace
//! patch plus small ambient noise; values are shifted/clipped to be
//! non-negative like pixel intensities.

use crate::data::matrix::Matrix;
use crate::util::rng::Rng;

/// Generate `n` points in `d` ambient dims from `k` classes, each an
/// `r`-dimensional manifold patch. Returns `(points, labels)`.
pub fn manifold_clusters(
    n: usize,
    d: usize,
    k: usize,
    r: usize,
    seed: u64,
) -> (Matrix, Vec<u32>) {
    assert!(r <= d && k >= 1 && n >= k);
    let mut rng = Rng::new(seed);

    // Per class: an offset vector and an orthogonal-ish basis d×r.
    let mut offsets = Matrix::zeros(k, d);
    let mut bases: Vec<Matrix> = Vec::with_capacity(k);
    for c in 0..k {
        let row = offsets.row_mut(c);
        for x in row.iter_mut() {
            *x = rng.range_f32(0.0, 4.0);
        }
        let mut basis = Matrix::zeros(r, d);
        for j in 0..r {
            let brow = basis.row_mut(j);
            for x in brow.iter_mut() {
                *x = rng.gaussian() / (d as f32).sqrt();
            }
        }
        bases.push(basis);
    }

    let mut points = Matrix::zeros(n, d);
    let mut labels = vec![0u32; n];
    for i in 0..n {
        let c = i % k;
        labels[i] = c as u32;
        // Latent coordinates on the manifold patch.
        let latent: Vec<f32> = (0..r).map(|_| 3.0 * rng.gaussian()).collect();
        let offset = offsets.row(c).to_vec();
        let row = points.row_mut(i);
        for (dim, x) in row.iter_mut().enumerate() {
            let mut v = offset[dim];
            for (j, &z) in latent.iter().enumerate() {
                v += z * bases[c].row(j)[dim] * (d as f32).sqrt();
            }
            v += 0.15 * rng.gaussian(); // ambient pixel noise
            *x = v.max(0.0); // intensities are non-negative
        }
    }
    (points, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_negative_values() {
        let (m, _) = manifold_clusters(100, 64, 5, 8, 1);
        assert!(m.as_slice().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn intrinsic_dim_lower_than_ambient() {
        // Points of one class, centered, should have energy concentrated
        // in ~r directions: compare variance captured by top-r PCs proxy
        // (pairwise distances within class much smaller than across).
        let (m, l) = manifold_clusters(300, 100, 3, 5, 2);
        let mut within = 0.0;
        let mut across = 0.0;
        let (mut nw, mut na) = (0, 0);
        for i in 0..150 {
            for j in (i + 1)..150 {
                let d = m.sqdist(i, j) as f64;
                if l[i] == l[j] {
                    within += d;
                    nw += 1;
                } else {
                    across += d;
                    na += 1;
                }
            }
        }
        assert!(across / na as f64 > within / nw as f64);
    }

    #[test]
    fn deterministic() {
        let (a, _) = manifold_clusters(50, 32, 4, 4, 11);
        let (b, _) = manifold_clusters(50, 32, 4, 4, 11);
        assert_eq!(a, b);
    }
}
