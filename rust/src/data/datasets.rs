//! Registry of paper-analog datasets (Table 1).
//!
//! Each [`DatasetSpec`] names one of the paper's seven corpora and its
//! synthetic substitute. `scale` shrinks the large sets so every figure
//! regenerates in minutes; `--scale 1.0` reproduces the paper's full N
//! (memory permitting). Network datasets are generated as SBM graphs
//! and embedded to 100-d with our LINE substrate, mirroring the paper's
//! preprocessing.

use crate::data::matrix::Matrix;
use crate::data::synth;
use crate::embed::line::{train_line, LineConfig};

/// A generated dataset: points, optional labels, provenance.
pub struct Dataset {
    /// Registry name (e.g. `20ng-like`).
    pub name: String,
    /// `n × d` feature matrix.
    pub points: Matrix,
    /// Class labels if the paper's original had them.
    pub labels: Option<Vec<u32>>,
    /// Number of distinct classes (0 when unlabeled).
    pub n_classes: usize,
}

/// Static description of a dataset in the registry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetSpec {
    /// Registry name.
    pub name: &'static str,
    /// Paper dataset this stands in for.
    pub paper_name: &'static str,
    /// Paper's N (Table 1).
    pub paper_n: usize,
    /// Our full-scale N (before `scale`).
    pub full_n: usize,
    /// Dimensionality.
    pub d: usize,
    /// Classes (0 = unlabeled).
    pub classes: usize,
    /// True when the source is a graph embedded via LINE.
    pub is_network: bool,
}

/// All seven paper datasets (Table 1) in paper order.
pub const REGISTRY: &[DatasetSpec] = &[
    DatasetSpec { name: "20ng-like", paper_name: "20NG", paper_n: 18_846, full_n: 18_846, d: 100, classes: 20, is_network: false },
    DatasetSpec { name: "mnist-like", paper_name: "MNIST", paper_n: 70_000, full_n: 70_000, d: 784, classes: 10, is_network: false },
    DatasetSpec { name: "wikiword-like", paper_name: "WikiWord", paper_n: 836_756, full_n: 200_000, d: 100, classes: 0, is_network: false },
    DatasetSpec { name: "wikidoc-like", paper_name: "WikiDoc", paper_n: 2_837_395, full_n: 400_000, d: 100, classes: 1000, is_network: false },
    DatasetSpec { name: "csauthor-like", paper_name: "CSAuthor", paper_n: 1_854_295, full_n: 200_000, d: 100, classes: 0, is_network: true },
    DatasetSpec { name: "dblp-like", paper_name: "DBLPPaper", paper_n: 1_345_560, full_n: 150_000, d: 100, classes: 30, is_network: true },
    DatasetSpec { name: "livejournal-like", paper_name: "LiveJournal", paper_n: 3_997_963, full_n: 400_000, d: 100, classes: 500, is_network: true },
];

/// Look up a spec by registry name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    REGISTRY.iter().find(|s| s.name == name)
}

/// Generate a dataset at `scale ∈ (0, 1]` of its full size.
///
/// Unknown names return `None`. Generation is deterministic in
/// `(name, scale, seed)`.
pub fn generate(name: &str, scale: f64, seed: u64) -> Option<Dataset> {
    let s = spec(name)?;
    let n = ((s.full_n as f64 * scale).round() as usize).max(s.classes.max(64) * 2);
    Some(match s.name {
        "20ng-like" => {
            let (points, labels) = synth::gaussian_mixture(n, s.d, s.classes, 0.55, seed);
            pack(s, points, Some(labels))
        }
        "mnist-like" => {
            let (points, labels) = synth::manifold_clusters(n, s.d, s.classes, 12, seed);
            pack(s, points, Some(labels))
        }
        "wikiword-like" => {
            let (points, _) = synth::zipf_mixture(n, s.d, 200, seed);
            pack(s, points, None)
        }
        "wikidoc-like" => {
            let k = s.classes.min(n / 4).max(2);
            let (points, labels) = synth::hierarchical_mixture(n, s.d, 25, k, seed);
            pack(s, points, Some(labels))
        }
        "csauthor-like" => {
            let k = (n / 400).max(8);
            let g = synth::sbm(n, k, 10.0, 1.0, seed);
            let emb = embed_graph(&g, s.d, seed);
            pack(s, emb, None)
        }
        "dblp-like" => {
            let k = s.classes.min(n / 50).max(4);
            let g = synth::sbm(n, k, 12.0, 1.5, seed);
            let emb = embed_graph(&g, s.d, seed);
            pack(s, emb, Some(g.communities))
        }
        "livejournal-like" => {
            let k = s.classes.min(n / 100).max(8);
            let g = synth::power_law_sbm(n, k, 10.0, 1.2, seed);
            let emb = embed_graph(&g, s.d, seed);
            pack(s, emb, Some(g.communities))
        }
        _ => unreachable!(),
    })
}

fn pack(s: &DatasetSpec, points: Matrix, labels: Option<Vec<u32>>) -> Dataset {
    let n_classes = labels
        .as_ref()
        .map(|ls| ls.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
        .unwrap_or(0);
    Dataset { name: s.name.to_string(), points, labels, n_classes }
}

fn embed_graph(g: &synth::SbmGraph, dim: usize, seed: u64) -> Matrix {
    let edges: Vec<(u32, u32, f32)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
    let cfg = LineConfig { dim, samples_per_vertex: 400, seed, ..Default::default() };
    train_line(g.n, &edges, &cfg).embedding
}

/// Table-1-style statistics row for a generated dataset.
pub fn stats_row(ds: &Dataset) -> String {
    format!(
        "{:<18} {:>9} {:>11} {:>12}",
        ds.name,
        ds.points.n(),
        ds.points.d(),
        if ds.n_classes > 0 { ds.n_classes.to_string() } else { "-".to_string() }
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_paper_table1() {
        assert_eq!(REGISTRY.len(), 7);
        assert_eq!(spec("mnist-like").unwrap().paper_n, 70_000);
        assert_eq!(spec("livejournal-like").unwrap().paper_n, 3_997_963);
        assert!(spec("nope").is_none());
    }

    #[test]
    fn generate_small_vector_sets() {
        for name in ["20ng-like", "mnist-like", "wikiword-like", "wikidoc-like"] {
            let ds = generate(name, 0.02, 1).unwrap();
            let s = spec(name).unwrap();
            assert_eq!(ds.points.d(), s.d, "{name}");
            assert!(ds.points.n() > 0);
            if s.classes > 0 {
                let labels = ds.labels.as_ref().unwrap();
                assert_eq!(labels.len(), ds.points.n());
            } else {
                assert!(ds.labels.is_none());
            }
        }
    }

    #[test]
    fn generate_network_set() {
        let ds = generate("dblp-like", 0.01, 2).unwrap();
        assert_eq!(ds.points.d(), 100);
        assert!(ds.labels.is_some());
        assert!(ds.points.as_slice().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn deterministic_generation() {
        let a = generate("20ng-like", 0.01, 5).unwrap();
        let b = generate("20ng-like", 0.01, 5).unwrap();
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn stats_row_formats() {
        let ds = generate("20ng-like", 0.01, 1).unwrap();
        let row = stats_row(&ds);
        assert!(row.contains("20ng-like"));
        assert!(row.contains("100"));
    }
}
