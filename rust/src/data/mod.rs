//! Data substrate: dense matrices, dataset I/O, synthetic generators,
//! and the registry of paper-analog datasets.
//!
//! The paper evaluates on seven proprietary/large corpora (Table 1).
//! Offline, each is replaced by a synthetic analog with matched
//! dimensionality, label structure, and (scaled) size — see
//! `DESIGN.md` §Data-substitutions for the mapping rationale.

pub mod matrix;
pub mod chunked;
pub mod io;
pub mod formats;
pub mod synth;
pub mod datasets;

pub use datasets::{Dataset, DatasetSpec};
pub use matrix::Matrix;
