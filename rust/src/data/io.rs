//! Binary dataset I/O.
//!
//! Format (`.lvec`, little-endian): magic `LVEC`, u32 version, u64 n,
//! u64 d, then `n*d` f32 values. Labels (`.lbl`): magic `LLBL`, u32
//! version, u64 n, then `n` u32 class ids. Layouts re-use `.lvec`.
//! Simple, mmap-friendly, and round-trips exactly.

use crate::data::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const VEC_MAGIC: &[u8; 4] = b"LVEC";
const LBL_MAGIC: &[u8; 4] = b"LLBL";
const VERSION: u32 = 1;

/// Write a matrix to `path` in `.lvec` format.
pub fn write_matrix(path: &Path, m: &Matrix) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(VEC_MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(m.n() as u64).to_le_bytes())?;
    w.write_all(&(m.d() as u64).to_le_bytes())?;
    for &x in m.as_slice() {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a `.lvec` matrix.
pub fn read_matrix(path: &Path) -> Result<Matrix> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != VEC_MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let n = read_u64(&mut r)? as usize;
    let d = read_u64(&mut r)? as usize;
    let total = n.checked_mul(d).context("n*d overflow")?;
    let mut bytes = vec![0u8; total * 4];
    r.read_exact(&mut bytes)?;
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Matrix::from_vec(data, n, d))
}

/// Write class labels to `path` in `.lbl` format.
pub fn write_labels(path: &Path, labels: &[u32]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(LBL_MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(labels.len() as u64).to_le_bytes())?;
    for &l in labels {
        w.write_all(&l.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a `.lbl` label file.
pub fn read_labels(path: &Path) -> Result<Vec<u32>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != LBL_MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let n = read_u64(&mut r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Write a 2D layout as TSV (`x<TAB>y[<TAB>label]`) for external tools.
pub fn write_layout_tsv(path: &Path, layout: &Matrix, labels: Option<&[u32]>) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..layout.n() {
        let row = layout.row(i);
        let coords: Vec<String> = row.iter().map(|x| format!("{x:.6}")).collect();
        match labels {
            Some(ls) => writeln!(w, "{}\t{}", coords.join("\t"), ls[i])?,
            None => writeln!(w, "{}", coords.join("\t"))?,
        }
    }
    w.flush()?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("largevis_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_vec((0..24).map(|x| x as f32 * 0.5 - 3.0).collect(), 6, 4);
        let p = tmp("roundtrip.lvec");
        write_matrix(&p, &m).unwrap();
        let back = read_matrix(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn labels_roundtrip() {
        let labels: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let p = tmp("roundtrip.lbl");
        write_labels(&p, &labels).unwrap();
        assert_eq!(read_labels(&p).unwrap(), labels);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.lvec");
        std::fs::write(&p, b"NOPE00000000").unwrap();
        assert!(read_matrix(&p).is_err());
        assert!(read_labels(&p).is_err());
    }

    #[test]
    fn tsv_written() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let p = tmp("layout.tsv");
        write_layout_tsv(&p, &m, Some(&[0, 1])).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().ends_with("\t0"));
    }
}
