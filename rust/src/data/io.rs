//! Dataset I/O: labels, layout TSV, and the binary matrix entrypoints.
//!
//! The binary matrix format (`.lvec`) is defined in
//! [`crate::data::formats::binary`] together with its streaming chunked
//! reader/writer; [`read_matrix`]/[`write_matrix`] here are the stable
//! whole-matrix convenience wrappers every existing caller imports.
//! Labels (`.lbl`, little-endian): magic `LLBL`, u32 version, u64 n,
//! then `n` u32 class ids.

use crate::data::formats::binary;
use crate::data::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::path::Path;

const LBL_MAGIC: &[u8; 4] = b"LLBL";
const VERSION: u32 = 1;

/// Write a matrix to `path` in `.lvec` format.
pub fn write_matrix(path: &Path, m: &Matrix) -> Result<()> {
    binary::write_binary(path, m)
}

/// Read a `.lvec` matrix (whole-file; for bounded-memory streaming use
/// [`crate::data::formats::binary::ChunkedMatrixReader`]).
pub fn read_matrix(path: &Path) -> Result<Matrix> {
    binary::read_binary(path)
}

/// Write class labels to `path` in `.lbl` format.
pub fn write_labels(path: &Path, labels: &[u32]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(LBL_MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(labels.len() as u64).to_le_bytes())?;
    binary::write_array(&mut w, labels, &mut Vec::new(), |l: u32| l.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read a `.lbl` label file.
///
/// The header's count is untrusted input: it is sanity-capped and the
/// ids are read through a bounded chunk buffer, so a corrupt or hostile
/// header yields an error instead of a huge allocation (or, via
/// `n * 4` overflow, a silently empty result).
pub fn read_labels(path: &Path) -> Result<Vec<u32>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    binary::check_magic(&mut r, LBL_MAGIC, VERSION, path)?;
    let n = binary::read_u64(&mut r)? as usize;
    if n > (1usize << 40) {
        bail!("{}: implausible label count {n}", path.display());
    }
    let hint = n.min(crate::data::formats::UNTRUSTED_CAPACITY_HINT);
    let mut out: Vec<u32> = Vec::with_capacity(hint);
    binary::read_array(&mut r, n, 4, &mut out, binary::dec_u32)
        .with_context(|| format!("{}: truncated label file", path.display()))?;
    Ok(out)
}

/// Write a 2D layout as TSV (`x<TAB>y[<TAB>label]`) for external tools.
pub fn write_layout_tsv(path: &Path, layout: &Matrix, labels: Option<&[u32]>) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..layout.n() {
        let row = layout.row(i);
        let coords: Vec<String> = row.iter().map(|x| format!("{x:.6}")).collect();
        match labels {
            Some(ls) => writeln!(w, "{}\t{}", coords.join("\t"), ls[i])?,
            None => writeln!(w, "{}", coords.join("\t"))?,
        }
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("largevis_io_tests_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_vec((0..24).map(|x| x as f32 * 0.5 - 3.0).collect(), 6, 4);
        let p = tmp("roundtrip.lvec");
        write_matrix(&p, &m).unwrap();
        let back = read_matrix(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn labels_roundtrip() {
        let labels: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let p = tmp("roundtrip.lbl");
        write_labels(&p, &labels).unwrap();
        assert_eq!(read_labels(&p).unwrap(), labels);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.lvec");
        std::fs::write(&p, b"NOPE00000000").unwrap();
        assert!(read_matrix(&p).is_err());
        assert!(read_labels(&p).is_err());
    }

    #[test]
    fn corrupt_label_header_rejected() {
        let p = tmp("huge.lbl");
        // Implausible count must error, not allocate.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"LLBL");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_labels(&p).is_err());
        // Truncated body: header says 10 labels, only 2 present.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"LLBL");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&10u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&p, &bytes).unwrap();
        let err = read_labels(&p).unwrap_err().to_string();
        assert!(err.contains("truncated label file"), "{err}");
    }

    #[test]
    fn tsv_written() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let p = tmp("layout.tsv");
        write_layout_tsv(&p, &m, Some(&[0, 1])).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().ends_with("\t0"));
    }
}
