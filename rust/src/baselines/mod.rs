//! Visualization baselines from the paper's evaluation (§4.3):
//! Barnes–Hut t-SNE, BH Symmetric SNE, and Fruchterman–Reingold.
//! (The LINE-2D baseline lives in [`crate::embed::line`].)

pub mod quadtree;
pub mod bhtsne;
pub mod sne;
pub mod fr;

pub use bhtsne::{bh_tsne, BhTsneConfig};
pub use fr::{fruchterman_reingold, FrConfig};
pub use quadtree::QuadTree;
pub use sne::{bh_sne, BhSneConfig};
