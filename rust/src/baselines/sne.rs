//! Barnes–Hut Symmetric SNE (Hinton & Roweis 2002, accelerated per van
//! der Maaten 2014) — Fig 5's weakest graph-visualization baseline.
//!
//! Identical to BH t-SNE except the low-dimensional kernel is Gaussian
//! `exp(-d²)` instead of Student-t — which is exactly why it crowds:
//! comparing the two isolates the heavy-tail choice (the same contrast
//! Fig 4 draws for LargeVis's f).

use crate::baselines::quadtree::QuadTree;
use crate::data::matrix::Matrix;
use crate::graph::CsrGraph;
use crate::util::pool;
use crate::vis::init_layout;

/// BH-SSNE hyper-parameters.
#[derive(Clone, Debug)]
pub struct BhSneConfig {
    /// Barnes–Hut accuracy θ.
    pub theta: f32,
    /// Iterations.
    pub iters: usize,
    /// Learning rate.
    pub eta: f32,
    /// Momentum.
    pub momentum: f32,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Seed.
    pub seed: u64,
}

impl Default for BhSneConfig {
    fn default() -> Self {
        BhSneConfig { theta: 0.5, iters: 1000, eta: 200.0, momentum: 0.7, threads: 0, seed: 0x55e }
    }
}

/// Run BH Symmetric SNE on a weighted graph; returns the 2D layout.
pub fn bh_sne(graph: &CsrGraph, cfg: &BhSneConfig) -> Matrix {
    let n = graph.n();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let mut y = init_layout(n, 2, cfg.seed);
    let mut velocity = vec![0f32; n * 2];
    let edges = graph.edges();

    for _iter in 0..cfg.iters {
        let tree = QuadTree::build(&y);
        // Gaussian far field: Σ_c N_c e^{-d²} (y_i-y_c) and Z terms.
        let rep: Vec<(f32, f32, f64)> = pool::parallel_map(n, threads, |i| {
            let (xi, yi) = (y.row(i)[0], y.row(i)[1]);
            let (mut fx, mut fy, mut z) = (0f32, 0f32, 0f64);
            tree.for_each_far_field(xi, yi, cfg.theta, i as u32, &mut |cnt, cx, cy| {
                let dx = xi - cx;
                let dy = yi - cy;
                let w = (-(dx * dx + dy * dy)).exp() * cnt as f32;
                fx += w * dx;
                fy += w * dy;
                z += w as f64;
            });
            (fx, fy, z)
        });
        let z: f64 = rep.iter().map(|&(_, _, zi)| zi).sum::<f64>().max(1e-12);

        let mut attr = vec![0f32; n * 2];
        for &(a, b, w) in edges {
            let (ai, bi) = (a as usize, b as usize);
            let dx = y.row(ai)[0] - y.row(bi)[0];
            let dy = y.row(ai)[1] - y.row(bi)[1];
            attr[ai * 2] += w as f32 * dx;
            attr[ai * 2 + 1] += w as f32 * dy;
        }

        for i in 0..n {
            for k in 0..2 {
                let g_rep = match k {
                    0 => rep[i].0,
                    _ => rep[i].1,
                } / z as f32;
                let grad = 2.0 * (attr[i * 2 + k] - g_rep);
                let idx = i * 2 + k;
                velocity[idx] = cfg.momentum * velocity[idx] - cfg.eta * grad;
                y.row_mut(i)[k] += velocity[idx];
            }
        }
        let means = y.col_means();
        for i in 0..n {
            for k in 0..2 {
                y.row_mut(i)[k] -= means[k];
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
    use crate::graph::weights::{weighted_graph, WeightConfig};
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn sne_recovers_coarse_structure() {
        let (m, labels) = gaussian_mixture(240, 12, 3, 0.0, 8);
        let knn = exact_knn(&m, 15, 2);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 8.0, ..Default::default() });
        let y = bh_sne(&g, &BhSneConfig { iters: 250, eta: 50.0, threads: 2, ..Default::default() });
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
        let acc = knn_accuracy(&y, &labels, &KnnEvalConfig { k: 5, ..Default::default() });
        assert!(acc > 0.6, "SSNE accuracy {acc}");
    }
}
