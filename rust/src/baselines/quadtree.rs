//! Barnes–Hut quadtree over 2D layouts: each cell stores its point
//! count and center of mass; far-field cells (size/dist < θ) stand in
//! for their points, giving O(N log N) repulsive-force sums for t-SNE
//! and SNE.

use crate::data::matrix::Matrix;

/// Quadtree node (Vec-backed; children index NONE = empty).
struct Node {
    /// Cell center (x, y).
    cx: f32,
    cy: f32,
    /// Cell half-width.
    half: f32,
    /// Number of points in the subtree.
    count: u32,
    /// Center of mass of contained points.
    mass_x: f32,
    mass_y: f32,
    /// A representative point when `count == 1`.
    point: u32,
    /// Child indices (NW, NE, SW, SE).
    children: [u32; 4],
}

const NONE: u32 = u32::MAX;

/// Barnes–Hut quadtree.
pub struct QuadTree {
    nodes: Vec<Node>,
}

impl QuadTree {
    /// Build over the first two columns of `layout`.
    pub fn build(layout: &Matrix) -> Self {
        assert!(layout.d() >= 2 && layout.n() > 0);
        let n = layout.n();
        let (mut xmin, mut xmax, mut ymin, mut ymax) = (f32::MAX, f32::MIN, f32::MAX, f32::MIN);
        for i in 0..n {
            let r = layout.row(i);
            xmin = xmin.min(r[0]);
            xmax = xmax.max(r[0]);
            ymin = ymin.min(r[1]);
            ymax = ymax.max(r[1]);
        }
        let half = 0.5 * ((xmax - xmin).max(ymax - ymin)).max(1e-6) + 1e-5;
        let mut tree = QuadTree { nodes: Vec::with_capacity(2 * n) };
        tree.nodes.push(Node {
            cx: 0.5 * (xmin + xmax),
            cy: 0.5 * (ymin + ymax),
            half,
            count: 0,
            mass_x: 0.0,
            mass_y: 0.0,
            point: NONE,
            children: [NONE; 4],
        });
        for i in 0..n {
            let r = layout.row(i);
            tree.insert(0, i as u32, r[0], r[1], 0);
        }
        tree
    }

    fn insert(&mut self, node: u32, point: u32, x: f32, y: f32, depth: usize) {
        let (count, cx, cy, half) = {
            let nd = &mut self.nodes[node as usize];
            nd.mass_x += x;
            nd.mass_y += y;
            nd.count += 1;
            (nd.count, nd.cx, nd.cy, nd.half)
        };
        if count == 1 {
            self.nodes[node as usize].point = point;
            return;
        }
        // Depth cap: coincident points pile up in one cell.
        if depth > 48 {
            return;
        }
        // On the second insertion, push the resident point down.
        if count == 2 {
            let old = self.nodes[node as usize].point;
            self.nodes[node as usize].point = NONE;
            if old != NONE {
                let (ox, oy) = {
                    let nd = &self.nodes[node as usize];
                    // Recover the old point's coords from the mass sums.
                    (nd.mass_x - x, nd.mass_y - y)
                };
                let qo = self.child_for(node, ox, oy, cx, cy, half, depth);
                self.insert_into_child(qo, old, ox, oy, depth);
            }
        }
        let q = self.child_for(node, x, y, cx, cy, half, depth);
        self.insert_into_child(q, point, x, y, depth);
    }

    fn insert_into_child(&mut self, child: u32, point: u32, x: f32, y: f32, depth: usize) {
        self.insert(child, point, x, y, depth + 1);
    }

    fn child_for(&mut self, node: u32, x: f32, y: f32, cx: f32, cy: f32, half: f32, _depth: usize) -> u32 {
        let (qi, ox, oy) = match (x >= cx, y >= cy) {
            (false, true) => (0, -0.5, 0.5),
            (true, true) => (1, 0.5, 0.5),
            (false, false) => (2, -0.5, -0.5),
            (true, false) => (3, 0.5, -0.5),
        };
        let existing = self.nodes[node as usize].children[qi];
        if existing != NONE {
            return existing;
        }
        let child = self.nodes.len() as u32;
        self.nodes.push(Node {
            cx: cx + ox * half,
            cy: cy + oy * half,
            half: 0.5 * half,
            count: 0,
            mass_x: 0.0,
            mass_y: 0.0,
            point: NONE,
            children: [NONE; 4],
        });
        self.nodes[node as usize].children[qi] = child;
        child
    }

    /// Barnes–Hut traversal: call `accept(count, com_x, com_y)` for every
    /// cell that is far enough from `(x, y)` (cell_size/dist < θ) or is a
    /// single point other than `skip`.
    pub fn for_each_far_field(
        &self,
        x: f32,
        y: f32,
        theta: f32,
        skip: u32,
        accept: &mut impl FnMut(u32, f32, f32),
    ) {
        self.walk(0, x, y, theta, skip, accept);
    }

    fn walk(
        &self,
        node: u32,
        x: f32,
        y: f32,
        theta: f32,
        skip: u32,
        accept: &mut impl FnMut(u32, f32, f32),
    ) {
        let nd = &self.nodes[node as usize];
        if nd.count == 0 {
            return;
        }
        let com_x = nd.mass_x / nd.count as f32;
        let com_y = nd.mass_y / nd.count as f32;
        if nd.count == 1 {
            if nd.point != skip {
                accept(1, com_x, com_y);
            }
            return;
        }
        let dx = x - com_x;
        let dy = y - com_y;
        let dist = (dx * dx + dy * dy).sqrt().max(1e-12);
        if (2.0 * nd.half) / dist < theta {
            // Far field. If the query point itself is inside this cell,
            // its self-contribution is one point at distance ~0 — the
            // callers' kernels are finite there, and the error is O(1/N).
            accept(nd.count, com_x, com_y);
            return;
        }
        for &c in &nd.children {
            if c != NONE {
                self.walk(c, x, y, theta, skip, accept);
            }
        }
    }

    /// Total number of points inserted.
    pub fn count(&self) -> u32 {
        self.nodes[0].count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_layout(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, 2);
        for i in 0..n {
            m.row_mut(i)[0] = rng.gaussian() * 3.0;
            m.row_mut(i)[1] = rng.gaussian() * 3.0;
        }
        m
    }

    #[test]
    fn mass_conservation() {
        let m = random_layout(500, 1);
        let t = QuadTree::build(&m);
        assert_eq!(t.count(), 500);
        // Sum of accepted counts with theta=0 (never accept internal
        // cells => every leaf visited) equals n-1 (skip = self).
        let mut total = 0u32;
        t.for_each_far_field(m.row(0)[0], m.row(0)[1], 0.0, 0, &mut |c, _, _| total += c);
        assert_eq!(total, 499);
    }

    #[test]
    fn far_field_approximates_exact_sum() {
        // Σ_j 1/(1+d²): BH vs exact within a few percent at θ=0.5.
        let m = random_layout(800, 2);
        let t = QuadTree::build(&m);
        let (qx, qy) = (m.row(0)[0], m.row(0)[1]);
        let mut approx = 0f64;
        t.for_each_far_field(qx, qy, 0.5, 0, &mut |cnt, cx, cy| {
            let d2 = (qx - cx) * (qx - cx) + (qy - cy) * (qy - cy);
            approx += cnt as f64 / (1.0 + d2 as f64);
        });
        let mut exact = 0f64;
        for j in 1..800 {
            let d2 = m.sqdist(0, j);
            exact += 1.0 / (1.0 + d2 as f64);
        }
        let rel = (approx - exact).abs() / exact;
        assert!(rel < 0.05, "rel err {rel}: approx {approx} vs exact {exact}");
    }

    #[test]
    fn duplicate_points_no_infinite_loop() {
        let mut m = Matrix::zeros(64, 2);
        for i in 0..64 {
            m.row_mut(i).copy_from_slice(&[1.5, -2.0]);
        }
        let t = QuadTree::build(&m);
        assert_eq!(t.count(), 64);
    }

    #[test]
    fn theta_large_visits_few_cells() {
        let m = random_layout(1000, 3);
        let t = QuadTree::build(&m);
        let mut visits_strict = 0;
        let mut visits_loose = 0;
        t.for_each_far_field(0.0, 0.0, 0.2, NONE, &mut |_, _, _| visits_strict += 1);
        t.for_each_far_field(0.0, 0.0, 1.5, NONE, &mut |_, _, _| visits_loose += 1);
        assert!(visits_loose < visits_strict);
    }
}
