//! Barnes–Hut t-SNE (van der Maaten, JMLR 2014) — the paper's main
//! layout baseline (Fig 5–6, Table 2).
//!
//! Full-batch gradient descent on KL(P ‖ Q) with the Student-t kernel,
//! momentum + adaptive gains, early exaggeration, and the quadtree
//! far-field approximation of the repulsive term — O(N log N) per
//! iteration (vs LargeVis's O(N) total sampling).
//!
//! The input P comes from the same perplexity-calibrated, symmetrized
//! KNN graph as LargeVis (our [`crate::graph::weights`]), matching the
//! paper's experimental setup where all visualizers share one KNN graph.

use crate::baselines::quadtree::QuadTree;
use crate::data::matrix::Matrix;
use crate::graph::CsrGraph;
use crate::util::pool;
use crate::vis::init_layout;

/// BH t-SNE hyper-parameters (defaults follow van der Maaten's code).
#[derive(Clone, Debug)]
pub struct BhTsneConfig {
    /// Barnes–Hut accuracy θ (paper setting: 0.5).
    pub theta: f32,
    /// Gradient-descent iterations (paper setting: 1000).
    pub iters: usize,
    /// Learning rate η (t-SNE default 200; the paper shows large data
    /// wants ~2500–3000, which Fig 5/6 sweeps explore).
    pub eta: f32,
    /// Early-exaggeration factor and duration.
    pub exaggeration: f32,
    /// Iterations with exaggeration on.
    pub exaggeration_iters: usize,
    /// Momentum before/after iteration 250.
    pub momentum: f32,
    /// Momentum after the switch.
    pub final_momentum: f32,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Layout init seed.
    pub seed: u64,
}

impl Default for BhTsneConfig {
    fn default() -> Self {
        BhTsneConfig {
            theta: 0.5,
            iters: 1000,
            eta: 200.0,
            exaggeration: 12.0,
            exaggeration_iters: 250,
            momentum: 0.5,
            final_momentum: 0.8,
            threads: 0,
            seed: 0x7e5e,
        }
    }
}

/// Run BH t-SNE on a weighted graph; returns the 2D layout.
pub fn bh_tsne(graph: &CsrGraph, cfg: &BhTsneConfig) -> Matrix {
    let n = graph.n();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let mut y = init_layout(n, 2, cfg.seed);
    let mut velocity = vec![0f32; n * 2];
    let mut gains = vec![1f32; n * 2];

    // P normalized over directed pairs (our weighted graph already sums
    // to 1 over directed edges).
    let edges = graph.edges();

    for iter in 0..cfg.iters {
        let exag = if iter < cfg.exaggeration_iters { cfg.exaggeration } else { 1.0 };
        let momentum = if iter < 250 { cfg.momentum } else { cfg.final_momentum };

        // Repulsive pass: per-point far-field sums and the global Z.
        let tree = QuadTree::build(&y);
        // rep[i] = (Σ_c N_c q_ic² (y_i - y_c), Σ_c N_c q_ic) with
        // q_ic = 1/(1+d²); Z = Σ_i Σ_c N_c q_ic.
        let rep: Vec<(f32, f32, f64)> = pool::parallel_map(n, threads, |i| {
            let (xi, yi) = (y.row(i)[0], y.row(i)[1]);
            let (mut fx, mut fy, mut z) = (0f32, 0f32, 0f64);
            tree.for_each_far_field(xi, yi, cfg.theta, i as u32, &mut |cnt, cx, cy| {
                let dx = xi - cx;
                let dy = yi - cy;
                let q = 1.0 / (1.0 + dx * dx + dy * dy);
                let q2 = q * q * cnt as f32;
                fx += q2 * dx;
                fy += q2 * dy;
                z += (cnt as f32 * q) as f64;
            });
            (fx, fy, z)
        });
        let z: f64 = rep.iter().map(|&(_, _, zi)| zi).sum::<f64>().max(1e-12);

        // Attractive pass over the sparse P (parallel over edge chunks,
        // each worker returns a private accumulator, merged after).
        let mut attr = vec![0f32; n * 2];
        {
            let nt = threads.max(1);
            let chunk = edges.len().div_ceil(nt);
            let partials: Vec<Vec<f32>> = pool::parallel_map(nt, nt, |tid| {
                let lo = tid * chunk;
                let hi = ((tid + 1) * chunk).min(edges.len());
                let mut local = vec![0f32; n * 2];
                for &(a, b, w) in &edges[lo..hi.max(lo)] {
                    let (ai, bi) = (a as usize, b as usize);
                    let dx = y.row(ai)[0] - y.row(bi)[0];
                    let dy = y.row(ai)[1] - y.row(bi)[1];
                    let q = 1.0 / (1.0 + dx * dx + dy * dy);
                    let c = (exag * w as f32) * q;
                    local[ai * 2] += c * dx;
                    local[ai * 2 + 1] += c * dy;
                }
                local
            });
            for local in &partials {
                for (a, l) in attr.iter_mut().zip(local) {
                    *a += l;
                }
            }
        }

        // Gradient + momentum/gains update.
        for i in 0..n {
            for k in 0..2 {
                let g_attr = attr[i * 2 + k];
                let g_rep = match k {
                    0 => rep[i].0,
                    _ => rep[i].1,
                } / z as f32;
                let grad = 4.0 * (g_attr - g_rep);
                let idx = i * 2 + k;
                // Adaptive gains (Jacobs): sign agreement shrinks, else grows.
                gains[idx] = if grad.signum() != velocity[idx].signum() {
                    (gains[idx] + 0.2).min(8.0)
                } else {
                    (gains[idx] * 0.8).max(0.01)
                };
                velocity[idx] = momentum * velocity[idx] - cfg.eta * gains[idx] * grad;
                y.row_mut(i)[k] += velocity[idx];
            }
        }
        // Recenter (t-SNE does this every iteration).
        let means = y.col_means();
        for i in 0..n {
            for k in 0..2 {
                y.row_mut(i)[k] -= means[k];
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
    use crate::graph::weights::{weighted_graph, WeightConfig};
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn tsne_separates_gaussian_clusters() {
        let (m, labels) = gaussian_mixture(300, 16, 3, 0.0, 5);
        let knn = exact_knn(&m, 20, 4);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 10.0, ..Default::default() });
        let cfg = BhTsneConfig { iters: 300, threads: 2, ..Default::default() };
        let y = bh_tsne(&g, &cfg);
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
        let acc = knn_accuracy(&y, &labels, &KnnEvalConfig { k: 5, ..Default::default() });
        assert!(acc > 0.85, "t-SNE accuracy {acc}");
    }

    #[test]
    fn layout_centered() {
        let (m, _) = gaussian_mixture(120, 8, 2, 0.2, 6);
        let knn = exact_knn(&m, 10, 2);
        let g = weighted_graph(&knn, &WeightConfig { perplexity: 5.0, ..Default::default() });
        let y = bh_tsne(&g, &BhTsneConfig { iters: 50, threads: 1, ..Default::default() });
        let means = y.col_means();
        assert!(means[0].abs() < 1e-3 && means[1].abs() < 1e-3, "{means:?}");
    }
}
