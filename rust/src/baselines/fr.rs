//! Fruchterman–Reingold force-directed layout (1991) — the classical
//! O(N²)-per-iteration graph-drawing baseline the paper cites as
//! unscalable beyond ~1M nodes. Included for the related-work
//! comparison on small graphs and as a sanity baseline in tests.

use crate::data::matrix::Matrix;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// FR parameters.
#[derive(Clone, Debug)]
pub struct FrConfig {
    /// Iterations.
    pub iters: usize,
    /// Layout area edge length.
    pub width: f32,
    /// Seed for the random initial placement.
    pub seed: u64,
}

impl Default for FrConfig {
    fn default() -> Self {
        FrConfig { iters: 200, width: 10.0, seed: 0xf4 }
    }
}

/// Run Fruchterman–Reingold; returns the 2D layout. O(iters · N²).
pub fn fruchterman_reingold(graph: &CsrGraph, cfg: &FrConfig) -> Matrix {
    let n = graph.n();
    let mut rng = Rng::new(cfg.seed);
    let mut y = Matrix::zeros(n, 2);
    for i in 0..n {
        y.row_mut(i)[0] = rng.range_f32(-cfg.width / 2.0, cfg.width / 2.0);
        y.row_mut(i)[1] = rng.range_f32(-cfg.width / 2.0, cfg.width / 2.0);
    }
    if n < 2 {
        return y;
    }
    let k = cfg.width / (n as f32).sqrt(); // optimal pair distance
    let mut disp = vec![0f32; n * 2];

    for iter in 0..cfg.iters {
        let temp = cfg.width / 10.0 * (1.0 - iter as f32 / cfg.iters as f32).max(0.01);
        disp.iter_mut().for_each(|d| *d = 0.0);
        // Repulsive: all pairs.
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = y.row(i)[0] - y.row(j)[0];
                let dy = y.row(i)[1] - y.row(j)[1];
                let d = (dx * dx + dy * dy).sqrt().max(1e-6);
                let f = k * k / d;
                let (ux, uy) = (dx / d, dy / d);
                disp[i * 2] += ux * f;
                disp[i * 2 + 1] += uy * f;
                disp[j * 2] -= ux * f;
                disp[j * 2 + 1] -= uy * f;
            }
        }
        // Attractive: edges.
        for &(a, b, _) in graph.edges() {
            let (i, j) = (a as usize, b as usize);
            let dx = y.row(i)[0] - y.row(j)[0];
            let dy = y.row(i)[1] - y.row(j)[1];
            let d = (dx * dx + dy * dy).sqrt().max(1e-6);
            let f = d * d / k;
            let (ux, uy) = (dx / d, dy / d);
            disp[i * 2] -= ux * f;
            disp[i * 2 + 1] -= uy * f;
            // (both directions present in edges(), so each endpoint
            // accumulates its pull once per direction)
        }
        // Apply with temperature cap.
        for i in 0..n {
            let dx = disp[i * 2];
            let dy = disp[i * 2 + 1];
            let d = (dx * dx + dy * dy).sqrt().max(1e-9);
            let step = d.min(temp);
            y.row_mut(i)[0] += dx / d * step;
            y.row_mut(i)[1] += dy / d * step;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_cliques() {
        let mut edges = Vec::new();
        for c in 0..2u32 {
            let base = c * 5;
            for a in 0..5u32 {
                for b in (a + 1)..5u32 {
                    edges.push((base + a, base + b, 1.0f64));
                }
            }
        }
        edges.push((0, 5, 1.0));
        let g = CsrGraph::from_undirected(10, &edges);
        let y = fruchterman_reingold(&g, &FrConfig::default());
        let mut intra = 0f64;
        let mut inter = 0f64;
        let (mut ni, mut nx) = (0, 0);
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d = y.sqdist(a, b) as f64;
                if (a < 5) == (b < 5) {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        assert!(inter / nx as f64 > intra / ni as f64, "FR failed to separate cliques");
    }

    #[test]
    fn all_coordinates_finite() {
        let g = CsrGraph::from_undirected(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let y = fruchterman_reingold(&g, &FrConfig { iters: 50, ..Default::default() });
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
    }
}
