//! The end-to-end LargeVis pipeline (Figure 1 of the paper).

use crate::config::PipelineConfig;
use crate::coordinator::metrics::Metrics;
use crate::data::datasets;
use crate::data::io::write_layout_tsv;
use crate::data::matrix::Matrix;
use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use crate::graph::weights::weighted_graph;
use crate::knn::explore::largevis_knn;
use crate::knn::sampled_recall;
use crate::render::{render_scatter, ScatterStyle};
use crate::util::timer::Timer;
use anyhow::{Context, Result};

/// Everything a pipeline run produces.
pub struct PipelineOutput {
    /// The 2D/3D layout.
    pub layout: Matrix,
    /// Labels (if the dataset has them).
    pub labels: Option<Vec<u32>>,
    /// Per-stage timings and quality metrics.
    pub metrics: Metrics,
}

/// Run the full pipeline per `cfg`, writing layout TSV + SVG + report
/// JSON into `cfg.out_dir`.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineOutput> {
    let mut metrics = Metrics::new();
    std::fs::create_dir_all(&cfg.out_dir)
        .with_context(|| format!("create {}", cfg.out_dir.display()))?;

    // Stage 1: dataset (generation stands in for I/O offline).
    let t = Timer::start("dataset");
    let ds = datasets::generate(&cfg.dataset, cfg.scale, cfg.data_seed)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    metrics.set("dataset.secs", t.report());
    metrics.set("dataset.n", ds.points.n() as f64);
    metrics.set("dataset.d", ds.points.d() as f64);
    eprintln!("[pipeline] dataset {} n={} d={}", ds.name, ds.points.n(), ds.points.d());

    // Stage 2: KNN graph (RP-forest + neighbor exploring).
    let k = cfg.k.min(ds.points.n().saturating_sub(1)).max(1);
    let t = Timer::start("knn");
    let knn = largevis_knn(&ds.points, k, &cfg.knn);
    metrics.set("knn.secs", t.report());
    let recall = sampled_recall(&ds.points, &knn, 200, 7, cfg.knn.threads);
    metrics.set("knn.sampled_recall", recall);
    eprintln!("[pipeline] knn k={k} sampled-recall={recall:.4}");

    // Stage 3: perplexity weights + symmetrization.
    let t = Timer::start("weights");
    let graph = weighted_graph(&knn, &cfg.weights);
    metrics.set("weights.secs", t.report());
    metrics.set("graph.directed_edges", graph.n_directed_edges() as f64);

    // Stage 4: layout.
    let t = Timer::start("layout");
    let mut layout = crate::vis::init_layout(graph.n(), cfg.vis.dim, cfg.vis.seed);
    let report = if cfg.use_xla {
        let rt = crate::runtime::Runtime::from_default_dir()?;
        crate::vis::batched::optimize_batched(&graph, &mut layout, &cfg.vis, &rt)?
    } else {
        crate::vis::sgd::optimize(&graph, &mut layout, &cfg.vis)
    };
    metrics.set("layout.secs", t.report());
    metrics.set("layout.samples", report.samples as f64);
    metrics.set("layout.samples_per_sec", report.throughput());

    // Stage 5: evaluation (labels permitting).
    if let Some(labels) = &ds.labels {
        let t = Timer::start("eval");
        let acc = knn_accuracy(&layout, labels, &KnnEvalConfig::default());
        metrics.set("eval.secs", t.report());
        metrics.set("eval.knn_accuracy", acc);
        eprintln!("[pipeline] 2D KNN-classifier accuracy = {acc:.4}");
    }

    // Stage 6: outputs.
    write_layout_tsv(&cfg.out_dir.join("layout.tsv"), &layout, ds.labels.as_deref())?;
    render_scatter(
        &cfg.out_dir.join("layout.svg"),
        &layout,
        ds.labels.as_deref(),
        ds.n_classes,
        &ScatterStyle { title: ds.name.clone(), ..Default::default() },
    )?;
    std::fs::write(cfg.out_dir.join("report.json"), metrics.to_json())?;
    eprintln!("[pipeline] outputs in {}", cfg.out_dir.display());

    Ok(PipelineOutput { layout, labels: ds.labels, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_pipeline_end_to_end() {
        let mut cfg = PipelineConfig {
            dataset: "20ng-like".into(),
            scale: 0.02, // ~380 points
            k: 10,
            out_dir: std::env::temp_dir().join("largevis_pipeline_test"),
            ..Default::default()
        };
        cfg.vis.samples_per_vertex = 400;
        cfg.knn.forest.n_trees = 2;
        let out = run_pipeline(&cfg).unwrap();
        assert_eq!(out.layout.d(), 2);
        assert!(out.metrics.get("eval.knn_accuracy").unwrap() > 0.3);
        assert!(cfg.out_dir.join("layout.svg").exists());
        assert!(cfg.out_dir.join("report.json").exists());
        let report = std::fs::read_to_string(cfg.out_dir.join("report.json")).unwrap();
        crate::util::json::Json::parse(&report).unwrap();
    }
}
