//! The end-to-end LargeVis pipeline (Figure 1 of the paper), with
//! durable stage boundaries.
//!
//! Stage 1 ingests real datasets from disk (LargeVis text or `.lvec`
//! binary, streamed through a bounded chunk buffer) or falls back to
//! the synthetic registry. After the expensive KNN stage — and after
//! symmetrization — the intermediate graph is checkpointed into
//! `<out_dir>/checkpoints/`, so layout experiments re-run with
//! `resume_from` pay for KNN construction once (paper Table 2: KNN
//! dominates end-to-end runtime at scale).

use crate::config::{LayoutMode, PipelineConfig, Stage};
use crate::coordinator::metrics::Metrics;
use crate::data::datasets::{self, Dataset};
use crate::data::formats::{self, checkpoint};
use crate::data::io::{read_labels, write_labels, write_layout_tsv};
use crate::data::matrix::Matrix;
use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use crate::graph::sparse::CsrGraph;
use crate::graph::weights::weighted_graph;
use crate::knn::explore::largevis_knn;
use crate::knn::{sampled_recall, KnnGraph};
use crate::render::{render_scatter, ScatterStyle};
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Everything a pipeline run produces.
pub struct PipelineOutput {
    /// The 2D/3D layout.
    pub layout: Matrix,
    /// Labels (if the dataset has them).
    pub labels: Option<Vec<u32>>,
    /// Per-stage timings and quality metrics.
    pub metrics: Metrics,
}

/// On-disk locations of the stage checkpoints for one `out_dir`.
///
/// Together these make the directory self-contained for `largevis
/// serve`: the high-dimensional points (`data.lvec`), the KNN graph,
/// the weighted graph, the final layout (`layout.lvec`), and labels.
pub struct CheckpointPaths {
    /// The checkpoint directory (`<out_dir>/checkpoints`).
    pub dir: PathBuf,
    /// Ingested high-dimensional points (`.lvec`), written so query
    /// serving never needs the original input file.
    pub data: PathBuf,
    /// KNN graph checkpoint.
    pub knn: PathBuf,
    /// Symmetrized weighted graph checkpoint.
    pub graph: PathBuf,
    /// Final layout (`.lvec`), whichever layout mode produced it.
    pub layout: PathBuf,
    /// Labels (`.lbl`), present only for labeled datasets.
    pub labels: PathBuf,
    /// Dataset name of the run that wrote the checkpoints (plain text).
    pub meta: PathBuf,
    /// Append-only live-insert WAL (`inserts.wal`), written by
    /// `largevis serve` when `POST /insert` traffic arrives and
    /// replayed at server startup; a fresh pipeline run removes any
    /// stale log (the base it referred to is gone).
    pub wal: PathBuf,
}

impl CheckpointPaths {
    /// Checkpoint paths under `out_dir` (the conventional
    /// `<out_dir>/checkpoints` location a pipeline run writes to).
    pub fn new(out_dir: &Path) -> Self {
        CheckpointPaths::in_dir(&out_dir.join("checkpoints"))
    }

    /// Checkpoint paths inside an explicit checkpoint directory — the
    /// `largevis serve --checkpoints <dir>` entry point, where the
    /// caller names the directory itself rather than its parent.
    pub fn in_dir(dir: &Path) -> Self {
        CheckpointPaths {
            data: dir.join("data.lvec"),
            knn: dir.join("knn.ckpt"),
            graph: dir.join("graph.ckpt"),
            layout: dir.join("layout.lvec"),
            labels: dir.join("labels.lbl"),
            meta: dir.join("dataset.txt"),
            wal: dir.join("inserts.wal"),
            dir: dir.to_path_buf(),
        }
    }

    /// Commit marker of an in-flight WAL compaction (`compact.commit`).
    /// Its presence means the staged `*.tmp` checkpoints are complete
    /// and durable; server startup rolls the compaction forward before
    /// loading anything.
    pub fn compact_marker(&self) -> PathBuf {
        self.dir.join("compact.commit")
    }
}

/// Stage 1: load points + labels from `cfg.input`, or generate the
/// registry dataset. Disk inputs stream through the chunked readers
/// into one preallocated matrix.
fn ingest_dataset(cfg: &PipelineConfig) -> Result<Dataset> {
    let Some(path) = &cfg.input else {
        return datasets::generate(&cfg.dataset, cfg.scale, cfg.data_seed)
            .with_context(|| format!("unknown dataset {:?}", cfg.dataset));
    };
    // The peeked shape only sizes the buffer; the shape returned by the
    // streaming read is authoritative (the file may have changed, or a
    // streamed writer may have patched its header between the opens).
    let (est_n, est_d) = formats::peek_shape(path)?;
    let chunk_rows = if cfg.chunk_rows == 0 { formats::DEFAULT_CHUNK_ROWS } else { cfg.chunk_rows };
    // Capacity hint clamped — the header is untrusted input.
    let hint = est_n.saturating_mul(est_d).min(formats::UNTRUSTED_CAPACITY_HINT);
    let mut data: Vec<f32> = Vec::with_capacity(hint);
    let (n, d) = formats::stream_any(path, chunk_rows, |vals, _| {
        data.extend_from_slice(vals);
        Ok(())
    })?;
    if data.len() != n * d {
        anyhow::bail!("{}: read {} values, expected {n}x{d}", path.display(), data.len());
    }
    let points = Matrix::from_vec(data, n, d);
    let labels = match &cfg.input_labels {
        Some(lp) => {
            let ls = read_labels(lp)?;
            if ls.len() != points.n() {
                anyhow::bail!(
                    "{}: {} labels for {} points",
                    lp.display(),
                    ls.len(),
                    points.n()
                );
            }
            Some(ls)
        }
        None => None,
    };
    let n_classes = labels
        .as_ref()
        .map(|ls| ls.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
        .unwrap_or(0);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "input".to_string());
    Ok(Dataset { name, points, labels, n_classes })
}

/// Run the full pipeline per `cfg`, writing layout TSV + SVG + report
/// JSON into `cfg.out_dir` (and stage checkpoints into
/// `<out_dir>/checkpoints/` unless disabled).
///
/// # Example
///
/// ```no_run
/// use largevis::config::PipelineConfig;
/// use largevis::coordinator::run_pipeline;
///
/// # fn main() -> anyhow::Result<()> {
/// let mut cfg = PipelineConfig {
///     dataset: "mnist-like".to_string(),
///     scale: 0.1,
///     k: 50,
///     out_dir: "target/mnist".into(),
///     ..Default::default()
/// };
/// cfg.vis.samples_per_vertex = 2000;
/// let out = run_pipeline(&cfg)?;
/// println!("laid out {} points in {}D", out.layout.n(), out.layout.d());
/// // target/mnist/checkpoints/ now holds everything `largevis serve` needs.
/// # Ok(())
/// # }
/// ```
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineOutput> {
    let mut metrics = Metrics::new();
    std::fs::create_dir_all(&cfg.out_dir)
        .with_context(|| format!("create {}", cfg.out_dir.display()))?;
    let ckpt = CheckpointPaths::new(&cfg.out_dir);
    if cfg.save_checkpoints {
        std::fs::create_dir_all(&ckpt.dir)
            .with_context(|| format!("create {}", ckpt.dir.display()))?;
    }
    if matches!(cfg.resume_from, Some(Stage::Dataset) | Some(Stage::Knn)) {
        anyhow::bail!(
            "--resume-from supports `weights` and `layout`; the dataset and knn \
             stages are always recomputed by a full run (omit --resume-from)"
        );
    }
    let resume = cfg.resume_from.unwrap_or(Stage::Dataset);

    let mut labels: Option<Vec<u32>> = None;
    let mut n_classes = 0usize;
    let mut title = cfg.dataset.clone();

    // Stages 1–2: dataset + KNN graph (skipped when resuming at
    // `weights` or later; `weights` reloads the KNN checkpoint).
    let knn: Option<KnnGraph> = if resume <= Stage::Knn {
        let t = Timer::start("dataset");
        let ds = ingest_dataset(cfg)?;
        metrics.set("dataset.secs", t.report());
        metrics.set("dataset.n", ds.points.n() as f64);
        metrics.set("dataset.d", ds.points.d() as f64);
        eprintln!("[pipeline] dataset {} n={} d={}", ds.name, ds.points.n(), ds.points.d());

        let k = cfg.k.min(ds.points.n().saturating_sub(1)).max(1);
        let t = Timer::start("knn");
        let knn = largevis_knn(&ds.points, k, &cfg.knn);
        metrics.set("knn.secs", t.report());
        let recall = sampled_recall(&ds.points, &knn, 200, 7, cfg.knn.threads);
        metrics.set("knn.sampled_recall", recall);
        eprintln!("[pipeline] knn k={k} sampled-recall={recall:.4}");

        if cfg.save_checkpoints {
            checkpoint::write_knn(&ckpt.knn, &knn)
                .with_context(|| format!("write {}", ckpt.knn.display()))?;
            // The raw points make the checkpoint directory self-contained
            // for `largevis serve` (/embed and /knn scan them).
            formats::binary::write_binary(&ckpt.data, &ds.points)
                .with_context(|| format!("write {}", ckpt.data.display()))?;
            std::fs::write(&ckpt.meta, &ds.name)?;
            // A live-insert WAL from an earlier serve run is bound to
            // the base this run just replaced — replaying it against
            // the new base would be garbage. Same stale-checkpoint
            // hazard as labels.lbl below. The WAL is a *set* now
            // (active log + sealed `inserts.wal.N` segments +
            // quarantined rejects), and a serve-side compaction may
            // have left a commit marker or staged `*.tmp` artifacts;
            // all of them refer to the replaced base.
            if ckpt.wal.exists() {
                std::fs::remove_file(&ckpt.wal)?;
            }
            let marker = ckpt.compact_marker();
            if marker.exists() {
                std::fs::remove_file(&marker)?;
            }
            for entry in std::fs::read_dir(&ckpt.dir)? {
                let p = entry?.path();
                let stale = p
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("inserts.wal") || n.ends_with(".tmp"));
                if stale && p.is_file() {
                    std::fs::remove_file(&p)?;
                }
            }
            match &ds.labels {
                Some(ls) => write_labels(&ckpt.labels, ls)?,
                // Drop any stale labels from a previous run of a
                // different dataset into the same out_dir.
                None => {
                    if ckpt.labels.exists() {
                        std::fs::remove_file(&ckpt.labels)?;
                    }
                }
            }
        }
        labels = ds.labels;
        n_classes = ds.n_classes;
        title = ds.name;
        Some(knn)
    } else {
        // Resumed run: the dataset is not reloaded; labels and the
        // dataset name come from the checkpoint directory.
        if ckpt.labels.exists() {
            let ls = read_labels(&ckpt.labels)?;
            n_classes = ls.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
            labels = Some(ls);
        }
        if let Ok(name) = std::fs::read_to_string(&ckpt.meta) {
            title = name.trim().to_string();
        }
        title = format!("{title} (resumed)");
        if resume == Stage::Weights {
            let t = Timer::start("knn.load");
            let knn = checkpoint::read_knn(&ckpt.knn).with_context(|| {
                format!("resume-from weights needs the KNN checkpoint at {}", ckpt.knn.display())
            })?;
            metrics.set("knn.load_secs", t.report());
            eprintln!("[pipeline] resumed KNN graph: n={} k={}", knn.n(), knn.k);
            Some(knn)
        } else {
            None
        }
    };

    // Stage 3: perplexity weights + parallel sharded symmetrization
    // (skipped when resuming at `layout`, which reloads the CSR
    // checkpoint).
    let graph: CsrGraph = if resume <= Stage::Weights {
        let Some(knn) = knn.as_ref() else {
            // Unreachable by stage ordering (resume <= Weights implies
            // the KNN stage ran or its checkpoint loaded), but a staging
            // bug must surface as an error, not a panic.
            anyhow::bail!("internal: weights stage reached without a KNN graph");
        };
        let t = Timer::start("weights");
        let graph = weighted_graph(knn, &cfg.weights);
        metrics.set("weights.secs", t.report());
        if cfg.save_checkpoints {
            checkpoint::write_csr(&ckpt.graph, &graph)
                .with_context(|| format!("write {}", ckpt.graph.display()))?;
        }
        graph
    } else {
        let t = Timer::start("weights.load");
        let graph = checkpoint::read_csr(&ckpt.graph).with_context(|| {
            format!("resume-from layout needs the graph checkpoint at {}", ckpt.graph.display())
        })?;
        metrics.set("weights.load_secs", t.report());
        eprintln!(
            "[pipeline] resumed weighted graph: n={} edges={}",
            graph.n(),
            graph.n_directed_edges()
        );
        graph
    };
    metrics.set("graph.directed_edges", graph.n_directed_edges() as f64);

    // A stale checkpoint directory (labels from a different run) must
    // fail here, not index out of bounds deep in eval/render.
    if let Some(ls) = &labels {
        if ls.len() != graph.n() {
            anyhow::bail!(
                "{}: {} labels for a graph of {} vertices — stale checkpoint directory?",
                ckpt.labels.display(),
                ls.len(),
                graph.n()
            );
        }
    }

    // Stage 4: layout — flat Hogwild, multilevel coarse-to-fine (the
    // default), or the AOT/XLA batched engine. Multilevel checkpoints
    // every level's layout into `<out>/checkpoints/layout_L<depth>.lvec`
    // (depth 0 = the finest, i.e. the final layout's own resolution).
    let t = Timer::start("layout");
    // Drop per-level layouts left by a previous run into the same
    // out_dir: a shallower hierarchy (or flat mode) would otherwise
    // leave deep layout_L<d>.lvec files that present as coarse previews
    // of *this* run — the same stale-checkpoint hazard handled for
    // labels.lbl above.
    if cfg.save_checkpoints && ckpt.dir.exists() {
        for entry in std::fs::read_dir(&ckpt.dir)? {
            let path = entry?.path();
            let name = path.file_name().map(|s| s.to_string_lossy().into_owned());
            if let Some(name) = name {
                if name.starts_with("layout_L") && name.ends_with(".lvec") {
                    std::fs::remove_file(&path)?;
                }
            }
        }
    }
    // The multilevel driver ignores the incoming layout (its coarsest
    // level re-initializes internally), so don't pay n·dim gaussian
    // draws for a buffer that is fully overwritten.
    let mut layout = if cfg.use_xla || cfg.layout_mode == LayoutMode::Flat {
        crate::vis::init_layout(graph.n(), cfg.vis.dim, cfg.vis.seed)
    } else {
        Matrix::zeros(graph.n(), cfg.vis.dim)
    };
    let report = if cfg.use_xla {
        if cfg.layout_mode == LayoutMode::Multilevel {
            eprintln!(
                "[pipeline] note: --engine xla runs the flat batched optimizer; \
                 the multilevel layout mode is ignored"
            );
        }
        let rt = crate::runtime::Runtime::from_default_dir()?;
        crate::vis::batched::optimize_batched(&graph, &mut layout, &cfg.vis, &rt)?
    } else if cfg.layout_mode == LayoutMode::Multilevel {
        let ml = crate::vis::multilevel::optimize_multilevel(
            &graph,
            &mut layout,
            &cfg.vis,
            &cfg.multilevel,
            |depth, _level_graph, level_layout| {
                if cfg.save_checkpoints {
                    let p = ckpt.dir.join(format!("layout_L{depth}.lvec"));
                    crate::data::formats::binary::write_binary(&p, level_layout)
                        .with_context(|| format!("write {}", p.display()))?;
                }
                Ok(())
            },
        )?;
        eprintln!(
            "[pipeline] multilevel layout: {} levels (coarsest n={}), fine samples {}",
            ml.levels.len(),
            ml.levels[0].n,
            ml.fine().samples
        );
        metrics.set("layout.levels", ml.levels.len() as f64);
        metrics.set("layout.coarsest_n", ml.levels[0].n as f64);
        metrics.set("layout.fine_samples", ml.fine().samples as f64);
        ml.total()
    } else {
        crate::vis::sgd::optimize(&graph, &mut layout, &cfg.vis)
    };
    metrics.set("layout.secs", t.report());
    metrics.set("layout.samples", report.samples as f64);
    metrics.set("layout.samples_per_sec", report.throughput());
    if cfg.save_checkpoints {
        // The final layout joins the checkpoint set regardless of
        // layout mode, so `largevis serve` (and any downstream tool)
        // has one canonical artifact to load.
        crate::data::formats::binary::write_binary(&ckpt.layout, &layout)
            .with_context(|| format!("write {}", ckpt.layout.display()))?;
    }

    // Stage 5: evaluation (labels permitting).
    if let Some(labels) = &labels {
        let t = Timer::start("eval");
        let acc = knn_accuracy(&layout, labels, &KnnEvalConfig::default());
        metrics.set("eval.secs", t.report());
        metrics.set("eval.knn_accuracy", acc);
        eprintln!("[pipeline] 2D KNN-classifier accuracy = {acc:.4}");
    }

    // Stage 6: outputs.
    write_layout_tsv(&cfg.out_dir.join("layout.tsv"), &layout, labels.as_deref())?;
    render_scatter(
        &cfg.out_dir.join("layout.svg"),
        &layout,
        labels.as_deref(),
        n_classes,
        &ScatterStyle { title, ..Default::default() },
    )?;
    std::fs::write(cfg.out_dir.join("report.json"), metrics.to_json())?;
    eprintln!("[pipeline] outputs in {}", cfg.out_dir.display());

    Ok(PipelineOutput { layout, labels, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(name: &str) -> PathBuf {
        let root = format!("largevis_pipeline_test_{}", std::process::id());
        std::env::temp_dir().join(root).join(name)
    }

    #[test]
    fn tiny_pipeline_end_to_end() {
        let mut cfg = PipelineConfig {
            dataset: "20ng-like".into(),
            scale: 0.02, // ~380 points
            k: 10,
            out_dir: test_dir("e2e"),
            ..Default::default()
        };
        cfg.vis.samples_per_vertex = 400;
        cfg.knn.forest.n_trees = 2;
        let out = run_pipeline(&cfg).unwrap();
        assert_eq!(out.layout.d(), 2);
        assert!(out.metrics.get("eval.knn_accuracy").unwrap() > 0.3);
        assert!(cfg.out_dir.join("layout.svg").exists());
        assert!(cfg.out_dir.join("report.json").exists());
        let report = std::fs::read_to_string(cfg.out_dir.join("report.json")).unwrap();
        crate::util::json::Json::parse(&report).unwrap();
        // Checkpoints written by default — the full serve set.
        let ckpt = CheckpointPaths::new(&cfg.out_dir);
        assert!(ckpt.knn.exists());
        assert!(ckpt.graph.exists());
        assert!(ckpt.labels.exists());
        assert!(ckpt.data.exists());
        assert!(ckpt.layout.exists());
        // The layout checkpoint is the final layout, bit for bit.
        let saved = crate::data::formats::binary::read_binary(&ckpt.layout).unwrap();
        assert_eq!(saved, out.layout);
        let data = crate::data::formats::binary::read_binary(&ckpt.data).unwrap();
        assert_eq!(data.n(), out.layout.n());
    }

    #[test]
    fn multilevel_mode_checkpoints_every_level() {
        let mut cfg = PipelineConfig {
            dataset: "20ng-like".into(),
            scale: 0.02, // ~380 points
            k: 8,
            out_dir: test_dir("mlvl"),
            ..Default::default()
        };
        cfg.vis.samples_per_vertex = 200;
        cfg.knn.forest.n_trees = 1;
        cfg.multilevel.coarsen.min_coarse_size = 64; // force real levels
        let out = run_pipeline(&cfg).unwrap();
        let levels = out.metrics.get("layout.levels").unwrap() as usize;
        assert!(levels > 1, "no coarse levels built: {levels}");
        assert!(out.metrics.get("layout.fine_samples").unwrap() > 0.0);
        let ckpt = CheckpointPaths::new(&cfg.out_dir);
        for depth in 0..levels {
            let p = ckpt.dir.join(format!("layout_L{depth}.lvec"));
            assert!(p.exists(), "missing per-level layout checkpoint {}", p.display());
        }
        // The depth-0 checkpoint is the final layout itself.
        let finest =
            crate::data::formats::binary::read_binary(&ckpt.dir.join("layout_L0.lvec")).unwrap();
        assert_eq!(finest, out.layout);
        // A stale deeper level from a previous run is cleaned up.
        let stale = ckpt.dir.join("layout_L9.lvec");
        std::fs::write(&stale, b"stale").unwrap();
        run_pipeline(&cfg).unwrap();
        assert!(!stale.exists(), "stale per-level checkpoint survived a re-run");
        assert!(ckpt.dir.join("layout_L0.lvec").exists());
    }

    #[test]
    fn flat_mode_still_available() {
        let mut cfg = PipelineConfig {
            dataset: "20ng-like".into(),
            scale: 0.02,
            k: 5,
            out_dir: test_dir("flatmode"),
            layout_mode: crate::config::LayoutMode::Flat,
            ..Default::default()
        };
        cfg.vis.samples_per_vertex = 100;
        cfg.knn.forest.n_trees = 1;
        let out = run_pipeline(&cfg).unwrap();
        assert!(out.metrics.get("layout.levels").is_none());
        assert!(out.layout.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn checkpoints_can_be_disabled() {
        let mut cfg = PipelineConfig {
            dataset: "20ng-like".into(),
            scale: 0.02,
            k: 5,
            out_dir: test_dir("nockpt"),
            save_checkpoints: false,
            ..Default::default()
        };
        cfg.vis.samples_per_vertex = 100;
        cfg.knn.forest.n_trees = 1;
        run_pipeline(&cfg).unwrap();
        assert!(!CheckpointPaths::new(&cfg.out_dir).dir.exists());
    }

    #[test]
    fn resume_from_early_stages_rejected() {
        for stage in [crate::config::Stage::Dataset, crate::config::Stage::Knn] {
            let cfg = PipelineConfig {
                out_dir: test_dir("early_resume"),
                resume_from: Some(stage),
                ..Default::default()
            };
            let err = run_pipeline(&cfg).unwrap_err().to_string();
            assert!(err.contains("--resume-from supports"), "{err}");
        }
    }

    #[test]
    fn resume_without_checkpoint_fails_with_context() {
        let cfg = PipelineConfig {
            out_dir: test_dir("missing_ckpt"),
            resume_from: Some(crate::config::Stage::Weights),
            ..Default::default()
        };
        let err = format!("{:#}", run_pipeline(&cfg).unwrap_err());
        assert!(err.contains("resume-from weights"), "{err}");
    }

    #[test]
    fn ingests_binary_input_file() {
        let dir = test_dir("ingest");
        std::fs::create_dir_all(&dir).unwrap();
        let (m, labels) = crate::data::synth::gaussian_mixture(150, 10, 3, 0.3, 9);
        let input = dir.join("points.lvec");
        crate::data::formats::binary::write_binary(&input, &m).unwrap();
        let label_path = dir.join("points.lbl");
        write_labels(&label_path, &labels).unwrap();
        let mut cfg = PipelineConfig {
            k: 5,
            out_dir: dir.join("out"),
            input: Some(input),
            input_labels: Some(label_path),
            ..Default::default()
        };
        cfg.vis.samples_per_vertex = 100;
        cfg.knn.forest.n_trees = 1;
        let out = run_pipeline(&cfg).unwrap();
        assert_eq!(out.layout.n(), 150);
        assert_eq!(out.labels.as_deref().unwrap(), &labels[..]);
        assert!(out.metrics.get("eval.knn_accuracy").is_some());
    }
}
