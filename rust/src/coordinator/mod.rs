//! Pipeline coordinator: stage orchestration, metrics, run reports.
//!
//! The L3 request path — `dataset → [LINE embed] → KNN graph →
//! perplexity weights → layout (Hogwild or XLA) → eval → render` — with
//! per-stage wall-clock accounting and a machine-readable report.

pub mod metrics;
pub mod pipeline;

pub use metrics::Metrics;
pub use pipeline::{run_pipeline, CheckpointPaths, PipelineOutput};
