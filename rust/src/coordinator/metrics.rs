//! A small append-only metrics registry for pipeline runs: named f64
//! gauges with insertion order preserved, dumpable as JSON.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Named metrics collected during a run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    entries: Vec<(String, f64)>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record (or overwrite) a metric.
    pub fn set(&mut self, name: &str, value: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 = value;
        } else {
            self.entries.push((name.to_string(), value));
        }
    }

    /// Add `delta` to a counter-style metric, creating it at `delta` if
    /// absent — the increment twin of [`Metrics::set`], used by the
    /// query server's per-endpoint request/error counters.
    pub fn add(&mut self, name: &str, delta: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += delta;
        } else {
            self.entries.push((name.to_string(), delta));
        }
    }

    /// Fetch a metric.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.entries.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// All entries in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(n, v)| (n.as_str(), *v))
    }

    /// Serialize to a JSON object string.
    pub fn to_json(&self) -> String {
        let mut map = BTreeMap::new();
        for (n, v) in &self.entries {
            map.insert(n.clone(), Json::Num(*v));
        }
        Json::Obj(map).to_string_compact()
    }

    /// Pretty print to stderr.
    pub fn report(&self, label: &str) {
        eprintln!("[metrics] {label}:");
        for (n, v) in self.iter() {
            eprintln!("    {n:<36} {v:.6}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_overwrite() {
        let mut m = Metrics::new();
        m.set("a", 1.0);
        m.set("b", 2.0);
        m.set("a", 3.0);
        assert_eq!(m.get("a"), Some(3.0));
        assert_eq!(m.iter().count(), 2);
    }

    #[test]
    fn add_accumulates() {
        let mut m = Metrics::new();
        m.add("hits", 1.0);
        m.add("hits", 2.5);
        assert_eq!(m.get("hits"), Some(3.5));
        m.set("hits", 0.0);
        m.add("hits", 4.0);
        assert_eq!(m.get("hits"), Some(4.0));
    }

    #[test]
    fn json_roundtrip() {
        let mut m = Metrics::new();
        m.set("x", 1.5);
        let j = crate::util::json::Json::parse(&m.to_json()).unwrap();
        assert_eq!(j.get("x"), Some(&crate::util::json::Json::Num(1.5)));
    }
}
