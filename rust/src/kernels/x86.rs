//! x86-64 SIMD kernels: AVX2+FMA (8-wide, two accumulators) and SSE2
//! (4-wide; guaranteed by the x86-64 baseline ISA).
//!
//! Safety model: every public function here is a safe `fn` whose body
//! enters a `#[target_feature]` implementation. The dispatcher
//! ([`super::available`] / [`super::active`]) only hands out these
//! [`super::KernelSet`]s after `is_x86_feature_detected!` confirms the
//! features, so the `unsafe` entry is sound. Do not call the AVX2 set
//! directly on unverified hardware — go through `kernels::active()` or
//! `kernels::available()`.

use super::KernelSet;
use std::arch::x86_64::*;

/// AVX2 + FMA kernel set (8-wide).
pub static AVX2: KernelSet = KernelSet {
    name: "avx2",
    sqdist: sqdist_avx2,
    sqdist_bounded: sqdist_bounded_avx2,
    dot: dot_avx2,
    sqdist_x4: sqdist_x4_avx2,
};

/// SSE2 kernel set (4-wide, always present on x86-64).
pub static SSE2: KernelSet = KernelSet {
    name: "sse2",
    sqdist: sqdist_sse2,
    sqdist_bounded: sqdist_bounded_sse2,
    dot: dot_sse2,
    sqdist_x4: sqdist_x4_sse2,
};

// ---------------------------------------------------------------- AVX2

fn sqdist_avx2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: only dispatched after avx2+fma detection (module docs).
    unsafe { sqdist_avx2_impl(a, b) }
}

fn sqdist_bounded_avx2(a: &[f32], b: &[f32], bound: f32) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: only dispatched after avx2+fma detection (module docs).
    unsafe { sqdist_bounded_avx2_impl(a, b, bound) }
}

fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: only dispatched after avx2+fma detection (module docs).
    unsafe { dot_avx2_impl(a, b) }
}

fn sqdist_x4_avx2(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    assert!(q.len() == d && rows.len() >= 4 * d);
    // SAFETY: only dispatched after avx2+fma detection (module docs).
    unsafe { sqdist_x4_avx2_impl(q, rows, d) }
}

// `__m256` by-value needs the avx ABI; annotating keeps the call sites
// (all avx2+fma) inlining-compatible and silences the vector-ABI lint.
#[inline]
#[target_feature(enable = "avx")]
unsafe fn hsum256(v: __m256) -> f32 {
    let mut lanes = [0f32; 8];
    // SAFETY: `lanes` is a properly aligned 8-float buffer and the
    // caller (a target_feature fn) established avx availability.
    unsafe {
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
    }
    ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
        + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sqdist_avx2_impl(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: the safe wrappers assert `a.len() == b.len()` before
    // entering; every vector load reads `i..i+8` only after the
    // `i + lanes <= n` guard, the scalar tail uses `i < n`, and the
    // target features were verified by the dispatcher.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc0 = _mm256_fmadd_ps(d0, d0, acc0);
            let d1 =
                _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
            acc1 = _mm256_fmadd_ps(d1, d1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc0 = _mm256_fmadd_ps(d, d, acc0);
            i += 8;
        }
        let mut s = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sqdist_bounded_avx2_impl(a: &[f32], b: &[f32], bound: f32) -> f32 {
    // SAFETY: same bounds discipline as `sqdist_avx2_impl` — equal
    // lengths asserted by the wrapper, every load guarded by
    // `i + lanes <= n`, features verified by the dispatcher.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut s = 0f32;
        let mut i = 0usize;
        // Same 32-lane early-exit blocking as the scalar reference.
        while i + 32 <= n {
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            let mut acc = _mm256_mul_ps(d0, d0);
            let d1 =
                _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
            acc = _mm256_fmadd_ps(d1, d1, acc);
            let d2 = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i + 16)),
                _mm256_loadu_ps(pb.add(i + 16)),
            );
            acc = _mm256_fmadd_ps(d2, d2, acc);
            let d3 = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i + 24)),
                _mm256_loadu_ps(pb.add(i + 24)),
            );
            acc = _mm256_fmadd_ps(d3, d3, acc);
            s += hsum256(acc);
            i += 32;
            if s > bound {
                return s;
            }
        }
        while i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            s += hsum256(_mm256_mul_ps(d, d));
            i += 8;
        }
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2_impl(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; loads guarded by
    // `i + lanes <= n`; features verified by the dispatcher.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += *a.get_unchecked(i) * *b.get_unchecked(i);
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sqdist_x4_avx2_impl(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    // SAFETY: the wrapper asserts `q.len() == d` and
    // `rows.len() >= 4 * d`, so `r * d + i + 8 <= 4 * d` holds for
    // every vector load (r < 4, i + 8 <= d); the scalar tail is
    // likewise bounded; features verified by the dispatcher.
    unsafe {
        let pq = q.as_ptr();
        let pr = rows.as_ptr();
        let mut acc = [_mm256_setzero_ps(); 4];
        let mut i = 0usize;
        while i + 8 <= d {
            // One query load amortized across the 4 candidate rows.
            let vq = _mm256_loadu_ps(pq.add(i));
            for (r, a) in acc.iter_mut().enumerate() {
                let diff = _mm256_sub_ps(vq, _mm256_loadu_ps(pr.add(r * d + i)));
                *a = _mm256_fmadd_ps(diff, diff, *a);
            }
            i += 8;
        }
        let mut out = [hsum256(acc[0]), hsum256(acc[1]), hsum256(acc[2]), hsum256(acc[3])];
        while i < d {
            let qv = *q.get_unchecked(i);
            for (r, o) in out.iter_mut().enumerate() {
                let dv = qv - *rows.get_unchecked(r * d + i);
                *o += dv * dv;
            }
            i += 1;
        }
        out
    }
}

// ---------------------------------------------------------------- SSE2

fn sqdist_sse2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: SSE2 is part of the x86-64 baseline ISA.
    unsafe { sqdist_sse2_impl(a, b) }
}

fn sqdist_bounded_sse2(a: &[f32], b: &[f32], bound: f32) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: SSE2 is part of the x86-64 baseline ISA.
    unsafe { sqdist_bounded_sse2_impl(a, b, bound) }
}

fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: SSE2 is part of the x86-64 baseline ISA.
    unsafe { dot_sse2_impl(a, b) }
}

fn sqdist_x4_sse2(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    assert!(q.len() == d && rows.len() >= 4 * d);
    // SAFETY: SSE2 is part of the x86-64 baseline ISA.
    unsafe { sqdist_x4_sse2_impl(q, rows, d) }
}

#[inline]
unsafe fn hsum128(v: __m128) -> f32 {
    let mut lanes = [0f32; 4];
    // SAFETY: `lanes` is a valid 4-float buffer; SSE2 is baseline.
    unsafe {
        _mm_storeu_ps(lanes.as_mut_ptr(), v);
    }
    (lanes[0] + lanes[2]) + (lanes[1] + lanes[3])
}

#[target_feature(enable = "sse2")]
unsafe fn sqdist_sse2_impl(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; every load is
    // guarded by `i + lanes <= n`; SSE2 is baseline on x86-64.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm_setzero_ps();
        let mut acc1 = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let d0 = _mm_sub_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i)));
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(d0, d0));
            let d1 = _mm_sub_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4)));
            acc1 = _mm_add_ps(acc1, _mm_mul_ps(d1, d1));
            i += 8;
        }
        if i + 4 <= n {
            let d = _mm_sub_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i)));
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(d, d));
            i += 4;
        }
        let mut s = hsum128(_mm_add_ps(acc0, acc1));
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "sse2")]
unsafe fn sqdist_bounded_sse2_impl(a: &[f32], b: &[f32], bound: f32) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; loads guarded by
    // `i + lanes <= n`; SSE2 is baseline on x86-64.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut s = 0f32;
        let mut i = 0usize;
        // Same 32-lane early-exit blocking as the scalar reference.
        while i + 32 <= n {
            let mut acc = _mm_setzero_ps();
            for c in 0..8 {
                let d =
                    _mm_sub_ps(_mm_loadu_ps(pa.add(i + c * 4)), _mm_loadu_ps(pb.add(i + c * 4)));
                acc = _mm_add_ps(acc, _mm_mul_ps(d, d));
            }
            s += hsum128(acc);
            i += 32;
            if s > bound {
                return s;
            }
        }
        while i + 4 <= n {
            let d = _mm_sub_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i)));
            s += hsum128(_mm_mul_ps(d, d));
            i += 4;
        }
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "sse2")]
unsafe fn dot_sse2_impl(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; loads guarded by
    // `i + lanes <= n`; SSE2 is baseline on x86-64.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = _mm_setzero_ps();
        let mut acc1 = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
            acc1 = _mm_add_ps(
                acc1,
                _mm_mul_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4))),
            );
            i += 8;
        }
        if i + 4 <= n {
            acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
            i += 4;
        }
        let mut s = hsum128(_mm_add_ps(acc0, acc1));
        while i < n {
            s += *a.get_unchecked(i) * *b.get_unchecked(i);
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "sse2")]
unsafe fn sqdist_x4_sse2_impl(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    // SAFETY: the wrapper asserts `q.len() == d` and
    // `rows.len() >= 4 * d`, so `r * d + i + 4 <= 4 * d` holds for
    // every vector load (r < 4, i + 4 <= d); the scalar tail is
    // likewise bounded; SSE2 is baseline on x86-64.
    unsafe {
        let pq = q.as_ptr();
        let pr = rows.as_ptr();
        let mut acc = [_mm_setzero_ps(); 4];
        let mut i = 0usize;
        while i + 4 <= d {
            let vq = _mm_loadu_ps(pq.add(i));
            for (r, a) in acc.iter_mut().enumerate() {
                let diff = _mm_sub_ps(vq, _mm_loadu_ps(pr.add(r * d + i)));
                *a = _mm_add_ps(*a, _mm_mul_ps(diff, diff));
            }
            i += 4;
        }
        let mut out = [hsum128(acc[0]), hsum128(acc[1]), hsum128(acc[2]), hsum128(acc[3])];
        while i < d {
            let qv = *q.get_unchecked(i);
            for (r, o) in out.iter_mut().enumerate() {
                let dv = qv - *rows.get_unchecked(r * d + i);
                *o += dv * dv;
            }
            i += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use super::*;

    fn vecs(d: usize) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin() * 2.0).collect();
        let b: Vec<f32> = (0..d).map(|i| (i as f32 * 0.53).cos() * 2.0).collect();
        (a, b)
    }

    #[test]
    fn sse2_matches_scalar_spot_check() {
        if !std::arch::is_x86_feature_detected!("sse2") {
            return;
        }
        for d in [1usize, 3, 4, 7, 8, 31, 33, 100] {
            let (a, b) = vecs(d);
            let want = scalar::sqdist(&a, &b);
            let got = (SSE2.sqdist)(&a, &b);
            assert!((got - want).abs() < 1e-4 * (1.0 + want), "d={d}: {got} vs {want}");
        }
    }

    #[test]
    fn avx2_matches_scalar_spot_check() {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            return;
        }
        for d in [1usize, 7, 8, 15, 16, 17, 31, 33, 200] {
            let (a, b) = vecs(d);
            let want = scalar::sqdist(&a, &b);
            let got = (AVX2.sqdist)(&a, &b);
            assert!((got - want).abs() < 1e-4 * (1.0 + want), "d={d}: {got} vs {want}");
        }
    }
}
