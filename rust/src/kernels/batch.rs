//! One-query-vs-many-candidates batched distance kernel.
//!
//! The KNN hot loops (RP-tree leaf scans, neighbor exploring, LSH
//! buckets, brute force, k-means assignment) all evaluate one query
//! against a *set* of candidate rows scattered through the data matrix.
//! Evaluating them one `sqdist` call at a time pays a scattered load
//! per candidate and re-reads the query from cache every call.
//!
//! [`sqdist_batch`] instead gathers the candidate rows into a
//! thread-local contiguous scratch block (bounded to [`BLOCK_ROWS`]
//! rows, so the block stays cache-resident at any dimensionality) and
//! computes the whole set with the dispatched [`KernelSet::sqdist_x4`]
//! kernel — four candidates per pass sharing each 8-wide query load.
//! The scratch block is reused across calls on the same thread, so
//! steady-state batched evaluation performs **no heap allocation**
//! (callers pass their own reusable `out` buffer).
//!
//! Distances are always computed in full (no per-candidate early exit —
//! the batch amortization replaces it); callers filter against their
//! heap threshold afterwards. Candidates strictly over the threshold
//! are rejected either way, but because SIMD lanes re-associate the
//! sums, a candidate within float tolerance (~1e-4 relative) of the
//! threshold can be decided differently than under the scalar bounded
//! path — the same cross-variant tolerance documented in
//! [`super`]'s module docs and enforced by the parity tests. Workloads
//! where the early exit matters more than the amortization (the
//! brute-force ground-truth scan) use [`super::sqdist_bounded`]
//! instead.
//!
//! [`KernelSet::sqdist_x4`]: super::KernelSet::sqdist_x4

use super::KernelSet;
use crate::data::matrix::RowStore;
use crate::util::heap::BoundedMaxHeap;
use std::cell::RefCell;

/// Candidate rows gathered per scratch block. 64 rows keeps the block
/// ≤ 196 KiB even at MNIST's d=784 (L2-resident on every target CPU).
pub const BLOCK_ROWS: usize = 64;

thread_local! {
    static GATHER: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Squared distance from `query` to `data[id]` for every `id` in `ids`,
/// written into `out` (cleared first; `out[r]` pairs with `ids[r]`).
///
/// `query.len()` must equal `data.d()`; every id must be `< data.n()`.
pub fn sqdist_batch(query: &[f32], data: &impl RowStore, ids: &[u32], out: &mut Vec<f32>) {
    let d = data.d();
    debug_assert_eq!(query.len(), d);
    out.clear();
    if ids.is_empty() {
        return;
    }
    out.reserve(ids.len());
    if d == 0 {
        out.resize(ids.len(), 0.0);
        return;
    }
    let ks = super::active();
    GATHER.with(|g| {
        let mut block = g.borrow_mut();
        for chunk in ids.chunks(BLOCK_ROWS) {
            block.clear();
            block.reserve(chunk.len() * d);
            for &id in chunk {
                block.extend_from_slice(data.row(id as usize));
            }
            compute_block(ks, query, &block, d, chunk.len(), out);
        }
    });
}

/// Squared distance from `query` to *every* row of `data`, written into
/// `out` (cleared first). Rows are contiguous within each
/// [`RowStore::row_block`], so this skips the gather and runs the
/// blocked kernel over the store's own buffers — one block for the flat
/// [`Matrix`](crate::data::matrix::Matrix) (the k-means assignment
/// inner loop), one per chunk for the serving path's
/// [`ChunkedMatrix`](crate::data::chunked::ChunkedMatrix).
pub fn sqdist_to_all(query: &[f32], data: &impl RowStore, out: &mut Vec<f32>) {
    let d = data.d();
    debug_assert_eq!(query.len(), d);
    out.clear();
    if data.n() == 0 {
        return;
    }
    out.reserve(data.n());
    if d == 0 {
        out.resize(data.n(), 0.0);
        return;
    }
    let ks = super::active();
    let mut i = 0;
    while i < data.n() {
        let (block, rows) = data.row_block(i);
        compute_block(ks, query, block, d, rows, out);
        i += rows;
    }
}

/// The `k` (floored at 1) nearest rows of `data` to `query`, as
/// `(id, sqdist)` pairs sorted ascending by distance — one
/// [`sqdist_to_all`] batch scan filtered through a bounded max-heap.
///
/// This is the single home of the exact one-query scan shared by the
/// query server's `/knn` endpoint, out-of-sample projection, and
/// incremental insertion — a fix to threshold or tie handling lands in
/// all of them at once. `dists` and `heap` are caller-owned scratch so
/// per-query loops stay allocation-free (the heap is reset to capacity
/// `k` on entry; ties at equal distance resolve to the lower id).
pub fn nearest_k(
    query: &[f32],
    data: &impl RowStore,
    k: usize,
    dists: &mut Vec<f32>,
    heap: &mut BoundedMaxHeap,
) -> Vec<(u32, f32)> {
    heap.reset(k.max(1));
    sqdist_to_all(query, data, dists);
    for (j, &d) in dists.iter().enumerate() {
        // `<=` so an equal-distance candidate reaches the heap, whose
        // (dist, id) order then decides lowest-index-wins; with `<` a
        // tie arriving after the heap fills would be dropped here and
        // the result would depend on arrival order.
        if d <= heap.threshold() {
            heap.push(j as u32, d, false);
        }
    }
    heap.drain_sorted_pairs()
}

/// Distances of `query` against `rows` contiguous `d`-length vectors in
/// `block`, appended to `out`: 4 rows per pass, remainder one-by-one.
fn compute_block(
    ks: &KernelSet,
    query: &[f32],
    block: &[f32],
    d: usize,
    rows: usize,
    out: &mut Vec<f32>,
) {
    debug_assert!(block.len() >= rows * d);
    let mut r = 0usize;
    while r + 4 <= rows {
        let four = (ks.sqdist_x4)(query, &block[r * d..], d);
        out.extend_from_slice(&four);
        r += 4;
    }
    while r < rows {
        out.push((ks.sqdist)(query, &block[r * d..(r + 1) * d]));
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::util::rng::Rng;

    fn random_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec((0..n * d).map(|_| rng.gaussian()).collect(), n, d)
    }

    #[test]
    fn batch_matches_scalar_per_pair() {
        let mut rng = Rng::new(7);
        for &d in &[1usize, 3, 7, 8, 31, 33, 100] {
            let m = random_matrix(120, d, 0xb0 + d as u64);
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
            for &cnt in &[0usize, 1, 3, 4, 5, 63, 64, 65, 120] {
                let ids: Vec<u32> = (0..cnt).map(|_| rng.below(120) as u32).collect();
                let mut out = Vec::new();
                sqdist_batch(&q, &m, &ids, &mut out);
                assert_eq!(out.len(), ids.len());
                for (&id, &got) in ids.iter().zip(&out) {
                    let want = scalar::sqdist(&q, m.row(id as usize));
                    assert!(
                        (got - want).abs() < 1e-4 * (1.0 + want),
                        "d={d} cnt={cnt} id={id}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn to_all_matches_batch_over_all_ids() {
        let d = 17;
        let m = random_matrix(37, d, 3);
        let mut rng = Rng::new(4);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
        let ids: Vec<u32> = (0..37).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        sqdist_batch(&q, &m, &ids, &mut a);
        sqdist_to_all(&q, &m, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn nearest_k_matches_sort_reference() {
        // Small-integer data: every squared distance is exactly
        // representable whatever order the SIMD lanes accumulate in, so
        // ranks are deterministic across kernel variants.
        let d = 13;
        let m = Matrix::from_vec(
            (0..90 * d).map(|x| ((x * 31 + 7) % 17) as f32 - 8.0).collect(),
            90,
            d,
        );
        let q: Vec<f32> = (0..d).map(|x| ((x * 5 + 3) % 11) as f32 - 5.0).collect();
        let mut dists = Vec::new();
        let mut heap = BoundedMaxHeap::new(1);
        for &k in &[1usize, 5, 89, 90, 200] {
            let got = nearest_k(&q, &m, k, &mut dists, &mut heap);
            let mut want: Vec<(u32, f32)> =
                (0..90u32).map(|j| (j, scalar::sqdist(&q, m.row(j as usize)))).collect();
            want.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            want.truncate(k.min(90));
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn nearest_k_duplicate_points_pick_lowest_ids() {
        // Regression for unpinned tie-breaking: exact duplicate rows
        // produce exactly equal distances, and the winner used to
        // depend on heap sift history (which of the tied entries sat at
        // the root when a closer candidate evicted). Rows 0..3 are the
        // same point, row 3 is closer to the query: k=2 must return
        // {3, 0} — never {3, 1} or {3, 2}.
        let d = 4;
        let dup = [1.0f32, 2.0, 3.0, 4.0];
        let near = [0.0f32, 0.0, 0.0, 0.0];
        let mut rows = Vec::new();
        for _ in 0..3 {
            rows.extend_from_slice(&dup);
        }
        rows.extend_from_slice(&near);
        let m = Matrix::from_vec(rows, 4, d);
        let q = vec![0.0f32; d];
        let mut dists = Vec::new();
        let mut heap = BoundedMaxHeap::new(1);
        let got = nearest_k(&q, &m, 2, &mut dists, &mut heap);
        let ids: Vec<u32> = got.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![3, 0]);
        // All-duplicates case: k of them, lowest indices, in id order.
        let got = nearest_k(&dup, &m, 3, &mut dists, &mut heap);
        let ids: Vec<u32> = got.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(got[0].1, 0.0);
    }

    #[test]
    fn out_buffer_is_cleared_and_reused() {
        let m = random_matrix(10, 5, 9);
        let q = vec![0.5f32; 5];
        let mut out = vec![99.0; 50];
        sqdist_batch(&q, &m, &[1, 2], &mut out);
        assert_eq!(out.len(), 2);
        sqdist_batch(&q, &m, &[], &mut out);
        assert!(out.is_empty());
    }
}
