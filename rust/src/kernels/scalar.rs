//! Portable scalar reference kernels: 4-lane unrolled loops the
//! compiler auto-vectorizes. Always available on every target, and the
//! correctness baseline every SIMD variant is property-tested against
//! (`rust/tests/kernel_parity.rs`).

/// Squared Euclidean distance between two equal-length vectors.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Squared distance with early exit: returns a value `> bound` as soon
/// as the partial sum exceeds `bound` (checked every 32 lanes).
#[inline]
pub fn sqdist_bounded(a: &[f32], b: &[f32], bound: f32) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut s = 0f32;
    let mut i = 0;
    while i + 32 <= n {
        let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
        for c in 0..8 {
            let base = i + c * 4;
            let d0 = a[base] - b[base];
            let d1 = a[base + 1] - b[base + 1];
            let d2 = a[base + 2] - b[base + 2];
            let d3 = a[base + 3] - b[base + 3];
            s0 += d0 * d0;
            s1 += d1 * d1;
            s2 += d2 * d2;
            s3 += d3 * d3;
        }
        s += s0 + s1 + s2 + s3;
        i += 32;
        if s > bound {
            return s;
        }
    }
    for k in i..n {
        let d = a[k] - b[k];
        s += d * d;
    }
    s
}

/// Dot product (same unrolling as [`sqdist`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// One query against 4 contiguous `d`-length rows (`rows.len() >= 4*d`).
#[inline]
pub fn sqdist_x4(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    debug_assert!(q.len() == d && rows.len() >= 4 * d);
    [
        sqdist(q, &rows[..d]),
        sqdist(q, &rows[d..2 * d]),
        sqdist(q, &rows[2 * d..3 * d]),
        sqdist(q, &rows[3 * d..4 * d]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_sqdist(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn sqdist_matches_naive_all_small_dims() {
        for d in 0..70usize {
            let a: Vec<f32> = (0..d).map(|i| (i as f32 * 0.31).sin()).collect();
            let b: Vec<f32> = (0..d).map(|i| (i as f32 * 0.17).cos()).collect();
            let naive = naive_sqdist(&a, &b);
            assert!((sqdist(&a, &b) - naive).abs() < 1e-4 * (1.0 + naive), "d={d}");
            assert!(
                (sqdist_bounded(&a, &b, f32::INFINITY) - naive).abs() < 1e-4 * (1.0 + naive),
                "d={d}"
            );
        }
    }

    #[test]
    fn bounded_early_exit_exceeds_bound() {
        let a = vec![0f32; 100];
        let b = vec![1f32; 100];
        // True distance 100; a tiny bound must make it exit early with
        // a partial sum that still exceeds the bound.
        let got = sqdist_bounded(&a, &b, 0.5);
        assert!(got > 0.5 && got <= 100.0);
    }

    #[test]
    fn x4_matches_individual_rows() {
        let d = 13;
        let q: Vec<f32> = (0..d).map(|i| i as f32 * 0.2).collect();
        let rows: Vec<f32> = (0..4 * d).map(|i| (i as f32 * 0.11).sin()).collect();
        let got = sqdist_x4(&q, &rows, d);
        for r in 0..4 {
            let want = sqdist(&q, &rows[r * d..(r + 1) * d]);
            assert_eq!(got[r], want, "row {r}");
        }
    }
}
