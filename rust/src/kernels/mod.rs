//! Runtime-dispatched SIMD distance kernels — the single home of every
//! pairwise-distance computation in the system.
//!
//! The paper's profile (Figs 2–3, Table 1) shows KNN-graph construction
//! dominating LargeVis runtime at scale, and inside KNN construction
//! nearly all cycles go to squared-Euclidean evaluations. This module
//! turns that hot scalar into a dispatched kernel family:
//!
//! * **scalar** — the portable 4-lane unrolled reference ([`scalar`]),
//!   always available, the parity baseline for every other variant.
//! * **sse2** / **avx2** — `x86_64` via `std::arch` (the `x86` module,
//!   compiled on x86-64 only — a cfg-gated module cannot be doc-linked
//!   portably). AVX2 uses 8-wide FMA; SSE2 is the 4-wide baseline
//!   guaranteed by the x86-64 ISA.
//! * **neon** — `aarch64` 4-wide FMA (the `neon` module, compiled on
//!   aarch64 only; NEON is mandatory there so no runtime check is
//!   needed).
//!
//! Each variant provides `sqdist`, `sqdist_bounded` (with the same
//! 32-lane early-exit blocking as the scalar path), `dot`, and
//! `sqdist_x4` — one query against four contiguous candidate rows,
//! which amortizes the query loads and feeds the batched gather kernel
//! in [`batch`].
//!
//! # Dispatch policy
//!
//! The active variant is chosen once per process, at first use:
//!
//! 1. If `LARGEVIS_KERNEL` is set to `scalar`, `sse2`, `avx2` or
//!    `neon`, that variant is used when available on this CPU (an
//!    unavailable request logs a warning and falls back to auto).
//!    `LARGEVIS_KERNEL=scalar` is the supported way to force the
//!    portable path for debugging or A/B timing.
//! 2. Otherwise the best detected variant wins: on `x86_64`,
//!    AVX2+FMA ≻ SSE2 (checked with `is_x86_feature_detected!`); on
//!    `aarch64`, NEON; anywhere else, scalar. Non-x86/ARM targets
//!    therefore build and run unchanged.
//!
//! All variants produce results within 1e-4 relative tolerance of the
//! scalar reference (enforced by `rust/tests/kernel_parity.rs`); exact
//! bit-equality is *not* guaranteed because SIMD lanes re-associate the
//! floating-point sums.

pub mod batch;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod scalar;
#[cfg(target_arch = "x86_64")]
pub mod x86;

pub use batch::{nearest_k, sqdist_batch, sqdist_to_all};

use std::sync::OnceLock;

/// One dispatchable set of distance kernels.
#[derive(Clone, Copy)]
pub struct KernelSet {
    /// Variant name (`scalar`, `sse2`, `avx2`, `neon`).
    pub name: &'static str,
    /// Squared Euclidean distance of two equal-length vectors.
    pub sqdist: fn(&[f32], &[f32]) -> f32,
    /// Squared distance with early exit once the partial sum exceeds
    /// `bound` (checked every 32 lanes). The return value is exact when
    /// `<= bound`; otherwise it is some partial sum `> bound` (and never
    /// greater than the true distance).
    pub sqdist_bounded: fn(&[f32], &[f32], f32) -> f32,
    /// Dot product of two equal-length vectors.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// One query against 4 contiguous rows: `rows` holds 4 back-to-back
    /// `d`-length vectors (`rows.len() >= 4 * d`). Returns the 4 squared
    /// distances. Amortizes query loads across candidates.
    pub sqdist_x4: fn(&[f32], &[f32], usize) -> [f32; 4],
}

/// The portable scalar reference kernels (always available).
pub static SCALAR: KernelSet = KernelSet {
    name: "scalar",
    sqdist: scalar::sqdist,
    sqdist_bounded: scalar::sqdist_bounded,
    dot: scalar::dot,
    sqdist_x4: scalar::sqdist_x4,
};

/// Every kernel variant usable on this machine, scalar first. Used by
/// the parity tests and the kernel micro-benchmarks.
pub fn available() -> Vec<&'static KernelSet> {
    let mut out: Vec<&'static KernelSet> = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("sse2") {
            out.push(&x86::SSE2);
        }
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            out.push(&x86::AVX2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        out.push(&neon::NEON);
    }
    out
}

// The trailing `&SCALAR` is unreachable on aarch64 (NEON always wins).
#[allow(unreachable_code)]
fn best_available() -> &'static KernelSet {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return &x86::AVX2;
        }
        if std::arch::is_x86_feature_detected!("sse2") {
            return &x86::SSE2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return &neon::NEON;
    }
    &SCALAR
}

fn detect() -> &'static KernelSet {
    if let Ok(requested) = std::env::var("LARGEVIS_KERNEL") {
        if requested != "auto" && !requested.is_empty() {
            if let Some(k) = available().into_iter().find(|k| k.name == requested) {
                return k;
            }
            eprintln!(
                "[kernels] LARGEVIS_KERNEL={requested:?} not available on this CPU; using auto"
            );
        }
    }
    best_available()
}

static ACTIVE: OnceLock<&'static KernelSet> = OnceLock::new();

/// The process-wide active kernel set (detected once, see module docs).
#[inline]
pub fn active() -> &'static KernelSet {
    *ACTIVE.get_or_init(detect)
}

/// Below this length the dispatched wrappers skip the indirect call
/// and inline the scalar reference: for 2–3-d layout rows (objective
/// evaluation, incremental SGD) the OnceLock load + fn-pointer call
/// would cost more than the arithmetic, and one SIMD iteration needs
/// ≥ 8 (AVX2) / 4 (SSE2, NEON) lanes to pay for itself anyway.
const SMALL_DIM: usize = 16;

/// Squared Euclidean distance between two equal-length vectors
/// (dispatched; the single hottest function in KNN construction).
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    if a.len() < SMALL_DIM {
        return scalar::sqdist(a, b);
    }
    (active().sqdist)(a, b)
}

/// Squared distance with early exit: returns a value `> bound` as soon
/// as the partial sum exceeds `bound` (checked every 32 lanes); exact
/// when the result is `<= bound`.
///
/// The KNN inner loops compare candidates against a bounded heap's
/// current worst distance; at d=784 most candidates exceed it within
/// the first blocks, so bailing early is a large win (§Perf).
#[inline]
pub fn sqdist_bounded(a: &[f32], b: &[f32], bound: f32) -> f32 {
    if a.len() < SMALL_DIM {
        return scalar::sqdist_bounded(a, b, bound);
    }
    (active().sqdist_bounded)(a, b, bound)
}

/// Dot product (dispatched).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    if a.len() < SMALL_DIM {
        return scalar::dot(a, b);
    }
    (active().dot)(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_always_listed_first() {
        let ks = available();
        assert!(!ks.is_empty());
        assert_eq!(ks[0].name, "scalar");
        // Names are unique.
        let names: std::collections::HashSet<&str> = ks.iter().map(|k| k.name).collect();
        assert_eq!(names.len(), ks.len());
    }

    #[test]
    fn active_is_available() {
        let act = active();
        assert!(available().iter().any(|k| k.name == act.name));
    }

    #[test]
    fn dispatched_wrappers_match_scalar() {
        let a: Vec<f32> = (0..97).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..97).map(|i| (i as f32 * 0.71).cos()).collect();
        let tol = 1e-4 * (1.0 + scalar::sqdist(&a, &b).abs());
        assert!((sqdist(&a, &b) - scalar::sqdist(&a, &b)).abs() < tol);
        assert!((dot(&a, &b) - scalar::dot(&a, &b)).abs() < tol);
        assert!((sqdist_bounded(&a, &b, f32::INFINITY) - scalar::sqdist(&a, &b)).abs() < tol);
    }
}
