//! aarch64 NEON kernels (4-wide FMA). NEON is mandatory in the aarch64
//! baseline ISA, so no runtime detection is needed — the dispatcher
//! selects this set unconditionally on aarch64.

use super::KernelSet;
use std::arch::aarch64::*;

/// NEON kernel set (always available on aarch64).
pub static NEON: KernelSet = KernelSet {
    name: "neon",
    sqdist: sqdist_neon,
    sqdist_bounded: sqdist_bounded_neon,
    dot: dot_neon,
    sqdist_x4: sqdist_x4_neon,
};

fn sqdist_neon(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: NEON is part of the aarch64 baseline ISA.
    unsafe { sqdist_neon_impl(a, b) }
}

fn sqdist_bounded_neon(a: &[f32], b: &[f32], bound: f32) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: NEON is part of the aarch64 baseline ISA.
    unsafe { sqdist_bounded_neon_impl(a, b, bound) }
}

fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    // SAFETY: NEON is part of the aarch64 baseline ISA.
    unsafe { dot_neon_impl(a, b) }
}

fn sqdist_x4_neon(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    assert!(q.len() == d && rows.len() >= 4 * d);
    // SAFETY: NEON is part of the aarch64 baseline ISA.
    unsafe { sqdist_x4_neon_impl(q, rows, d) }
}

#[target_feature(enable = "neon")]
unsafe fn sqdist_neon_impl(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; every vector load
    // is guarded by `i + lanes <= n` and the scalar tail by `i < n`;
    // NEON is baseline on aarch64.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            let d0 = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            acc0 = vfmaq_f32(acc0, d0, d0);
            let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            acc1 = vfmaq_f32(acc1, d1, d1);
            i += 8;
        }
        if i + 4 <= n {
            let d = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            acc0 = vfmaq_f32(acc0, d, d);
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "neon")]
unsafe fn sqdist_bounded_neon_impl(a: &[f32], b: &[f32], bound: f32) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; loads guarded by
    // `i + lanes <= n`; NEON is baseline on aarch64.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut s = 0f32;
        let mut i = 0usize;
        // Same 32-lane early-exit blocking as the scalar reference.
        while i + 32 <= n {
            let mut acc = vdupq_n_f32(0.0);
            for c in 0..8 {
                let d = vsubq_f32(vld1q_f32(pa.add(i + c * 4)), vld1q_f32(pb.add(i + c * 4)));
                acc = vfmaq_f32(acc, d, d);
            }
            s += vaddvq_f32(acc);
            i += 32;
            if s > bound {
                return s;
            }
        }
        while i + 4 <= n {
            let d = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            s += vaddvq_f32(vmulq_f32(d, d));
            i += 4;
        }
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            s += d * d;
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "neon")]
unsafe fn dot_neon_impl(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: equal lengths asserted by the wrapper; loads guarded by
    // `i + lanes <= n`; NEON is baseline on aarch64.
    unsafe {
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            i += 8;
        }
        if i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += *a.get_unchecked(i) * *b.get_unchecked(i);
            i += 1;
        }
        s
    }
}

#[target_feature(enable = "neon")]
unsafe fn sqdist_x4_neon_impl(q: &[f32], rows: &[f32], d: usize) -> [f32; 4] {
    // SAFETY: the wrapper asserts `q.len() == d` and
    // `rows.len() >= 4 * d`, so `r * d + i + 4 <= 4 * d` holds for
    // every vector load (r < 4, i + 4 <= d); the scalar tail is
    // likewise bounded; NEON is baseline on aarch64.
    unsafe {
        let pq = q.as_ptr();
        let pr = rows.as_ptr();
        let mut acc = [vdupq_n_f32(0.0); 4];
        let mut i = 0usize;
        while i + 4 <= d {
            // One query load amortized across the 4 candidate rows.
            let vq = vld1q_f32(pq.add(i));
            for (r, a) in acc.iter_mut().enumerate() {
                let diff = vsubq_f32(vq, vld1q_f32(pr.add(r * d + i)));
                *a = vfmaq_f32(*a, diff, diff);
            }
            i += 4;
        }
        let mut out =
            [vaddvq_f32(acc[0]), vaddvq_f32(acc[1]), vaddvq_f32(acc[2]), vaddvq_f32(acc[3])];
        while i < d {
            let qv = *q.get_unchecked(i);
            for (r, o) in out.iter_mut().enumerate() {
                let dv = qv - *rows.get_unchecked(r * d + i);
                *o += dv * dv;
            }
            i += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use super::*;

    #[test]
    fn neon_matches_scalar_spot_check() {
        for d in [1usize, 3, 4, 7, 8, 31, 33, 100] {
            let a: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin() * 2.0).collect();
            let b: Vec<f32> = (0..d).map(|i| (i as f32 * 0.53).cos() * 2.0).collect();
            let want = scalar::sqdist(&a, &b);
            let got = (NEON.sqdist)(&a, &b);
            assert!((got - want).abs() < 1e-4 * (1.0 + want), "d={d}: {got} vs {want}");
        }
    }
}
