//! Epoch-swapped snapshot cell: the publication protocol between the
//! single writer and the lock-free readers, extracted from
//! `ServerState` so the model checker can drive it as a closed
//! protocol (see `tools/modelcheck`).
//!
//! The protocol couples a mutex-protected `Arc<T>` cell with a
//! lock-free epoch counter and guarantees one invariant to readers:
//! **a reader that observes epoch `e` via [`EpochCell::hint`] finds a
//! value of epoch `>= e` in the cell.** That is what lets connection
//! workers cache a snapshot and re-fetch only when the hint moves —
//! the steady-state read path touches no mutex. The invariant holds
//! because [`EpochCell::publish`] swaps the cell *before* the
//! `Release` store of the counter (and the `Acquire` hint load pairs
//! with that store); bumping the counter first reintroduces the
//! torn-read window, which is exactly the seeded bug under
//! `--cfg modelcheck_mutant_epoch_first` that CI asserts the checker
//! catches.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};

/// A mutex-protected `Arc<T>` current-value cell plus a lock-free
/// epoch hint, swapped together by a single writer.
///
/// The mutex is held only for `Arc` clones and swaps — never while
/// building a value — so readers are never blocked behind snapshot
/// construction.
pub struct EpochCell<T> {
    /// Epoch of the newest published value, readable without a lock.
    epoch: AtomicU64,
    /// The current value.
    cell: Mutex<Arc<T>>,
}

impl<T> EpochCell<T> {
    /// A cell holding `initial` at epoch 0.
    pub fn new(initial: Arc<T>) -> Self {
        EpochCell { epoch: AtomicU64::new(0), cell: Mutex::new(initial) }
    }

    /// Lock-free epoch hint. A reader holding a cached value compares
    /// its epoch against this and calls [`EpochCell::get`] only on
    /// mismatch.
    pub fn hint(&self) -> u64 {
        // ordering: Acquire — pairs with the Release store in
        // `publish`: a reader that observes epoch `e` here is
        // guaranteed the swap that preceded that store is visible, so
        // the cell holds a value of epoch >= e.
        self.epoch.load(Ordering::Acquire)
    }

    /// The current value (one brief mutex for the `Arc` clone).
    pub fn get(&self) -> Arc<T> {
        self.cell.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Publish `value` as epoch `epoch`: swap the cell, then release
    /// the counter. Epochs must be produced by a single writer (or
    /// under an external writer lock, as `ServerState` does); the cell
    /// itself only guarantees the hint/cell coupling.
    pub fn publish(&self, epoch: u64, value: Arc<T>) {
        #[cfg(not(modelcheck_mutant_epoch_first))]
        {
            *self.cell.lock().unwrap_or_else(|e| e.into_inner()) = value;
            // ordering: Release — pairs with the Acquire in `hint`;
            // the swap above must be visible to any reader that
            // observes this epoch (see the module docs).
            self.epoch.store(epoch, Ordering::Release);
        }
        // Seeded publication-order bug for the mutation corpus: bump
        // the counter before the swap. A reader interleaved between
        // the two observes hint `e` but fetches the previous epoch's
        // value — the torn-read window the real ordering closes. The
        // checker must catch this.
        #[cfg(modelcheck_mutant_epoch_first)]
        {
            // ordering: Release — deliberate mutant, see above.
            self.epoch.store(epoch, Ordering::Release);
            *self.cell.lock().unwrap_or_else(|e| e.into_inner()) = value;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hint_tracks_publishes_and_get_returns_newest() {
        let c = EpochCell::new(Arc::new(0u64));
        assert_eq!(c.hint(), 0);
        assert_eq!(*c.get(), 0);
        c.publish(1, Arc::new(10));
        c.publish(2, Arc::new(20));
        assert_eq!(c.hint(), 2);
        assert_eq!(*c.get(), 20);
    }

    #[test]
    fn held_value_survives_later_publishes() {
        let c = EpochCell::new(Arc::new(vec![1u8, 2, 3]));
        let held = c.get();
        c.publish(1, Arc::new(vec![9, 9, 9]));
        assert_eq!(*held, vec![1, 2, 3]);
        assert_eq!(*c.get(), vec![9, 9, 9]);
    }
}
