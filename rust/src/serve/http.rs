//! Minimal HTTP/1.1 request parsing and response emission.
//!
//! The offline crate registry has no hyper/axum, and the query server
//! needs only a narrow slice of HTTP: persistent (keep-alive)
//! connections with `Content-Length` framing, query strings, and
//! fixed-size responses. This module implements exactly that over any
//! `BufRead`/`Write`, so it is unit-testable without sockets.
//!
//! Keep-alive is the HTTP/1.1 default; answering `Connection: close`
//! on every response (as this server once did) forces a fresh TCP
//! handshake per request and dominates small-query latency under
//! load. [`read_request`] reports the client's own close intent
//! ([`Request::wants_close`]: an explicit `Connection: close`, or
//! HTTP/1.0 without `keep-alive`), and [`Response::write_to`] frames
//! the response for whichever mode the connection loop decides —
//! bounded per-connection request counts and idle timeouts live in
//! the server loop, not here.
//!
//! Limits are enforced during parse (header count, body size) so a
//! malformed or hostile client fails fast instead of ballooning
//! memory.

use anyhow::{bail, Result};
use std::io::{BufRead, Read, Write};

/// Maximum header lines accepted per request.
const MAX_HEADERS: usize = 128;
/// Maximum request-line / header-line length in bytes.
const MAX_LINE_BYTES: usize = 16 * 1024;

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request method (`GET`, `POST`, ...), uppercase as sent.
    pub method: String,
    /// Decoded path component (query string stripped).
    pub path: String,
    /// Decoded `key=value` query parameters in order of appearance.
    pub query: Vec<(String, String)>,
    /// Headers with lowercased names, in order of appearance.
    pub headers: Vec<(String, String)>,
    /// Request body (empty when no `Content-Length`).
    pub body: Vec<u8>,
    /// True when the client asked this to be the connection's last
    /// request: an explicit `Connection: close`, or an HTTP/1.0
    /// request without `Connection: keep-alive`.
    pub wants_close: bool,
}

impl Request {
    /// First query parameter named `key`.
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// First header named `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 text.
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow::anyhow!("request body is not UTF-8"))
    }
}

/// Marker prefix of the over-limit body error; the connection handler
/// maps it to `413 Payload Too Large` instead of a generic `400`.
pub const BODY_TOO_LARGE: &str = "request body too large";

/// Marker of the idle-timeout error: the socket read timed out while
/// waiting for the *start* of the next request on a kept-alive
/// connection. The connection handler closes silently — an idle client
/// is not a protocol error.
pub const IDLE_TIMEOUT: &str = "idle timeout waiting for the next request";

/// Read one request from `r`, emitting interim output (the
/// `100 Continue` handshake) to `w`. Returns `Ok(None)` on a clean EOF
/// before any bytes (client closed without sending a request); errors
/// on malformed requests, over-limit headers, and bodies over
/// `max_body`.
///
/// `curl` (and other clients) send `Expect: 100-continue` for larger
/// POST bodies and wait up to a second for the interim response before
/// transmitting; honoring it here keeps every documented `/embed` and
/// `/knn` example latency-free.
pub fn read_request(
    r: &mut impl BufRead,
    w: &mut impl Write,
    max_body: usize,
) -> Result<Option<Request>> {
    let line = match read_crlf_line(r) {
        Ok(None) => return Ok(None),
        Ok(Some(l)) => l,
        // A read timeout at a request boundary is the keep-alive idle
        // case; mark it so the connection loop can close silently.
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            bail!("{IDLE_TIMEOUT}")
        }
        Err(e) => return Err(e.into()),
    };
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        bail!("malformed request line {line:?}");
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol {version:?}");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (percent_decode(p), parse_query(q)),
        None => (percent_decode(target), Vec::new()),
    };

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let Some(h) = read_crlf_line(r)? else {
            bail!("connection closed mid-headers");
        };
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            bail!("too many request headers (> {MAX_HEADERS})");
        }
        let Some((k, v)) = h.split_once(':') else {
            bail!("malformed header line {h:?}");
        };
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let len = match headers.iter().find(|(k, _)| k == "content-length") {
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad Content-Length {v:?}"))?,
        None => 0,
    };
    if len > max_body {
        bail!("{BODY_TOO_LARGE}: {len} bytes exceeds the {max_body}-byte limit");
    }
    let expects_continue = headers
        .iter()
        .any(|(k, v)| k == "expect" && v.eq_ignore_ascii_case("100-continue"));
    if expects_continue && len > 0 {
        w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
        w.flush()?;
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let connection = headers
        .iter()
        .find(|(k, _)| k == "connection")
        .map(|(_, v)| v.to_ascii_lowercase());
    let has_token = |t: &str| {
        connection
            .as_deref()
            .map(|v| v.split(',').any(|tok| tok.trim() == t))
            .unwrap_or(false)
    };
    // An explicit `close` always closes; HTTP/1.0 closes unless the
    // client explicitly opted into keep-alive (any other Connection
    // token list does not change the 1.0 default).
    let wants_close =
        has_token("close") || (version == "HTTP/1.0" && !has_token("keep-alive"));
    Ok(Some(Request { method: method.to_string(), path, query, headers, body, wants_close }))
}

/// Read a `\r\n`- (or `\n`-) terminated line, trimmed; `None` on EOF at
/// a line boundary. Lines are length-limited (reported as
/// `InvalidData`). Returns the raw `io::Error` so the caller can tell
/// an idle-timeout apart from a malformed request.
fn read_crlf_line(r: &mut impl BufRead) -> std::io::Result<Option<String>> {
    let mut line = String::new();
    let n = r
        .by_ref()
        .take(MAX_LINE_BYTES as u64 + 1)
        .read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    if n > MAX_LINE_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("request line exceeds {MAX_LINE_BYTES} bytes"),
        ));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(Some(line))
}

/// Decode a query string into ordered `(key, value)` pairs.
fn parse_query(q: &str) -> Vec<(String, String)> {
    q.split('&')
        .filter(|s| !s.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect()
}

/// Percent-decode (`%XX` and `+` → space); invalid escapes pass through.
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                let hex = bytes.get(i + 1..i + 3);
                match hex
                    .and_then(|h| std::str::from_utf8(h).ok())
                    .and_then(|h| u8::from_str_radix(h, 16).ok())
                {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// An HTTP response ready to serialize.
#[derive(Clone, Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body bytes.
    pub body: Vec<u8>,
    /// Optional `Retry-After` header value in seconds — set on `503`
    /// overload/not-ready responses so clients back off instead of
    /// hammering a saturated server.
    pub retry_after: Option<u32>,
}

impl Response {
    /// A `200 OK` JSON response.
    pub fn json(body: String) -> Response {
        Response {
            status: 200,
            content_type: "application/json",
            body: body.into_bytes(),
            retry_after: None,
        }
    }

    /// A `200 OK` SVG response.
    pub fn svg(body: String) -> Response {
        Response {
            status: 200,
            content_type: "image/svg+xml",
            body: body.into_bytes(),
            retry_after: None,
        }
    }

    /// An error response with a JSON `{"error": ...}` body.
    pub fn error(status: u16, message: &str) -> Response {
        let body = crate::util::json::Json::Str(message.to_string()).to_string_compact();
        Response {
            status,
            content_type: "application/json",
            body: format!("{{\"error\":{body}}}").into_bytes(),
            retry_after: None,
        }
    }

    /// A `503 Service Unavailable` with a `Retry-After` hint — the
    /// shape of every load-shed and not-yet-ready refusal.
    pub fn unavailable(message: &str, retry_secs: u32) -> Response {
        let mut resp = Response::error(503, message);
        resp.retry_after = Some(retry_secs);
        resp
    }

    /// Standard reason phrase for the status code.
    pub fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialize status line, headers and body to `w`. `keep_alive`
    /// picks the `Connection` framing: the response always carries an
    /// exact `Content-Length`, so a kept-alive peer knows where the
    /// next response begins.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        if let Some(secs) = self.retry_after {
            write!(w, "Retry-After: {secs}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), &mut Vec::<u8>::new(), 1 << 20)
    }

    #[test]
    fn parses_get_with_query() {
        let req = parse("GET /viewport?x0=-1.5&y0=2&x1=3&y1=4 HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/viewport");
        assert_eq!(req.query_param("x0"), Some("-1.5"));
        assert_eq!(req.query_param("y1"), Some("4"));
        assert_eq!(req.query_param("nope"), None);
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
        // HTTP/1.1 without a Connection header keeps the socket open.
        assert!(!req.wants_close);
    }

    #[test]
    fn connection_intent_parsed() {
        let req = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(req.wants_close);
        let req = parse("GET / HTTP/1.1\r\nConnection: Keep-Alive\r\n\r\n").unwrap().unwrap();
        assert!(!req.wants_close);
        // HTTP/1.0 defaults to close unless keep-alive is explicit.
        let req = parse("GET / HTTP/1.0\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert!(req.wants_close);
        let req = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(!req.wants_close);
        // A 1.0 Connection list without keep-alive keeps the default.
        let req = parse("GET / HTTP/1.0\r\nConnection: TE\r\n\r\n").unwrap().unwrap();
        assert!(req.wants_close);
        // ...and a 1.1 list without close stays open.
        let req = parse("GET / HTTP/1.1\r\nConnection: TE\r\n\r\n").unwrap().unwrap();
        assert!(!req.wants_close);
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let req = parse(
            "POST /knn HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 11\r\n\r\n{\"k\":5}junk",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body_str().unwrap(), "{\"k\":5}junk");
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        assert!(parse("GARBAGE\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nbad header line\r\n\r\n").is_err());
        let huge = read_request(
            &mut Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 999999\r\n\r\n".to_vec()),
            &mut Vec::<u8>::new(),
            1024,
        );
        // Over-limit bodies carry the 413 marker for the connection
        // handler; no body bytes are read.
        assert!(format!("{:#}", huge.unwrap_err()).contains(BODY_TOO_LARGE));
        // Truncated body (content-length longer than stream).
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").is_err());
    }

    #[test]
    fn expect_100_continue_is_acknowledged() {
        let mut interim = Vec::new();
        let req = read_request(
            &mut Cursor::new(
                b"POST /embed HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nhi"
                    .to_vec(),
            ),
            &mut interim,
            1 << 20,
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.body_str().unwrap(), "hi");
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        // No interim response without the header.
        let mut interim = Vec::new();
        read_request(
            &mut Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".to_vec()),
            &mut interim,
            1 << 20,
        )
        .unwrap()
        .unwrap();
        assert!(interim.is_empty());
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c"), "a b c");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%2Fpath%3f"), "/path?");
        assert_eq!(percent_decode("-1.25"), "-1.25");
    }

    #[test]
    fn response_serialization() {
        let mut buf = Vec::new();
        Response::json("{\"ok\":true}".to_string()).write_to(&mut buf, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
        let mut buf = Vec::new();
        Response::json("{}".to_string()).write_to(&mut buf, true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
        let mut buf = Vec::new();
        Response::error(404, "no such endpoint \"x\"").write_to(&mut buf, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.contains("{\"error\":\"no such endpoint \\\"x\\\"\"}"));
    }

    #[test]
    fn unavailable_carries_retry_after() {
        let mut buf = Vec::new();
        Response::unavailable("server overloaded", 1).write_to(&mut buf, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("{\"error\":\"server overloaded\"}"));
        // Plain responses must not grow the header.
        let mut buf = Vec::new();
        Response::json("{}".to_string()).write_to(&mut buf, true).unwrap();
        assert!(!String::from_utf8(buf).unwrap().contains("Retry-After"));
    }
}
