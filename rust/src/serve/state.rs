//! Server state: every artifact of a finished pipeline run, loaded
//! once and shared read-mostly across worker threads.
//!
//! All heavy artifacts (points, KNN graph, layout, spatial index) are
//! immutable after load — handlers take `&ServerState` and the server
//! shares it behind an `Arc`, so request handling needs no locking at
//! all on the data path. The only mutable member is the metrics
//! registry, a small `Mutex<Metrics>` touched once per request.

use crate::config::ServeConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::CheckpointPaths;
use crate::data::formats::{binary, checkpoint};
use crate::data::io::read_labels;
use crate::data::matrix::Matrix;
use crate::knn::KnnGraph;
use crate::render::grid::GridIndex;
use crate::vis::LargeVisConfig;
use anyhow::{bail, Context, Result};
use std::sync::Mutex;

/// Immutable (post-load) state shared by every server worker.
pub struct ServerState {
    /// Server configuration the state was loaded under.
    pub cfg: ServeConfig,
    /// Dataset name recorded by the run that wrote the checkpoints.
    pub dataset: String,
    /// High-dimensional base points (`data.lvec`).
    pub data: Matrix,
    /// KNN graph of the base points (`knn.ckpt`) — kept resident: the
    /// incremental insert path splices into it, and `/embed` defaults
    /// its neighbor count to its `k`.
    pub knn: KnnGraph,
    /// Directed edge count of the symmetrized graph checkpoint
    /// (`graph.ckpt`), 0 when absent. The CSR itself is validated at
    /// load and then dropped — no handler walks its edges, and at
    /// million-point scale keeping it resident would roughly double
    /// the server's memory for nothing.
    pub graph_edges: usize,
    /// Frozen 2D/3D base layout (`layout.lvec`).
    pub layout: Matrix,
    /// Class labels (`labels.lbl`), when the run had them.
    pub labels: Option<Vec<u32>>,
    /// Number of distinct classes in `labels` (0 when unlabeled).
    pub n_classes: usize,
    /// Uniform-grid spatial index over the layout for `/viewport`.
    pub grid: GridIndex,
    /// Gradient/hyper-parameters for `/embed`'s localized SGD.
    pub vis: LargeVisConfig,
    /// Request counters, served verbatim by `/metrics`.
    pub metrics: Mutex<Metrics>,
}

impl ServerState {
    /// Load every artifact from `cfg.checkpoints` and cross-validate
    /// shapes, so a stale or mixed checkpoint directory fails at
    /// startup instead of serving garbage.
    pub fn load(cfg: ServeConfig) -> Result<ServerState> {
        let paths = CheckpointPaths::in_dir(&cfg.checkpoints);
        let data = binary::read_binary(&paths.data).with_context(|| {
            format!(
                "{}: serving needs the raw-points checkpoint (written by a \
                 full pipeline run with checkpoints enabled)",
                paths.data.display()
            )
        })?;
        let layout = binary::read_binary(&paths.layout).with_context(|| {
            format!(
                "{}: serving needs the final-layout checkpoint (written by a \
                 pipeline run with checkpoints enabled)",
                paths.layout.display()
            )
        })?;
        let knn = checkpoint::read_knn(&paths.knn)
            .with_context(|| format!("{}: serving needs the KNN checkpoint", paths.knn.display()))?;
        let graph = if paths.graph.exists() {
            Some(
                checkpoint::read_csr(&paths.graph)
                    .with_context(|| format!("read {}", paths.graph.display()))?,
            )
        } else {
            None
        };

        let n = data.n();
        if n == 0 {
            bail!("{}: empty dataset cannot be served", paths.data.display());
        }
        if layout.n() != n || knn.n() != n {
            bail!(
                "stale checkpoint directory {}: {} points, layout of {}, knn of {}",
                paths.dir.display(),
                n,
                layout.n(),
                knn.n()
            );
        }
        if layout.d() < 2 {
            bail!("{}: layout must have >= 2 dims, has {}", paths.layout.display(), layout.d());
        }
        let graph_edges = match &graph {
            Some(g) => {
                if g.n() != n {
                    bail!(
                        "stale checkpoint directory {}: graph of {} vertices for {} points",
                        paths.dir.display(),
                        g.n(),
                        n
                    );
                }
                g.n_directed_edges()
            }
            None => 0,
        };
        drop(graph);
        let labels = if paths.labels.exists() {
            let ls = read_labels(&paths.labels)?;
            if ls.len() != n {
                bail!(
                    "{}: {} labels for {} points — stale checkpoint directory?",
                    paths.labels.display(),
                    ls.len(),
                    n
                );
            }
            Some(ls)
        } else {
            None
        };
        let n_classes = labels
            .as_ref()
            .map(|ls| ls.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
            .unwrap_or(0);
        let dataset = std::fs::read_to_string(&paths.meta)
            .map(|s| s.trim().to_string())
            .unwrap_or_else(|_| "unknown".to_string());

        let grid = GridIndex::build(&layout, cfg.grid.max(1));
        // Gradient family/hyper-parameters for the localized /embed SGD
        // (paper defaults; the layout itself fixes the output dim).
        let vis = LargeVisConfig { dim: layout.d(), threads: 1, ..Default::default() };

        let mut metrics = Metrics::new();
        metrics.set("serve.points", n as f64);
        metrics.set("serve.graph_edges", graph_edges as f64);
        Ok(ServerState {
            cfg,
            dataset,
            data,
            knn,
            graph_edges,
            layout,
            labels,
            n_classes,
            grid,
            vis,
            metrics: Mutex::new(metrics),
        })
    }

    /// Effective neighbor count for `/embed`: the configured override,
    /// or the checkpointed graph's `k`, clamped to the base size.
    pub fn embed_k(&self) -> usize {
        let k = if self.cfg.embed_k == 0 { self.knn.k } else { self.cfg.embed_k };
        k.max(1).min(self.data.n())
    }

    /// Bump a metrics counter (lock-poisoning tolerant: a panicking
    /// worker must not take the metrics endpoint down with it).
    pub fn count(&self, name: &str, delta: f64) {
        let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        m.add(name, delta);
    }

    /// Snapshot the metrics registry as a JSON object string.
    pub fn metrics_json(&self) -> String {
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_directory_fails_with_context() {
        let cfg = ServeConfig {
            checkpoints: std::path::PathBuf::from("/nonexistent/checkpoints"),
            ..Default::default()
        };
        let err = format!("{:#}", ServerState::load(cfg).unwrap_err());
        assert!(err.contains("data.lvec"), "{err}");
        assert!(err.contains("full pipeline run"), "{err}");
    }

    #[test]
    fn stale_shapes_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let paths = CheckpointPaths::in_dir(&dir);
        let data = Matrix::from_vec(vec![0.0; 5 * 3], 5, 3);
        let layout = Matrix::from_vec(vec![0.0; 4 * 2], 4, 2); // wrong n
        binary::write_binary(&paths.data, &data).unwrap();
        binary::write_binary(&paths.layout, &layout).unwrap();
        checkpoint::write_knn(&paths.knn, &KnnGraph::empty(5, 2)).unwrap();
        let cfg = ServeConfig { checkpoints: dir.clone(), ..Default::default() };
        let err = format!("{:#}", ServerState::load(cfg).unwrap_err());
        assert!(err.contains("stale checkpoint directory"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
