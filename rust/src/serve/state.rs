//! Server state: epoch-versioned snapshots over a live, growing layout.
//!
//! The read path is built around one rule: **a request sees exactly one
//! epoch**. All heavy artifacts (points, KNN graph, layout, spatial
//! index, labels) live inside an immutable [`Snapshot`] shared behind
//! an `Arc`; handlers take `&Snapshot` and can never observe a torn
//! mix of epochs. Writers (`POST /insert`, the background refinement
//! worker) mutate a private `Writer` double-buffer under a mutex,
//! then build a fresh `Arc<Snapshot>` and atomically publish it. The
//! paper's asynchronous-SGD tolerance for slightly-stale reads is what
//! makes this safe: a reader finishing on epoch `e` while `e+1` is
//! published simply rendered a consistent, marginally older layout.
//!
//! Readers are lock-free in the steady state: each connection worker
//! caches its `Arc<Snapshot>` and revalidates it against one
//! `AtomicU64` epoch counter per request ([`ServerState::snapshot_if_stale`]);
//! only an actual epoch change takes the (pointer-clone-only) snapshot
//! mutex. The only other lock on the read path is the metrics counter
//! mutex, as before.
//!
//! Durability: accepted inserts are appended to the WAL set rooted at
//! `inserts.wal` (see [`crate::data::formats::wal`]) *before* being
//! applied, and replayed on startup — a restarted server recovers
//! every acknowledged point bit-identically. Startup is two-phase:
//! [`ServerState::open`] loads the checkpoints (and rolls forward any
//! interrupted compaction) but leaves the server *not ready*;
//! [`ServerState::recover`] replays the WAL — possibly long — while
//! `/readyz` reports 503 and inserts are refused. Replay is bounded:
//! the active segment rotates at `wal_segment_bytes`, and once
//! `wal_max_segments` sealed segments accumulate they are *compacted*
//! into the base checkpoints (staged `*.tmp` files + a fsynced commit
//! marker, so a crash at any byte either replays the old WAL or rolls
//! the finished compaction forward — never both, never neither).
//!
//! All durable writes go through a [`Storage`] handle so the crash
//! tests can inject short writes, fsync failures and torn writes at
//! every fault point (`rust/tests/fault_recovery.rs`).

use crate::config::{SearchMode, ServeConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::CheckpointPaths;
use crate::data::chunked::{ChunkedKnn, ChunkedLabels, ChunkedMatrix, LABEL_CHUNK_LEN};
use crate::data::formats::wal::{self, WalSet};
use crate::data::formats::{binary, checkpoint};
use crate::data::io::{read_labels, write_labels};
use crate::data::matrix::Matrix;
use crate::graph::weights::WeightConfig;
use crate::knn::search::{search_nearest, SearchHandle, SearchIndex, SearchTotals};
use crate::render::grid::GridIndex;
use crate::serve::epoch::EpochCell;
use crate::util::heap::BoundedMaxHeap;
use crate::util::faultio::{RealStorage, Storage};
use crate::util::notify::Doorbell;
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Mutex};
use crate::vis::incremental::IncrementalLayout;
use crate::vis::LargeVisConfig;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// One immutable epoch of the served artifacts. Everything a handler
/// reads for a single response comes from one `Snapshot`, so every
/// response is internally consistent even while inserts land.
pub struct Snapshot {
    /// Epoch counter: 0 for the freshly loaded checkpoints, +1 per
    /// publish (insert batch or refinement pass).
    pub epoch: u64,
    /// High-dimensional points (base + live inserts). Chunked
    /// copy-on-write: untouched chunks are shared with every other
    /// epoch by `Arc`, so holding old snapshots costs O(changed), not
    /// O(N) each.
    pub data: ChunkedMatrix,
    /// KNN graph over `data` (live inserts spliced in); chunked like
    /// `data`.
    pub knn: ChunkedKnn,
    /// Low-dimensional layout aligned with `data`; chunked like `data`.
    pub layout: ChunkedMatrix,
    /// Class labels; live inserts carry the pseudo-class `n_classes`.
    pub labels: Option<ChunkedLabels>,
    /// Number of distinct classes in the *base* labels (0 = unlabeled).
    pub n_classes: usize,
    /// Spatial index over `layout` for `/viewport`.
    pub grid: GridIndex,
    /// Navigable-graph search metadata (entry seeds + coarsening maps)
    /// for sub-linear `/knn` and `/embed` lookups. Built once at load
    /// (and after each WAL compaction); shared across epochs by `Arc` —
    /// live inserts stay findable through their spliced in-edges, not
    /// by rebuilding this.
    pub search: Arc<SearchIndex>,
    /// Points loaded from the checkpoints (frozen base); ids at or
    /// above this were inserted live.
    pub base_n: usize,
}

/// The single-writer mutable state behind the snapshots.
struct Writer {
    /// The growing dataset/graph/layout. Its chunked stores are cloned
    /// into each published [`Snapshot`] — a pointer copy per chunk;
    /// the first mutation of a chunk after a publish copies just that
    /// chunk (copy-on-write).
    inc: IncrementalLayout,
    /// Incrementally maintained spatial index (overflow + threshold
    /// rebuild; cloned into each snapshot — the bucket CSR is shared
    /// by `Arc`, only the small overflow list is copied).
    grid: GridIndex,
    /// Labels aligned with `inc.data` (base labels + pseudo-class).
    labels: Option<ChunkedLabels>,
    /// Class id assigned to live-inserted points when the base is
    /// labeled: the first id past the base classes (palette lookups
    /// are modulo, so any value is render-safe).
    pseudo_class: u32,
    /// Search metadata cloned into every published snapshot (see
    /// [`Snapshot::search`]).
    search: Arc<SearchIndex>,
    /// Durable insert log; `None` until [`ServerState::recover`] runs,
    /// and always `None` when the server is read-only.
    wal: Option<WalSet>,
    /// Set when a compaction died *after* its commit marker landed:
    /// the on-disk checkpoints and WAL no longer match this process's
    /// in-memory picture, so inserts are refused until a restart rolls
    /// the compaction forward.
    wal_failed: bool,
    /// Localized-edge windows of batches not yet refined.
    pending_edges: Vec<(u32, u32, f64)>,
    /// Rows covered by `pending_edges`.
    pending_rows: usize,
}

/// Shared state of a running server: configuration, the epoch-swapped
/// snapshot cell, the writer double-buffer, and metrics.
pub struct ServerState {
    /// Server configuration the state was loaded under.
    pub cfg: ServeConfig,
    /// Dataset name recorded by the run that wrote the checkpoints.
    pub dataset: String,
    /// Directed edge count of the symmetrized graph checkpoint
    /// (`graph.ckpt`), 0 when absent. The CSR itself is validated at
    /// load and then dropped — no handler walks its edges, and at
    /// million-point scale keeping it resident would roughly double
    /// the server's memory for nothing.
    pub graph_edges: usize,
    /// Points loaded from the checkpoints (the frozen base).
    pub base_n: usize,
    /// Distinct classes in the base labels (0 when unlabeled).
    pub n_classes: usize,
    /// Gradient/hyper-parameters for `/embed` and the insert path's
    /// localized SGD.
    pub vis: LargeVisConfig,
    /// Request counters, served verbatim by `/metrics`.
    pub metrics: Mutex<Metrics>,
    /// Durable-write factory; `RealStorage` in production, a
    /// fault-injecting implementation in the crash tests.
    storage: Arc<dyn Storage>,
    /// Checkpoint-directory layout the state was loaded from.
    paths: CheckpointPaths,
    /// False until [`ServerState::recover`] finishes WAL replay;
    /// `/readyz` and the insert path gate on this.
    ready: AtomicBool,
    /// Connections currently admitted (accepted and not yet finished);
    /// the acceptor sheds above `max_inflight`.
    admitted: AtomicUsize,
    /// The current snapshot plus its lock-free epoch hint, swapped
    /// together by [`EpochCell::publish`]: a reader that sees epoch
    /// `e` in the hint finds a snapshot of epoch `>= e` in the cell.
    snap: EpochCell<Snapshot>,
    /// Writer double-buffer (insert handlers + refinement worker).
    writer: Mutex<Writer>,
    /// Refinement worker doorbell: rung when un-refined insert windows
    /// are pending.
    refine_bell: Doorbell,
}

/// `<path>.tmp` — the staging name compaction writes next to each
/// final artifact before the atomic rename.
fn tmp_path(p: &Path) -> PathBuf {
    let mut s = p.as_os_str().to_os_string();
    s.push(".tmp");
    PathBuf::from(s)
}

/// Which side of the commit marker a compaction failure landed on —
/// before it, nothing changed and the next attempt retries; after it,
/// the on-disk state is ahead of this process and only a restart
/// (which rolls the compaction forward) is safe.
enum CompactError {
    BeforeCommit(anyhow::Error),
    AfterCommit(anyhow::Error),
}

/// Complete a committed compaction: rename every staged artifact into
/// place, drop the now-stale CSR graph checkpoint, reset the WAL to an
/// empty active segment continuing at `absorbed_seq`, and remove the
/// marker. Idempotent — every step tolerates having already run, so
/// crash-then-retry converges.
fn finish_compaction(
    storage: &dyn Storage,
    paths: &CheckpointPaths,
    absorbed_seq: u64,
    d: usize,
    wal: Option<&mut WalSet>,
) -> Result<()> {
    for target in [&paths.data, &paths.layout, &paths.knn, &paths.labels] {
        let staged = tmp_path(target);
        if staged.exists() {
            storage
                .persist(&staged, target)
                .with_context(|| format!("install compacted {}", target.display()))?;
        }
    }
    // The CSR graph checkpoint describes only the old base (it has one
    // vertex per pre-compaction point); keeping it would fail the
    // shape cross-validation on the next load. The server runs fine
    // without it (`graph_edges` reports 0).
    storage
        .remove(&paths.graph)
        .with_context(|| format!("remove stale {}", paths.graph.display()))?;
    match wal {
        Some(set) => set.reset_absorbed(absorbed_seq)?,
        None => wal::reset_wal_set(storage, &paths.wal, d, absorbed_seq)?,
    }
    storage
        .remove(&paths.compact_marker())
        .context("remove compaction marker")?;
    Ok(())
}

/// Startup crash recovery for compaction: a present commit marker
/// means the staged checkpoints are complete and durable, so the
/// compaction is rolled *forward*; no marker means any stray `*.tmp`
/// files are from an attempt that died before commit and are removed.
fn roll_forward_compaction(storage: &dyn Storage, paths: &CheckpointPaths) -> Result<()> {
    let marker = paths.compact_marker();
    let raw = match std::fs::read_to_string(&marker) {
        Ok(s) => s,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            for target in [&paths.data, &paths.layout, &paths.knn, &paths.labels, &marker] {
                storage.remove(&tmp_path(target)).ok();
            }
            return Ok(());
        }
        Err(e) => return Err(e).with_context(|| format!("read {}", marker.display())),
    };
    let mut absorbed: Option<u64> = None;
    let mut d: Option<usize> = None;
    for line in raw.lines() {
        if let Some(v) = line.strip_prefix("absorbed=") {
            absorbed = v.trim().parse().ok();
        } else if let Some(v) = line.strip_prefix("d=") {
            d = v.trim().parse().ok();
        }
    }
    let (Some(absorbed), Some(d)) = (absorbed, d) else {
        bail!(
            "{}: unparseable compaction marker (remove it manually to discard the compaction)",
            marker.display()
        );
    };
    eprintln!("[serve] completing interrupted WAL compaction (absorbed seq < {absorbed})");
    finish_compaction(storage, paths, absorbed, d, None)
        .context("roll forward interrupted WAL compaction")
}

impl ServerState {
    /// [`ServerState::open`] + [`ServerState::recover`] in one call —
    /// the convenience entry point for tests and synchronous startup.
    pub fn load(cfg: ServeConfig) -> Result<ServerState> {
        Self::load_with(cfg, Arc::new(RealStorage))
    }

    /// [`ServerState::load`] with an explicit [`Storage`].
    pub fn load_with(cfg: ServeConfig, storage: Arc<dyn Storage>) -> Result<ServerState> {
        let st = Self::open_with(cfg, storage)?;
        st.recover()?;
        Ok(st)
    }

    /// Load every artifact from `cfg.checkpoints` and cross-validate
    /// shapes (a stale or mixed checkpoint directory fails at startup
    /// instead of serving garbage). Rolls forward any interrupted WAL
    /// compaction first. The returned state serves reads of epoch 0
    /// but is **not ready**: the WAL has not been replayed — call
    /// [`ServerState::recover`] (possibly from another thread while
    /// `/readyz` reports 503).
    pub fn open(cfg: ServeConfig) -> Result<ServerState> {
        Self::open_with(cfg, Arc::new(RealStorage))
    }

    /// [`ServerState::open`] with an explicit [`Storage`] (the crash
    /// tests inject faults through it).
    pub fn open_with(cfg: ServeConfig, storage: Arc<dyn Storage>) -> Result<ServerState> {
        let paths = CheckpointPaths::in_dir(&cfg.checkpoints);
        roll_forward_compaction(storage.as_ref(), &paths)?;
        let data = binary::read_binary(&paths.data).with_context(|| {
            format!(
                "{}: serving needs the raw-points checkpoint (written by a \
                 full pipeline run with checkpoints enabled)",
                paths.data.display()
            )
        })?;
        let layout = binary::read_binary(&paths.layout).with_context(|| {
            format!(
                "{}: serving needs the final-layout checkpoint (written by a \
                 pipeline run with checkpoints enabled)",
                paths.layout.display()
            )
        })?;
        let knn = checkpoint::read_knn(&paths.knn)
            .with_context(|| format!("{}: serving needs the KNN checkpoint", paths.knn.display()))?;
        let graph = if paths.graph.exists() {
            Some(
                checkpoint::read_csr(&paths.graph)
                    .with_context(|| format!("read {}", paths.graph.display()))?,
            )
        } else {
            None
        };

        let n = data.n();
        if n == 0 {
            bail!("{}: empty dataset cannot be served", paths.data.display());
        }
        if layout.n() != n || knn.n() != n {
            bail!(
                "stale checkpoint directory {}: {} points, layout of {}, knn of {}",
                paths.dir.display(),
                n,
                layout.n(),
                knn.n()
            );
        }
        if layout.d() < 2 {
            bail!("{}: layout must have >= 2 dims, has {}", paths.layout.display(), layout.d());
        }
        let graph_edges = match &graph {
            Some(g) => {
                if g.n() != n {
                    bail!(
                        "stale checkpoint directory {}: graph of {} vertices for {} points",
                        paths.dir.display(),
                        g.n(),
                        n
                    );
                }
                g.n_directed_edges()
            }
            None => 0,
        };
        drop(graph);
        let labels = if paths.labels.exists() {
            let ls = read_labels(&paths.labels)?;
            if ls.len() != n {
                bail!(
                    "{}: {} labels for {} points — stale checkpoint directory?",
                    paths.labels.display(),
                    ls.len(),
                    n
                );
            }
            Some(ls)
        } else {
            None
        };
        let n_classes = labels
            .as_ref()
            .map(|ls| ls.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
            .unwrap_or(0);
        let labels = labels.map(|ls| ChunkedLabels::from_slice(&ls, LABEL_CHUNK_LEN));
        let dataset = std::fs::read_to_string(&paths.meta)
            .map(|s| s.trim().to_string())
            .unwrap_or_else(|_| "unknown".to_string());

        let grid = GridIndex::build(&layout, cfg.grid.max(1));
        // Gradient family/hyper-parameters for the localized SGD of
        // `/embed` and `/insert` (paper defaults; the layout itself
        // fixes the output dim).
        let vis = LargeVisConfig { dim: layout.d(), threads: 1, ..Default::default() };

        let mut metrics = Metrics::new();
        metrics.set("serve.points", n as f64);
        metrics.set("serve.graph_edges", graph_edges as f64);
        // Robustness counters exist from the first `/metrics` scrape,
        // so dashboards and the overload tests never probe a missing
        // key.
        for key in [
            "serve.shed",
            "serve.panics",
            "serve.write_timeouts",
            "serve.sockopt_errors",
            "serve.replayed_batches",
            "serve.wal_rotations",
            "serve.wal_rotation_errors",
            "serve.compactions",
            "serve.compact_errors",
            "serve.wal_corrupt_segments",
            "serve.search_queries",
            "serve.search_visited",
            "serve.search_scored",
            "serve.search_fallbacks",
        ] {
            metrics.set(key, 0.0);
        }

        // The writer wraps the loaded base; insert batches grow it.
        // Re-weighting of spliced rows uses the default perplexity
        // (calibrate_row clamps the target to each row's support, so
        // this is well-defined for any checkpointed k).
        let mut inc =
            IncrementalLayout::new(data, knn, layout, WeightConfig::default(), vis.clone());
        inc.samples_per_insert = cfg.insert_samples;
        // Navigable-graph search metadata over the loaded base. Built
        // in both modes (it is small and lets tests flip modes without
        // a reload); the insert path only *uses* it in graph mode.
        let search = Arc::new(SearchIndex::build(
            &inc.data,
            &inc.knn,
            Some(&grid),
            cfg.search_seeds.max(1),
        ));
        if cfg.search == SearchMode::Graph {
            inc.search =
                Some(SearchHandle { index: search.clone(), beam_width: cfg.beam_width });
        }
        let writer = Writer {
            inc,
            grid,
            labels,
            search,
            pseudo_class: n_classes as u32,
            wal: None,
            wal_failed: false,
            pending_edges: Vec::new(),
            pending_rows: 0,
        };

        let snapshot = Arc::new(Self::snapshot_of(&writer, 0, n, n_classes));
        Ok(ServerState {
            cfg,
            dataset,
            graph_edges,
            base_n: n,
            n_classes,
            vis,
            metrics: Mutex::new(metrics),
            storage,
            paths,
            ready: AtomicBool::new(false),
            admitted: AtomicUsize::new(0),
            snap: EpochCell::new(snapshot),
            writer: Mutex::new(writer),
            refine_bell: Doorbell::new(),
        })
    }

    /// Replay the live-insert WAL set and mark the server ready.
    /// Replay goes through the exact same `add_points` path live
    /// inserts take, so the recovered data/KNN state is bit-identical
    /// to the pre-restart one; the published epoch equals the number
    /// of replayed batches. Idempotent — a second call is a no-op.
    /// Corruption is handled per `cfg.recovery_policy`: fail fast
    /// (default), or salvage the clean prefix, quarantine the corrupt
    /// files, and count them in `serve.wal_corrupt_segments`.
    pub fn recover(&self) -> Result<()> {
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        // ordering: Acquire — pairs with the Release store at the end
        // of this function; a second caller that observes true also
        // sees the fully replayed state.
        if self.ready.load(Ordering::Acquire) {
            return Ok(());
        }
        let d = w.inc.data.d();
        let recovery = if self.cfg.read_only {
            wal::read_wal_set(&self.paths.wal, d, self.cfg.recovery_policy)?
        } else {
            let (set, rec) = WalSet::open(
                self.storage.clone(),
                &self.paths.wal,
                d,
                self.cfg.recovery_policy,
            )
            .with_context(|| format!("open insert WAL {}", self.paths.wal.display()))?;
            w.wal = Some(set);
            rec
        };
        let mut recovered_rows = 0usize;
        let mut replay_totals = SearchTotals::default();
        for b in &recovery.batches {
            Self::apply_batch(&mut w, b);
            recovered_rows += b.n();
            replay_totals.merge(&w.inc.last_search);
        }
        let recovered_batches = recovery.batches.len() as u64;
        if recovery.torn_tail {
            eprintln!(
                "[serve] {}: torn WAL tail dropped ({recovered_batches} complete batches \
                 recovered)",
                self.paths.wal.display(),
            );
        }
        if recovery.corrupt_segments > 0 {
            eprintln!(
                "[serve] {}: {} corrupt WAL segment(s) quarantined \
                 (recovery_policy=truncate)",
                self.paths.wal.display(),
                recovery.corrupt_segments,
            );
        }
        // Recovered rows count as already-refined (their localized
        // passes ran during replay; the background worker starts clean).
        w.pending_edges.clear();
        w.pending_rows = 0;
        {
            let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
            m.set("serve.wal_batches", recovered_batches as f64);
            m.set("serve.inserted", recovered_rows as f64);
            m.set("serve.replayed_batches", recovered_batches as f64);
            m.set("serve.wal_corrupt_segments", recovery.corrupt_segments as f64);
        }
        self.record_search_totals(&replay_totals);

        let epoch = recovered_batches;
        let snapshot = Arc::new(Self::snapshot_of(&w, epoch, self.base_n, self.n_classes));
        self.snap.publish(epoch, snapshot);
        // ordering: Release — pairs with the Acquire loads in
        // `is_ready` and above: whoever observes true also sees the
        // replayed snapshot and metrics written before this store.
        self.ready.store(true, Ordering::Release);
        Ok(())
    }

    /// True once WAL replay finished; `/readyz` and inserts gate on it.
    pub fn is_ready(&self) -> bool {
        // ordering: Acquire — pairs with the Release in `recover`;
        // observing true implies the replayed snapshot is visible.
        self.ready.load(Ordering::Acquire)
    }

    /// Connections currently admitted (accepted, not yet finished).
    pub fn inflight(&self) -> usize {
        // ordering: Relaxed — an overload gauge; the RMWs below keep
        // the count exact, and no memory is published through it. An
        // admission decision made on a slightly stale value only
        // shifts the shed threshold by one in-flight connection.
        self.admitted.load(Ordering::Relaxed)
    }

    /// Record one admitted connection (acceptor side).
    pub fn admit_one(&self) {
        // ordering: Relaxed — RMW atomicity alone keeps the gauge
        // exact; see `inflight`.
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one finished connection (worker side).
    pub fn release_one(&self) {
        // ordering: Relaxed — see `admit_one`.
        self.admitted.fetch_sub(1, Ordering::Relaxed);
    }

    /// Apply one insert batch to the writer state (shared by live
    /// inserts and WAL replay): grow the layout through the localized
    /// insert path, extend the spatial index incrementally, extend
    /// labels with the live pseudo-class, record the refinement window.
    fn apply_batch(w: &mut Writer, pts: &Matrix) -> Vec<usize> {
        let ids = w.inc.add_points(pts);
        for &id in &ids {
            let r = w.inc.layout.row(id);
            w.grid.insert(id as u32, r[0], r[1]);
        }
        if let Some(ls) = &mut w.labels {
            // All live inserts share one stable pseudo-class so they
            // stay distinguishable in `/viewport` tiles.
            let fill = w.pseudo_class;
            for _ in 0..ids.len() {
                ls.push(fill);
            }
        }
        w.pending_edges.extend_from_slice(&w.inc.last_edges);
        w.pending_rows += ids.len();
        ids
    }

    /// Build a snapshot of the writer's current state (the caller
    /// publishes the result).
    ///
    /// Cost note: a publish is **O(batch), not O(N)**. Every heavy
    /// artifact is a chunked copy-on-write store
    /// ([`crate::data::chunked`]) or `Arc`-shared (grid buckets,
    /// search index): cloning it here copies one `Arc` pointer per
    /// chunk, and the *data* of a chunk is copied at most once per
    /// epoch, on the writer's first mutation of it after the previous
    /// publish. An insert batch touches the tail chunks it appends to
    /// plus the chunks holding the spliced KNN rows of its neighbors —
    /// a set bounded by the batch's neighborhood, independent of the
    /// base size (measured by `rust/tests/publish_cost.rs` via
    /// [`crate::data::chunked::copied_bytes`]). The algorithmic
    /// per-insert work — KNN splice, reweighting, placement SGD — is
    /// bounded the same way
    /// ([`crate::vis::incremental::LocalizedStats`]).
    fn snapshot_of(w: &Writer, epoch: u64, base_n: usize, n_classes: usize) -> Snapshot {
        Snapshot {
            epoch,
            data: w.inc.data.clone(),
            knn: w.inc.knn.clone(),
            layout: w.inc.layout.clone(),
            labels: w.labels.clone(),
            n_classes,
            grid: w.grid.clone(),
            search: w.search.clone(),
            base_n,
        }
    }

    /// The current snapshot (one brief mutex for the `Arc` clone).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.snap.get()
    }

    /// Lock-free epoch hint. A connection worker holding a cached
    /// snapshot compares its `epoch` against this and re-fetches only
    /// on mismatch — the steady-state read path touches no mutex.
    /// (The Acquire/Release pairing lives in [`EpochCell`].)
    pub fn epoch_hint(&self) -> u64 {
        self.snap.hint()
    }

    /// Refresh `cached` if the epoch moved; returns a snapshot no
    /// older than the hint read at call time.
    pub fn snapshot_if_stale(&self, cached: &mut Arc<Snapshot>) {
        if cached.epoch != self.epoch_hint() {
            *cached = self.snapshot();
        }
    }

    /// Publish the writer's state as the next epoch. Called with the
    /// writer lock held; the snapshot mutex is taken only for the swap.
    fn publish(&self, w: &Writer) -> u64 {
        let epoch = self.epoch_hint() + 1;
        let snapshot = Arc::new(Self::snapshot_of(w, epoch, self.base_n, self.n_classes));
        self.snap.publish(epoch, snapshot);
        epoch
    }

    /// Insert a batch of points: WAL first, then the localized insert
    /// path, then an atomic snapshot swap. Returns the assigned ids and
    /// the epoch that contains them. Serialized with other writers by
    /// the writer mutex; readers are never blocked. WAL maintenance
    /// (segment rotation, compaction) runs after the ack point — its
    /// failures are counted, never surfaced to an already-durable
    /// insert.
    pub fn insert(&self, pts: &Matrix) -> Result<(Vec<usize>, u64)> {
        if self.cfg.read_only {
            bail!("server is read-only (--read-only)");
        }
        if !self.is_ready() {
            bail!("server is still replaying the insert WAL");
        }
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        if w.wal_failed {
            bail!("inserts disabled after a failed WAL compaction (restart to recover)");
        }
        if let Some(set) = &mut w.wal {
            set.append(pts).context("append insert WAL")?;
        }
        let ids = Self::apply_batch(&mut w, pts);
        let totals = w.inc.last_search;
        let epoch = self.publish(&w);
        self.maintain_wal(&mut w);
        drop(w);
        self.record_search_totals(&totals);
        self.ring_refine_bell();
        Ok((ids, epoch))
    }

    /// Answer a `/knn`-style nearest-neighbor query against `snap`,
    /// dispatching on `cfg.search`: the exact scan, or the
    /// navigable-graph beam walk with its automatic exact fallback.
    /// Graph-mode queries bump the `serve.search_*` counters.
    pub fn query_knn(&self, snap: &Snapshot, query: &[f32], k: usize) -> Vec<(u32, f32)> {
        match self.cfg.search {
            SearchMode::Exact => {
                let mut dists = Vec::new();
                let mut heap = BoundedMaxHeap::new(k.max(1));
                crate::kernels::nearest_k(query, &snap.data, k, &mut dists, &mut heap)
            }
            SearchMode::Graph => {
                let (out, stats) = search_nearest(
                    query,
                    &snap.data,
                    &snap.knn,
                    &snap.search,
                    k,
                    self.cfg.beam_width,
                );
                let mut totals = SearchTotals::default();
                totals.absorb(&stats);
                self.record_search_totals(&totals);
                out
            }
        }
    }

    /// Fold accumulated walk counters into the `serve.search_*`
    /// metrics (one lock for all four keys). A no-op for all-zero
    /// totals, so exact-mode paths can call it unconditionally.
    pub fn record_search_totals(&self, t: &SearchTotals) {
        if t.queries == 0 {
            return;
        }
        let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        m.add("serve.search_queries", t.queries as f64);
        m.add("serve.search_visited", t.visited as f64);
        m.add("serve.search_scored", t.scored as f64);
        m.add("serve.search_fallbacks", t.fallbacks as f64);
    }

    /// Post-ack WAL maintenance: rotate the active segment once it
    /// exceeds `wal_segment_bytes`, and compact once `wal_max_segments`
    /// sealed segments have accumulated — both bound how much WAL a
    /// restart must replay.
    fn maintain_wal(&self, w: &mut Writer) {
        let seg_bytes = self.cfg.wal_segment_bytes.max(1);
        let max_segments = self.cfg.wal_max_segments.max(1);
        let mut want_compact = false;
        if let Some(set) = w.wal.as_mut() {
            if set.active_bytes() >= seg_bytes {
                match set.rotate() {
                    Ok(()) => self.count("serve.wal_rotations", 1.0),
                    Err(e) => {
                        self.count("serve.wal_rotation_errors", 1.0);
                        eprintln!("[serve] WAL rotation failed: {e:#}");
                        return;
                    }
                }
            }
            want_compact = set.sealed_count() >= max_segments;
        }
        if want_compact {
            self.compact(w);
        }
    }

    /// Compact the WAL into the base checkpoints; counts success or
    /// failure and (only for a post-commit failure) disables inserts.
    fn compact(&self, w: &mut Writer) {
        match self.try_compact(w) {
            Ok(()) => {
                self.count("serve.compactions", 1.0);
                self.rebuild_search(w);
            }
            Err(CompactError::BeforeCommit(e)) => {
                self.count("serve.compact_errors", 1.0);
                eprintln!("[serve] WAL compaction failed before commit (will retry): {e:#}");
            }
            Err(CompactError::AfterCommit(e)) => {
                self.count("serve.compact_errors", 1.0);
                w.wal_failed = true;
                eprintln!(
                    "[serve] WAL compaction failed after commit; inserts disabled until \
                     restart rolls it forward: {e:#}"
                );
            }
        }
    }

    /// Rebuild the search metadata after a WAL compaction absorbed the
    /// live inserts into the base checkpoints. A process restarted from
    /// those checkpoints builds its index from exactly this graph, so
    /// the live index must match it — otherwise WAL batches acked after
    /// the compaction would replay with different base neighbors and
    /// break the bit-identical-recovery contract. The grid is freshly
    /// re-bucketed (what a restart would build) rather than the
    /// incrementally extended writer copy, for the same reason.
    fn rebuild_search(&self, w: &mut Writer) {
        let grid = GridIndex::build(&w.inc.layout, self.cfg.grid.max(1));
        w.search = Arc::new(SearchIndex::build(
            &w.inc.data,
            &w.inc.knn,
            Some(&grid),
            self.cfg.search_seeds.max(1),
        ));
        if let Some(h) = &mut w.inc.search {
            h.index = w.search.clone();
        }
    }

    /// Absorb every WAL batch into the base checkpoints. Protocol:
    /// stage `data/layout/knn/labels` as fsynced `*.tmp` files, then
    /// atomically rename a fsynced commit marker into place (the
    /// point of no return), then [`finish_compaction`]. A crash before
    /// the marker leaves the old checkpoints + full WAL (tmps are
    /// discarded at the next open); a crash after it is rolled forward
    /// at the next open. Runs with the writer lock held, so the state
    /// written is exactly the state every acked insert sees.
    fn try_compact(&self, w: &mut Writer) -> Result<(), CompactError> {
        let Some(absorbed) = w.wal.as_ref().map(|set| set.next_seq()) else {
            return Ok(());
        };
        let storage = self.storage.as_ref();
        let paths = &self.paths;
        let d = w.inc.data.d();
        let before = CompactError::BeforeCommit;

        binary::write_binary_with(storage, &tmp_path(&paths.data), &w.inc.data).map_err(before)?;
        binary::write_binary_with(storage, &tmp_path(&paths.layout), &w.inc.layout)
            .map_err(before)?;
        checkpoint::write_knn_with(storage, &tmp_path(&paths.knn), &w.inc.knn).map_err(before)?;
        if let Some(ls) = &w.labels {
            let staged = tmp_path(&paths.labels);
            // The label file format wants a flat slice; labels are one
            // u32 per point, so this transient flatten is tiny next to
            // the matrix/KNN writes above.
            write_labels(&staged, &ls.to_vec()).map_err(before)?;
            // `write_labels` uses plain buffered I/O; the staged file
            // must be durable before the marker commits.
            storage
                .open_durable(&staged)
                .and_then(|mut f| f.sync_data())
                .with_context(|| format!("sync {}", staged.display()))
                .map_err(before)?;
        }

        let marker = paths.compact_marker();
        let staged_marker = tmp_path(&marker);
        let commit = || -> Result<()> {
            let mut f = storage
                .create_durable(&staged_marker)
                .with_context(|| format!("create {}", staged_marker.display()))?;
            f.write_all(format!("absorbed={absorbed}\nd={d}\n").as_bytes())?;
            f.sync_data()?;
            drop(f);
            storage.persist(&staged_marker, &marker)?;
            Ok(())
        };
        commit().context("commit WAL compaction marker").map_err(before)?;

        finish_compaction(storage, paths, absorbed, d, w.wal.as_mut())
            .map_err(CompactError::AfterCommit)
    }

    /// Final fsync of the active WAL on graceful shutdown — a no-op
    /// after clean appends (every append syncs), cheap insurance
    /// otherwise. Failures are logged, not raised: the process is
    /// exiting either way.
    pub fn final_wal_sync(&self) {
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(set) = w.wal.as_mut() {
            if let Err(e) = set.sync() {
                eprintln!("[serve] final WAL sync failed: {e:#}");
            }
        }
    }

    /// One background refinement pass: replay the accumulated localized
    /// windows with `cfg.refine_samples` SGD steps per pending row,
    /// then republish. Returns the steps run (0 = nothing pending).
    /// Only points inserted live move; the checkpointed base stays
    /// frozen, so `/embed` semantics and landmark stability hold.
    pub fn refine_pass(&self) -> u64 {
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        if w.pending_edges.is_empty() || w.pending_rows == 0 {
            return 0;
        }
        let edges = std::mem::take(&mut w.pending_edges);
        let rows = std::mem::take(&mut w.pending_rows);
        let samples = (self.cfg.refine_samples * rows) as u64;
        if samples == 0 {
            return 0;
        }
        let seed = self.vis.seed ^ (0xbeef + self.epoch_hint()).wrapping_mul(0x9E3779B97F4A7C15);
        let base_n = self.base_n;
        w.inc.localized_sgd(&edges, base_n, samples, seed);
        // The refined points moved: re-fit the writer's spatial index
        // before publishing. This is a bulk O(N) re-bucketing, but it
        // runs in the background thread (never on the request path)
        // and one pass coalesces every batch inserted since the last
        // one — the per-insert grid path stays the O(1) overflow
        // append. (A base-grid + live-overlay split would make this
        // O(inserted); not worth the two-index complexity yet.)
        w.grid = GridIndex::build(&w.inc.layout, self.cfg.grid.max(1));
        self.publish(&w);
        self.count("refine.passes", 1.0);
        self.count("refine.samples", samples as f64);
        samples
    }

    /// Wake the refinement worker (new windows are pending).
    fn ring_refine_bell(&self) {
        self.refine_bell.ring();
    }

    /// Wake the refinement worker so it can observe `stop` (shutdown).
    pub fn wake_refiner(&self) {
        self.refine_bell.knock();
    }

    /// The background refinement loop: wait for the doorbell (or the
    /// periodic interval), run one pass, repeat until `stop`. Runs the
    /// SGD between requests — writers queue behind the writer mutex
    /// for the duration of a pass, readers never wait.
    pub fn refine_loop(&self, stop: &AtomicBool) {
        let interval = Duration::from_millis(self.cfg.refine_interval_ms.max(10));
        loop {
            // ordering: Relaxed — `stop` is a pure termination flag;
            // the doorbell provides the wakeup handoff, and no memory
            // rides on the flag itself.
            self.refine_bell.wait_or(interval, || stop.load(Ordering::Relaxed));
            // ordering: Relaxed — see above.
            if stop.load(Ordering::Relaxed) {
                return;
            }
            self.refine_pass();
        }
    }

    /// Effective neighbor count for `/embed`: the configured override,
    /// or the checkpointed graph's `k`, clamped to the snapshot's size.
    pub fn embed_k(&self, snap: &Snapshot) -> usize {
        let k = if self.cfg.embed_k == 0 { snap.knn.k } else { self.cfg.embed_k };
        k.max(1).min(snap.data.n())
    }

    /// Bump a metrics counter (lock-poisoning tolerant: a panicking
    /// worker must not take the metrics endpoint down with it).
    pub fn count(&self, name: &str, delta: f64) {
        let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        m.add(name, delta);
    }

    /// Snapshot the metrics registry as a JSON object string.
    pub fn metrics_json(&self) -> String {
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::KnnGraph;

    #[test]
    fn missing_directory_fails_with_context() {
        let cfg = ServeConfig {
            checkpoints: std::path::PathBuf::from("/nonexistent/checkpoints"),
            ..Default::default()
        };
        let err = format!("{:#}", ServerState::load(cfg).unwrap_err());
        assert!(err.contains("data.lvec"), "{err}");
        assert!(err.contains("full pipeline run"), "{err}");
    }

    #[test]
    fn stale_shapes_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let paths = CheckpointPaths::in_dir(&dir);
        let data = Matrix::from_vec(vec![0.0; 5 * 3], 5, 3);
        let layout = Matrix::from_vec(vec![0.0; 4 * 2], 4, 2); // wrong n
        binary::write_binary(&paths.data, &data).unwrap();
        binary::write_binary(&paths.layout, &layout).unwrap();
        checkpoint::write_knn(&paths.knn, &KnnGraph::empty(5, 2)).unwrap();
        let cfg = ServeConfig { checkpoints: dir.clone(), ..Default::default() };
        let err = format!("{:#}", ServerState::load(cfg).unwrap_err());
        assert!(err.contains("stale checkpoint directory"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Write a minimal valid checkpoint directory: n points on a line,
    /// each with one KNN neighbor.
    fn fabricate_checkpoints(dir: &std::path::Path, n: usize) {
        std::fs::create_dir_all(dir).unwrap();
        let paths = CheckpointPaths::in_dir(dir);
        let d = 3;
        let data: Vec<f32> = (0..n * d).map(|i| i as f32 * 0.25).collect();
        let layout: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.5).collect();
        binary::write_binary(&paths.data, &Matrix::from_vec(data, n, d)).unwrap();
        binary::write_binary(&paths.layout, &Matrix::from_vec(layout, n, 2)).unwrap();
        let mut knn = KnnGraph::empty(n, 1);
        for i in 0..n {
            knn.neighbors[i] = vec![(((i + 1) % n) as u32, 1.0)];
        }
        checkpoint::write_knn(&paths.knn, &knn).unwrap();
        std::fs::write(&paths.meta, "fabricated").unwrap();
    }

    #[test]
    fn open_is_not_ready_until_recover() {
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_ready_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        fabricate_checkpoints(&dir, 6);
        let cfg = ServeConfig { checkpoints: dir.clone(), ..Default::default() };
        let st = ServerState::open(cfg).unwrap();
        assert!(!st.is_ready());
        let pts = Matrix::from_vec(vec![0.5, 0.5, 0.5], 1, 3);
        let err = format!("{:#}", st.insert(&pts).unwrap_err());
        assert!(err.contains("replaying"), "{err}");
        st.recover().unwrap();
        assert!(st.is_ready());
        st.recover().unwrap(); // idempotent
        let (ids, epoch) = st.insert(&pts).unwrap();
        assert_eq!(ids, vec![6]);
        assert_eq!(epoch, 1);
        // The insert hit the WAL durably; a fresh load replays it.
        let cfg = ServeConfig { checkpoints: dir.clone(), ..Default::default() };
        let st2 = ServerState::load(cfg).unwrap();
        let snap = st2.snapshot();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.data.n(), 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn query_knn_dispatch_and_fallback_counter() {
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_qknn_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        fabricate_checkpoints(&dir, 6);
        // Overwrite the KNN checkpoint with an edgeless graph: with
        // only 2 entry seeds the walk can reach 2 of the 6 points, so
        // a k=3 query cannot be satisfied from the graph — it must
        // fall back to the exact oracle and count the fallback.
        let paths = CheckpointPaths::in_dir(&dir);
        checkpoint::write_knn(&paths.knn, &KnnGraph::empty(6, 1)).unwrap();
        let cfg = ServeConfig { checkpoints: dir.clone(), search_seeds: 2, ..Default::default() };
        assert_eq!(cfg.search, SearchMode::Graph, "graph search must be the default");
        let st = ServerState::load(cfg).unwrap();
        let snap = st.snapshot();
        let q = vec![0.3f32, 0.6, 0.9];
        let got = st.query_knn(&snap, &q, 3);
        let mut dists = Vec::new();
        let mut heap = BoundedMaxHeap::new(3);
        let want = crate::kernels::nearest_k(&q, &snap.data, 3, &mut dists, &mut heap);
        assert_eq!(got, want, "fallback must reproduce the exact oracle");
        {
            let m = st.metrics.lock().unwrap();
            assert_eq!(m.get("serve.search_queries"), Some(1.0));
            assert_eq!(m.get("serve.search_fallbacks"), Some(1.0));
            assert!(m.get("serve.search_visited").unwrap() >= 2.0);
        }
        drop(snap);
        drop(st);
        // Exact mode: same answer, no search counters.
        let cfg = ServeConfig {
            checkpoints: dir.clone(),
            search: SearchMode::Exact,
            ..Default::default()
        };
        let st = ServerState::load(cfg).unwrap();
        let snap = st.snapshot();
        assert_eq!(st.query_knn(&snap, &q, 3), want);
        {
            let m = st.metrics.lock().unwrap();
            assert_eq!(m.get("serve.search_queries"), Some(0.0));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn admission_counter_tracks() {
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_admit_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        fabricate_checkpoints(&dir, 4);
        let cfg = ServeConfig { checkpoints: dir.clone(), ..Default::default() };
        let st = ServerState::load(cfg).unwrap();
        assert_eq!(st.inflight(), 0);
        st.admit_one();
        st.admit_one();
        assert_eq!(st.inflight(), 2);
        st.release_one();
        assert_eq!(st.inflight(), 1);
        st.release_one();
        assert_eq!(st.inflight(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
