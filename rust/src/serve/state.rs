//! Server state: epoch-versioned snapshots over a live, growing layout.
//!
//! The read path is built around one rule: **a request sees exactly one
//! epoch**. All heavy artifacts (points, KNN graph, layout, spatial
//! index, labels) live inside an immutable [`Snapshot`] shared behind
//! an `Arc`; handlers take `&Snapshot` and can never observe a torn
//! mix of epochs. Writers (`POST /insert`, the background refinement
//! worker) mutate a private `Writer` double-buffer under a mutex,
//! then build a fresh `Arc<Snapshot>` and atomically publish it. The
//! paper's asynchronous-SGD tolerance for slightly-stale reads is what
//! makes this safe: a reader finishing on epoch `e` while `e+1` is
//! published simply rendered a consistent, marginally older layout.
//!
//! Readers are lock-free in the steady state: each connection worker
//! caches its `Arc<Snapshot>` and revalidates it against one
//! `AtomicU64` epoch counter per request ([`ServerState::snapshot_if_stale`]);
//! only an actual epoch change takes the (pointer-clone-only) snapshot
//! mutex. The only other lock on the read path is the metrics counter
//! mutex, as before.
//!
//! Durability: accepted inserts are appended to `inserts.wal` in the
//! checkpoint directory (see [`crate::data::formats::wal`]) *before*
//! being applied, and replayed on startup — a restarted server
//! recovers every acknowledged point bit-identically.

use crate::config::ServeConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::CheckpointPaths;
use crate::data::formats::wal::WalWriter;
use crate::data::formats::{binary, checkpoint};
use crate::data::io::read_labels;
use crate::data::matrix::Matrix;
use crate::graph::weights::WeightConfig;
use crate::knn::KnnGraph;
use crate::render::grid::GridIndex;
use crate::vis::incremental::IncrementalLayout;
use crate::vis::LargeVisConfig;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// One immutable epoch of the served artifacts. Everything a handler
/// reads for a single response comes from one `Snapshot`, so every
/// response is internally consistent even while inserts land.
pub struct Snapshot {
    /// Epoch counter: 0 for the freshly loaded checkpoints, +1 per
    /// publish (insert batch or refinement pass).
    pub epoch: u64,
    /// High-dimensional points (base + live inserts).
    pub data: Matrix,
    /// KNN graph over `data` (live inserts spliced in).
    pub knn: KnnGraph,
    /// Low-dimensional layout aligned with `data`.
    pub layout: Matrix,
    /// Class labels; live inserts carry the pseudo-class `n_classes`.
    pub labels: Option<Vec<u32>>,
    /// Number of distinct classes in the *base* labels (0 = unlabeled).
    pub n_classes: usize,
    /// Spatial index over `layout` for `/viewport`.
    pub grid: GridIndex,
    /// Points loaded from the checkpoints (frozen base); ids at or
    /// above this were inserted live.
    pub base_n: usize,
}

/// The single-writer mutable state behind the snapshots.
struct Writer {
    /// The growing dataset/graph/layout (its matrices are cloned into
    /// each published [`Snapshot`]).
    inc: IncrementalLayout,
    /// Incrementally maintained spatial index (overflow + threshold
    /// rebuild; cloned into each snapshot).
    grid: GridIndex,
    /// Labels aligned with `inc.data` (base labels + pseudo-class).
    labels: Option<Vec<u32>>,
    /// Class id assigned to live-inserted points when the base is
    /// labeled: the first id past the base classes (palette lookups
    /// are modulo, so any value is render-safe).
    pseudo_class: u32,
    /// Durable insert log; `None` when the server is read-only.
    wal: Option<WalWriter>,
    /// Localized-edge windows of batches not yet refined.
    pending_edges: Vec<(u32, u32, f64)>,
    /// Rows covered by `pending_edges`.
    pending_rows: usize,
}

/// Shared state of a running server: configuration, the epoch-swapped
/// snapshot cell, the writer double-buffer, and metrics.
pub struct ServerState {
    /// Server configuration the state was loaded under.
    pub cfg: ServeConfig,
    /// Dataset name recorded by the run that wrote the checkpoints.
    pub dataset: String,
    /// Directed edge count of the symmetrized graph checkpoint
    /// (`graph.ckpt`), 0 when absent. The CSR itself is validated at
    /// load and then dropped — no handler walks its edges, and at
    /// million-point scale keeping it resident would roughly double
    /// the server's memory for nothing.
    pub graph_edges: usize,
    /// Points loaded from the checkpoints (the frozen base).
    pub base_n: usize,
    /// Distinct classes in the base labels (0 when unlabeled).
    pub n_classes: usize,
    /// Gradient/hyper-parameters for `/embed` and the insert path's
    /// localized SGD.
    pub vis: LargeVisConfig,
    /// Request counters, served verbatim by `/metrics`.
    pub metrics: Mutex<Metrics>,
    /// Current epoch, readable without any lock. Published *after* the
    /// snapshot cell is updated, so a reader that sees epoch `e` here
    /// finds a snapshot of epoch `>= e` in the cell.
    epoch: AtomicU64,
    /// The current snapshot. The mutex is held only for `Arc` clones
    /// and swaps — never while building a snapshot.
    snap: Mutex<Arc<Snapshot>>,
    /// Writer double-buffer (insert handlers + refinement worker).
    writer: Mutex<Writer>,
    /// Refinement worker doorbell: `true` when un-refined insert
    /// windows are pending.
    refine_bell: (Mutex<bool>, Condvar),
}

impl ServerState {
    /// Load every artifact from `cfg.checkpoints`, cross-validate
    /// shapes (a stale or mixed checkpoint directory fails at startup
    /// instead of serving garbage), replay the live-insert WAL, and
    /// publish epoch `N` (one epoch per recovered WAL batch).
    pub fn load(cfg: ServeConfig) -> Result<ServerState> {
        let paths = CheckpointPaths::in_dir(&cfg.checkpoints);
        let data = binary::read_binary(&paths.data).with_context(|| {
            format!(
                "{}: serving needs the raw-points checkpoint (written by a \
                 full pipeline run with checkpoints enabled)",
                paths.data.display()
            )
        })?;
        let layout = binary::read_binary(&paths.layout).with_context(|| {
            format!(
                "{}: serving needs the final-layout checkpoint (written by a \
                 pipeline run with checkpoints enabled)",
                paths.layout.display()
            )
        })?;
        let knn = checkpoint::read_knn(&paths.knn)
            .with_context(|| format!("{}: serving needs the KNN checkpoint", paths.knn.display()))?;
        let graph = if paths.graph.exists() {
            Some(
                checkpoint::read_csr(&paths.graph)
                    .with_context(|| format!("read {}", paths.graph.display()))?,
            )
        } else {
            None
        };

        let n = data.n();
        if n == 0 {
            bail!("{}: empty dataset cannot be served", paths.data.display());
        }
        if layout.n() != n || knn.n() != n {
            bail!(
                "stale checkpoint directory {}: {} points, layout of {}, knn of {}",
                paths.dir.display(),
                n,
                layout.n(),
                knn.n()
            );
        }
        if layout.d() < 2 {
            bail!("{}: layout must have >= 2 dims, has {}", paths.layout.display(), layout.d());
        }
        let graph_edges = match &graph {
            Some(g) => {
                if g.n() != n {
                    bail!(
                        "stale checkpoint directory {}: graph of {} vertices for {} points",
                        paths.dir.display(),
                        g.n(),
                        n
                    );
                }
                g.n_directed_edges()
            }
            None => 0,
        };
        drop(graph);
        let labels = if paths.labels.exists() {
            let ls = read_labels(&paths.labels)?;
            if ls.len() != n {
                bail!(
                    "{}: {} labels for {} points — stale checkpoint directory?",
                    paths.labels.display(),
                    ls.len(),
                    n
                );
            }
            Some(ls)
        } else {
            None
        };
        let n_classes = labels
            .as_ref()
            .map(|ls| ls.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
            .unwrap_or(0);
        let dataset = std::fs::read_to_string(&paths.meta)
            .map(|s| s.trim().to_string())
            .unwrap_or_else(|_| "unknown".to_string());

        let grid = GridIndex::build(&layout, cfg.grid.max(1));
        // Gradient family/hyper-parameters for the localized SGD of
        // `/embed` and `/insert` (paper defaults; the layout itself
        // fixes the output dim).
        let vis = LargeVisConfig { dim: layout.d(), threads: 1, ..Default::default() };

        let mut metrics = Metrics::new();
        metrics.set("serve.points", n as f64);
        metrics.set("serve.graph_edges", graph_edges as f64);

        // The writer wraps the loaded base; insert batches grow it.
        // Re-weighting of spliced rows uses the default perplexity
        // (calibrate_row clamps the target to each row's support, so
        // this is well-defined for any checkpointed k).
        let mut inc =
            IncrementalLayout::new(data, knn, layout, WeightConfig::default(), vis.clone());
        inc.samples_per_insert = cfg.insert_samples;
        let mut writer = Writer {
            inc,
            grid,
            labels,
            pseudo_class: n_classes as u32,
            wal: None,
            pending_edges: Vec::new(),
            pending_rows: 0,
        };

        // Recover acknowledged inserts, then (in live mode) keep the
        // log open for appending. Replay goes through the exact same
        // `add_points` path live inserts take, so the recovered
        // data/KNN state is bit-identical to the pre-restart one.
        let contents = if cfg.read_only {
            crate::data::formats::wal::read_wal(&paths.wal, writer.inc.data.d())?
        } else {
            let (wal, contents) = WalWriter::open(&paths.wal, writer.inc.data.d())
                .with_context(|| format!("open insert WAL {}", paths.wal.display()))?;
            writer.wal = Some(wal);
            contents
        };
        let mut recovered_rows = 0usize;
        for b in &contents.batches {
            Self::apply_batch(&mut writer, b);
            recovered_rows += b.n();
        }
        let recovered_batches = contents.batches.len() as u64;
        if contents.torn_tail {
            eprintln!(
                "[serve] {}: torn WAL tail dropped ({recovered_batches} complete batches \
                 recovered)",
                paths.wal.display(),
            );
        }
        // Recovered rows count as already-refined (their localized
        // passes ran during replay; the background worker starts clean).
        writer.pending_edges.clear();
        writer.pending_rows = 0;
        metrics.set("serve.wal_batches", recovered_batches as f64);
        metrics.set("serve.inserted", recovered_rows as f64);

        let epoch0 = recovered_batches;
        let snapshot = Arc::new(Self::snapshot_of(&writer, epoch0, n, n_classes));
        Ok(ServerState {
            cfg,
            dataset,
            graph_edges,
            base_n: n,
            n_classes,
            vis,
            metrics: Mutex::new(metrics),
            epoch: AtomicU64::new(epoch0),
            snap: Mutex::new(snapshot),
            writer: Mutex::new(writer),
            refine_bell: (Mutex::new(false), Condvar::new()),
        })
    }

    /// Apply one insert batch to the writer state (shared by live
    /// inserts and WAL replay): grow the layout through the localized
    /// insert path, extend the spatial index incrementally, extend
    /// labels with the live pseudo-class, record the refinement window.
    fn apply_batch(w: &mut Writer, pts: &Matrix) -> Vec<usize> {
        let ids = w.inc.add_points(pts);
        for &id in &ids {
            let r = w.inc.layout.row(id);
            w.grid.insert(id as u32, r[0], r[1]);
        }
        if let Some(ls) = &mut w.labels {
            // All live inserts share one stable pseudo-class so they
            // stay distinguishable in `/viewport` tiles.
            let fill = w.pseudo_class;
            ls.resize(ls.len() + ids.len(), fill);
        }
        w.pending_edges.extend_from_slice(&w.inc.last_edges);
        w.pending_rows += ids.len();
        ids
    }

    /// Build a snapshot of the writer's current state (clones the
    /// heavy artifacts; the caller publishes the result).
    ///
    /// Cost note: a publish is an O(N) flat memcpy of the matrices,
    /// KNN lists and grid — that is the deliberate price of the
    /// epoch-swap design (readers get torn-proof immutable snapshots
    /// with zero locking). The *algorithmic* per-insert work — KNN
    /// splice, reweighting, placement SGD — is bounded by the batch's
    /// neighborhood ([`crate::vis::incremental::LocalizedStats`]);
    /// the memcpy amortizes over `/insert_batch` rows and is the first
    /// thing to replace (chunked/persistent structures) if insert
    /// throughput at very large N becomes the bottleneck.
    fn snapshot_of(w: &Writer, epoch: u64, base_n: usize, n_classes: usize) -> Snapshot {
        Snapshot {
            epoch,
            data: w.inc.data.clone(),
            knn: w.inc.knn.clone(),
            layout: w.inc.layout.clone(),
            labels: w.labels.clone(),
            n_classes,
            grid: w.grid.clone(),
            base_n,
        }
    }

    /// The current snapshot (one brief mutex for the `Arc` clone).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.snap.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Lock-free epoch hint. A connection worker holding a cached
    /// snapshot compares its `epoch` against this and re-fetches only
    /// on mismatch — the steady-state read path touches no mutex.
    pub fn epoch_hint(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Refresh `cached` if the epoch moved; returns a snapshot no
    /// older than the hint read at call time.
    pub fn snapshot_if_stale(&self, cached: &mut Arc<Snapshot>) {
        if cached.epoch != self.epoch_hint() {
            *cached = self.snapshot();
        }
    }

    /// Publish the writer's state as the next epoch. Called with the
    /// writer lock held; the snapshot mutex is taken only for the swap.
    fn publish(&self, w: &Writer) -> u64 {
        let epoch = self.epoch_hint() + 1;
        let snapshot = Arc::new(Self::snapshot_of(w, epoch, self.base_n, self.n_classes));
        *self.snap.lock().unwrap_or_else(|e| e.into_inner()) = snapshot;
        // Readers that load this hint are guaranteed to find an
        // epoch >= it in the cell (Release pairs with the Acquire
        // in `epoch_hint`).
        self.epoch.store(epoch, Ordering::Release);
        epoch
    }

    /// Insert a batch of points: WAL first, then the localized insert
    /// path, then an atomic snapshot swap. Returns the assigned ids and
    /// the epoch that contains them. Serialized with other writers by
    /// the writer mutex; readers are never blocked.
    pub fn insert(&self, pts: &Matrix) -> Result<(Vec<usize>, u64)> {
        if self.cfg.read_only {
            bail!("server is read-only (--read-only)");
        }
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(wal) = &mut w.wal {
            wal.append(pts).context("append insert WAL")?;
        }
        let ids = Self::apply_batch(&mut w, pts);
        let epoch = self.publish(&w);
        drop(w);
        self.ring_refine_bell();
        Ok((ids, epoch))
    }

    /// One background refinement pass: replay the accumulated localized
    /// windows with `cfg.refine_samples` SGD steps per pending row,
    /// then republish. Returns the steps run (0 = nothing pending).
    /// Only points inserted live move; the checkpointed base stays
    /// frozen, so `/embed` semantics and landmark stability hold.
    pub fn refine_pass(&self) -> u64 {
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        if w.pending_edges.is_empty() || w.pending_rows == 0 {
            return 0;
        }
        let edges = std::mem::take(&mut w.pending_edges);
        let rows = std::mem::take(&mut w.pending_rows);
        let samples = (self.cfg.refine_samples * rows) as u64;
        if samples == 0 {
            return 0;
        }
        let seed = self.vis.seed ^ (0xbeef + self.epoch_hint()).wrapping_mul(0x9E3779B97F4A7C15);
        let base_n = self.base_n;
        w.inc.localized_sgd(&edges, base_n, samples, seed);
        // The refined points moved: re-fit the writer's spatial index
        // before publishing. This is a bulk O(N) re-bucketing, but it
        // runs in the background thread (never on the request path)
        // and one pass coalesces every batch inserted since the last
        // one — the per-insert grid path stays the O(1) overflow
        // append. (A base-grid + live-overlay split would make this
        // O(inserted); not worth the two-index complexity yet.)
        w.grid = GridIndex::build(&w.inc.layout, self.cfg.grid.max(1));
        self.publish(&w);
        self.count("refine.passes", 1.0);
        self.count("refine.samples", samples as f64);
        samples
    }

    /// Wake the refinement worker (new windows are pending).
    fn ring_refine_bell(&self) {
        let (lock, cvar) = &self.refine_bell;
        *lock.lock().unwrap_or_else(|e| e.into_inner()) = true;
        cvar.notify_all();
    }

    /// Wake the refinement worker so it can observe `stop` (shutdown).
    pub fn wake_refiner(&self) {
        let (lock, cvar) = &self.refine_bell;
        let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
        cvar.notify_all();
    }

    /// The background refinement loop: wait for the doorbell (or the
    /// periodic interval), run one pass, repeat until `stop`. Runs the
    /// SGD between requests — writers queue behind the writer mutex
    /// for the duration of a pass, readers never wait.
    pub fn refine_loop(&self, stop: &AtomicBool) {
        let interval = Duration::from_millis(self.cfg.refine_interval_ms.max(10));
        let (lock, cvar) = &self.refine_bell;
        loop {
            {
                let mut bell = lock.lock().unwrap_or_else(|e| e.into_inner());
                while !*bell && !stop.load(Ordering::SeqCst) {
                    let (guard, timeout) = cvar
                        .wait_timeout(bell, interval)
                        .unwrap_or_else(|e| e.into_inner());
                    bell = guard;
                    if timeout.timed_out() {
                        break;
                    }
                }
                *bell = false;
            }
            if stop.load(Ordering::SeqCst) {
                return;
            }
            self.refine_pass();
        }
    }

    /// Effective neighbor count for `/embed`: the configured override,
    /// or the checkpointed graph's `k`, clamped to the snapshot's size.
    pub fn embed_k(&self, snap: &Snapshot) -> usize {
        let k = if self.cfg.embed_k == 0 { snap.knn.k } else { self.cfg.embed_k };
        k.max(1).min(snap.data.n())
    }

    /// Bump a metrics counter (lock-poisoning tolerant: a panicking
    /// worker must not take the metrics endpoint down with it).
    pub fn count(&self, name: &str, delta: f64) {
        let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        m.add(name, delta);
    }

    /// Snapshot the metrics registry as a JSON object string.
    pub fn metrics_json(&self) -> String {
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_directory_fails_with_context() {
        let cfg = ServeConfig {
            checkpoints: std::path::PathBuf::from("/nonexistent/checkpoints"),
            ..Default::default()
        };
        let err = format!("{:#}", ServerState::load(cfg).unwrap_err());
        assert!(err.contains("data.lvec"), "{err}");
        assert!(err.contains("full pipeline run"), "{err}");
    }

    #[test]
    fn stale_shapes_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let paths = CheckpointPaths::in_dir(&dir);
        let data = Matrix::from_vec(vec![0.0; 5 * 3], 5, 3);
        let layout = Matrix::from_vec(vec![0.0; 4 * 2], 4, 2); // wrong n
        binary::write_binary(&paths.data, &data).unwrap();
        binary::write_binary(&paths.layout, &layout).unwrap();
        checkpoint::write_knn(&paths.knn, &KnnGraph::empty(5, 2)).unwrap();
        let cfg = ServeConfig { checkpoints: dir.clone(), ..Default::default() };
        let err = format!("{:#}", ServerState::load(cfg).unwrap_err());
        assert!(err.contains("stale checkpoint directory"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
