//! Endpoint handlers and request routing for the query server.
//!
//! Every handler is a pure function of `(&Request, &ServerState)` —
//! the base artifacts are never mutated, so handlers run concurrently
//! without locks (metrics counters aside). Endpoints:
//!
//! | method | path        | body / params                     | returns |
//! |--------|-------------|-----------------------------------|---------|
//! | POST   | `/embed`    | `{"points": [[f; d]; n], "k"?, "samples"?}` | projected positions + base neighbors (JSON) |
//! | POST   | `/knn`      | `{"point": [f; d], "k"?}`         | nearest base ids + squared distances (JSON) |
//! | GET    | `/viewport` | `x0,y0,x1,y1` (`size` optional)   | SVG tile of the layout region |
//! | GET    | `/healthz`  | —                                 | dataset/shape summary (JSON) |
//! | GET    | `/metrics`  | —                                 | request counters (JSON) |
//!
//! Malformed input yields `400` with a JSON `{"error": ...}` body;
//! unknown paths `404`; wrong methods on known paths `405`.

use crate::render::{viewport_svg, ScatterStyle};
use crate::serve::http::{Request, Response};
use crate::serve::state::ServerState;
use crate::util::heap::BoundedMaxHeap;
use crate::util::json::Json;
use crate::vis::incremental;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Cap on points per `/embed` request (keeps one request's work and
/// response bounded; batch more via multiple requests).
pub const MAX_EMBED_POINTS: usize = 4096;
/// Cap on per-point SGD steps a request may ask for.
pub const MAX_EMBED_SAMPLES: usize = 100_000;

/// Dispatch a request to its handler, maintaining the counters.
pub fn route(req: &Request, st: &ServerState) -> Response {
    st.count("serve.requests", 1.0);
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/embed") => embed(req, st),
        ("POST", "/knn") => knn(req, st),
        ("GET", "/viewport") => viewport(req, st),
        ("GET", "/healthz") => healthz(st),
        ("GET", "/metrics") => Response::json(st.metrics_json()),
        ("GET", "/") => index(),
        (_, "/embed" | "/knn") => Response::error(405, "use POST"),
        (_, "/viewport" | "/healthz" | "/metrics" | "/") => Response::error(405, "use GET"),
        _ => Response::error(404, "no such endpoint (GET / lists them)"),
    };
    if resp.status >= 400 {
        st.count("serve.errors", 1.0);
    }
    resp
}

/// `GET /` — endpoint listing.
fn index() -> Response {
    Response::json(
        "{\"endpoints\":[\"POST /embed\",\"POST /knn\",\"GET /viewport\",\
         \"GET /healthz\",\"GET /metrics\"]}"
            .to_string(),
    )
}

/// `GET /healthz` — dataset and artifact summary.
fn healthz(st: &ServerState) -> Response {
    let mut o = BTreeMap::new();
    o.insert("status".to_string(), Json::Str("ok".to_string()));
    o.insert("dataset".to_string(), Json::Str(st.dataset.clone()));
    o.insert("points".to_string(), Json::Num(st.data.n() as f64));
    o.insert("data_dim".to_string(), Json::Num(st.data.d() as f64));
    o.insert("layout_dim".to_string(), Json::Num(st.layout.d() as f64));
    o.insert("knn_k".to_string(), Json::Num(st.knn.k as f64));
    o.insert("graph_edges".to_string(), Json::Num(st.graph_edges as f64));
    o.insert("labeled".to_string(), Json::Bool(st.labels.is_some()));
    Response::json(Json::Obj(o).to_string_compact())
}

/// `POST /embed` — out-of-sample projection of new high-dim points
/// against the frozen base layout (see [`incremental::project`]).
fn embed(req: &Request, st: &ServerState) -> Response {
    st.count("embed.requests", 1.0);
    let json = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(points) = json.get("points") else {
        return Response::error(400, "missing \"points\"");
    };
    let pts = match points_matrix(points, st.data.d()) {
        Ok(m) => m,
        Err(msg) => return Response::error(400, &msg),
    };
    if pts.n() > MAX_EMBED_POINTS {
        return Response::error(
            400,
            &format!("{} points exceeds the per-request cap of {MAX_EMBED_POINTS}", pts.n()),
        );
    }
    let samples = json
        .get("samples")
        .and_then(|j| j.as_usize())
        .unwrap_or(st.cfg.embed_samples)
        .min(MAX_EMBED_SAMPLES);
    let k = json
        .get("k")
        .and_then(|j| j.as_usize())
        .unwrap_or_else(|| st.embed_k())
        .clamp(1, st.data.n());

    let (pos, neighbors) = incremental::project(&st.data, &st.layout, &st.vis, &pts, k, samples);
    st.count("embed.points", pos.n() as f64);

    let mut body = String::with_capacity(64 + pos.n() * (pos.d() * 16 + k * 8));
    let _ = write!(body, "{{\"n\":{},\"dim\":{},\"positions\":[", pos.n(), pos.d());
    for r in 0..pos.n() {
        if r > 0 {
            body.push(',');
        }
        push_f32_array(&mut body, pos.row(r));
    }
    body.push_str("],\"neighbors\":[");
    for (r, nb) in neighbors.iter().enumerate() {
        if r > 0 {
            body.push(',');
        }
        body.push('[');
        for (i, &(id, _)) in nb.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let _ = write!(body, "{id}");
        }
        body.push(']');
    }
    body.push_str("]}");
    Response::json(body)
}

/// `POST /knn` — exact K nearest base points of one query vector via
/// the batched distance kernel.
fn knn(req: &Request, st: &ServerState) -> Response {
    st.count("knn.requests", 1.0);
    let json = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(point) = json.get("point") else {
        return Response::error(400, "missing \"point\"");
    };
    let q = match f32_array(point, st.data.d()) {
        Ok(v) => v,
        Err(msg) => return Response::error(400, &msg),
    };
    let k = json
        .get("k")
        .and_then(|j| j.as_usize())
        .unwrap_or(10)
        .clamp(1, st.data.n());

    // One batched scan of the contiguous base matrix — the same
    // shared exact-KNN helper the insert/projection paths use.
    let mut dists: Vec<f32> = Vec::new();
    let mut heap = BoundedMaxHeap::new(k);
    let nb = crate::kernels::nearest_k(&q, &st.data, k, &mut dists, &mut heap);

    let mut body = String::with_capacity(32 + nb.len() * 20);
    let _ = write!(body, "{{\"k\":{},\"ids\":[", nb.len());
    for (i, &(id, _)) in nb.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{id}");
    }
    body.push_str("],\"dists\":[");
    for (i, &(_, d)) in nb.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{d}");
    }
    body.push_str("]}");
    Response::json(body)
}

/// `GET /viewport` — SVG tile of the layout region `[x0,x1]×[y0,y1]`,
/// culled through the grid spatial index so the cost is bounded by the
/// tile's own point count.
fn viewport(req: &Request, st: &ServerState) -> Response {
    st.count("viewport.requests", 1.0);
    // Default bounds come from the layout; pad any zero-width axis so
    // the parameterless "full view" request stays valid even for a
    // degenerate (line- or point-collapsed) layout.
    let (mut bx0, mut by0, mut bx1, mut by1) = st.grid.bounds();
    if bx1 <= bx0 {
        bx0 -= 0.5;
        bx1 += 0.5;
    }
    if by1 <= by0 {
        by0 -= 0.5;
        by1 += 0.5;
    }
    let x0 = match param_f32(req, "x0", bx0) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let y0 = match param_f32(req, "y0", by0) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let x1 = match param_f32(req, "x1", bx1) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let y1 = match param_f32(req, "y1", by1) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    if !(x0 < x1 && y0 < y1) {
        return Response::error(400, "viewport needs x0 < x1 and y0 < y1");
    }
    let size = match req.query_param("size") {
        None => 900u32,
        Some(raw) => match raw.parse::<u32>() {
            Ok(v) => v.clamp(64, 4096),
            Err(_) => return Response::error(400, "size: not an integer"),
        },
    };

    let mut pts = Vec::new();
    let examined = st.grid.query(x0, y0, x1, y1, &mut pts);
    st.count("viewport.examined", examined as f64);
    st.count("viewport.points", pts.len() as f64);
    let style = ScatterStyle {
        size,
        max_points: st.cfg.tile_max_points.max(1),
        ..Default::default()
    };
    Response::svg(viewport_svg(&pts, st.labels.as_deref(), st.n_classes, (x0, y0, x1, y1), &style))
}

/// Parse the request body as JSON (400 on empty/non-UTF-8/bad JSON).
fn parse_body(req: &Request) -> Result<Json, Response> {
    let text = req
        .body_str()
        .map_err(|_| Response::error(400, "request body is not UTF-8"))?;
    if text.trim().is_empty() {
        return Err(Response::error(400, "empty request body (expected JSON)"));
    }
    Json::parse(text).map_err(|e| Response::error(400, &format!("bad JSON: {e:#}")))
}

/// A JSON array of `d` finite numbers as `Vec<f32>`.
fn f32_array(j: &Json, d: usize) -> Result<Vec<f32>, String> {
    let Json::Arr(vals) = j else {
        return Err("expected an array of numbers".to_string());
    };
    if vals.len() != d {
        return Err(format!("vector has {} dims, dataset has {d}", vals.len()));
    }
    let mut out = Vec::with_capacity(d);
    for v in vals {
        let Json::Num(x) = v else {
            return Err("expected an array of numbers".to_string());
        };
        // Check finiteness *after* the cast: a value finite in f64
        // (e.g. 1e39) can still overflow to f32 infinity and would
        // otherwise silently poison every distance downstream.
        let x32 = *x as f32;
        if !x32.is_finite() {
            return Err("non-finite value in vector".to_string());
        }
        out.push(x32);
    }
    Ok(out)
}

/// A JSON array of `n` rows, each `d` finite numbers, as a [`Matrix`].
///
/// [`Matrix`]: crate::data::matrix::Matrix
fn points_matrix(j: &Json, d: usize) -> Result<crate::data::matrix::Matrix, String> {
    let Json::Arr(rows) = j else {
        return Err("\"points\" must be an array of arrays".to_string());
    };
    if rows.is_empty() {
        return Err("\"points\" is empty".to_string());
    }
    let mut flat = Vec::with_capacity(rows.len() * d);
    for (i, row) in rows.iter().enumerate() {
        let vals = f32_array(row, d).map_err(|e| format!("points[{i}]: {e}"))?;
        flat.extend_from_slice(&vals);
    }
    Ok(crate::data::matrix::Matrix::from_vec(flat, rows.len(), d))
}

/// Float query parameter with default; 400 on parse failure.
fn param_f32(req: &Request, key: &str, default: f32) -> Result<f32, Response> {
    match req.query_param(key) {
        None => Ok(default),
        Some(raw) => raw
            .parse::<f32>()
            .ok()
            .filter(|v| v.is_finite())
            .ok_or_else(|| Response::error(400, &format!("{key}: not a finite number"))),
    }
}

/// Append `[a,b,...]` to `out`.
fn push_f32_array(out: &mut String, vals: &[f32]) {
    out.push('[');
    for (i, v) in vals.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_vector_helpers() {
        let j = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(f32_array(&j, 3).unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(f32_array(&j, 2).unwrap_err().contains("3 dims"));
        assert!(f32_array(&Json::parse("[1, \"x\"]").unwrap(), 2).is_err());
        // Finite in f64, infinite once cast to f32: rejected.
        assert!(f32_array(&Json::parse("[1e39, 0]").unwrap(), 2)
            .unwrap_err()
            .contains("non-finite"));
        let m = points_matrix(&Json::parse("[[1,2],[3,4],[5,6]]").unwrap(), 2).unwrap();
        assert_eq!((m.n(), m.d()), (3, 2));
        assert_eq!(m.row(2), &[5.0, 6.0]);
        assert!(points_matrix(&Json::parse("[[1,2],[3]]").unwrap(), 2)
            .unwrap_err()
            .contains("points[1]"));
        assert!(points_matrix(&Json::parse("[]").unwrap(), 2).is_err());
    }

    #[test]
    fn f32_array_formatting_roundtrips() {
        let mut s = String::new();
        push_f32_array(&mut s, &[1.5, -0.25, 3.0]);
        assert_eq!(s, "[1.5,-0.25,3]");
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(f32_array(&parsed, 3).unwrap(), vec![1.5, -0.25, 3.0]);
    }
}
