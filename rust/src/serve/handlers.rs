//! Endpoint handlers and request routing for the query server.
//!
//! Every read handler is a pure function of `(&Request, &ServerState,
//! &Snapshot)` — the snapshot is immutable, so read handlers run
//! concurrently without locks (metrics counters aside) and every
//! response is internally consistent with exactly one epoch. Write
//! handlers (`/insert`, `/insert_batch`) go through
//! [`ServerState::insert`], which serializes on the writer mutex and
//! publishes a new epoch without ever blocking readers. Endpoints:
//!
//! | method | path        | body / params                     | returns |
//! |--------|-------------|-----------------------------------|---------|
//! | POST   | `/embed`    | `{"points": [[f; d]; n], "k"?, "samples"?}` | projected positions + base neighbors (JSON) |
//! | POST   | `/knn`      | `{"point": [f; d], "k"?}`         | nearest base ids + squared distances (JSON) |
//! | POST   | `/insert`   | `{"point": [f; d]}`               | assigned id + publishing epoch (JSON) |
//! | POST   | `/insert_batch` | `{"points": [[f; d]; n]}`     | assigned ids + publishing epoch (JSON) |
//! | GET    | `/viewport` | `x0,y0,x1,y1` (`size` optional)   | SVG tile of the layout region |
//! | GET    | `/healthz`  | —                                 | dataset/shape/epoch summary (JSON) |
//! | GET    | `/readyz`   | —                                 | 200 once WAL replay finished; 503 + `Retry-After` before |
//! | GET    | `/metrics`  | —                                 | request counters (JSON) |
//!
//! JSON responses that describe the layout carry `"epoch"` and
//! `"points"` so clients (and the concurrency fuzz test) can check
//! cross-field consistency; `/viewport` appends the same pair as a
//! trailing XML comment.
//!
//! Malformed input yields `400` with a JSON `{"error": ...}` body;
//! unknown paths `404`; wrong methods on known paths `405`; writes to
//! a `--read-only` server `403`.

use crate::config::SearchMode;
use crate::knn::search::{search_nearest, SearchTotals};
use crate::render::{viewport_svg_with, ScatterStyle};
use crate::serve::http::{Request, Response};
use crate::serve::state::{ServerState, Snapshot};
use crate::util::json::Json;
use crate::vis::incremental;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Cap on points per `/embed` request (keeps one request's work and
/// response bounded; batch more via multiple requests).
pub const MAX_EMBED_POINTS: usize = 4096;
/// Cap on per-point SGD steps a request may ask for.
pub const MAX_EMBED_SAMPLES: usize = 100_000;
/// Cap on points per `/insert_batch` request (bounds one writer
/// critical section and one WAL record).
pub const MAX_INSERT_POINTS: usize = 4096;

/// Dispatch a request to its handler, maintaining the counters.
/// `snap` is the epoch the whole request is answered from.
pub fn route(req: &Request, st: &ServerState, snap: &Snapshot) -> Response {
    st.count("serve.requests", 1.0);
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/embed") => embed(req, st, snap),
        ("POST", "/knn") => knn(req, st, snap),
        ("POST", "/insert") => insert(req, st, snap, false),
        ("POST", "/insert_batch") => insert(req, st, snap, true),
        ("GET", "/viewport") => viewport(req, st, snap),
        ("GET", "/healthz") => healthz(st, snap),
        ("GET", "/readyz") => readyz(st),
        ("GET", "/metrics") => Response::json(st.metrics_json()),
        ("GET", "/") => index(),
        ("GET", "/__panic") if st.cfg.debug_panic => {
            panic!("debug_panic: deliberate handler panic")
        }
        (_, "/embed" | "/knn" | "/insert" | "/insert_batch") => Response::error(405, "use POST"),
        (_, "/viewport" | "/healthz" | "/readyz" | "/metrics" | "/") => {
            Response::error(405, "use GET")
        }
        _ => Response::error(404, "no such endpoint (GET / lists them)"),
    };
    if resp.status >= 400 {
        st.count("serve.errors", 1.0);
    }
    resp
}

/// `GET /` — endpoint listing.
fn index() -> Response {
    Response::json(
        "{\"endpoints\":[\"POST /embed\",\"POST /knn\",\"POST /insert\",\
         \"POST /insert_batch\",\"GET /viewport\",\"GET /healthz\",\"GET /readyz\",\
         \"GET /metrics\"]}"
            .to_string(),
    )
}

/// `GET /readyz` — readiness (distinct from `/healthz` liveness): 200
/// once WAL replay finished, `503` + `Retry-After` while it is still
/// running. Load balancers should route traffic on this, not
/// `/healthz`, so a restarting server replays in peace.
fn readyz(st: &ServerState) -> Response {
    if st.is_ready() {
        Response::json("{\"ready\":true}".to_string())
    } else {
        Response::unavailable("not ready: replaying the insert WAL", 1)
    }
}

/// `GET /healthz` — dataset, artifact and epoch summary.
fn healthz(st: &ServerState, snap: &Snapshot) -> Response {
    let mut o = BTreeMap::new();
    o.insert("status".to_string(), Json::Str("ok".to_string()));
    o.insert("dataset".to_string(), Json::Str(st.dataset.clone()));
    o.insert("epoch".to_string(), Json::Num(snap.epoch as f64));
    o.insert("points".to_string(), Json::Num(snap.data.n() as f64));
    o.insert("base_points".to_string(), Json::Num(snap.base_n as f64));
    o.insert(
        "inserted".to_string(),
        Json::Num((snap.data.n() - snap.base_n) as f64),
    );
    o.insert("data_dim".to_string(), Json::Num(snap.data.d() as f64));
    o.insert("layout_dim".to_string(), Json::Num(snap.layout.d() as f64));
    o.insert("knn_k".to_string(), Json::Num(snap.knn.k as f64));
    o.insert("graph_edges".to_string(), Json::Num(st.graph_edges as f64));
    o.insert("labeled".to_string(), Json::Bool(snap.labels.is_some()));
    o.insert("read_only".to_string(), Json::Bool(st.cfg.read_only));
    o.insert("ready".to_string(), Json::Bool(st.is_ready()));
    Response::json(Json::Obj(o).to_string_compact())
}

/// `POST /embed` — out-of-sample projection of new high-dim points
/// against the snapshot's (frozen-for-this-request) layout (see
/// [`incremental::project`]). Unlike `/insert`, nothing is retained.
fn embed(req: &Request, st: &ServerState, snap: &Snapshot) -> Response {
    st.count("embed.requests", 1.0);
    let json = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(points) = json.get("points") else {
        return Response::error(400, "missing \"points\"");
    };
    let pts = match points_matrix(points, snap.data.d()) {
        Ok(m) => m,
        Err(msg) => return Response::error(400, &msg),
    };
    if pts.n() > MAX_EMBED_POINTS {
        return Response::error(
            400,
            &format!("{} points exceeds the per-request cap of {MAX_EMBED_POINTS}", pts.n()),
        );
    }
    let samples = json
        .get("samples")
        .and_then(|j| j.as_usize())
        .unwrap_or(st.cfg.embed_samples)
        .min(MAX_EMBED_SAMPLES);
    let k = json
        .get("k")
        .and_then(|j| j.as_usize())
        .unwrap_or_else(|| st.embed_k(snap))
        .clamp(1, snap.data.n());

    // Base-neighbor lookups follow the configured search mode: the
    // exact scan, or the navigable-graph walk (sub-linear; counted in
    // the `serve.search_*` metrics, falls back to exact per query).
    let (pos, neighbors) = match st.cfg.search {
        SearchMode::Exact => {
            incremental::project(&snap.data, &snap.layout, &st.vis, &pts, k, samples)
        }
        SearchMode::Graph => {
            let mut totals = SearchTotals::default();
            let out = incremental::project_with(
                &snap.data,
                &snap.layout,
                &st.vis,
                &pts,
                k,
                samples,
                |q, kk| {
                    let (nb, stats) = search_nearest(
                        q,
                        &snap.data,
                        &snap.knn,
                        &snap.search,
                        kk,
                        st.cfg.beam_width,
                    );
                    totals.absorb(&stats);
                    nb
                },
            );
            st.record_search_totals(&totals);
            out
        }
    };
    st.count("embed.points", pos.n() as f64);

    let mut body = String::with_capacity(96 + pos.n() * (pos.d() * 16 + k * 8));
    let _ = write!(
        body,
        "{{\"epoch\":{},\"points\":{},\"n\":{},\"dim\":{},\"positions\":[",
        snap.epoch,
        snap.data.n(),
        pos.n(),
        pos.d()
    );
    for r in 0..pos.n() {
        if r > 0 {
            body.push(',');
        }
        push_f32_array(&mut body, pos.row(r));
    }
    body.push_str("],\"neighbors\":[");
    for (r, nb) in neighbors.iter().enumerate() {
        if r > 0 {
            body.push(',');
        }
        body.push('[');
        for (i, &(id, _)) in nb.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let _ = write!(body, "{id}");
        }
        body.push(']');
    }
    body.push_str("]}");
    Response::json(body)
}

/// `POST /knn` — K nearest points of one query vector over the
/// snapshot's full (base + inserted) dataset: the navigable-graph beam
/// walk by default (`--search graph`, automatic exact fallback), or
/// the exact batched scan (`--search exact`). Live-inserted points are
/// reachable through the in-edges the insert path splices.
fn knn(req: &Request, st: &ServerState, snap: &Snapshot) -> Response {
    st.count("knn.requests", 1.0);
    let json = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(point) = json.get("point") else {
        return Response::error(400, "missing \"point\"");
    };
    let q = match f32_array(point, snap.data.d()) {
        Ok(v) => v,
        Err(msg) => return Response::error(400, &msg),
    };
    let k = json
        .get("k")
        .and_then(|j| j.as_usize())
        .unwrap_or(10)
        .clamp(1, snap.data.n());

    let nb = st.query_knn(snap, &q, k);

    let mut body = String::with_capacity(64 + nb.len() * 20);
    let _ = write!(
        body,
        "{{\"epoch\":{},\"points\":{},\"k\":{},\"ids\":[",
        snap.epoch,
        snap.data.n(),
        nb.len()
    );
    for (i, &(id, _)) in nb.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{id}");
    }
    body.push_str("],\"dists\":[");
    for (i, &(_, d)) in nb.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{d}");
    }
    body.push_str("]}");
    Response::json(body)
}

/// `POST /insert` / `POST /insert_batch` — durably append new points
/// to the live layout. The batch form takes `{"points": [[f; d]; n]}`;
/// the single form `{"point": [f; d]}`. The response's `epoch` is the
/// first epoch whose snapshots contain the new ids.
fn insert(req: &Request, st: &ServerState, snap: &Snapshot, batch: bool) -> Response {
    st.count("insert.requests", 1.0);
    if st.cfg.read_only {
        return Response::error(403, "server is read-only (--read-only)");
    }
    if !st.is_ready() {
        return Response::unavailable("not ready: replaying the insert WAL", 1);
    }
    let json = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let pts = if batch {
        let Some(points) = json.get("points") else {
            return Response::error(400, "missing \"points\"");
        };
        match points_matrix(points, snap.data.d()) {
            Ok(m) => m,
            Err(msg) => return Response::error(400, &msg),
        }
    } else {
        let Some(point) = json.get("point") else {
            return Response::error(400, "missing \"point\"");
        };
        match f32_array(point, snap.data.d()) {
            Ok(v) => crate::data::matrix::Matrix::from_vec(v, 1, snap.data.d()),
            Err(msg) => return Response::error(400, &msg),
        }
    };
    if pts.n() > MAX_INSERT_POINTS {
        return Response::error(
            400,
            &format!("{} points exceeds the per-request cap of {MAX_INSERT_POINTS}", pts.n()),
        );
    }
    match st.insert(&pts) {
        Ok((ids, epoch)) => {
            st.count("insert.points", ids.len() as f64);
            let mut body = String::with_capacity(48 + ids.len() * 10);
            let _ = write!(body, "{{\"epoch\":{epoch},\"ids\":[");
            for (i, id) in ids.iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                let _ = write!(body, "{id}");
            }
            let total = ids.last().map(|&l| l + 1).unwrap_or(snap.data.n());
            let _ = write!(body, "],\"points\":{total}}}");
            Response::json(body)
        }
        Err(e) => Response::error(500, &format!("insert failed: {e:#}")),
    }
}

/// `GET /viewport` — SVG tile of the layout region `[x0,x1]×[y0,y1]`,
/// culled through the snapshot's grid index so the cost is bounded by
/// the tile's own point count (plus the bounded insert overflow).
fn viewport(req: &Request, st: &ServerState, snap: &Snapshot) -> Response {
    st.count("viewport.requests", 1.0);
    // Default bounds come from the layout; pad any zero-width axis so
    // the parameterless "full view" request stays valid even for a
    // degenerate (line- or point-collapsed) layout.
    let (mut bx0, mut by0, mut bx1, mut by1) = snap.grid.bounds();
    if bx1 <= bx0 {
        bx0 -= 0.5;
        bx1 += 0.5;
    }
    if by1 <= by0 {
        by0 -= 0.5;
        by1 += 0.5;
    }
    let x0 = match param_f32(req, "x0", bx0) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let y0 = match param_f32(req, "y0", by0) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let x1 = match param_f32(req, "x1", bx1) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let y1 = match param_f32(req, "y1", by1) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    if !(x0 < x1 && y0 < y1) {
        return Response::error(400, "viewport needs x0 < x1 and y0 < y1");
    }
    let size = match req.query_param("size") {
        None => 900u32,
        Some(raw) => match raw.parse::<u32>() {
            Ok(v) => v.clamp(64, 4096),
            Err(_) => return Response::error(400, "size: not an integer"),
        },
    };

    let mut pts = Vec::new();
    let examined = snap.grid.query(x0, y0, x1, y1, &mut pts);
    st.count("viewport.examined", examined as f64);
    st.count("viewport.points", pts.len() as f64);
    let style = ScatterStyle {
        size,
        max_points: st.cfg.tile_max_points.max(1),
        ..Default::default()
    };
    // Live inserts add one pseudo-class past the base classes.
    let palette_classes = if snap.data.n() > snap.base_n && snap.n_classes > 0 {
        snap.n_classes + 1
    } else {
        snap.n_classes
    };
    // Labels are chunked (copy-on-write); color through the per-id
    // lookup closure instead of flattening them per request.
    let mut svg = viewport_svg_with(
        &pts,
        |i| snap.labels.as_ref().map(|ls| ls.get(i)),
        palette_classes,
        (x0, y0, x1, y1),
        &style,
    );
    // Trailing XML comment (valid after the root element) so SVG
    // consumers can also check epoch consistency.
    let _ = writeln!(svg, "<!-- epoch={} points={} -->", snap.epoch, snap.data.n());
    Response::svg(svg)
}

/// Parse the request body as JSON (400 on empty/non-UTF-8/bad JSON).
fn parse_body(req: &Request) -> Result<Json, Response> {
    let text = req
        .body_str()
        .map_err(|_| Response::error(400, "request body is not UTF-8"))?;
    if text.trim().is_empty() {
        return Err(Response::error(400, "empty request body (expected JSON)"));
    }
    Json::parse(text).map_err(|e| Response::error(400, &format!("bad JSON: {e:#}")))
}

/// A JSON array of `d` finite numbers as `Vec<f32>`.
fn f32_array(j: &Json, d: usize) -> Result<Vec<f32>, String> {
    let Json::Arr(vals) = j else {
        return Err("expected an array of numbers".to_string());
    };
    if vals.len() != d {
        return Err(format!("vector has {} dims, dataset has {d}", vals.len()));
    }
    let mut out = Vec::with_capacity(d);
    for v in vals {
        let Json::Num(x) = v else {
            return Err("expected an array of numbers".to_string());
        };
        // Check finiteness *after* the cast: a value finite in f64
        // (e.g. 1e39) can still overflow to f32 infinity and would
        // otherwise silently poison every distance downstream.
        let x32 = *x as f32;
        if !x32.is_finite() {
            return Err("non-finite value in vector".to_string());
        }
        out.push(x32);
    }
    Ok(out)
}

/// A JSON array of `n` rows, each `d` finite numbers, as a [`Matrix`].
///
/// [`Matrix`]: crate::data::matrix::Matrix
fn points_matrix(j: &Json, d: usize) -> Result<crate::data::matrix::Matrix, String> {
    let Json::Arr(rows) = j else {
        return Err("\"points\" must be an array of arrays".to_string());
    };
    if rows.is_empty() {
        return Err("\"points\" is empty".to_string());
    }
    let mut flat = Vec::with_capacity(rows.len() * d);
    for (i, row) in rows.iter().enumerate() {
        let vals = f32_array(row, d).map_err(|e| format!("points[{i}]: {e}"))?;
        flat.extend_from_slice(&vals);
    }
    Ok(crate::data::matrix::Matrix::from_vec(flat, rows.len(), d))
}

/// Float query parameter with default; 400 on parse failure.
fn param_f32(req: &Request, key: &str, default: f32) -> Result<f32, Response> {
    match req.query_param(key) {
        None => Ok(default),
        Some(raw) => raw
            .parse::<f32>()
            .ok()
            .filter(|v| v.is_finite())
            .ok_or_else(|| Response::error(400, &format!("{key}: not a finite number"))),
    }
}

/// Append `[a,b,...]` to `out`.
fn push_f32_array(out: &mut String, vals: &[f32]) {
    out.push('[');
    for (i, v) in vals.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_vector_helpers() {
        let j = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(f32_array(&j, 3).unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(f32_array(&j, 2).unwrap_err().contains("3 dims"));
        assert!(f32_array(&Json::parse("[1, \"x\"]").unwrap(), 2).is_err());
        // Finite in f64, infinite once cast to f32: rejected.
        assert!(f32_array(&Json::parse("[1e39, 0]").unwrap(), 2)
            .unwrap_err()
            .contains("non-finite"));
        let m = points_matrix(&Json::parse("[[1,2],[3,4],[5,6]]").unwrap(), 2).unwrap();
        assert_eq!((m.n(), m.d()), (3, 2));
        assert_eq!(m.row(2), &[5.0, 6.0]);
        assert!(points_matrix(&Json::parse("[[1,2],[3]]").unwrap(), 2)
            .unwrap_err()
            .contains("points[1]"));
        assert!(points_matrix(&Json::parse("[]").unwrap(), 2).is_err());
    }

    #[test]
    fn f32_array_formatting_roundtrips() {
        let mut s = String::new();
        push_f32_array(&mut s, &[1.5, -0.25, 3.0]);
        assert_eq!(s, "[1.5,-0.25,3]");
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(f32_array(&parsed, 3).unwrap(), vec![1.5, -0.25, 3.0]);
    }
}
