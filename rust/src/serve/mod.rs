//! Live layout service (`largevis serve`).
//!
//! The LargeVis premise is that the expensive work — KNN graph
//! construction and layout — happens **once**; serving the result
//! should then be cheap, interactive, and (since PR 5) *mutable*: new
//! points can be inserted while the server answers queries. This
//! module turns a finished pipeline run's checkpoint directory into a
//! long-running HTTP/1.1 service, dependency-free over `std::net` plus
//! the existing [`crate::util::pool`] workers:
//!
//! * `POST /insert`, `POST /insert_batch` — durable live insertion:
//!   the batch is WAL-logged, spliced into the KNN graph, placed by
//!   the localized insert path, and published as a new epoch-versioned
//!   snapshot ([`state::Snapshot`]). A restarted server replays the
//!   WAL and recovers every acknowledged point bit-identically.
//! * `POST /embed` — out-of-sample projection against the current
//!   epoch's layout ([`crate::vis::incremental::project`]); nothing is
//!   retained.
//! * `POST /knn` — exact K nearest points of a query vector, one
//!   [`crate::kernels::sqdist_to_all`] batch scan.
//! * `GET /viewport` — an SVG tile of a layout rectangle, culled by the
//!   [`crate::render::grid::GridIndex`] so tile cost tracks the tile's
//!   content, not the dataset size.
//! * `GET /healthz`, `GET /metrics` — liveness + JSON counters
//!   (reusing [`crate::coordinator::metrics::Metrics`]).
//!
//! Readers are lock-free in the steady state: every worker caches an
//! `Arc` of the current snapshot and revalidates it against an atomic
//! epoch counter per request; writers build the next snapshot off to
//! the side and swap it in atomically. A background refinement worker
//! runs localized SGD over recently-inserted points between requests
//! (see [`ServerState::refine_loop`]).
//!
//! Connections are persistent (HTTP/1.1 keep-alive) with a bounded
//! per-connection request count (`keep_alive_max`) and an idle timeout
//! (`idle_timeout_ms`); a client can opt out per request with
//! `Connection: close`.
//!
//! # Example
//!
//! ```no_run
//! use largevis::config::ServeConfig;
//! use largevis::serve::{Server, ServerState};
//!
//! # fn main() -> anyhow::Result<()> {
//! // After: largevis pipeline --dataset mnist-like --out target/mnist
//! let cfg = ServeConfig {
//!     checkpoints: "target/mnist/checkpoints".into(),
//!     addr: "127.0.0.1:7878".to_string(),
//!     ..Default::default()
//! };
//! let server = Server::bind(ServerState::load(cfg)?)?;
//! println!("listening on http://{}", server.local_addr()?);
//! server.run()?; // blocks; a ServerHandle can stop it from elsewhere
//! # Ok(())
//! # }
//! ```

pub mod handlers;
pub mod http;
pub mod state;

pub use state::{ServerState, Snapshot};

use crate::util::pool;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A bound (but not yet running) query server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    threads: usize,
}

/// A cloneable remote control for a running [`Server`]: signals the
/// accept workers to stop and wakes them up.
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: Option<SocketAddr>,
    threads: usize,
}

impl ServerHandle {
    /// Ask the server to stop. Blocked `accept` calls are woken by
    /// loopback connections; [`Server::run`] returns once every worker
    /// has observed the flag (workers idling inside a keep-alive
    /// connection notice at the next request or at the idle timeout,
    /// whichever comes first).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(mut addr) = self.addr {
            // An unspecified bind address (0.0.0.0) is not connectable;
            // wake via loopback on the same port.
            if addr.ip().is_unspecified() {
                addr = SocketAddr::new(IpAddr::V4(Ipv4Addr::LOCALHOST), addr.port());
            }
            for _ in 0..self.threads {
                let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
            }
        }
    }
}

impl Server {
    /// Bind the listen socket for `state` (per `state.cfg.addr`; port 0
    /// picks an ephemeral port, see [`Server::local_addr`]).
    pub fn bind(state: ServerState) -> Result<Server> {
        let listener = TcpListener::bind(&state.cfg.addr)
            .with_context(|| format!("bind {}", state.cfg.addr))?;
        let threads = if state.cfg.threads == 0 {
            pool::default_threads().min(16)
        } else {
            state.cfg.threads
        };
        Ok(Server {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
            threads: threads.max(1),
        })
    }

    /// The bound socket address (resolves an ephemeral port request).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared handle to the server state (epoch counter, snapshots,
    /// metrics; lets tests take snapshots while the server runs).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// A control handle usable from another thread to stop [`Server::run`].
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            stop: self.stop.clone(),
            addr: self.listener.local_addr().ok(),
            threads: self.threads,
        }
    }

    /// Serve until [`ServerHandle::shutdown`] is called: `threads`
    /// workers share the listener, each handling one connection at a
    /// time (multiple requests per connection — HTTP/1.1 keep-alive,
    /// bounded by `keep_alive_max` and `idle_timeout_ms`). A separate
    /// background thread runs the insert-refinement loop.
    pub fn run(&self) -> Result<()> {
        std::thread::scope(|scope| {
            let refiner = scope.spawn(|| self.state.refine_loop(&self.stop));
            pool::spawn_workers(self.threads, |_worker| {
                // Per-worker snapshot cache: in the steady state a
                // request revalidates it with one atomic load — no
                // locks on the read path.
                let mut cached = self.state.snapshot();
                loop {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match self.listener.accept() {
                        Ok((stream, _peer)) => {
                            if self.stop.load(Ordering::SeqCst) {
                                break;
                            }
                            handle_connection(stream, &self.state, &mut cached, &self.stop);
                        }
                        Err(_) => {
                            if self.stop.load(Ordering::SeqCst) {
                                break;
                            }
                            // Transient accept errors (EMFILE, aborted
                            // handshake): back off briefly instead of
                            // hot-spinning.
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            });
            // Accept workers are done; let the refiner observe `stop`.
            self.state.wake_refiner();
            let _ = refiner.join();
        });
        Ok(())
    }
}

/// Serve one connection: up to `keep_alive_max` requests, each answered
/// from a single consistent snapshot. I/O errors and idle timeouts are
/// swallowed (the peer is gone or silent; nothing to tell it).
fn handle_connection(
    stream: TcpStream,
    state: &ServerState,
    cached: &mut Arc<Snapshot>,
    stop: &AtomicBool,
) {
    let idle = Duration::from_millis(state.cfg.idle_timeout_ms.max(100));
    let _ = stream.set_read_timeout(Some(idle));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(&stream);
    let max_requests = state.cfg.keep_alive_max.max(1);
    for served in 1..=max_requests {
        let req = match http::read_request(&mut reader, &mut writer, state.cfg.max_body_bytes) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF between requests
            Err(e) => {
                // An idle keep-alive connection hitting the socket
                // timeout is a normal close, not a protocol error.
                let msg = format!("{e:#}");
                if !msg.contains(http::IDLE_TIMEOUT) {
                    state.count("serve.errors", 1.0);
                    let status = if msg.contains(http::BODY_TOO_LARGE) { 413 } else { 400 };
                    let _ = http::Response::error(status, &msg).write_to(&mut writer, false);
                }
                return;
            }
        };
        // One snapshot per request: every field of the response comes
        // from the same epoch.
        state.snapshot_if_stale(cached);
        let resp = handlers::route(&req, state, cached);
        let last = served == max_requests || req.wants_close || stop.load(Ordering::SeqCst);
        if resp.write_to(&mut writer, !last).is_err() || last {
            return;
        }
    }
}
