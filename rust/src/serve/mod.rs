//! Checkpoint-backed layout query server (`largevis serve`).
//!
//! The LargeVis premise is that the expensive work — KNN graph
//! construction and layout — happens **once**; serving the result
//! should then be cheap and interactive. This module turns a finished
//! pipeline run's checkpoint directory into a long-running HTTP/1.1
//! service, dependency-free over `std::net` plus the existing
//! [`crate::util::pool`] workers:
//!
//! * `POST /embed` — out-of-sample projection: new high-dimensional
//!   points are placed into the *frozen* base layout via the
//!   incremental-insertion math ([`crate::vis::incremental::project`]),
//!   one batched SIMD scan + a short localized SGD per point. The base
//!   layout is never modified, so concurrent embeds are safe and
//!   repeatable.
//! * `POST /knn` — exact K nearest base points of a query vector, one
//!   [`crate::kernels::sqdist_to_all`] batch scan.
//! * `GET /viewport` — an SVG tile of a layout rectangle, culled by the
//!   [`crate::render::grid::GridIndex`] so tile cost tracks the tile's
//!   content, not the dataset size.
//! * `GET /healthz`, `GET /metrics` — liveness + JSON counters
//!   (reusing [`crate::coordinator::metrics::Metrics`]).
//!
//! Artifacts are loaded once into [`ServerState`] and shared read-only
//! across `N` accept workers behind an `Arc`; the only lock on the
//! request path is the metrics counter mutex.
//!
//! # Example
//!
//! ```no_run
//! use largevis::config::ServeConfig;
//! use largevis::serve::{Server, ServerState};
//!
//! # fn main() -> anyhow::Result<()> {
//! // After: largevis pipeline --dataset mnist-like --out target/mnist
//! let cfg = ServeConfig {
//!     checkpoints: "target/mnist/checkpoints".into(),
//!     addr: "127.0.0.1:7878".to_string(),
//!     ..Default::default()
//! };
//! let server = Server::bind(ServerState::load(cfg)?)?;
//! println!("listening on http://{}", server.local_addr()?);
//! server.run()?; // blocks; a ServerHandle can stop it from elsewhere
//! # Ok(())
//! # }
//! ```

pub mod handlers;
pub mod http;
pub mod state;

pub use state::ServerState;

use crate::util::pool;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Per-connection socket read timeout (a stalled client must not pin a
/// worker forever).
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// A bound (but not yet running) query server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    threads: usize,
}

/// A cloneable remote control for a running [`Server`]: signals the
/// accept workers to stop and wakes them up.
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: Option<SocketAddr>,
    threads: usize,
}

impl ServerHandle {
    /// Ask the server to stop. Blocked `accept` calls are woken by
    /// loopback connections; [`Server::run`] returns once every worker
    /// has observed the flag.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(mut addr) = self.addr {
            // An unspecified bind address (0.0.0.0) is not connectable;
            // wake via loopback on the same port.
            if addr.ip().is_unspecified() {
                addr = SocketAddr::new(IpAddr::V4(Ipv4Addr::LOCALHOST), addr.port());
            }
            for _ in 0..self.threads {
                let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
            }
        }
    }
}

impl Server {
    /// Bind the listen socket for `state` (per `state.cfg.addr`; port 0
    /// picks an ephemeral port, see [`Server::local_addr`]).
    pub fn bind(state: ServerState) -> Result<Server> {
        let listener = TcpListener::bind(&state.cfg.addr)
            .with_context(|| format!("bind {}", state.cfg.addr))?;
        let threads = if state.cfg.threads == 0 {
            pool::default_threads().min(16)
        } else {
            state.cfg.threads
        };
        Ok(Server {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
            threads: threads.max(1),
        })
    }

    /// The bound socket address (resolves an ephemeral port request).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared handle to the loaded artifacts (read-only; lets an
    /// embedding test assert the base layout is untouched while the
    /// server runs).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// A control handle usable from another thread to stop [`Server::run`].
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            stop: self.stop.clone(),
            addr: self.listener.local_addr().ok(),
            threads: self.threads,
        }
    }

    /// Serve until [`ServerHandle::shutdown`] is called: `threads`
    /// workers share the listener, each handling one connection at a
    /// time (one request per connection, `Connection: close`).
    pub fn run(&self) -> Result<()> {
        pool::spawn_workers(self.threads, |_worker| loop {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    handle_connection(stream, &self.state);
                }
                Err(_) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // Transient accept errors (EMFILE, aborted handshake):
                    // back off briefly instead of hot-spinning.
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        });
        Ok(())
    }
}

/// Serve one connection: parse a request, dispatch, write the response.
/// I/O errors are swallowed (the peer is gone; nothing to tell it).
fn handle_connection(stream: TcpStream, state: &ServerState) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(&stream);
    let resp = match http::read_request(&mut reader, &mut writer, state.cfg.max_body_bytes) {
        Ok(Some(req)) => handlers::route(&req, state),
        Ok(None) => return, // clean EOF: client connected and left
        Err(e) => {
            state.count("serve.errors", 1.0);
            let msg = format!("{e:#}");
            let status = if msg.contains(http::BODY_TOO_LARGE) { 413 } else { 400 };
            http::Response::error(status, &msg)
        }
    };
    let _ = resp.write_to(&mut writer);
}
