//! Live layout service (`largevis serve`).
//!
//! The LargeVis premise is that the expensive work — KNN graph
//! construction and layout — happens **once**; serving the result
//! should then be cheap, interactive, and (since PR 5) *mutable*: new
//! points can be inserted while the server answers queries. This
//! module turns a finished pipeline run's checkpoint directory into a
//! long-running HTTP/1.1 service, dependency-free over `std::net` plus
//! the existing [`crate::util::pool`] workers:
//!
//! * `POST /insert`, `POST /insert_batch` — durable live insertion:
//!   the batch is WAL-logged, spliced into the KNN graph, placed by
//!   the localized insert path, and published as a new epoch-versioned
//!   snapshot ([`state::Snapshot`]). A restarted server replays the
//!   WAL and recovers every acknowledged point bit-identically.
//! * `POST /embed` — out-of-sample projection against the current
//!   epoch's layout ([`crate::vis::incremental::project`]); nothing is
//!   retained.
//! * `POST /knn` — K nearest points of a query vector. By default
//!   (`--search graph`) this is the sub-linear navigable-graph beam
//!   walk of [`crate::knn::search`], seeded from coarse-hierarchy
//!   centroids carried by each snapshot and falling back to the exact
//!   scan whenever the walk cannot answer (`serve.search_*` metrics
//!   count visited/scored points and fallbacks); `--search exact`
//!   forces the one-batch [`crate::kernels::sqdist_to_all`] scan. The
//!   same dispatch drives `/embed` and insert base-neighbor lookups
//!   (`--beam-width`, `--search-seeds` tune it).
//! * `GET /viewport` — an SVG tile of a layout rectangle, culled by the
//!   [`crate::render::grid::GridIndex`] so tile cost tracks the tile's
//!   content, not the dataset size.
//! * `GET /healthz`, `GET /readyz`, `GET /metrics` — liveness,
//!   readiness (503 while the insert WAL replays) and JSON counters
//!   (reusing [`crate::coordinator::metrics::Metrics`]).
//!
//! Readers are lock-free in the steady state: every worker caches an
//! `Arc` of the current snapshot and revalidates it against an atomic
//! epoch counter per request; writers build the next snapshot off to
//! the side and swap it in atomically. A background refinement worker
//! runs localized SGD over recently-inserted points between requests
//! (see [`ServerState::refine_loop`]).
//!
//! # Overload and failure containment
//!
//! One acceptor thread owns the listener and hands connections to a
//! fixed worker pool through a queue. Admission is bounded
//! (`max_inflight`, default `2×threads + 8`): connections beyond the
//! bound are *shed* immediately with `503` + `Retry-After` instead of
//! queueing without limit — under saturation the server degrades into
//! fast, explicit refusals rather than unbounded latency. Every
//! connection carries a read timeout (`idle_timeout_ms`) **and** a
//! write timeout (`write_timeout_ms`), so a stalled or absent client
//! cannot pin a worker; each request's handler runs under
//! `catch_unwind`, so a panic costs the client a `500` and the server
//! nothing (counted in `serve.panics`). Shutdown is a graceful drain:
//! the acceptor stops, queued and in-flight connections finish, and
//! the WAL gets a final fsync.
//!
//! Connections are persistent (HTTP/1.1 keep-alive) with a bounded
//! per-connection request count (`keep_alive_max`) and an idle timeout
//! (`idle_timeout_ms`); a client can opt out per request with
//! `Connection: close`.
//!
//! # Example
//!
//! ```no_run
//! use largevis::config::ServeConfig;
//! use largevis::serve::{Server, ServerState};
//!
//! # fn main() -> anyhow::Result<()> {
//! // After: largevis pipeline --dataset mnist-like --out target/mnist
//! let cfg = ServeConfig {
//!     checkpoints: "target/mnist/checkpoints".into(),
//!     addr: "127.0.0.1:7878".to_string(),
//!     ..Default::default()
//! };
//! let server = Server::bind(ServerState::load(cfg)?)?;
//! println!("listening on http://{}", server.local_addr()?);
//! server.run()?; // blocks; a ServerHandle can stop it from elsewhere
//! # Ok(())
//! # }
//! ```

pub mod epoch;
pub mod handlers;
pub mod http;
pub mod state;

pub use state::{ServerState, Snapshot};

use crate::util::pool;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{thread, Arc, Condvar, Mutex};
use std::time::Duration;

/// A bound (but not yet running) query server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    threads: usize,
}

/// A cloneable remote control for a running [`Server`]: signals the
/// acceptor to stop and wakes it up.
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: Option<SocketAddr>,
}

impl ServerHandle {
    /// Ask the server to stop. The (single) blocked `accept` call is
    /// woken by a loopback connection; [`Server::run`] returns after
    /// the drain: queued and in-flight connections finish (workers
    /// idling inside a keep-alive connection notice at the next
    /// request or at the idle timeout), then the WAL is fsynced once
    /// more.
    pub fn shutdown(&self) {
        // ordering: Relaxed — `stop` is a pure termination flag read
        // in loop conditions; the loopback connect below (and the
        // condvar handoffs on the worker side) provide the wakeups,
        // and no memory is published through the flag.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(mut addr) = self.addr {
            // An unspecified bind address (0.0.0.0) is not connectable;
            // wake via loopback on the same port.
            if addr.ip().is_unspecified() {
                addr = SocketAddr::new(IpAddr::V4(Ipv4Addr::LOCALHOST), addr.port());
            }
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
    }
}

/// Hand-off queue between the acceptor and the worker pool. Bounded
/// implicitly by the admission counter — the acceptor never pushes
/// beyond `max_inflight`.
struct Admission {
    q: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
}

impl Server {
    /// Bind the listen socket for `state` (per `state.cfg.addr`; port 0
    /// picks an ephemeral port, see [`Server::local_addr`]).
    pub fn bind(state: ServerState) -> Result<Server> {
        let listener = TcpListener::bind(&state.cfg.addr)
            .with_context(|| format!("bind {}", state.cfg.addr))?;
        let threads = if state.cfg.threads == 0 {
            pool::default_threads().min(16)
        } else {
            state.cfg.threads
        };
        Ok(Server {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
            threads: threads.max(1),
        })
    }

    /// The bound socket address (resolves an ephemeral port request).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared handle to the server state (epoch counter, snapshots,
    /// metrics; lets tests take snapshots while the server runs).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// A control handle usable from another thread to stop [`Server::run`].
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: self.stop.clone(), addr: self.listener.local_addr().ok() }
    }

    /// Admitted-connection bound: the configured value, or
    /// `2×threads + 8` when 0 (every worker busy, a full hand-off
    /// queue, and headroom for keep-alive turnaround).
    fn max_inflight(&self) -> usize {
        if self.state.cfg.max_inflight == 0 {
            self.threads * 2 + 8
        } else {
            self.state.cfg.max_inflight
        }
    }

    /// Serve until [`ServerHandle::shutdown`] is called. The calling
    /// thread becomes the acceptor; `threads` workers drain the
    /// admission queue, each handling one connection at a time
    /// (multiple requests per connection — HTTP/1.1 keep-alive,
    /// bounded by `keep_alive_max` and `idle_timeout_ms`). A separate
    /// background thread runs the insert-refinement loop. Connections
    /// arriving while `max_inflight` are already admitted are shed
    /// with `503` + `Retry-After` (counted in `serve.shed`).
    pub fn run(&self) -> Result<()> {
        let max_inflight = self.max_inflight().max(1);
        let admission = Admission { q: Mutex::new(VecDeque::new()), cv: Condvar::new() };
        thread::scope(|scope| {
            let refiner = scope.spawn(|| self.state.refine_loop(&self.stop));
            let mut workers = Vec::with_capacity(self.threads);
            for _ in 0..self.threads {
                let adm = &admission;
                workers.push(scope.spawn(move || {
                    // Per-worker snapshot cache: in the steady state a
                    // request revalidates it with one atomic load — no
                    // locks on the read path.
                    let mut cached = self.state.snapshot();
                    loop {
                        // Pop before checking `stop`: the drain serves
                        // every connection admitted before shutdown.
                        let stream = {
                            let mut q = adm.q.lock().unwrap_or_else(|e| e.into_inner());
                            loop {
                                if let Some(s) = q.pop_front() {
                                    break Some(s);
                                }
                                // ordering: Relaxed — termination flag
                                // only (see `ServerHandle::shutdown`);
                                // the queue mutex orders the drain.
                                if self.stop.load(Ordering::Relaxed) {
                                    break None;
                                }
                                q = adm.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                            }
                        };
                        let Some(stream) = stream else { return };
                        handle_connection(stream, &self.state, &mut cached, &self.stop);
                        self.state.release_one();
                    }
                }));
            }

            // Acceptor loop (this thread owns the listener).
            loop {
                // ordering: Relaxed — termination flag only (see
                // `ServerHandle::shutdown`).
                if self.stop.load(Ordering::Relaxed) {
                    break;
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        // ordering: Relaxed — as above; the shutdown
                        // wake-up connection lands here.
                        if self.stop.load(Ordering::Relaxed) {
                            break;
                        }
                        if self.state.inflight() >= max_inflight {
                            shed(stream, &self.state);
                            continue;
                        }
                        self.state.admit_one();
                        let mut q = admission.q.lock().unwrap_or_else(|e| e.into_inner());
                        q.push_back(stream);
                        drop(q);
                        admission.cv.notify_one();
                    }
                    Err(_) => {
                        // ordering: Relaxed — as above.
                        if self.stop.load(Ordering::Relaxed) {
                            break;
                        }
                        // Transient accept errors (EMFILE, aborted
                        // handshake): back off briefly instead of
                        // hot-spinning.
                        thread::sleep(Duration::from_millis(10));
                    }
                }
            }

            // Graceful drain: stop accepting, wake every worker (under
            // the queue lock, so a worker between its empty-check and
            // its wait cannot miss the signal), let them finish the
            // admitted connections.
            {
                let _guard = admission.q.lock().unwrap_or_else(|e| e.into_inner());
                admission.cv.notify_all();
            }
            for w in workers {
                let _ = w.join();
            }
            self.state.wake_refiner();
            let _ = refiner.join();
        });
        // Final durability point of the drain (a no-op after clean
        // appends; insurance if the WAL writer was mid-recovery).
        self.state.final_wal_sync();
        Ok(())
    }
}

/// Refuse one connection under overload: `503` + `Retry-After: 1`,
/// then a half-close and a brief read-drain so the client reliably
/// receives the response instead of a connection reset.
fn shed(stream: TcpStream, state: &ServerState) {
    state.count("serve.shed", 1.0);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(1000)));
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    {
        let mut w = BufWriter::new(&stream);
        let _ = http::Response::unavailable("server overloaded; retry shortly", 1)
            .write_to(&mut w, false);
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    // Drain whatever request bytes are in flight; closing with unread
    // data makes many TCP stacks send RST, which can destroy the 503
    // sitting in the client's receive buffer.
    let mut buf = [0u8; 1024];
    let mut r = &stream;
    for _ in 0..8 {
        match r.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(_) => continue,
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Serve one connection: up to `keep_alive_max` requests, each answered
/// from a single consistent snapshot. I/O errors and idle timeouts are
/// swallowed (the peer is gone or silent; nothing to tell it); write
/// timeouts and handler panics are counted.
fn handle_connection(
    stream: TcpStream,
    state: &ServerState,
    cached: &mut Arc<Snapshot>,
    stop: &AtomicBool,
) {
    let idle = Duration::from_millis(state.cfg.idle_timeout_ms.max(100));
    let write_timeout = Duration::from_millis(state.cfg.write_timeout_ms.max(100));
    // A socket option that cannot be set degrades the timeout story
    // for this one connection; count it rather than dropping the
    // error on the floor (or the connection with it).
    if stream.set_read_timeout(Some(idle)).is_err() {
        state.count("serve.sockopt_errors", 1.0);
    }
    if stream.set_write_timeout(Some(write_timeout)).is_err() {
        state.count("serve.sockopt_errors", 1.0);
    }
    if stream.set_nodelay(true).is_err() {
        state.count("serve.sockopt_errors", 1.0);
    }
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(&stream);
    let max_requests = state.cfg.keep_alive_max.max(1);
    for served in 1..=max_requests {
        let req = match http::read_request(&mut reader, &mut writer, state.cfg.max_body_bytes) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF between requests
            Err(e) => {
                // An idle keep-alive connection hitting the socket
                // timeout is a normal close, not a protocol error.
                let msg = format!("{e:#}");
                if !msg.contains(http::IDLE_TIMEOUT) {
                    state.count("serve.errors", 1.0);
                    let status = if msg.contains(http::BODY_TOO_LARGE) { 413 } else { 400 };
                    let _ = http::Response::error(status, &msg).write_to(&mut writer, false);
                }
                return;
            }
        };
        // One snapshot per request: every field of the response comes
        // from the same epoch.
        state.snapshot_if_stale(cached);
        // Contain handler panics to the one request that caused them:
        // the worker, its siblings, and the connection all survive
        // (every shared-state mutex acquisition is poison-tolerant).
        let resp = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handlers::route(&req, state, cached)
        })) {
            Ok(resp) => resp,
            Err(_) => {
                state.count("serve.panics", 1.0);
                http::Response::error(500, "internal handler panic")
            }
        };
        // ordering: Relaxed — termination flag only; worst case the
        // connection serves one more keep-alive request before the
        // drain notices.
        let last = served == max_requests || req.wants_close || stop.load(Ordering::Relaxed);
        if let Err(e) = resp.write_to(&mut writer, !last) {
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) {
                state.count("serve.write_timeouts", 1.0);
            }
            return;
        }
        if last {
            return;
        }
    }
}
