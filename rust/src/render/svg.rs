//! Minimal SVG scatter-plot writer.
//!
//! One emission core ([`svg_document`]) serves both entry points: the
//! whole-layout figure writer ([`render_scatter`]) and the query
//! server's viewport tiles ([`viewport_svg`]) — canvas structure,
//! deterministic subsampling and per-point circles stay in lockstep.

use crate::data::matrix::Matrix;
use crate::render::palette::class_color;
use crate::util::rng::Rng;
use anyhow::Result;
use std::fmt::Write as _;
use std::path::Path;

/// Rendering options.
#[derive(Clone, Debug)]
pub struct ScatterStyle {
    /// Canvas width/height in px.
    pub size: u32,
    /// Point radius in px.
    pub radius: f32,
    /// Max points drawn (uniform subsample beyond this).
    pub max_points: usize,
    /// Point opacity.
    pub opacity: f32,
    /// Background color.
    pub background: String,
    /// Figure title (empty = none).
    pub title: String,
}

impl Default for ScatterStyle {
    fn default() -> Self {
        ScatterStyle {
            size: 1200,
            radius: 1.4,
            max_points: 120_000,
            opacity: 0.55,
            background: "#ffffff".to_string(),
            title: String::new(),
        }
    }
}

/// Deterministic choice of which of `n` points to draw: all of them up
/// to `style.max_points`, a seeded uniform subsample beyond.
fn draw_ids(n: usize, max_points: usize, seed: u64) -> Vec<usize> {
    if n > max_points {
        let mut rng = Rng::new(seed);
        rng.sample_indices(n, max_points)
    } else {
        (0..n).collect()
    }
}

/// Emit one complete SVG scatter document: square canvas, background
/// rect, optional title, then a circle per `(px, py, color)` triple
/// (already in canvas coordinates).
fn svg_document(style: &ScatterStyle, pts: impl Iterator<Item = (f32, f32, String)>) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{s}" height="{s}" viewBox="0 0 {s} {s}">"#,
        s = style.size
    );
    let _ = writeln!(out, r#"<rect width="100%" height="100%" fill="{}"/>"#, style.background);
    if !style.title.is_empty() {
        let _ = writeln!(
            out,
            r##"<text x="12" y="24" font-family="sans-serif" font-size="18" fill="#333">{}</text>"##,
            style.title
        );
    }
    for (px, py, color) in pts {
        let _ = writeln!(
            out,
            r#"<circle cx="{px:.1}" cy="{py:.1}" r="{}" fill="{color}" fill-opacity="{}"/>"#,
            style.radius, style.opacity
        );
    }
    out.push_str("</svg>\n");
    out
}

/// Color of point `i` under the optional labeling.
fn point_color(i: usize, labels: Option<&[u32]>, n_classes: usize) -> String {
    match labels {
        Some(ls) => class_color(ls[i] as usize, n_classes.max(1)),
        None => "#3366aa".to_string(),
    }
}

/// Render a 2D layout (first two columns) to an SVG file.
///
/// `labels` colors points by class; `n_classes` selects the palette.
pub fn render_scatter(
    path: &Path,
    layout: &Matrix,
    labels: Option<&[u32]>,
    n_classes: usize,
    style: &ScatterStyle,
) -> Result<()> {
    assert!(layout.d() >= 2, "need at least 2 output dims to render");
    let n = layout.n();
    // Bounds.
    let (mut xmin, mut xmax, mut ymin, mut ymax) = (f32::MAX, f32::MIN, f32::MAX, f32::MIN);
    for i in 0..n {
        let r = layout.row(i);
        xmin = xmin.min(r[0]);
        xmax = xmax.max(r[0]);
        ymin = ymin.min(r[1]);
        ymax = ymax.max(r[1]);
    }
    let pad = 0.03 * ((xmax - xmin).max(ymax - ymin)).max(1e-9);
    let (xmin, ymin) = (xmin - pad, ymin - pad);
    let (xmax, ymax) = (xmax + pad, ymax + pad);
    let scale = style.size as f32 / (xmax - xmin).max(ymax - ymin).max(1e-9);

    let ids = draw_ids(n, style.max_points, 0x5caa);
    let doc = svg_document(
        style,
        ids.iter().map(|&i| {
            let r = layout.row(i);
            let px = (r[0] - xmin) * scale;
            let py = style.size as f32 - (r[1] - ymin) * scale;
            (px, py, point_color(i, labels, n_classes))
        }),
    );
    std::fs::write(path, doc)?;
    Ok(())
}

/// Render a viewport rectangle of a layout to an SVG document string.
///
/// `pts` is the `(id, x, y)` set inside the viewport (normally produced
/// by [`crate::render::grid::GridIndex::query`]); only those points are
/// emitted, so the cost of a tile is bounded by its own content, never
/// by the full layout size. The viewport rectangle `bbox =
/// (x0, y0, x1, y1)` maps to the square canvas with the same
/// orientation as [`render_scatter`] (y up). Beyond `style.max_points`
/// the tile is deterministically subsampled.
pub fn viewport_svg(
    pts: &[(u32, f32, f32)],
    labels: Option<&[u32]>,
    n_classes: usize,
    bbox: (f32, f32, f32, f32),
    style: &ScatterStyle,
) -> String {
    viewport_svg_with(pts, |i| labels.map(|ls| ls[i]), n_classes, bbox, style)
}

/// [`viewport_svg`] with a point-id → label closure instead of a flat
/// label slice, so label stores without a contiguous buffer (the query
/// server's chunked copy-on-write labels) can color tiles without an
/// O(N) flatten per request. `label_of` returning `None` draws the
/// unlabeled default color.
pub fn viewport_svg_with<F: Fn(usize) -> Option<u32>>(
    pts: &[(u32, f32, f32)],
    label_of: F,
    n_classes: usize,
    bbox: (f32, f32, f32, f32),
    style: &ScatterStyle,
) -> String {
    let (x0, y0, x1, y1) = bbox;
    let span = (x1 - x0).max(y1 - y0).max(1e-9);
    let scale = style.size as f32 / span;
    let ids = draw_ids(pts.len(), style.max_points, 0x711e);
    svg_document(
        style,
        ids.iter().map(|&i| {
            let (id, x, y) = pts[i];
            let px = (x - x0) * scale;
            let py = style.size as f32 - (y - y0) * scale;
            let color = match label_of(id as usize) {
                Some(l) => class_color(l as usize, n_classes.max(1)),
                None => "#3366aa".to_string(),
            };
            (px, py, color)
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("largevis_svg_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn writes_valid_svg() {
        let m = Matrix::from_vec(vec![0.0, 0.0, 1.0, 1.0, -1.0, 2.0], 3, 2);
        let p = tmp("a.svg");
        render_scatter(&p, &m, Some(&[0, 1, 2]), 3, &ScatterStyle::default()).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("<svg"));
        assert!(text.trim_end().ends_with("</svg>"));
        assert_eq!(text.matches("<circle").count(), 3);
    }

    #[test]
    fn subsamples_when_huge() {
        let n = 5000;
        let mut data = Vec::with_capacity(n * 2);
        for i in 0..n {
            data.push((i % 71) as f32);
            data.push((i % 37) as f32);
        }
        let m = Matrix::from_vec(data, n, 2);
        let style = ScatterStyle { max_points: 100, ..Default::default() };
        let p = tmp("b.svg");
        render_scatter(&p, &m, None, 0, &style).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.matches("<circle").count(), 100);
    }

    #[test]
    fn degenerate_single_point() {
        let m = Matrix::from_vec(vec![2.0, 3.0], 1, 2);
        let p = tmp("c.svg");
        render_scatter(&p, &m, None, 0, &ScatterStyle::default()).unwrap();
    }

    #[test]
    fn title_emitted_once() {
        let m = Matrix::from_vec(vec![0.0, 0.0], 1, 2);
        let p = tmp("t.svg");
        let style = ScatterStyle { title: "hello".to_string(), ..Default::default() };
        render_scatter(&p, &m, None, 0, &style).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.matches("<text").count(), 1);
        assert!(text.contains(">hello</text>"));
    }

    #[test]
    fn viewport_emits_only_given_points() {
        let pts = vec![(0u32, 0.0f32, 0.0f32), (1, 0.5, 0.5), (2, 1.0, 1.0)];
        let style = ScatterStyle::default();
        let svg = viewport_svg(&pts, Some(&[0, 1, 2]), 3, (0.0, 0.0, 1.0, 1.0), &style);
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert_eq!(svg.matches("<circle").count(), 3);
        // Corner points land on the canvas corners (y flipped).
        assert!(svg.contains("cx=\"0.0\""));
    }

    #[test]
    fn viewport_subsamples_beyond_cap() {
        let pts: Vec<(u32, f32, f32)> =
            (0..500).map(|i| (i as u32, (i % 23) as f32, (i % 7) as f32)).collect();
        let style = ScatterStyle { max_points: 40, ..Default::default() };
        let svg = viewport_svg(&pts, None, 0, (0.0, 0.0, 23.0, 7.0), &style);
        assert_eq!(svg.matches("<circle").count(), 40);
    }

    #[test]
    fn viewport_empty_is_valid_svg() {
        let svg = viewport_svg(&[], None, 0, (0.0, 0.0, 1.0, 1.0), &ScatterStyle::default());
        assert!(svg.starts_with("<svg") && svg.contains("</svg>"));
        assert_eq!(svg.matches("<circle").count(), 0);
    }
}
