//! Minimal SVG scatter-plot writer.

use crate::data::matrix::Matrix;
use crate::render::palette::class_color;
use crate::util::rng::Rng;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Rendering options.
#[derive(Clone, Debug)]
pub struct ScatterStyle {
    /// Canvas width/height in px.
    pub size: u32,
    /// Point radius in px.
    pub radius: f32,
    /// Max points drawn (uniform subsample beyond this).
    pub max_points: usize,
    /// Point opacity.
    pub opacity: f32,
    /// Background color.
    pub background: String,
    /// Figure title (empty = none).
    pub title: String,
}

impl Default for ScatterStyle {
    fn default() -> Self {
        ScatterStyle {
            size: 1200,
            radius: 1.4,
            max_points: 120_000,
            opacity: 0.55,
            background: "#ffffff".to_string(),
            title: String::new(),
        }
    }
}

/// Render a 2D layout (first two columns) to an SVG file.
///
/// `labels` colors points by class; `n_classes` selects the palette.
pub fn render_scatter(
    path: &Path,
    layout: &Matrix,
    labels: Option<&[u32]>,
    n_classes: usize,
    style: &ScatterStyle,
) -> Result<()> {
    assert!(layout.d() >= 2, "need at least 2 output dims to render");
    let n = layout.n();
    // Bounds.
    let (mut xmin, mut xmax, mut ymin, mut ymax) = (f32::MAX, f32::MIN, f32::MAX, f32::MIN);
    for i in 0..n {
        let r = layout.row(i);
        xmin = xmin.min(r[0]);
        xmax = xmax.max(r[0]);
        ymin = ymin.min(r[1]);
        ymax = ymax.max(r[1]);
    }
    let pad = 0.03 * ((xmax - xmin).max(ymax - ymin)).max(1e-9);
    let (xmin, xmax) = (xmin - pad, xmax + pad);
    let (ymin, ymax) = (ymin - pad, ymax + pad);
    let scale = style.size as f32 / (xmax - xmin).max(ymax - ymin).max(1e-9);

    // Subsample deterministically if huge.
    let ids: Vec<usize> = if n > style.max_points {
        let mut rng = Rng::new(0x5caa);
        rng.sample_indices(n, style.max_points)
    } else {
        (0..n).collect()
    };

    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(
        w,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{s}" height="{s}" viewBox="0 0 {s} {s}">"#,
        s = style.size
    )?;
    writeln!(w, r#"<rect width="100%" height="100%" fill="{}"/>"#, style.background)?;
    if !style.title.is_empty() {
        writeln!(
            w,
            r##"<text x="12" y="24" font-family="sans-serif" font-size="18" fill="#333">{}</text>"##,
            style.title
        )?;
    }
    for &i in &ids {
        let r = layout.row(i);
        let px = (r[0] - xmin) * scale;
        let py = style.size as f32 - (r[1] - ymin) * scale;
        let color = match labels {
            Some(ls) => class_color(ls[i] as usize, n_classes.max(1)),
            None => "#3366aa".to_string(),
        };
        writeln!(
            w,
            r#"<circle cx="{px:.1}" cy="{py:.1}" r="{}" fill="{color}" fill-opacity="{}"/>"#,
            style.radius, style.opacity
        )?;
    }
    writeln!(w, "</svg>")?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("largevis_svg_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn writes_valid_svg() {
        let m = Matrix::from_vec(vec![0.0, 0.0, 1.0, 1.0, -1.0, 2.0], 3, 2);
        let p = tmp("a.svg");
        render_scatter(&p, &m, Some(&[0, 1, 2]), 3, &ScatterStyle::default()).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("<svg"));
        assert!(text.trim_end().ends_with("</svg>"));
        assert_eq!(text.matches("<circle").count(), 3);
    }

    #[test]
    fn subsamples_when_huge() {
        let n = 5000;
        let mut data = Vec::with_capacity(n * 2);
        for i in 0..n {
            data.push((i % 71) as f32);
            data.push((i % 37) as f32);
        }
        let m = Matrix::from_vec(data, n, 2);
        let style = ScatterStyle { max_points: 100, ..Default::default() };
        let p = tmp("b.svg");
        render_scatter(&p, &m, None, 0, &style).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.matches("<circle").count(), 100);
    }

    #[test]
    fn degenerate_single_point() {
        let m = Matrix::from_vec(vec![2.0, 3.0], 1, 2);
        let p = tmp("c.svg");
        render_scatter(&p, &m, None, 0, &ScatterStyle::default()).unwrap();
    }
}
