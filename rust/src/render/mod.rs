//! SVG scatter rendering for the qualitative figures (paper Figs 8–10).
//!
//! No plotting library exists offline, so this is a small self-contained
//! SVG writer: categorical palette, point down-sampling for huge
//! layouts, axes-free themes like the paper's figures.

pub mod palette;
pub mod svg;

pub use svg::{render_scatter, ScatterStyle};
