//! SVG scatter rendering for the qualitative figures (paper Figs 8–10)
//! and for the query server's viewport tiles.
//!
//! No plotting library exists offline, so this is a small self-contained
//! SVG writer: categorical palette, point down-sampling for huge
//! layouts, axes-free themes like the paper's figures. For interactive
//! serving, [`grid::GridIndex`] buckets the layout once so a viewport
//! tile ([`svg::viewport_svg`]) renders in time proportional to its own
//! content rather than the full layout size.

pub mod grid;
pub mod palette;
pub mod svg;

pub use grid::{GridIndex, GridPoint};
pub use svg::{render_scatter, viewport_svg, viewport_svg_with, ScatterStyle};
