//! Uniform-grid spatial index over a 2D layout, for viewport queries.
//!
//! The query server's `/viewport` endpoint renders an arbitrary
//! rectangle of the layout. Scanning all N points per tile would make
//! tile cost O(N) regardless of how little of the layout is visible;
//! instead the layout is bucketed once at load time into a `g × g`
//! uniform grid stored CSR-style (one contiguous id array plus cell
//! offsets), and a viewport query walks only the cells overlapping the
//! requested rectangle. Tile cost is then proportional to the points in
//! (a one-cell neighborhood of) the viewport, not to N.
//!
//! Coordinates are copied next to the ids so a query never touches the
//! layout matrix — the index is self-contained and can be shared
//! read-only across server worker threads.
//!
//! The live-serving path grows the layout while the index is in use:
//! [`GridIndex::insert`] appends new points to a small overflow list
//! (scanned linearly per query — its length is bounded by the rebuild
//! threshold, so query cost stays bounded) and re-buckets the whole
//! CSR only when the overflow exceeds [`GridIndex::rebuild_threshold`].
//! Per-epoch cost is therefore O(batch) amortized, not O(N)
//! re-bucketing on every insert batch.
//!
//! The immutable CSR arrays live behind an [`Arc`] ([`GridBuckets`]),
//! so cloning the index for a new snapshot epoch copies one pointer
//! plus the bounded overflow list — O(batch), never O(N). The
//! overflow-copy bytes are charged to the copy-on-write counter
//! ([`crate::data::chunked::copied_bytes`]) alongside the chunk store's
//! own copies, and a rebuild charges the fresh CSR arrays it writes.

use crate::data::chunked;
use crate::data::matrix::RowStore;
use std::sync::Arc;

/// A point surfaced by a viewport query: `(id, x, y)`.
pub type GridPoint = (u32, f32, f32);

/// The immutable bucketed core of a [`GridIndex`]: bounds, cell
/// geometry and the CSR arrays. Shared between snapshot epochs via
/// [`Arc`]; replaced wholesale by a rebuild.
#[derive(Debug)]
struct GridBuckets {
    /// Layout bounds (min x, min y, max x, max y).
    bounds: (f32, f32, f32, f32),
    /// Cell width / height (always > 0).
    cell_w: f32,
    cell_h: f32,
    /// Cell start offsets into `ids`, row-major, length `g*g + 1`.
    starts: Vec<u32>,
    /// Point ids grouped by cell.
    ids: Vec<u32>,
    /// `x` coordinate of `ids[i]`'s point.
    xs: Vec<f32>,
    /// `y` coordinate of `ids[i]`'s point.
    ys: Vec<f32>,
}

/// CSR-bucketed uniform grid over the first two layout dimensions.
#[derive(Debug)]
pub struct GridIndex {
    /// Cells per axis.
    g: usize,
    /// Shared immutable buckets (epoch-shared; swapped on rebuild).
    buckets: Arc<GridBuckets>,
    /// Points inserted since the last (re)build, scanned linearly by
    /// every query; bounded by [`GridIndex::rebuild_threshold`].
    overflow: Vec<GridPoint>,
}

/// Cloning bumps the shared bucket pointer and copies only the bounded
/// overflow list — the O(batch) snapshot-publish path. The overflow
/// bytes are charged to the global copy-on-write counter.
impl Clone for GridIndex {
    fn clone(&self) -> Self {
        chunked::count_copied(self.overflow.len() * std::mem::size_of::<GridPoint>());
        GridIndex {
            g: self.g,
            buckets: Arc::clone(&self.buckets),
            overflow: self.overflow.clone(),
        }
    }
}

impl GridIndex {
    /// Bucket `layout` (first two columns) into a `cells × cells` grid.
    ///
    /// `cells` is clamped to at least 1; degenerate layouts (a single
    /// point, or all points coincident) still produce a valid index.
    /// Generic over [`RowStore`] so both flat and chunked layouts feed
    /// the same bucketing.
    pub fn build(layout: &impl RowStore, cells: usize) -> GridIndex {
        assert!(layout.d() >= 2, "grid index needs a 2D+ layout");
        let pts: Vec<GridPoint> =
            (0..layout.n()).map(|i| (i as u32, layout.row(i)[0], layout.row(i)[1])).collect();
        GridIndex::rebucket(cells.max(1), pts)
    }

    /// Bucket `pts` into a fresh `g × g` CSR grid (bounds recomputed).
    fn rebucket(g: usize, pts: Vec<GridPoint>) -> GridIndex {
        let n = pts.len();
        let (mut xmin, mut xmax, mut ymin, mut ymax) = (f32::MAX, f32::MIN, f32::MAX, f32::MIN);
        for &(_, x, y) in &pts {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if n == 0 {
            (xmin, xmax, ymin, ymax) = (0.0, 1.0, 0.0, 1.0);
        }
        let cell_w = ((xmax - xmin) / g as f32).max(1e-9);
        let cell_h = ((ymax - ymin) / g as f32).max(1e-9);

        let cell_of = |x: f32, y: f32| -> usize {
            let cx = (((x - xmin) / cell_w) as usize).min(g - 1);
            let cy = (((y - ymin) / cell_h) as usize).min(g - 1);
            cy * g + cx
        };

        // Counting sort into CSR: count per cell, prefix-sum, scatter.
        let mut counts = vec![0u32; g * g + 1];
        for &(_, x, y) in &pts {
            counts[cell_of(x, y) + 1] += 1;
        }
        for c in 1..counts.len() {
            counts[c] += counts[c - 1];
        }
        let starts = counts.clone();
        let mut cursor = counts;
        let mut ids = vec![0u32; n];
        let mut xs = vec![0f32; n];
        let mut ys = vec![0f32; n];
        for &(id, x, y) in &pts {
            let c = cell_of(x, y);
            let slot = cursor[c] as usize;
            cursor[c] += 1;
            ids[slot] = id;
            xs[slot] = x;
            ys[slot] = y;
        }
        GridIndex {
            g,
            buckets: Arc::new(GridBuckets {
                bounds: (xmin, ymin, xmax, ymax),
                cell_w,
                cell_h,
                starts,
                ids,
                xs,
                ys,
            }),
            overflow: Vec::new(),
        }
    }

    /// Overflow size that triggers a full re-bucketing on the next
    /// [`GridIndex::insert`]: 1/8 of the bucketed points, floored at
    /// 256 so small indexes don't rebuild per insert. Until then a
    /// query pays one extra linear scan of at most this many points.
    pub fn rebuild_threshold(&self) -> usize {
        (self.buckets.ids.len() / 8).max(256)
    }

    /// Insert one point incrementally. The point lands in the overflow
    /// list (O(1)); once the overflow exceeds
    /// [`GridIndex::rebuild_threshold`] the whole index re-buckets,
    /// folding the overflow in and re-fitting the bounds. Returns
    /// `true` when this call triggered a rebuild.
    pub fn insert(&mut self, id: u32, x: f32, y: f32) -> bool {
        self.overflow.push((id, x, y));
        if self.overflow.len() > self.rebuild_threshold() {
            self.rebuild();
            true
        } else {
            false
        }
    }

    /// Fold the overflow into the CSR buckets now (bounds re-fitted).
    /// The new bucket arrays replace the shared `Arc` — older epochs
    /// keep the previous buckets untouched. The bytes written into the
    /// fresh CSR are charged to the copy counter (amortized O(1) per
    /// insert thanks to the threshold).
    pub fn rebuild(&mut self) {
        let mut pts: Vec<GridPoint> =
            Vec::with_capacity(self.buckets.ids.len() + self.overflow.len());
        for i in 0..self.buckets.ids.len() {
            pts.push((self.buckets.ids[i], self.buckets.xs[i], self.buckets.ys[i]));
        }
        pts.append(&mut self.overflow);
        *self = GridIndex::rebucket(self.g, pts);
        let b = &self.buckets;
        chunked::count_copied(
            b.starts.len() * std::mem::size_of::<u32>()
                + b.ids.len() * std::mem::size_of::<u32>()
                + (b.xs.len() + b.ys.len()) * std::mem::size_of::<f32>(),
        );
    }

    /// Number of points awaiting the next re-bucketing.
    pub fn overflow_len(&self) -> usize {
        self.overflow.len()
    }

    /// Number of indexed points (bucketed + overflow).
    pub fn len(&self) -> usize {
        self.buckets.ids.len() + self.overflow.len()
    }

    /// True if the index holds no points.
    pub fn is_empty(&self) -> bool {
        self.buckets.ids.is_empty() && self.overflow.is_empty()
    }

    /// Layout bounds as `(xmin, ymin, xmax, ymax)`.
    pub fn bounds(&self) -> (f32, f32, f32, f32) {
        self.buckets.bounds
    }

    /// Whether `a` and `b` share the same bucket allocation — the
    /// sharing probe used by the chunk-sharing property tests.
    pub fn buckets_shared(a: &GridIndex, b: &GridIndex) -> bool {
        Arc::ptr_eq(&a.buckets, &b.buckets)
    }

    /// One representative point id per non-empty cell (the first id in
    /// each bucket, plus any overflow points), strided down to at most
    /// `max` ids. Deterministic for a given index state — used as the
    /// spatially-spread seed fallback for graph-based KNN search when
    /// no coarsening hierarchy is available.
    pub fn cell_representatives(&self, max: usize) -> Vec<u32> {
        let b = &self.buckets;
        let mut reps: Vec<u32> = Vec::new();
        for c in 0..self.g * self.g {
            let (s, e) = (b.starts[c] as usize, b.starts[c + 1] as usize);
            if s < e {
                reps.push(b.ids[s]);
            }
        }
        reps.extend(self.overflow.iter().map(|&(id, _, _)| id));
        if max == 0 {
            reps.clear();
        } else if reps.len() > max {
            let stride = reps.len().div_ceil(max);
            reps = reps.into_iter().step_by(stride).collect();
        }
        reps
    }

    /// Collect every point inside `[x0, x1] × [y0, y1]` into `out`
    /// (cleared first), visiting only the grid cells the rectangle
    /// overlaps. Returns the number of *candidates examined* — the
    /// point count of the visited cells — so callers (and tests) can
    /// assert the cost bound.
    pub fn query(&self, x0: f32, y0: f32, x1: f32, y1: f32, out: &mut Vec<GridPoint>) -> usize {
        out.clear();
        // The overflow list is scanned on every query — it may hold
        // points outside the bucketed bounds, so it is checked even
        // when the rectangle misses the grid entirely. Its length is
        // bounded by the rebuild threshold, so this stays O(threshold).
        let mut examined = self.overflow.len();
        for &(id, x, y) in &self.overflow {
            if x >= x0 && x <= x1 && y >= y0 && y <= y1 {
                out.push((id, x, y));
            }
        }
        let b = &self.buckets;
        let (bx0, by0, bx1, by1) = b.bounds;
        if b.ids.is_empty() || x1 < bx0 || x0 > bx1 || y1 < by0 || y0 > by1 {
            return examined;
        }
        let g = self.g;
        let cell_range = |lo: f32, hi: f32, min: f32, cell: f32| -> (usize, usize) {
            let a = (((lo - min) / cell).floor().max(0.0) as usize).min(g - 1);
            let bb = (((hi - min) / cell).floor().max(0.0) as usize).min(g - 1);
            (a, bb)
        };
        let (cx0, cx1) = cell_range(x0, x1, bx0, b.cell_w);
        let (cy0, cy1) = cell_range(y0, y1, by0, b.cell_h);
        for cy in cy0..=cy1 {
            for cx in cx0..=cx1 {
                let c = cy * g + cx;
                let (s, e) = (b.starts[c] as usize, b.starts[c + 1] as usize);
                examined += e - s;
                for i in s..e {
                    let (x, y) = (b.xs[i], b.ys[i]);
                    if x >= x0 && x <= x1 && y >= y0 && y <= y1 {
                        out.push((b.ids[i], x, y));
                    }
                }
            }
        }
        examined
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::util::rng::Rng;

    fn uniform_layout(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec((0..n * 2).map(|_| rng.range_f32(-10.0, 10.0)).collect(), n, 2)
    }

    #[test]
    fn query_matches_linear_scan() {
        let m = uniform_layout(2000, 7);
        let idx = GridIndex::build(&m, 16);
        assert_eq!(idx.len(), 2000);
        let mut out = Vec::new();
        let boxes = [
            (-10.0f32, -10.0f32, 10.0f32, 10.0f32),
            (-1.0, -1.0, 1.0, 1.0),
            (3.0, -9.0, 9.5, -3.0),
        ];
        for &(x0, y0, x1, y1) in &boxes {
            idx.query(x0, y0, x1, y1, &mut out);
            let mut got: Vec<u32> = out.iter().map(|&(id, _, _)| id).collect();
            got.sort_unstable();
            let mut want: Vec<u32> = (0..2000)
                .filter(|&i| {
                    let r = m.row(i);
                    r[0] >= x0 && r[0] <= x1 && r[1] >= y0 && r[1] <= y1
                })
                .map(|i| i as u32)
                .collect();
            want.sort_unstable();
            assert_eq!(got, want, "bbox ({x0},{y0})-({x1},{y1})");
            // Coordinates carried through unchanged.
            for &(id, x, y) in &out {
                let r = m.row(id as usize);
                assert_eq!((x, y), (r[0], r[1]));
            }
        }
    }

    #[test]
    fn small_tile_examines_few_candidates() {
        let m = uniform_layout(20_000, 11);
        let idx = GridIndex::build(&m, 64);
        let mut out = Vec::new();
        // A tile of ~1/100 the area must not examine anywhere near all
        // N candidates — this is the spatial-culling cost bound.
        let examined = idx.query(0.0, 0.0, 2.0, 2.0, &mut out);
        assert!(!out.is_empty());
        assert!(examined < 20_000 / 10, "examined {examined} of 20000");
        assert!(out.len() <= examined);
    }

    #[test]
    fn out_of_bounds_and_empty() {
        let m = uniform_layout(50, 3);
        let idx = GridIndex::build(&m, 8);
        let mut out = vec![(0u32, 0.0f32, 0.0f32)];
        let examined = idx.query(100.0, 100.0, 200.0, 200.0, &mut out);
        assert_eq!(examined, 0);
        assert!(out.is_empty());
        let empty = GridIndex::build(&Matrix::zeros(0, 2), 8);
        assert!(empty.is_empty());
        assert_eq!(empty.query(-1.0, -1.0, 1.0, 1.0, &mut out), 0);
    }

    #[test]
    fn incremental_insert_visible_and_bounded() {
        let m = uniform_layout(5000, 13);
        let mut idx = GridIndex::build(&m, 32);
        let threshold = idx.rebuild_threshold();
        // Insert points inside and *outside* the original bounds; all
        // must be query-visible immediately, without a rebuild.
        let mut rng = Rng::new(99);
        let mut inserted: Vec<(u32, f32, f32)> = Vec::new();
        for i in 0..threshold / 2 {
            let (x, y) = (rng.range_f32(-15.0, 15.0), rng.range_f32(-15.0, 15.0));
            let rebuilt = idx.insert((5000 + i) as u32, x, y);
            assert!(!rebuilt, "rebuild before the threshold");
            inserted.push(((5000 + i) as u32, x, y));
        }
        assert_eq!(idx.len(), 5000 + inserted.len());
        assert_eq!(idx.overflow_len(), inserted.len());
        let mut out = Vec::new();
        let examined = idx.query(-20.0, -20.0, 20.0, 20.0, &mut out);
        assert_eq!(out.len(), 5000 + inserted.len(), "inserted points missing from query");
        assert!(examined <= 5000 + inserted.len());
        // A tile that misses the grid still surfaces overflow points in
        // it, and examines at most the overflow.
        let far = idx.query(100.0, 100.0, 200.0, 200.0, &mut out);
        assert!(far <= idx.overflow_len());

        // The narrow-tile cost bound survives insertion: bucketed cells
        // plus at most the (threshold-bounded) overflow.
        let examined = idx.query(0.0, 0.0, 1.0, 1.0, &mut out);
        assert!(
            examined < 5000 / 4 + idx.overflow_len(),
            "examined {examined} — culling lost after inserts"
        );
    }

    #[test]
    fn threshold_triggers_rebuild_and_refits_bounds() {
        let m = uniform_layout(100, 17);
        let mut idx = GridIndex::build(&m, 8);
        let threshold = idx.rebuild_threshold();
        let mut rng = Rng::new(7);
        let mut rebuilds = 0;
        let total = threshold + 10;
        for i in 0..total {
            // Outside the original [-10, 10] bounds on purpose.
            let (x, y) = (rng.range_f32(20.0, 30.0), rng.range_f32(20.0, 30.0));
            if idx.insert((100 + i) as u32, x, y) {
                rebuilds += 1;
            }
        }
        assert!(rebuilds >= 1, "no rebuild after {total} inserts (threshold {threshold})");
        assert!(idx.overflow_len() <= threshold);
        assert_eq!(idx.len(), 100 + total);
        // Bounds re-fitted to cover the out-of-range inserts.
        let (_, _, bx1, by1) = idx.bounds();
        assert!(bx1 >= 20.0 && by1 >= 20.0, "bounds not refitted: {:?}", idx.bounds());
        // Every point still query-visible exactly once.
        let mut out = Vec::new();
        idx.query(-50.0, -50.0, 50.0, 50.0, &mut out);
        assert_eq!(out.len(), 100 + total);
        let mut ids: Vec<u32> = out.iter().map(|&(id, _, _)| id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100 + total, "duplicate or lost ids after rebuild");
    }

    #[test]
    fn cell_representatives_spread_and_capped() {
        let m = uniform_layout(2000, 21);
        let mut idx = GridIndex::build(&m, 16);
        let reps = idx.cell_representatives(usize::MAX);
        assert!(!reps.is_empty() && reps.len() <= 16 * 16);
        let mut sorted = reps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), reps.len(), "representatives must be distinct");
        // Cap honored, deterministic, overflow points included.
        let capped = idx.cell_representatives(10);
        assert!(capped.len() <= 10 && !capped.is_empty());
        assert_eq!(capped, idx.cell_representatives(10));
        idx.insert(9999, 50.0, 50.0);
        assert!(idx.cell_representatives(usize::MAX).contains(&9999));
        assert!(idx.cell_representatives(0).is_empty());
    }

    #[test]
    fn degenerate_coincident_points() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0], 3, 2);
        let idx = GridIndex::build(&m, 4);
        let mut out = Vec::new();
        idx.query(0.0, 0.0, 3.0, 3.0, &mut out);
        assert_eq!(out.len(), 3);
        idx.query(1.5, 1.5, 3.0, 3.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn clone_shares_buckets_until_rebuild() {
        let m = uniform_layout(400, 5);
        let mut idx = GridIndex::build(&m, 8);
        idx.insert(400, 0.5, 0.5);
        let snap = idx.clone();
        assert!(GridIndex::buckets_shared(&idx, &snap));
        // More overflow inserts never touch the shared buckets.
        idx.insert(401, 0.25, 0.25);
        assert!(GridIndex::buckets_shared(&idx, &snap));
        assert_eq!(snap.len(), 401);
        assert_eq!(idx.len(), 402);
        // A rebuild swaps in a new allocation; the old snapshot keeps
        // the previous one and stays fully queryable.
        idx.rebuild();
        assert!(!GridIndex::buckets_shared(&idx, &snap));
        let mut out = Vec::new();
        snap.query(-50.0, -50.0, 50.0, 50.0, &mut out);
        assert_eq!(out.len(), 401);
    }
}
