//! Categorical color palettes.
//!
//! For ≤ 20 classes a hand-picked qualitative palette (colorblind-aware
//! first 10); beyond that, evenly spaced HSL hues with alternating
//! lightness, which is what the paper's 200-cluster figures amount to.

/// A hand-tuned qualitative palette (tab10 + tab10-dark style).
const QUALITATIVE: [&str; 20] = [
    "#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b", "#e377c2", "#7f7f7f",
    "#bcbd22", "#17becf", "#aec7e8", "#ffbb78", "#98df8a", "#ff9896", "#c5b0d5", "#c49c94",
    "#f7b6d2", "#c7c7c7", "#dbdb8d", "#9edae5",
];

/// Color for class `c` out of `n_classes` as an SVG color string.
pub fn class_color(c: usize, n_classes: usize) -> String {
    if n_classes <= QUALITATIVE.len() {
        QUALITATIVE[c % QUALITATIVE.len()].to_string()
    } else {
        // Golden-ratio hue walk: adjacent class ids get distant hues.
        let hue = (c as f64 * 0.618_033_988_749_895).fract() * 360.0;
        let light = if c % 2 == 0 { 45.0 } else { 62.0 };
        hsl_to_hex(hue, 0.72, light / 100.0)
    }
}

/// Convert HSL (h in degrees, s/l in [0,1]) to `#rrggbb`.
pub fn hsl_to_hex(h: f64, s: f64, l: f64) -> String {
    let c = (1.0 - (2.0 * l - 1.0).abs()) * s;
    let hp = (h.rem_euclid(360.0)) / 60.0;
    let x = c * (1.0 - (hp % 2.0 - 1.0).abs());
    let (r1, g1, b1) = match hp as u32 {
        0 => (c, x, 0.0),
        1 => (x, c, 0.0),
        2 => (0.0, c, x),
        3 => (0.0, x, c),
        4 => (x, 0.0, c),
        _ => (c, 0.0, x),
    };
    let m = l - c / 2.0;
    let to8 = |v: f64| ((v + m).clamp(0.0, 1.0) * 255.0).round() as u8;
    format!("#{:02x}{:02x}{:02x}", to8(r1), to8(g1), to8(b1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_class_counts_use_qualitative() {
        assert_eq!(class_color(0, 10), "#1f77b4");
        assert_eq!(class_color(3, 20), "#d62728");
    }

    #[test]
    fn large_class_counts_generated() {
        let a = class_color(0, 200);
        let b = class_color(1, 200);
        assert!(a.starts_with('#') && a.len() == 7);
        assert_ne!(a, b);
    }

    #[test]
    fn hsl_known_values() {
        assert_eq!(hsl_to_hex(0.0, 1.0, 0.5), "#ff0000");
        assert_eq!(hsl_to_hex(120.0, 1.0, 0.5), "#00ff00");
        assert_eq!(hsl_to_hex(240.0, 1.0, 0.5), "#0000ff");
        assert_eq!(hsl_to_hex(0.0, 0.0, 1.0), "#ffffff");
    }

    #[test]
    fn all_colors_distinct_up_to_64() {
        let set: std::collections::HashSet<String> =
            (0..64).map(|c| class_color(c, 64)).collect();
        assert_eq!(set.len(), 64);
    }
}
