//! Low-level substrates built from scratch for the offline environment:
//! PRNG, alias sampling, bounded heaps, a scoped thread pool, timers,
//! streaming statistics, and a light property-testing driver.

pub mod rng;
pub mod faultio;
pub mod alias;
pub mod heap;
pub mod notify;
pub mod pool;
pub mod sync;
pub mod timer;
pub mod stats;
pub mod proptest;
pub mod json;
pub mod visited;

pub use alias::AliasTable;
pub use heap::BoundedMaxHeap;
pub use rng::Rng;
pub use timer::Timer;
pub use visited::VisitedSet;
