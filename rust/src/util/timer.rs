//! Wall-clock timing utilities for the bench harness and pipeline logs.

use std::time::Instant;

/// A running wall-clock timer.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    /// Start a timer with a label used in [`Timer::report`].
    pub fn start(label: &str) -> Self {
        Timer { start: Instant::now(), label: label.to_string() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Print `label: N.NNNs` to stderr and return elapsed seconds.
    pub fn report(&self) -> f64 {
        let s = self.secs();
        eprintln!("[timer] {}: {}", self.label, fmt_duration(s));
        s
    }
}

/// Format a duration in seconds adaptively (µs / ms / s / m / h).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else if secs < 7200.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{:.2}h", secs / 3600.0)
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start("x");
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn formats() {
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(0.005).ends_with("ms"));
        assert!(fmt_duration(3.0).ends_with('s'));
        assert!(fmt_duration(300.0).ends_with('m'));
        assert!(fmt_duration(10_000.0).ends_with('h'));
    }

    #[test]
    fn time_returns_result() {
        let (v, s) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
