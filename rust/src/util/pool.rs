//! Data-parallel helpers over scoped threads (no rayon offline).
//!
//! The paper parallelizes three things: tree search per node, neighbor
//! exploring per node, and the asynchronous SGD workers. All are
//! expressible as a `parallel_for` over an index range with per-worker
//! state, or as `spawn_workers` for long-lived SGD threads.
//!
//! Threads come from `util::sync::thread` (the sync shim), so under
//! `--cfg modelcheck` every worker is a schedulable model thread and
//! the teardown handshake below is explored, not sampled.

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::thread;

/// Worker-teardown completion latch, reviewed under the model checker.
///
/// Each worker calls [`DoneLatch::arrive`] as its last action; any
/// thread that observes [`DoneLatch::is_done`] may then read data the
/// workers wrote *without further synchronization*. That guarantee is
/// exactly the Release/Acquire pair documented on the two methods —
/// the regression model test `pool_latch_publishes_worker_writes` in
/// `tools/modelcheck` pins it, and the
/// `modelcheck_mutant_latch_relaxed` corpus entry proves the checker
/// notices when the Release half is dropped.
pub struct DoneLatch {
    remaining: AtomicUsize,
}

impl DoneLatch {
    /// Latch that opens after `n` arrivals.
    pub fn new(n: usize) -> Self {
        DoneLatch { remaining: AtomicUsize::new(n) }
    }

    /// Records one worker's completion; returns true for the final
    /// arrival.
    pub fn arrive(&self) -> bool {
        // ordering: AcqRel — the Release half publishes everything
        // this worker wrote before arriving to whoever sees the count
        // reach zero (pairs with the Acquire in `is_done`); the
        // Acquire half makes the *last* arriver see every earlier
        // worker's writes, so it can safely tear shared state down.
        #[cfg(not(modelcheck_mutant_latch_relaxed))]
        let prev = self.remaining.fetch_sub(1, Ordering::AcqRel);
        // Seeded ordering bug for the mutation corpus: dropping the
        // Release half means observers of zero may still read stale
        // pre-arrival data. The checker must catch this.
        // ordering: Relaxed — deliberate mutant, see above.
        #[cfg(modelcheck_mutant_latch_relaxed)]
        let prev = self.remaining.fetch_sub(1, Ordering::Relaxed);
        prev == 1
    }

    /// True once every worker has arrived. Observing true makes all
    /// workers' pre-arrival writes visible to the caller.
    pub fn is_done(&self) -> bool {
        // ordering: Acquire — pairs with the Release half of the
        // AcqRel in `arrive`; see the struct docs.
        #[cfg(not(modelcheck_mutant_latch_weak_poll))]
        return self.remaining.load(Ordering::Acquire) == 0;
        // Seeded ordering bug for the mutation corpus: polling with a
        // Relaxed load observes the count hit zero without acquiring
        // the arrivers' writes, so the caller can read stale payloads.
        // ordering: Relaxed — deliberate mutant, see above.
        #[cfg(modelcheck_mutant_latch_weak_poll)]
        return self.remaining.load(Ordering::Relaxed) == 0;
    }
}

/// Number of worker threads to use by default (respects
/// `LARGEVIS_THREADS`, falling back to available parallelism).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LARGEVIS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(worker_id, range)` over `n_items` split into contiguous chunks
/// across `threads` workers. Blocks until all complete.
pub fn parallel_for_chunks<F>(n_items: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n_items.max(1));
    if threads == 1 {
        f(0, 0..n_items);
        return;
    }
    let chunk = n_items.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n_items);
            if lo >= hi {
                break;
            }
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Map `f` over `0..n_items` in parallel, collecting results in order.
///
/// Results are written into a pre-allocated vector through chunked
/// disjoint mutable slices, so no locking is involved.
pub fn parallel_map<T, F>(n_items: usize, threads: usize, f: F) -> Vec<T>
where
    T: Default + Clone + Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_with(n_items, threads, |_worker| (), |_state, i| f(i))
}

/// Like [`parallel_map`], but threads reusable per-worker state through
/// the mapping: `init(worker_id)` builds each worker's scratch once and
/// `f(&mut scratch, i)` maps every item with it.
///
/// This is what makes the KNN hot loops allocation-free: heaps, visited
/// sets and gather buffers are built once per worker instead of once
/// per node (§Perf; see `knn::explore`).
pub fn parallel_map_with<T, S, I, F>(n_items: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Default + Clone + Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let mut out = vec![T::default(); n_items];
    let threads = threads.max(1).min(n_items.max(1));
    if threads <= 1 {
        let mut state = init(0);
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(&mut state, i);
        }
        return out;
    }
    let chunk = n_items.div_ceil(threads);
    thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            let init = &init;
            let base = t * chunk;
            s.spawn(move || {
                let mut state = init(t);
                for (off, slot) in slice.iter_mut().enumerate() {
                    *slot = f(&mut state, base + off);
                }
            });
        }
    });
    out
}

/// Spawn `threads` long-lived workers, each given its id; blocks until
/// all return. Used by the Hogwild SGD engine and LINE.
pub fn spawn_workers<F>(threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        f(0);
        return;
    }
    let latch = DoneLatch::new(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let latch = &latch;
            s.spawn(move || {
                f(t);
                latch.arrive();
            });
        }
    });
    // The scope join above already synchronizes, so this is an
    // invariant check of the latch protocol, not a synchronization
    // point: every worker must have arrived exactly once.
    debug_assert!(latch.is_done());
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_all_items_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(500, 8, |i| i * 2);
        assert_eq!(out, (0..500).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn workers_all_run() {
        let count = AtomicUsize::new(0);
        spawn_workers(9, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(10, 1, |i| i);
        assert_eq!(out.len(), 10);
        parallel_for_chunks(0, 4, |_, r| assert!(r.is_empty()));
    }

    #[test]
    fn map_with_state_preserves_order_and_reuses_scratch() {
        // Each worker counts the items it maps through its own state;
        // results must still land in item order.
        let out = parallel_map_with(
            500,
            8,
            |_worker| 0usize,
            |seen, i| {
                *seen += 1;
                (i * 3, *seen)
            },
        );
        assert_eq!(out.len(), 500);
        for (i, &(v, seen)) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
            assert!(seen >= 1); // state threaded through, monotone per worker
        }
        // Workers see their chunk sequentially: within a chunk the
        // per-worker counter increments by one per item.
        let chunk = 500usize.div_ceil(8);
        for c in out.chunks(chunk) {
            for (off, &(_, seen)) in c.iter().enumerate() {
                assert_eq!(seen, off + 1);
            }
        }
    }

    #[test]
    fn map_with_single_thread_and_empty() {
        let out = parallel_map_with(7, 1, |_| Vec::<u8>::new(), |_, i| i);
        assert_eq!(out, (0..7).collect::<Vec<_>>());
        let empty = parallel_map_with(0, 4, |_| (), |_, i| i);
        assert!(empty.is_empty());
    }
}
