//! Walker's alias method for O(1) sampling from a discrete distribution.
//!
//! LargeVis relies on two alias tables in its hot loop:
//! * **edge sampling** — positive edges are drawn with probability
//!   proportional to their weight `w_ij` and then treated as binary
//!   (Section 3.2, "edge sampling" from the LINE paper), and
//! * **negative sampling** — vertices are drawn from the noise
//!   distribution `P_n(j) ∝ d_j^0.75`.
//!
//! Construction is O(n); each draw costs one uniform and one compare.

use crate::util::rng::Rng;

/// Precomputed alias table over `n` outcomes.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f32>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized).
    ///
    /// Zero-weight outcomes are never sampled. Panics if all weights are
    /// zero or the slice is empty.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table over empty support");
        assert!(n <= u32::MAX as usize, "alias table too large for u32 indices");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "weights must sum to a positive finite value");

        let mut prob = vec![0f32; n];
        let mut alias = vec![0u32; n];
        // Scaled probabilities (mean 1).
        let scale = n as f64 / total;
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * scale).collect();

        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            // Clamp the stored residual: under a large weight dynamic
            // range the repeated `scaled[l] -= …` below drifts, so a
            // bucket can come back around with a residual slightly
            // below 0 or above 1. Stored raw, a negative residual makes
            // the f64→f32 cast produce a negative accept threshold
            // (outcome silently never sampled directly) and a >1
            // residual skews the alias branch; clamping bounds the
            // distortion at one f32 ulp instead.
            prob[s as usize] = scaled[s as usize].clamp(0.0, 1.0) as f32;
            alias[s as usize] = l;
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers are numerically 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
            alias[i as usize] = i;
        }
        AliasTable { prob, alias }
    }

    /// Number of outcomes.
    #[inline]
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True if the table has no outcomes (never: `new` panics on empty).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome.
    ///
    /// Uses a single 64-bit draw: the high 32 bits select the slot
    /// (Lemire 32-bit multiply-shift; bias < 2⁻³² for n < 2³²), the low
    /// 32 bits form the accept fraction — the two halves of a
    /// xoshiro256** output are independent enough for Vose acceptance
    /// (validated by the χ² test below). This halves RNG work in the
    /// SGD hot loop, which draws 1 + M times per edge sample.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.next_u64();
        let hi = (x >> 32) as u32;
        let lo = x as u32;
        let i = ((hi as u64 * self.prob.len() as u64) >> 32) as usize;
        let frac = lo as f32 * (1.0 / 4294967296.0);
        if frac < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(weights: &[f64], draws: usize, seed: u64) -> Vec<f64> {
        let t = AliasTable::new(weights);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[t.sample(&mut rng)] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_weights() {
        let freq = empirical(&[1.0; 8], 160_000, 1);
        for &f in &freq {
            assert!((f - 0.125).abs() < 0.01, "{freq:?}");
        }
    }

    #[test]
    fn skewed_weights_match() {
        let w = [1.0, 2.0, 3.0, 10.0];
        let total: f64 = w.iter().sum();
        let freq = empirical(&w, 400_000, 2);
        for (f, &wi) in freq.iter().zip(&w) {
            let p = wi / total;
            assert!((f - p).abs() < 0.01, "freq={freq:?}");
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let freq = empirical(&[0.0, 1.0, 0.0, 1.0], 50_000, 3);
        assert_eq!(freq[0], 0.0);
        assert_eq!(freq[2], 0.0);
    }

    #[test]
    fn singleton() {
        let freq = empirical(&[3.5], 100, 4);
        assert_eq!(freq, vec![1.0]);
    }

    #[test]
    #[should_panic]
    fn all_zero_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    fn extreme_dynamic_range_residuals_clamped_and_frequencies_match() {
        // Property: across extreme weight dynamic ranges (1e-12 ..
        // 1e12), every stored residual probability stays in [0, 1] and
        // the empirical frequencies still match the weights — heavy
        // outcomes by chi-square, near-zero-mass outcomes by being
        // (essentially) never drawn.
        let mut wrng = Rng::new(0xa11a5);
        for trial in 0..4u64 {
            let n = 16 + wrng.below(48);
            let w: Vec<f64> = (0..n).map(|_| 10f64.powf(wrng.f64() * 24.0 - 12.0)).collect();
            let t = AliasTable::new(&w);
            for &p in &t.prob {
                assert!((0.0..=1.0).contains(&p), "residual probability {p} outside [0,1]");
            }
            let draws = 300_000usize;
            let mut rng = Rng::new(500 + trial);
            let mut counts = vec![0usize; n];
            for _ in 0..draws {
                counts[t.sample(&mut rng)] += 1;
            }
            let total: f64 = w.iter().sum();
            let mut chi2 = 0.0f64;
            let mut dof = 0usize;
            let mut rare_hits = 0usize;
            for (&c, &wi) in counts.iter().zip(&w) {
                let e = wi / total * draws as f64;
                if e >= 20.0 {
                    chi2 += (c as f64 - e) * (c as f64 - e) / e;
                    dof += 1;
                } else if e < 0.01 {
                    rare_hits += c;
                }
            }
            // chi2 99.99th percentile at dof=64 is ~118.
            assert!(chi2 < 120.0, "chi2={chi2} over {dof} heavy outcomes (n={n})");
            // The near-zero-mass outcomes jointly expect < 1 draw.
            assert!(rare_hits < 10, "vanishing-weight outcomes drawn {rare_hits} times");
        }
    }

    #[test]
    fn chi_square_within_bound() {
        // Property: empirical distribution matches weights by chi-square.
        let mut rng = Rng::new(99);
        for trial in 0..5 {
            let n = 3 + rng.below(30);
            let w: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0 + 0.01).collect();
            let draws = 200_000;
            let freq = empirical(&w, draws, 100 + trial);
            let total: f64 = w.iter().sum();
            let chi2: f64 = freq
                .iter()
                .zip(&w)
                .map(|(f, &wi)| {
                    let p = wi / total;
                    let e = p * draws as f64;
                    let o = f * draws as f64;
                    (o - e) * (o - e) / e
                })
                .sum();
            // dof <= 32; chi2 99.9th percentile at dof=32 is ~62.5.
            assert!(chi2 < 80.0, "chi2={chi2} n={n}");
        }
    }
}
