//! Seedable pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we implement the small
//! set of primitives the system needs: `xoshiro256**` for the bulk
//! stream (fast, 256-bit state, passes BigCrush), `SplitMix64` for
//! seeding/stream-splitting, uniform floats/ranges, Box–Muller
//! gaussians, shuffles and choice without replacement.
//!
//! Every stochastic component in the library takes an explicit seed so
//! end-to-end runs are reproducible; parallel workers derive
//! independent streams via [`Rng::split`].

/// SplitMix64 step — used for seeding and stream splitting.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// `xoshiro256**` generator with convenience sampling methods.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second gaussian from Box–Muller.
    gauss_spare: Option<f32>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for a parallel worker `idx`).
    pub fn split(&self, idx: u64) -> Rng {
        // Mix the current state with the worker index through SplitMix64.
        let mut sm = self.s[0] ^ self.s[2] ^ idx.wrapping_mul(0xA0761D6478BD642F);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` (f32).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (no modulo bias).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the spare).
    pub fn gaussian(&mut self) -> f32 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some((r * theta.sin()) as f32);
            return (r * theta.cos()) as f32;
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (reservoir when k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Floyd's algorithm.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `s` (rejection
    /// sampling; used by the word-like dataset generator).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF over a precomputable harmonic is overkill here; use
        // the classic rejection method of Devroye.
        let n_f = n as f64;
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = ((n_f + 1.0).powf(1.0 - s) * u + 1.0 - u).powf(1.0 / (1.0 - s));
            let k = x.floor().max(1.0);
            let ratio = (k / x).powf(s) * x / k; // acceptance ratio ~ O(1)
            if v * ratio <= 1.0 && (k as usize) <= n {
                return k as usize - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_independent() {
        let base = Rng::new(7);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(5);
        let n = 7;
        let mut counts = vec![0usize; n];
        let trials = 70_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let g = r.gaussian() as f64;
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10), (1000, 50)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let mut r = Rng::new(19);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
    }
}
