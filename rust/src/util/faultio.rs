//! Injectable durable-file I/O: the seam the crash-recovery torture
//! harness drives.
//!
//! Every write path that must survive a crash (the insert WAL, the
//! checkpoint matrix/KNN writers, compaction renames) goes through the
//! [`Storage`] + [`DurableFile`] traits instead of touching
//! `std::fs::File` directly. Production code uses [`RealStorage`]
//! (plain files, `fdatasync`, atomic rename + parent-directory sync);
//! the fault tests swap in [`FaultStorage`], which counts every
//! write/fsync operation across all files it opened and injects one
//! seeded fault ([`FaultKind`]) at a chosen operation index. Because
//! the workload is deterministic, the operation schedule is identical
//! up to the first fault, so enumerating `trigger_op` from 0 to the
//! probed operation count visits every injectable fault point exactly
//! once.
//!
//! Fault semantics:
//! - transient faults ([`FaultKind::ShortWrite`], [`FaultKind::Enospc`],
//!   [`FaultKind::FsyncFail`]) fire once and later operations succeed,
//!   exercising the callers' rollback/retry paths;
//! - [`FaultKind::TornWrite`] persists a prefix of the buffer and then
//!   marks the whole storage *crashed*: every subsequent operation on
//!   every file errors, modelling a process kill mid-write;
//! - a failed fsync drops the bytes written since the last successful
//!   sync (the page cache was never persisted), which is the disk
//!   behavior fsync-error handling bugs get wrong.

use std::io::{self, Seek, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// A writable file handle with explicit durability operations.
///
/// The supertrait bound means a `Box<dyn DurableFile>` can sit inside
/// a `std::io::BufWriter` exactly like a `std::fs::File`.
pub trait DurableFile: Write + Send {
    /// Flush file *contents* to stable storage (`fdatasync`).
    fn sync_data(&mut self) -> io::Result<()>;
    /// Truncate (or extend) the file to `len` bytes.
    fn set_len(&mut self, len: u64) -> io::Result<()>;
    /// Reposition the write cursor.
    fn seek(&mut self, pos: io::SeekFrom) -> io::Result<u64>;
}

/// A file-system factory for [`DurableFile`] handles plus the two
/// metadata operations crash recovery depends on (atomic rename and
/// tolerant remove).
pub trait Storage: Send + Sync {
    /// Open `path` read/write without truncating, creating it if
    /// absent (the WAL resume path).
    fn open_durable(&self, path: &Path) -> io::Result<Box<dyn DurableFile>>;
    /// Create `path` truncated to zero length (fresh WAL segments,
    /// checkpoint temporaries).
    fn create_durable(&self, path: &Path) -> io::Result<Box<dyn DurableFile>>;
    /// Atomically rename `from` onto `to`, then best-effort sync the
    /// destination's parent directory so the rename itself is durable.
    fn persist(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Remove a file; a file that is already absent is not an error
    /// (recovery retries removals idempotently).
    fn remove(&self, path: &Path) -> io::Result<()>;
}

/// The production [`Storage`]: plain `std::fs` files.
#[derive(Clone, Copy, Debug, Default)]
pub struct RealStorage;

struct RealFile(std::fs::File);

impl Write for RealFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.0.flush()
    }
}

impl DurableFile for RealFile {
    fn sync_data(&mut self) -> io::Result<()> {
        self.0.sync_data()
    }

    fn set_len(&mut self, len: u64) -> io::Result<()> {
        self.0.set_len(len)
    }

    fn seek(&mut self, pos: io::SeekFrom) -> io::Result<u64> {
        self.0.seek(pos)
    }
}

fn sync_parent_dir(path: &Path) {
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

fn remove_tolerant(path: &Path) -> io::Result<()> {
    match std::fs::remove_file(path) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(e),
    }
}

impl Storage for RealStorage {
    fn open_durable(&self, path: &Path) -> io::Result<Box<dyn DurableFile>> {
        let f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)?;
        Ok(Box::new(RealFile(f)))
    }

    fn create_durable(&self, path: &Path) -> io::Result<Box<dyn DurableFile>> {
        Ok(Box::new(RealFile(std::fs::File::create(path)?)))
    }

    fn persist(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)?;
        sync_parent_dir(to);
        Ok(())
    }

    fn remove(&self, path: &Path) -> io::Result<()> {
        remove_tolerant(path)
    }
}

/// The kind of storage fault a [`FaultPlan`] injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// A write persists only a seeded prefix of the buffer, then
    /// errors; later operations succeed (transient).
    ShortWrite,
    /// A write fails outright, ENOSPC-style, persisting nothing;
    /// later operations succeed (transient).
    Enospc,
    /// An fsync fails and every byte written since the last successful
    /// sync is dropped (the page cache was lost); later operations
    /// succeed (transient).
    FsyncFail,
    /// A write tears mid-buffer and the process "crashes": every
    /// subsequent operation on every file errors until the storage is
    /// reopened.
    TornWrite,
}

impl FaultKind {
    fn fires_on_write(self) -> bool {
        matches!(self, FaultKind::ShortWrite | FaultKind::Enospc | FaultKind::TornWrite)
    }

    fn fires_on_sync(self) -> bool {
        matches!(self, FaultKind::FsyncFail)
    }
}

/// One planned fault: `kind` fires at the first matching operation
/// whose global index is `>= trigger_op`; `seed` picks the torn byte
/// for the partial-write kinds.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// What goes wrong.
    pub kind: FaultKind,
    /// Global write/fsync operation index at (or after) which the
    /// fault fires. `u64::MAX` never fires (probe mode).
    pub trigger_op: u64,
    /// Picks the persisted prefix length for short/torn writes.
    pub seed: u64,
}

/// A [`Storage`] that injects exactly one [`FaultPlan`] fault across
/// all files it opens. Clones share the operation counter and fault
/// state, so a single `FaultStorage` can be handed to several writers
/// while keeping one global, deterministic operation schedule.
#[derive(Clone)]
pub struct FaultStorage {
    plan: FaultPlan,
    ops: Arc<AtomicU64>,
    fired: Arc<AtomicBool>,
    crashed: Arc<AtomicBool>,
}

impl FaultStorage {
    /// Storage that injects `plan`.
    pub fn new(plan: FaultPlan) -> Self {
        FaultStorage {
            plan,
            ops: Arc::new(AtomicU64::new(0)),
            fired: Arc::new(AtomicBool::new(false)),
            crashed: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Storage that never faults but still counts operations — run the
    /// workload once under a probe to learn how many injectable fault
    /// points it has.
    pub fn probe() -> Self {
        let plan = FaultPlan { kind: FaultKind::ShortWrite, trigger_op: u64::MAX, seed: 0 };
        FaultStorage::new(plan)
    }

    /// Write/fsync operations observed so far.
    pub fn ops(&self) -> u64 {
        // ordering: Relaxed — a monotonic counter read for reporting;
        // the fetch_add RMWs keep it exact without extra ordering.
        self.ops.load(Ordering::Relaxed)
    }

    /// Whether the planned fault has fired.
    pub fn fired(&self) -> bool {
        // ordering: Relaxed — observer-side flag read; exactly-once
        // firing is guaranteed by the `swap` in `should_fire`, not by
        // ordering.
        self.fired.load(Ordering::Relaxed)
    }

    /// Whether a torn write has "crashed" the storage.
    pub fn crashed(&self) -> bool {
        // ordering: Relaxed — see `check_crashed`.
        self.crashed.load(Ordering::Relaxed)
    }

    fn check_crashed(&self) -> io::Result<()> {
        // ordering: Relaxed — the flag only gates error returns; the
        // on-disk bytes it models are ordered by the file syscalls
        // themselves, and the harness observes the flag after joining
        // the workload thread (join provides the happens-before edge).
        if self.crashed.load(Ordering::Relaxed) {
            Err(io::Error::other("injected crash: storage is offline"))
        } else {
            Ok(())
        }
    }

    /// Returns true exactly once: at the first matching op at or past
    /// the trigger.
    fn should_fire(&self, op: u64, on_write: bool) -> bool {
        // ordering: Relaxed — early-exit fast path; the authoritative
        // exactly-once decision is the `swap` below.
        if op < self.plan.trigger_op || self.fired.load(Ordering::Relaxed) {
            return false;
        }
        let matches = if on_write {
            self.plan.kind.fires_on_write()
        } else {
            self.plan.kind.fires_on_sync()
        };
        // ordering: Relaxed — RMW atomicity alone makes the swap
        // exactly-once; no memory is published through the flag.
        matches && !self.fired.swap(true, Ordering::Relaxed)
    }
}

struct FaultFile {
    inner: std::fs::File,
    /// File length as of the last successful sync; a failed sync
    /// truncates back to this, modelling lost page cache.
    synced_len: u64,
    ctl: FaultStorage,
}

impl Write for FaultFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.ctl.check_crashed()?;
        // ordering: Relaxed — the RMW hands every op a unique ticket
        // regardless of ordering; nothing else rides on the counter.
        let op = self.ctl.ops.fetch_add(1, Ordering::Relaxed);
        if self.ctl.should_fire(op, true) {
            match self.ctl.plan.kind {
                FaultKind::ShortWrite => {
                    let keep = (self.ctl.plan.seed % (buf.len().max(1) as u64)) as usize;
                    self.inner.write_all(&buf[..keep])?;
                    return Err(io::Error::other("injected short write"));
                }
                FaultKind::Enospc => {
                    return Err(io::Error::other("injected ENOSPC: no space left on device"));
                }
                FaultKind::TornWrite => {
                    let keep = (self.ctl.plan.seed % (buf.len().max(1) as u64)) as usize;
                    self.inner.write_all(&buf[..keep])?;
                    // ordering: Relaxed — see `check_crashed` for why
                    // the crash flag needs no publication ordering.
                    self.ctl.crashed.store(true, Ordering::Relaxed);
                    return Err(io::Error::other("injected torn write (process crash)"));
                }
                FaultKind::FsyncFail => unreachable!("fsync faults fire on sync"),
            }
        }
        self.inner.write_all(buf)?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.ctl.check_crashed()?;
        self.inner.flush()
    }
}

impl DurableFile for FaultFile {
    fn sync_data(&mut self) -> io::Result<()> {
        self.ctl.check_crashed()?;
        // ordering: Relaxed — unique ticket via RMW; see `write`.
        let op = self.ctl.ops.fetch_add(1, Ordering::Relaxed);
        if self.ctl.should_fire(op, false) {
            // The kernel never promised the unsynced bytes; drop them.
            self.inner.set_len(self.synced_len)?;
            return Err(io::Error::other("injected fsync failure; unsynced bytes dropped"));
        }
        self.inner.sync_data()?;
        self.synced_len = self.inner.metadata()?.len();
        Ok(())
    }

    fn set_len(&mut self, len: u64) -> io::Result<()> {
        self.ctl.check_crashed()?;
        self.inner.set_len(len)?;
        self.synced_len = self.synced_len.min(len);
        Ok(())
    }

    fn seek(&mut self, pos: io::SeekFrom) -> io::Result<u64> {
        self.ctl.check_crashed()?;
        self.inner.seek(pos)
    }
}

impl Storage for FaultStorage {
    fn open_durable(&self, path: &Path) -> io::Result<Box<dyn DurableFile>> {
        self.check_crashed()?;
        let f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)?;
        let synced_len = f.metadata()?.len();
        Ok(Box::new(FaultFile { inner: f, synced_len, ctl: self.clone() }))
    }

    fn create_durable(&self, path: &Path) -> io::Result<Box<dyn DurableFile>> {
        self.check_crashed()?;
        let f = std::fs::File::create(path)?;
        Ok(Box::new(FaultFile { inner: f, synced_len: 0, ctl: self.clone() }))
    }

    fn persist(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.check_crashed()?;
        std::fs::rename(from, to)?;
        sync_parent_dir(to);
        Ok(())
    }

    fn remove(&self, path: &Path) -> io::Result<()> {
        self.check_crashed()?;
        remove_tolerant(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let pid = std::process::id();
        let p = std::env::temp_dir().join(format!("largevis_faultio_{pid}_{name}"));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn real_storage_roundtrip_and_tolerant_remove() {
        let p = tmp("real");
        let s = RealStorage;
        let mut f = s.create_durable(&p).unwrap();
        f.write_all(b"abc").unwrap();
        f.sync_data().unwrap();
        drop(f);
        assert_eq!(std::fs::read(&p).unwrap(), b"abc");
        s.remove(&p).unwrap();
        s.remove(&p).unwrap(); // absent file is fine
    }

    #[test]
    fn short_write_is_transient_and_persists_prefix() {
        let p = tmp("short");
        let plan = FaultPlan { kind: FaultKind::ShortWrite, trigger_op: 1, seed: 2 };
        let s = FaultStorage::new(plan);
        let mut f = s.create_durable(&p).unwrap();
        f.write_all(b"aaaa").unwrap(); // op 0: before trigger
        let err = f.write_all(b"bbbb").unwrap_err(); // op 1: fires, keeps seed % 4 = 2 bytes
        assert!(err.to_string().contains("short write"), "{err}");
        assert!(s.fired());
        f.write_all(b"cccc").unwrap(); // transient: succeeds
        drop(f);
        assert_eq!(std::fs::read(&p).unwrap(), b"aaaabbcccc");
    }

    #[test]
    fn fsync_failure_drops_unsynced_bytes() {
        let p = tmp("fsync");
        // Ops: write(0) sync(1) write(2) sync(3 = trigger).
        let plan = FaultPlan { kind: FaultKind::FsyncFail, trigger_op: 3, seed: 0 };
        let s = FaultStorage::new(plan);
        let mut f = s.create_durable(&p).unwrap();
        f.write_all(b"hello").unwrap();
        f.sync_data().unwrap();
        f.write_all(b"world").unwrap();
        assert!(f.sync_data().is_err());
        drop(f);
        assert_eq!(std::fs::read(&p).unwrap(), b"hello", "unsynced bytes must disappear");
    }

    #[test]
    fn torn_write_crashes_all_subsequent_ops() {
        let p = tmp("torn");
        let plan = FaultPlan { kind: FaultKind::TornWrite, trigger_op: 0, seed: 3 };
        let s = FaultStorage::new(plan);
        let mut f = s.create_durable(&p).unwrap();
        assert!(f.write_all(b"abcdef").is_err());
        assert!(s.crashed());
        assert!(f.write_all(b"x").is_err(), "post-crash writes must fail");
        assert!(f.sync_data().is_err(), "post-crash syncs must fail");
        assert!(s.create_durable(&tmp("torn2")).is_err(), "post-crash opens must fail");
        drop(f);
        assert_eq!(std::fs::read(&p).unwrap(), b"abc", "torn prefix persists");
    }

    #[test]
    fn probe_counts_ops_without_firing() {
        let p = tmp("probe");
        let s = FaultStorage::probe();
        let mut f = s.create_durable(&p).unwrap();
        f.write_all(b"a").unwrap();
        f.sync_data().unwrap();
        f.write_all(b"b").unwrap();
        drop(f);
        assert_eq!(s.ops(), 3);
        assert!(!s.fired());
    }
}
