//! Streaming/summary statistics for benches and evaluation reports.

/// Summary statistics of a sample.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 when n < 2).
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (linear interpolation).
    pub p50: f64,
    /// 95th percentile (linear interpolation).
    pub p95: f64,
}

/// Compute summary statistics of `xs` (empty input gives zeros).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
    }
}

/// Percentile of an ascending-sorted slice with linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Pearson correlation of two equal-length samples.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(ys) {
        let (dx, dy) = (x - mx, y - my);
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summary() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_is_zeros() {
        assert_eq!(summarize(&[]), Summary::default());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }
}
