//! Bounded max-heaps for K-nearest-neighbor candidate sets.
//!
//! [`BoundedMaxHeap`] keeps the K smallest-distance candidates seen so
//! far (a max-heap on distance, popping the worst when over capacity) —
//! the structure `H_i` in the paper's Algorithm 1. A `flag` bit per
//! entry supports NN-Descent's "new vs old" bookkeeping, and a
//! membership set keeps candidates distinct.

/// One KNN candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Candidate {
    /// Squared distance to the query point (the heap key).
    pub dist: f32,
    /// Candidate point id.
    pub id: u32,
    /// NN-Descent "new" flag (true until the candidate has been expanded).
    pub flag: bool,
}

/// Max-heap on `dist` holding at most `k` *distinct* candidate ids.
#[derive(Clone, Debug)]
pub struct BoundedMaxHeap {
    k: usize,
    heap: Vec<Candidate>,
    members: std::collections::HashSet<u32>,
}

impl Default for BoundedMaxHeap {
    /// A capacity-1 heap (placeholder value for `parallel_map` slots).
    fn default() -> Self {
        BoundedMaxHeap::new(1)
    }
}

impl BoundedMaxHeap {
    /// Create with capacity `k > 0`.
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        BoundedMaxHeap { k, heap: Vec::with_capacity(k + 1), members: std::collections::HashSet::with_capacity(k * 2) }
    }

    /// Number of stored candidates.
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no candidates stored.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Worst (largest) distance currently kept, or `+inf` when not full.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].dist
        }
    }

    /// Try to insert; returns true if the candidate was kept.
    ///
    /// Duplicates (same `id`) are rejected; when full, a candidate is
    /// kept only if strictly better than the current worst under the
    /// `(dist, id)` order — so on equal distances the *lowest* ids are
    /// the ones retained, deterministically, regardless of arrival
    /// order (the oracle-parity contract `kernels::nearest_k` relies
    /// on).
    pub fn push(&mut self, id: u32, dist: f32, flag: bool) -> bool {
        if self.members.contains(&id) {
            return false;
        }
        if self.heap.len() < self.k {
            self.members.insert(id);
            self.heap.push(Candidate { dist, id, flag });
            self.sift_up(self.heap.len() - 1);
            true
        } else if (dist, id) < (self.heap[0].dist, self.heap[0].id) {
            self.members.remove(&self.heap[0].id);
            self.members.insert(id);
            self.heap[0] = Candidate { dist, id, flag };
            self.sift_down(0);
            true
        } else {
            false
        }
    }

    /// Candidates sorted ascending by distance (consumes the heap).
    pub fn into_sorted(mut self) -> Vec<Candidate> {
        self.heap.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.id.cmp(&b.id)));
        self.heap
    }

    /// Reset in place to capacity `k`, keeping the allocations — the
    /// scratch-reuse twin of `new` for per-worker heaps.
    pub fn reset(&mut self, k: usize) {
        assert!(k > 0);
        self.k = k;
        self.heap.clear();
        self.members.clear();
    }

    /// Sorted `(id, dist)` pairs, draining the heap in place — the
    /// scratch-reuse twin of [`BoundedMaxHeap::into_sorted`]: the heap
    /// is left empty (capacity retained) and only the returned result
    /// vector is allocated.
    pub fn drain_sorted_pairs(&mut self) -> Vec<(u32, f32)> {
        self.heap.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.id.cmp(&b.id)));
        self.members.clear();
        self.heap.drain(..).map(|c| (c.id, c.dist)).collect()
    }

    /// Unordered view of the stored candidates.
    #[inline]
    pub fn as_slice(&self) -> &[Candidate] {
        &self.heap
    }

    /// Mutable access (used by NN-Descent to clear flags in place).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [Candidate] {
        &mut self.heap
    }

    /// Lexicographic `(dist, id)` heap order: the root is the entry
    /// with the largest distance, ties broken toward the largest id —
    /// exactly the entry that must be evicted first for deterministic
    /// lowest-index-wins results.
    #[inline]
    fn worse(&self, a: usize, b: usize) -> bool {
        (self.heap[a].dist, self.heap[a].id) > (self.heap[b].dist, self.heap[b].id)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.worse(i, parent) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < n && self.worse(l, largest) {
                largest = l;
            }
            if r < n && self.worse(r, largest) {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_k_smallest() {
        let mut h = BoundedMaxHeap::new(3);
        for (id, d) in [(0, 5.0), (1, 1.0), (2, 4.0), (3, 2.0), (4, 3.0)] {
            h.push(id, d, false);
        }
        let out: Vec<u32> = h.into_sorted().iter().map(|c| c.id).collect();
        assert_eq!(out, vec![1, 3, 4]);
    }

    #[test]
    fn rejects_duplicates() {
        let mut h = BoundedMaxHeap::new(4);
        assert!(h.push(7, 1.0, false));
        assert!(!h.push(7, 0.5, false));
        assert_eq!(h.len(), 1);
    }

    #[test]
    fn threshold_tracks_worst() {
        let mut h = BoundedMaxHeap::new(2);
        assert_eq!(h.threshold(), f32::INFINITY);
        h.push(0, 3.0, false);
        assert_eq!(h.threshold(), f32::INFINITY);
        h.push(1, 1.0, false);
        assert_eq!(h.threshold(), 3.0);
        h.push(2, 2.0, false);
        assert_eq!(h.threshold(), 2.0);
    }

    #[test]
    fn eviction_maintains_membership() {
        let mut h = BoundedMaxHeap::new(2);
        h.push(0, 3.0, false);
        h.push(1, 2.0, false);
        h.push(2, 1.0, false); // evicts id=0
        assert!(h.push(0, 0.5, false)); // id=0 may re-enter
        let ids: Vec<u32> = h.into_sorted().iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 2]);
    }

    #[test]
    fn reset_and_drain_reuse() {
        let mut h = BoundedMaxHeap::new(2);
        h.push(0, 3.0, false);
        h.push(1, 1.0, false);
        h.push(2, 2.0, false);
        assert_eq!(h.drain_sorted_pairs(), vec![(1, 1.0), (2, 2.0)]);
        assert!(h.is_empty());
        // Drained ids may re-enter; reset can change capacity.
        assert!(h.push(1, 5.0, false));
        h.reset(3);
        assert!(h.is_empty());
        assert_eq!(h.threshold(), f32::INFINITY);
        for (id, d) in [(9, 0.5), (8, 0.25), (7, 1.0), (6, 0.75)] {
            h.push(id, d, false);
        }
        assert_eq!(h.drain_sorted_pairs(), vec![(8, 0.25), (9, 0.5), (6, 0.75)]);
    }

    #[test]
    fn ties_keep_lowest_ids_regardless_of_arrival_order() {
        // Regression: with dist-only heap ordering, equal-distance
        // entries could be evicted by root position (arrival order),
        // so {0,1} vs {1,2} depended on the sift history. The (dist,
        // id) order pins lowest-index-wins.
        let mut h = BoundedMaxHeap::new(2);
        for (id, d) in [(0, 3.0), (1, 3.0), (2, 3.0), (3, 1.0)] {
            h.push(id, d, false);
        }
        let ids: Vec<u32> = h.into_sorted().iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![3, 0], "lowest id must survive the tie");

        // Same distances presented in reverse id order.
        let mut h = BoundedMaxHeap::new(2);
        for (id, d) in [(3, 1.0), (2, 3.0), (1, 3.0), (0, 3.0)] {
            h.push(id, d, false);
        }
        let ids: Vec<u32> = h.into_sorted().iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![3, 0], "arrival order must not matter");
    }

    #[test]
    fn property_matches_sort_reference() {
        // Property test: heap result == take-k-smallest of a sorted copy,
        // across random inputs (distinct keys to avoid tie ambiguity).
        let mut rng = Rng::new(2024);
        for trial in 0..50 {
            let n = 1 + rng.below(200);
            let k = 1 + rng.below(30);
            let mut items: Vec<(u32, f32)> =
                (0..n).map(|i| (i as u32, rng.f32() + i as f32 * 1e-6)).collect();
            rng.shuffle(&mut items);
            let mut h = BoundedMaxHeap::new(k);
            for &(id, d) in &items {
                h.push(id, d, false);
            }
            let got: Vec<u32> = h.into_sorted().iter().map(|c| c.id).collect();
            let mut sorted = items.clone();
            sorted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let expect: Vec<u32> = sorted.iter().take(k).map(|&(id, _)| id).collect();
            assert_eq!(got, expect, "trial={trial} n={n} k={k}");
        }
    }
}
