//! Scheduler-instrumented drop-in replacements for the `std::sync`
//! types (`--cfg modelcheck` builds only).
//!
//! Every type here keeps a *real* `std` primitive inside it and runs in
//! one of two modes, decided per call by [`current`]:
//!
//! - **Under an active exploration** (the calling OS thread is a
//!   registered model thread of the live engine): the operation is
//!   routed through the scheduler, which decides interleaving and —
//!   for atomic loads — which stored value is observed. The real
//!   primitive is kept as an uncontended mirror: model-level mutual
//!   exclusion is enforced by the engine, so the real `Mutex` below a
//!   model-owned one never blocks for long, and the real atomic just
//!   mirrors the newest store so the next schedule (and any
//!   unregistered observer) seeds from a sane value.
//! - **Outside an exploration** the wrappers delegate to the real
//!   primitive untouched, so a modelcheck-cfg'd binary still behaves
//!   like a normal build.
//!
//! The method surface is intentionally the subset the migrated modules
//! use (`load`/`store`/`fetch_add`/`fetch_sub`, `lock`, `wait`/
//! `wait_timeout`/`notify_*`, `spawn`/`scope`/`sleep`); grow it with
//! call sites, not speculatively.

use std::fmt;
use std::mem::ManuallyDrop;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{
    Arc as StdArc, Condvar as StdCondvar, LockResult, Mutex as StdMutex,
    MutexGuard as StdMutexGuard, PoisonError,
};
use std::time::Duration;

use super::sched::{abort_schedule, current, record_thread_panic, Engine, TId};

// ------------------------------------------------------------ atomics

macro_rules! numeric_atomic {
    ($Name:ident, $Std:ident, $Prim:ty) => {
        /// Instrumented counterpart of the same-named `std` atomic:
        /// identical method signatures, scheduler-routed under an
        /// active exploration, plain `std` otherwise.
        pub struct $Name {
            real: std::sync::atomic::$Std,
        }

        impl $Name {
            /// Creates a new atomic with the given initial value.
            pub const fn new(v: $Prim) -> Self {
                Self { real: std::sync::atomic::$Std::new(v) }
            }

            fn addr(&self) -> usize {
                self as *const Self as usize
            }

            fn init(&self) -> u64 {
                // ordering: Relaxed — this only seeds the model cell's
                // history on first touch; no cross-thread protocol
                // hangs off the mirror itself.
                self.real.load(Ordering::Relaxed) as u64
            }

            /// See [`std::sync::atomic::$Std::load`].
            pub fn load(&self, order: Ordering) -> $Prim {
                match current() {
                    Some((e, t)) => e.atomic_load(t, self.addr(), self.init(), order) as $Prim,
                    None => self.real.load(order),
                }
            }

            /// See [`std::sync::atomic::$Std::store`].
            pub fn store(&self, val: $Prim, order: Ordering) {
                match current() {
                    Some((e, t)) => {
                        e.atomic_store(t, self.addr(), self.init(), val as u64, order);
                        // ordering: Relaxed — mirror write; model
                        // threads never read through the mirror while
                        // a schedule is live.
                        self.real.store(val, Ordering::Relaxed);
                    }
                    None => self.real.store(val, order),
                }
            }

            /// See [`std::sync::atomic::$Std::fetch_add`].
            pub fn fetch_add(&self, val: $Prim, order: Ordering) -> $Prim {
                self.rmw(order, |o| o.wrapping_add(val))
            }

            /// See [`std::sync::atomic::$Std::fetch_sub`].
            pub fn fetch_sub(&self, val: $Prim, order: Ordering) -> $Prim {
                self.rmw(order, |o| o.wrapping_sub(val))
            }

            fn rmw(&self, order: Ordering, f: impl Fn($Prim) -> $Prim) -> $Prim {
                match current() {
                    Some((e, t)) => {
                        let old = e.atomic_rmw(t, self.addr(), self.init(), order, |o| {
                            f(o as $Prim) as u64
                        }) as $Prim;
                        // ordering: Relaxed — mirror write (see store).
                        self.real.store(f(old), Ordering::Relaxed);
                        old
                    }
                    None => {
                        // Outside a schedule there is no scheduler to
                        // serialize us, so use the real RMW.
                        let mut cur = self.real.load(Ordering::Relaxed);
                        loop {
                            match self.real.compare_exchange_weak(
                                cur,
                                f(cur),
                                order,
                                Ordering::Relaxed,
                            ) {
                                Ok(v) => return v,
                                Err(v) => cur = v,
                            }
                        }
                    }
                }
            }
        }

        impl fmt::Debug for $Name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.debug_tuple(stringify!($Name)).field(&self.real.load(Ordering::Relaxed)).finish()
            }
        }
    };
}

numeric_atomic!(AtomicU64, AtomicU64, u64);
numeric_atomic!(AtomicU32, AtomicU32, u32);
numeric_atomic!(AtomicUsize, AtomicUsize, usize);

/// Instrumented counterpart of [`std::sync::atomic::AtomicBool`].
pub struct AtomicBool {
    real: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    /// Creates a new boolean atomic with the given initial value.
    pub const fn new(v: bool) -> Self {
        Self { real: std::sync::atomic::AtomicBool::new(v) }
    }

    fn addr(&self) -> usize {
        self as *const Self as usize
    }

    /// See [`std::sync::atomic::AtomicBool::load`].
    pub fn load(&self, order: Ordering) -> bool {
        match current() {
            Some((e, t)) => {
                // ordering: Relaxed — mirror read only seeds history.
                let init = self.real.load(Ordering::Relaxed) as u64;
                e.atomic_load(t, self.addr(), init, order) != 0
            }
            None => self.real.load(order),
        }
    }

    /// See [`std::sync::atomic::AtomicBool::store`].
    pub fn store(&self, val: bool, order: Ordering) {
        match current() {
            Some((e, t)) => {
                // ordering: Relaxed — mirror read only seeds history.
                let init = self.real.load(Ordering::Relaxed) as u64;
                e.atomic_store(t, self.addr(), init, val as u64, order);
                // ordering: Relaxed — mirror write (see module doc).
                self.real.store(val, Ordering::Relaxed);
            }
            None => self.real.store(val, order),
        }
    }
}

impl fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_tuple("AtomicBool").field(&self.real.load(Ordering::Relaxed)).finish()
    }
}

// ------------------------------------------------------------ mutexes

/// Instrumented counterpart of [`std::sync::Mutex`]. Model-level
/// ownership (who may hold it, in what order) is decided by the
/// scheduler; the data itself still lives behind the real `std` mutex,
/// which is uncontended whenever the model owns locking order.
pub struct Mutex<T> {
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// Creates a new mutex protecting `t`.
    pub const fn new(t: T) -> Self {
        Self { inner: StdMutex::new(t) }
    }

    fn addr(&self) -> usize {
        &self.inner as *const StdMutex<T> as usize
    }

    /// See [`std::sync::Mutex::lock`]; poisoning behaves as in `std`.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let model = match current() {
            Some((e, t)) => {
                e.mutex_lock(t, self.addr());
                Some((e, t))
            }
            None => None,
        };
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard { real: Some(g), lock: self, model }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                real: Some(p.into_inner()),
                lock: self,
                model,
            })),
        }
    }

    /// See [`std::sync::Mutex::into_inner`].
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    /// See [`std::sync::Mutex::get_mut`].
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

/// RAII guard returned by [`Mutex::lock`]; releases model ownership,
/// then the real lock, on drop.
pub struct MutexGuard<'a, T> {
    /// `Some` for the guard's whole life; only taken when a condvar
    /// wait dismantles the guard without running its `Drop`.
    real: Option<StdMutexGuard<'a, T>>,
    lock: &'a Mutex<T>,
    model: Option<(StdArc<Engine>, TId)>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.real.as_deref().expect("guard dismantled")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.real.as_deref_mut().expect("guard dismantled")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((e, t)) = self.model.take() {
            // During an unwind the schedule is being aborted (or the
            // panic itself is the model failure); re-entering the
            // scheduler from a destructor would panic-in-panic, and
            // the model state is discarded anyway.
            if !std::thread::panicking() {
                e.mutex_unlock(t, self.lock.addr());
            }
        }
        // The real guard (self.real) drops after this body, releasing
        // the underlying std mutex.
    }
}

// ----------------------------------------------------------- condvars

/// Result of [`Condvar::wait_timeout`]. The std type cannot be
/// constructed outside `std`, so the shim defines its own; under an
/// active exploration waits never time out — a missing wakeup then
/// surfaces as a detected deadlock instead of being masked by a
/// timeout retry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// True when the wait returned because the timeout elapsed.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Instrumented counterpart of [`std::sync::Condvar`].
pub struct Condvar {
    real: StdCondvar,
}

impl Condvar {
    /// Creates a new condition variable.
    pub const fn new() -> Self {
        Self { real: StdCondvar::new() }
    }

    fn addr(&self) -> usize {
        self as *const Self as usize
    }

    /// See [`std::sync::Condvar::wait`].
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match guard.model.clone() {
            Some((e, t)) => {
                let lock = guard.lock;
                // Dismantle the guard without running its Drop: the
                // model-level mutex release happens atomically with
                // parking inside `cond_wait`.
                let mut g = ManuallyDrop::new(guard);
                let real = g.real.take();
                g.model = None;
                // Release the real lock before parking so the thread
                // that will notify us can take it.
                drop(real);
                e.cond_wait(t, self.addr(), lock.addr());
                // Woken and rescheduled: reacquire model, then real.
                e.mutex_lock(t, lock.addr());
                match lock.inner.lock() {
                    Ok(rg) => {
                        Ok(MutexGuard { real: Some(rg), lock, model: Some((e, t)) })
                    }
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        real: Some(p.into_inner()),
                        lock,
                        model: Some((e, t)),
                    })),
                }
            }
            None => {
                let lock = guard.lock;
                let mut g = ManuallyDrop::new(guard);
                let real = g.real.take().expect("guard dismantled");
                match self.real.wait(real) {
                    Ok(rg) => Ok(MutexGuard { real: Some(rg), lock, model: None }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        real: Some(p.into_inner()),
                        lock,
                        model: None,
                    })),
                }
            }
        }
    }

    /// See [`std::sync::Condvar::wait_timeout`]. Under an active
    /// exploration the timeout is ignored (see [`WaitTimeoutResult`]).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if guard.model.is_some() {
            return match self.wait(guard) {
                Ok(g) => Ok((g, WaitTimeoutResult(false))),
                Err(p) => Err(PoisonError::new((p.into_inner(), WaitTimeoutResult(false)))),
            };
        }
        let lock = guard.lock;
        let mut g = ManuallyDrop::new(guard);
        let real = g.real.take().expect("guard dismantled");
        match self.real.wait_timeout(real, dur) {
            Ok((rg, wtr)) => Ok((
                MutexGuard { real: Some(rg), lock, model: None },
                WaitTimeoutResult(wtr.timed_out()),
            )),
            Err(p) => {
                let (rg, wtr) = p.into_inner();
                Err(PoisonError::new((
                    MutexGuard { real: Some(rg), lock, model: None },
                    WaitTimeoutResult(wtr.timed_out()),
                )))
            }
        }
    }

    /// See [`std::sync::Condvar::notify_one`].
    pub fn notify_one(&self) {
        match current() {
            Some((e, t)) => e.cond_notify(t, self.addr(), false),
            None => self.real.notify_one(),
        }
    }

    /// See [`std::sync::Condvar::notify_all`].
    pub fn notify_all(&self) {
        match current() {
            Some((e, t)) => e.cond_notify(t, self.addr(), true),
            None => self.real.notify_all(),
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

// ------------------------------------------------------------ threads

/// Instrumented `std::thread` subset: spawned threads register with
/// the live engine (when one exists) so they become schedulable model
/// entities; scopes model-join their children before std's implicit
/// real join so teardown stays under scheduler control.
pub mod thread_shim {
    use super::*;

    /// See [`std::thread::sleep`]. Under an active exploration this is
    /// a pure scheduling point — model time does not exist, and a
    /// sleep-based backoff loop becomes an explorable yield.
    pub fn sleep(dur: Duration) {
        match current() {
            Some((e, t)) => e.yield_point(t),
            None => std::thread::sleep(dur),
        }
    }

    /// Body wrapper for every model-registered thread: claims the
    /// model id on the OS thread, converts panics into schedule
    /// failures, and always reports completion to the scheduler.
    fn run_model_thread<T>(e: StdArc<Engine>, tid: TId, f: impl FnOnce() -> T) -> Option<T> {
        e.claim(tid);
        let e2 = e.clone();
        match catch_unwind(AssertUnwindSafe(move || {
            // First decision point: the scheduler — not the OS —
            // decides when this thread first runs relative to its
            // siblings' instrumented operations.
            e2.yield_point(tid);
            f()
        })) {
            Ok(v) => {
                e.finish_thread(tid, None);
                Some(v)
            }
            Err(p) => {
                record_thread_panic(&e, tid, p.as_ref());
                None
            }
        }
    }

    /// See [`std::thread::spawn`].
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        match current() {
            Some((e, parent)) => {
                let child = e.register_thread(parent);
                let e2 = e.clone();
                let inner = std::thread::spawn(move || run_model_thread(e2, child, f));
                JoinHandle { inner, model: Some(child) }
            }
            None => JoinHandle { inner: std::thread::spawn(move || Some(f())), model: None },
        }
    }

    /// See [`std::thread::JoinHandle`].
    pub struct JoinHandle<T> {
        inner: std::thread::JoinHandle<Option<T>>,
        model: Option<TId>,
    }

    impl<T> JoinHandle<T> {
        /// See [`std::thread::JoinHandle::join`]. Joins at the model
        /// level first (a schedulable blocking point), then reaps the
        /// OS thread, which exits promptly once model-finished.
        pub fn join(self) -> std::thread::Result<T> {
            if let Some(child) = self.model {
                if let Some((e, me)) = current() {
                    e.join_thread(me, child);
                }
            }
            match self.inner.join() {
                Ok(Some(v)) => Ok(v),
                Ok(None) => Err(Box::new("model thread panicked")),
                Err(p) => Err(p),
            }
        }
    }

    /// See [`std::thread::scope`]. The shim passes the scope token by
    /// value (it is `Copy`); call sites written against std's by-ref
    /// token compile unchanged because closure parameter types are
    /// inferred and method calls auto-reference.
    pub fn scope<'env, F, T>(f: F) -> T
    where
        F: for<'scope> FnOnce(Scope<'scope, 'env>) -> T,
    {
        // Model ids of every thread spawned through this scope; the
        // scope must model-join them all before std's implicit *real*
        // join parks this OS thread outside the scheduler's view.
        let children: StdMutex<Vec<TId>> = StdMutex::new(Vec::new());
        std::thread::scope(|s| {
            let r = catch_unwind(AssertUnwindSafe(|| f(Scope { inner: s, children: &children })));
            if let Some((e, me)) = current() {
                match &r {
                    Ok(_) => {
                        let kids: Vec<TId> =
                            children.lock().unwrap_or_else(|p| p.into_inner()).clone();
                        for c in kids {
                            e.join_thread(me, c);
                        }
                    }
                    Err(p) => {
                        // The scope body failed: abort the schedule so
                        // blocked children unwind and std's implicit
                        // join can finish, then re-raise below.
                        abort_schedule(&e, p.as_ref());
                    }
                }
            }
            match r {
                Ok(v) => v,
                Err(p) => resume_unwind(p),
            }
        })
    }

    /// See [`std::thread::Scope`].
    #[derive(Clone, Copy)]
    pub struct Scope<'scope, 'env: 'scope> {
        inner: &'scope std::thread::Scope<'scope, 'env>,
        children: &'scope StdMutex<Vec<TId>>,
    }

    impl<'scope, 'env> Scope<'scope, 'env> {
        /// See [`std::thread::Scope::spawn`].
        pub fn spawn<F, T>(&self, f: F) -> ScopedJoinHandle<'scope, T>
        where
            F: FnOnce() -> T + Send + 'scope,
            T: Send + 'scope,
        {
            match current() {
                Some((e, parent)) => {
                    let child = e.register_thread(parent);
                    self.children.lock().unwrap_or_else(|p| p.into_inner()).push(child);
                    let e2 = e.clone();
                    let inner = self.inner.spawn(move || run_model_thread(e2, child, f));
                    ScopedJoinHandle { inner, model: Some(child) }
                }
                None => ScopedJoinHandle {
                    inner: self.inner.spawn(move || Some(f())),
                    model: None,
                },
            }
        }
    }

    /// See [`std::thread::ScopedJoinHandle`].
    pub struct ScopedJoinHandle<'scope, T> {
        inner: std::thread::ScopedJoinHandle<'scope, Option<T>>,
        model: Option<TId>,
    }

    impl<T> ScopedJoinHandle<'_, T> {
        /// See [`std::thread::ScopedJoinHandle::join`]; model join
        /// first, then the real reap (see [`JoinHandle::join`]).
        pub fn join(self) -> std::thread::Result<T> {
            if let Some(child) = self.model {
                if let Some((e, me)) = current() {
                    e.join_thread(me, child);
                }
            }
            match self.inner.join() {
                Ok(Some(v)) => Ok(v),
                Ok(None) => Err(Box::new("model thread panicked")),
                Err(p) => Err(p),
            }
        }
    }
}
