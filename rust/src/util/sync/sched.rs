//! Deterministic schedule-exploration engine behind the sync shim
//! (`--cfg modelcheck` builds only).
//!
//! One *exploration* ([`explore`]) runs a test closure under many
//! thread interleavings. Within one *schedule* (a single run of the
//! closure) exactly one model thread executes at a time: every
//! instrumented operation — atomic load/store/RMW, mutex lock/unlock,
//! condvar wait/notify, thread spawn/join — is a *decision point*
//! where the engine consults the schedule driver for (a) which thread
//! runs next and (b), on atomic loads with several legal values, which
//! store the load observes.
//!
//! Two drivers:
//! - **DFS** — bounded-exhaustive depth-first search over the decision
//!   tree with a preemption bound (Musuvathi/Qadeer-style iterative
//!   context bounding): option 0 at every thread node keeps the
//!   current thread running, so the default path is the sequential
//!   execution and each backtracked branch spends preemptions
//!   explicitly. Complete (up to the bound) for the small models the
//!   invariant tests build.
//! - **PCT** — seeded probabilistic concurrency testing: random thread
//!   priorities with `pct_depth - 1` priority-change points per
//!   schedule, which gives a known lower bound on the probability of
//!   hitting any bug of depth `pct_depth`. Used for sweeps above the
//!   exhaustive budget.
//!
//! Memory-model approximation (documented, deliberately simple): every
//! store to an atomic cell is kept in the cell's modification-order
//! history together with the writer's vector clock. A load may observe
//! any store that is not *known-overwritten* — i.e. no later store of
//! the same cell happens-before the loading thread's current clock —
//! and not older than anything the thread already read or wrote there
//! (per-thread coherence). `Release`/`AcqRel`/`SeqCst` stores attach
//! the writer's clock; `Acquire`/`AcqRel`/`SeqCst` loads that observe
//! such a store join it, creating the happens-before edge that prunes
//! staleness. `Relaxed` transfers nothing, so downgrading a
//! publication store is an observable model change — exactly what the
//! mutation corpus relies on. RMWs always read the newest store
//! (coherence requires it). `SeqCst` is approximated as `AcqRel`: the
//! single total order is not modeled, which can only make the checker
//! *more* suspicious of SeqCst-dependent code, never less. Mutexes and
//! condvars are sequentially consistent (as in practice); condvar
//! waits never time out spuriously inside the model, so a lost wakeup
//! manifests as a detected deadlock instead of being masked by a
//! timeout retry.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex};

// ------------------------------------------------------------- config

/// Which schedule driver an exploration uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Bounded-exhaustive DFS with a preemption bound.
    Dfs,
    /// Seeded PCT-style random priority scheduling.
    Pct,
}

/// Exploration configuration. [`Config::from_env`] reads the CI knobs;
/// every field can also be set directly by a test.
#[derive(Clone, Debug)]
pub struct Config {
    /// Driver choice (`LARGEVIS_MODELCHECK_MODE` = `dfs` | `pct`).
    pub mode: Mode,
    /// PCT seed (`LARGEVIS_MODELCHECK_SEED`); ignored by DFS.
    pub seed: u64,
    /// Schedule budget (`LARGEVIS_MODELCHECK_SCHEDULES`): DFS stops
    /// early (reported as incomplete), PCT runs exactly this many.
    pub max_schedules: u64,
    /// DFS preemption bound (`LARGEVIS_MODELCHECK_PREEMPTIONS`).
    pub preemption_bound: u32,
    /// Per-schedule step guard against accidental livelock.
    pub max_steps: u64,
    /// PCT priority-change points per schedule.
    pub pct_depth: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            mode: Mode::Dfs,
            seed: 1,
            max_schedules: 20_000,
            preemption_bound: 2,
            max_steps: 50_000,
            pct_depth: 3,
        }
    }
}

impl Config {
    /// Defaults overridden by the `LARGEVIS_MODELCHECK_*` environment
    /// knobs (the CI sweep's interface).
    pub fn from_env() -> Config {
        let mut c = Config::default();
        if let Ok(v) = std::env::var("LARGEVIS_MODELCHECK_MODE") {
            if v.eq_ignore_ascii_case("pct") {
                c.mode = Mode::Pct;
            }
        }
        let num = |name: &str| std::env::var(name).ok().and_then(|v| v.parse::<u64>().ok());
        if let Some(v) = num("LARGEVIS_MODELCHECK_SEED") {
            c.seed = v;
        }
        if let Some(v) = num("LARGEVIS_MODELCHECK_SCHEDULES") {
            c.max_schedules = v.max(1);
        }
        if let Some(v) = num("LARGEVIS_MODELCHECK_PREEMPTIONS") {
            c.preemption_bound = v.min(u32::MAX as u64) as u32;
        }
        c
    }
}

/// Outcome of one exploration.
#[derive(Clone, Debug)]
pub struct Report {
    /// Model name (for the JSON report / failure messages).
    pub name: String,
    /// Driver that ran.
    pub mode: Mode,
    /// Seed used (PCT; echoed for DFS).
    pub seed: u64,
    /// Schedules executed.
    pub schedules: u64,
    /// True when DFS exhausted the (bounded) tree within the budget.
    pub complete: bool,
    /// Longest schedule, in decision steps.
    pub max_steps: u64,
    /// Preemption bound in force (DFS).
    pub preemption_bound: u32,
    /// Most preemptions spent by any executed schedule.
    pub max_preemptions: u32,
    /// First invariant violation found, if any.
    pub failure: Option<Failure>,
}

/// A schedule that violated an invariant.
#[derive(Clone, Debug)]
pub struct Failure {
    /// 1-based index of the failing schedule.
    pub schedule: u64,
    /// Panic message / deadlock description.
    pub message: String,
    /// Tail of the failing schedule's operation log.
    pub trace: Vec<String>,
}

// --------------------------------------------------------- primitives

/// Thread id inside one schedule (0 = the closure's own thread).
pub(super) type TId = usize;

/// Vector clock over model threads.
#[derive(Clone, Debug, Default, PartialEq)]
struct VClock(Vec<u64>);

impl VClock {
    fn tick(&mut self, t: TId) -> u64 {
        self.ensure(t);
        self.0[t] += 1;
        self.0[t]
    }
    fn ensure(&mut self, t: TId) {
        if self.0.len() <= t {
            self.0.resize(t + 1, 0);
        }
    }
    fn get(&self, t: TId) -> u64 {
        self.0.get(t).copied().unwrap_or(0)
    }
    fn join(&mut self, other: &VClock) {
        self.ensure(other.0.len().saturating_sub(1));
        for (i, &v) in other.0.iter().enumerate() {
            if self.0[i] < v {
                self.0[i] = v;
            }
        }
    }
}

/// One store in a cell's modification order.
#[derive(Clone, Debug)]
struct StoreRec {
    val: u64,
    /// Writer thread and its own clock component at the store — the
    /// "write event" used by the known-overwritten rule.
    wtid: TId,
    wtick: u64,
    /// Writer's full clock, attached when the store was
    /// `Release`/`AcqRel`/`SeqCst`; acquiring readers join it.
    release: Option<VClock>,
}

#[derive(Debug, Default)]
struct CellHist {
    stores: Vec<StoreRec>,
    /// Per-thread coherence floor: the newest store index each thread
    /// has read or written (it may never observe anything older).
    floor: Vec<usize>,
}

impl CellHist {
    fn seeded(init: u64) -> CellHist {
        CellHist {
            stores: vec![StoreRec { val: init, wtid: 0, wtick: 0, release: Some(VClock::default()) }],
            floor: Vec::new(),
        }
    }
    fn floor_of(&self, t: TId) -> usize {
        self.floor.get(t).copied().unwrap_or(0)
    }
    fn raise_floor(&mut self, t: TId, idx: usize) {
        if self.floor.len() <= t {
            self.floor.resize(t + 1, 0);
        }
        if self.floor[t] < idx {
            self.floor[t] = idx;
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    Runnable,
    /// Waiting to acquire the mutex keyed by this id.
    BlockedMutex(usize),
    /// Parked on the condvar keyed by this id.
    BlockedCond(usize),
    /// Waiting for the given thread to finish.
    BlockedJoin(TId),
    Finished,
}

#[derive(Debug)]
struct ThreadState {
    status: Status,
    clock: VClock,
    /// PCT priority (higher runs first).
    priority: u64,
}

#[derive(Debug, Default)]
struct MutexInfo {
    owner: Option<TId>,
    /// Clock joined by each successful acquire (release consistency of
    /// the critical sections).
    sync: VClock,
}

#[derive(Debug, Default)]
struct CondInfo {
    /// Parked waiters in arrival order, with the mutex they released.
    waiters: Vec<(TId, usize)>,
}

// ------------------------------------------------------------- driver

/// One recorded DFS decision node.
#[derive(Clone, Debug)]
struct Node {
    chosen: usize,
    n: usize,
    /// For thread nodes: whether option `i` preempts (switches away
    /// from a still-runnable active thread).
    preemptive: Vec<bool>,
    preempts_before: u32,
}

enum Driver {
    Dfs { script: Vec<Node>, pos: usize, bound: u32 },
    Pct { rng: Pcg, change_steps: Vec<u64>, step: u64 },
}

/// Minimal PCG32-style generator: deterministic per seed, no deps.
struct Pcg(u64);

impl Pcg {
    fn new(seed: u64) -> Pcg {
        Pcg(seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xDA3E39CB94B95BDB))
    }
    fn next(&mut self) -> u64 {
        // xorshift64*: plenty for schedule sampling.
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }
}

// ------------------------------------------------------------- engine

/// Panic payload used to unwind model threads when a schedule aborts
/// (deadlock, budget, or another thread's failure). Swallowed by the
/// spawn wrappers; never reaches user code.
pub(super) struct ModelAbort;

struct EngineState {
    threads: Vec<ThreadState>,
    active: TId,
    abort: bool,
    failure: Option<String>,
    trace: Vec<String>,
    driver: Driver,
    cells: HashMap<usize, CellHist>,
    mutexes: HashMap<usize, MutexInfo>,
    condvars: HashMap<usize, CondInfo>,
    steps: u64,
    max_steps: u64,
    preemptions: u32,
    /// Model threads whose OS thread has not yet finished (schedule
    /// teardown waits for this to reach zero).
    live: usize,
}

pub(super) struct Engine {
    mu: StdMutex<EngineState>,
    cv: StdCondvar,
    /// Exploration generation stamp; detached threads from an aborted
    /// schedule compare it and unwind instead of touching fresh state.
    pub(super) gen: u64,
}

thread_local! {
    /// (engine generation, model thread id) of the current OS thread.
    static SELF_ID: std::cell::Cell<Option<(u64, TId)>> = const { std::cell::Cell::new(None) };
}

/// The engine of the exploration currently running (one at a time;
/// [`explore`] serializes on `EXPLORE_LOCK`).
static ACTIVE: StdMutex<Option<StdArc<Engine>>> = StdMutex::new(None);
static EXPLORE_LOCK: StdMutex<()> = StdMutex::new(());
static GEN: StdMutex<u64> = StdMutex::new(0);

/// The current engine + this thread's model id, when this OS thread is
/// a registered model thread of the live exploration.
pub(super) fn current() -> Option<(StdArc<Engine>, TId)> {
    let engine = ACTIVE.lock().unwrap_or_else(|e| e.into_inner()).clone()?;
    let (gen, tid) = SELF_ID.with(|s| s.get())?;
    if gen == engine.gen {
        Some((engine, tid))
    } else {
        None
    }
}

fn unwind_abort() -> ! {
    std::panic::panic_any(ModelAbort)
}

impl Engine {
    fn new(gen: u64, driver: Driver, max_steps: u64) -> Engine {
        let mut clock = VClock::default();
        clock.ensure(0);
        Engine {
            mu: StdMutex::new(EngineState {
                threads: vec![ThreadState { status: Status::Runnable, clock, priority: u64::MAX }],
                active: 0,
                abort: false,
                failure: None,
                trace: Vec::new(),
                driver,
                cells: HashMap::new(),
                mutexes: HashMap::new(),
                condvars: HashMap::new(),
                steps: 0,
                max_steps,
                preemptions: 0,
                live: 1,
            }),
            cv: StdCondvar::new(),
            gen,
        }
    }

    fn log(st: &mut EngineState, t: TId, msg: &str) {
        if st.trace.len() >= 512 {
            st.trace.remove(0);
        }
        st.trace.push(format!("[t{t}] {msg}"));
    }

    /// Record a failure and abort every thread of this schedule.
    fn fail(&self, st: &mut EngineState, msg: String) {
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.abort = true;
        self.cv.notify_all();
    }

    /// Run `op` as the active thread: wait for the scheduler to grant
    /// this thread the baton, execute, then hand the next decision to
    /// the driver. `op` must not block.
    fn turn<R>(&self, t: TId, desc: &str, op: impl FnOnce(&mut EngineState) -> R) -> R {
        let mut st = self.mu.lock().unwrap_or_else(|e| e.into_inner());
        while !st.abort && st.active != t {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.abort {
            drop(st);
            unwind_abort();
        }
        st.steps += 1;
        if st.steps > st.max_steps {
            self.fail(&mut st, format!("model exceeded {} steps (livelock?)", st.max_steps));
            drop(st);
            unwind_abort();
        }
        Self::log(&mut st, t, desc);
        let r = op(&mut st);
        self.reschedule(&mut st, t);
        if st.abort && st.active == t {
            // This thread was chosen but the schedule already failed.
            drop(st);
            unwind_abort();
        }
        r
    }

    /// Pick the next thread to hold the baton. Called with the state
    /// lock held, after `from` completed an operation (or blocked).
    fn reschedule(&self, st: &mut EngineState, from: TId) {
        let mut opts: Vec<TId> = Vec::new();
        if st.threads[from].status == Status::Runnable {
            opts.push(from);
        }
        for (i, th) in st.threads.iter().enumerate() {
            if i != from && th.status == Status::Runnable {
                opts.push(i);
            }
        }
        if opts.is_empty() {
            let unfinished: Vec<TId> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, th)| th.status != Status::Finished)
                .map(|(i, _)| i)
                .collect();
            if !unfinished.is_empty() {
                let detail: Vec<String> = unfinished
                    .iter()
                    .map(|&i| format!("t{}={:?}", i, st.threads[i].status))
                    .collect();
                self.fail(st, format!("deadlock: no runnable thread ({})", detail.join(", ")));
            }
            return;
        }
        let from_runnable = st.threads[from].status == Status::Runnable;
        let pick = match &mut st.driver {
            Driver::Dfs { script, pos, bound } => {
                let preemptive: Vec<bool> =
                    opts.iter().map(|&o| from_runnable && o != from).collect();
                let preempts_before = st.preemptions;
                let chosen = if *pos < script.len() {
                    script[*pos].chosen.min(opts.len() - 1)
                } else {
                    // Default: first option within the preemption
                    // budget (option 0 never preempts by construction).
                    let c = (0..opts.len())
                        .find(|&i| !preemptive[i] || preempts_before < *bound)
                        .unwrap_or(0);
                    script.push(Node { chosen: c, n: opts.len(), preemptive: preemptive.clone(), preempts_before });
                    c
                };
                *pos += 1;
                if preemptive[chosen] {
                    st.preemptions += 1;
                }
                opts[chosen]
            }
            Driver::Pct { rng, change_steps, step } => {
                *step += 1;
                if change_steps.contains(step) {
                    // Priority-change point: demote the active thread.
                    let new = rng.next() % 1024;
                    st.threads[from].priority = new;
                }
                let mut best = opts[0];
                for &o in &opts {
                    if st.threads[o].priority > st.threads[best].priority {
                        best = o;
                    }
                }
                if from_runnable && best != from {
                    st.preemptions += 1;
                }
                best
            }
        };
        if pick != st.active {
            st.active = pick;
        }
        self.cv.notify_all();
    }

    /// A value decision (which candidate a load observes).
    fn choose_value(&self, st: &mut EngineState, n: usize) -> usize {
        if n <= 1 {
            return 0;
        }
        match &mut st.driver {
            Driver::Dfs { script, pos, .. } => {
                let chosen = if *pos < script.len() {
                    script[*pos].chosen.min(n - 1)
                } else {
                    script.push(Node {
                        chosen: 0,
                        n,
                        preemptive: vec![false; n],
                        preempts_before: st.preemptions,
                    });
                    0
                };
                *pos += 1;
                chosen
            }
            Driver::Pct { rng, .. } => {
                // Bias toward the newest value (candidate 0), exploring
                // staleness with probability ~1/4.
                if rng.below(4) == 0 {
                    rng.below(n)
                } else {
                    0
                }
            }
        }
    }

    // ------------------------------------------------------- atomics

    /// Atomic load at `addr` (seeded with `init` on first touch).
    pub(super) fn atomic_load(&self, t: TId, addr: usize, init: u64, ord: Ordering) -> u64 {
        self.turn(t, "atomic load", |st| {
            let clock = st.threads[t].clock.clone();
            let cell = st.cells.entry(addr).or_insert_with(|| CellHist::seeded(init));
            let floor = cell.floor_of(t);
            // Known-overwritten rule: s is readable unless a newer
            // store's write event is already in t's clock.
            let mut candidates: Vec<usize> = Vec::new();
            for i in (floor..cell.stores.len()).rev() {
                let known_newer = cell.stores[i + 1..]
                    .iter()
                    .any(|s| clock.get(s.wtid) >= s.wtick);
                if !known_newer {
                    candidates.push(i);
                }
            }
            if candidates.is_empty() {
                candidates.push(cell.stores.len() - 1);
            }
            let n = candidates.len();
            // Borrowck: finish with `cell` before the driver choice
            // (which needs `&mut EngineState` again).
            let stores_snapshot: Vec<(u64, Option<VClock>)> = candidates
                .iter()
                .map(|&i| {
                    let s = &cell.stores[i];
                    (s.val, s.release.clone())
                })
                .collect();
            let choice = self.choose_value(st, n);
            let (val, release) = stores_snapshot[choice].clone();
            let idx = candidates[choice];
            let acquire = matches!(ord, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst);
            if acquire {
                if let Some(rel) = &release {
                    st.threads[t].clock.join(rel);
                }
            }
            let cell = st.cells.get_mut(&addr).expect("cell just seeded");
            cell.raise_floor(t, idx);
            val
        })
    }

    /// Atomic store at `addr`.
    pub(super) fn atomic_store(&self, t: TId, addr: usize, init: u64, val: u64, ord: Ordering) {
        self.turn(t, "atomic store", |st| {
            let tick = st.threads[t].clock.tick(t);
            let release = matches!(ord, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
                .then(|| st.threads[t].clock.clone());
            let cell = st.cells.entry(addr).or_insert_with(|| CellHist::seeded(init));
            let idx = cell.stores.len();
            cell.stores.push(StoreRec { val, wtid: t, wtick: tick, release });
            cell.raise_floor(t, idx);
        })
    }

    /// Atomic read-modify-write at `addr`; reads the newest store
    /// (modification-order coherence), writes `f(old)`, returns `old`.
    pub(super) fn atomic_rmw(
        &self,
        t: TId,
        addr: usize,
        init: u64,
        ord: Ordering,
        f: impl FnOnce(u64) -> u64,
    ) -> u64 {
        self.turn(t, "atomic rmw", |st| {
            let cell = st.cells.entry(addr).or_insert_with(|| CellHist::seeded(init));
            let last = cell.stores.last().expect("history never empty");
            let old = last.val;
            let read_release = last.release.clone();
            let acquire = matches!(ord, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst);
            if acquire {
                if let Some(rel) = read_release {
                    st.threads[t].clock.join(&rel);
                }
            }
            let tick = st.threads[t].clock.tick(t);
            let release = matches!(ord, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
                .then(|| st.threads[t].clock.clone());
            let cell = st.cells.get_mut(&addr).expect("cell just seeded");
            let idx = cell.stores.len();
            cell.stores.push(StoreRec { val: f(old), wtid: t, wtick: tick, release });
            cell.raise_floor(t, idx);
            old
        })
    }

    // -------------------------------------------------------- mutexes

    /// Acquire the model mutex keyed by `addr`; blocks (model-level)
    /// while another thread owns it.
    pub(super) fn mutex_lock(&self, t: TId, addr: usize) {
        loop {
            let acquired = self.turn(t, "mutex lock", |st| {
                let m = st.mutexes.entry(addr).or_default();
                if m.owner.is_none() {
                    m.owner = Some(t);
                    let sync = m.sync.clone();
                    st.threads[t].clock.join(&sync);
                    true
                } else {
                    st.threads[t].status = Status::BlockedMutex(addr);
                    false
                }
            });
            if acquired {
                return;
            }
            self.wait_runnable(t);
        }
    }

    /// Release the model mutex keyed by `addr` and wake its waiters.
    pub(super) fn mutex_unlock(&self, t: TId, addr: usize) {
        self.turn(t, "mutex unlock", |st| {
            st.threads[t].clock.tick(t);
            let clock = st.threads[t].clock.clone();
            let m = st.mutexes.entry(addr).or_default();
            m.owner = None;
            m.sync.join(&clock);
            for th in st.threads.iter_mut() {
                if th.status == Status::BlockedMutex(addr) {
                    th.status = Status::Runnable;
                }
            }
        })
    }

    // ------------------------------------------------------- condvars

    /// Atomically release `mutex_addr` and park on the condvar keyed
    /// by `cond_addr`; returns once notified *and* rescheduled (the
    /// caller then reacquires the mutex).
    pub(super) fn cond_wait(&self, t: TId, cond_addr: usize, mutex_addr: usize) {
        self.turn(t, "cond wait", |st| {
            st.threads[t].clock.tick(t);
            let clock = st.threads[t].clock.clone();
            let m = st.mutexes.entry(mutex_addr).or_default();
            m.owner = None;
            m.sync.join(&clock);
            for th in st.threads.iter_mut() {
                if th.status == Status::BlockedMutex(mutex_addr) {
                    th.status = Status::Runnable;
                }
            }
            st.condvars.entry(cond_addr).or_default().waiters.push((t, mutex_addr));
            st.threads[t].status = Status::BlockedCond(cond_addr);
        });
        self.wait_runnable(t);
    }

    /// Wake one/all threads parked on `cond_addr`. Only *currently
    /// parked* waiters are woken — a notify with nobody parked is lost,
    /// which is precisely the semantics lost-wakeup bugs depend on.
    pub(super) fn cond_notify(&self, t: TId, cond_addr: usize, all: bool) {
        self.turn(t, if all { "cond notify_all" } else { "cond notify_one" }, |st| {
            let c = st.condvars.entry(cond_addr).or_default();
            let woken: Vec<(TId, usize)> =
                if all { c.waiters.drain(..).collect() } else { c.waiters.drain(..1.min(c.waiters.len())).collect() };
            for (w, _mx) in woken {
                st.threads[w].status = Status::Runnable;
            }
        })
    }

    // -------------------------------------------------------- threads

    /// Register a child thread (parent must be the active thread);
    /// returns the child's model id. The child's clock starts at the
    /// parent's (spawn happens-before everything in the child).
    pub(super) fn register_thread(&self, parent: TId) -> TId {
        self.turn(parent, "spawn", |st| {
            st.threads[parent].clock.tick(parent);
            let clock = st.threads[parent].clock.clone();
            let id = st.threads.len();
            st.threads.push(ThreadState { status: Status::Runnable, clock, priority: 0 });
            st.live += 1;
            if let Driver::Pct { rng, .. } = &mut st.driver {
                st.threads[id].priority = rng.next() % 1024;
            }
            id
        })
    }

    /// Claim `tid` on the current OS thread (first thing the spawned
    /// closure wrapper does).
    pub(super) fn claim(&self, tid: TId) {
        SELF_ID.with(|s| s.set(Some((self.gen, tid))));
    }

    /// Mark `t` finished (model-level) and wake joiners. Also the
    /// teardown signal [`Engine::drain`] waits on.
    pub(super) fn finish_thread(&self, t: TId, panic_msg: Option<String>) {
        let mut st = self.mu.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(msg) = panic_msg {
            self.fail(&mut st, msg);
        }
        st.threads[t].clock.tick(t);
        st.threads[t].status = Status::Finished;
        st.live -= 1;
        for th in st.threads.iter_mut() {
            if th.status == Status::BlockedJoin(t) {
                th.status = Status::Runnable;
            }
        }
        if !st.abort && st.active == t {
            self.reschedule(&mut st, t);
        }
        self.cv.notify_all();
    }

    /// Model-level join: block until `target` finishes, then inherit
    /// its clock (join happens-after everything in the target).
    pub(super) fn join_thread(&self, t: TId, target: TId) {
        loop {
            let done = self.turn(t, "join", |st| {
                if st.threads[target].status == Status::Finished {
                    let clock = st.threads[target].clock.clone();
                    st.threads[t].clock.join(&clock);
                    true
                } else {
                    st.threads[t].status = Status::BlockedJoin(target);
                    false
                }
            });
            if done {
                return;
            }
            self.wait_runnable(t);
        }
    }

    /// A plain scheduling point with no state effect (sleep/yield).
    pub(super) fn yield_point(&self, t: TId) {
        self.turn(t, "yield", |_| ());
    }

    /// Park until the scheduler makes this thread active again (used
    /// after the thread marked itself blocked inside a [`Engine::turn`]).
    fn wait_runnable(&self, t: TId) {
        let mut st = self.mu.lock().unwrap_or_else(|e| e.into_inner());
        while !st.abort && !(st.active == t && st.threads[t].status == Status::Runnable) {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.abort {
            drop(st);
            unwind_abort();
        }
    }

    /// Wait for every model thread's OS thread to finish (schedule
    /// teardown; aborted threads count too).
    fn drain(&self) {
        let mut st = self.mu.lock().unwrap_or_else(|e| e.into_inner());
        while st.live > 0 {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

// -------------------------------------------------------- exploration

fn panic_message(p: &(dyn std::any::Any + Send)) -> Option<String> {
    if p.is::<ModelAbort>() {
        return None;
    }
    if let Some(s) = p.downcast_ref::<&str>() {
        return Some((*s).to_string());
    }
    if let Some(s) = p.downcast_ref::<String>() {
        return Some(s.clone());
    }
    Some("model thread panicked (non-string payload)".to_string())
}

/// Called by spawn wrappers when their closure unwinds.
pub(super) fn record_thread_panic(engine: &Engine, tid: TId, payload: &(dyn std::any::Any + Send)) {
    engine.finish_thread(tid, panic_message(payload));
}

/// Abort the running schedule because `payload` unwound through a
/// structured-concurrency boundary (a scope body). Blocked children
/// are released so the scope's implicit real join can complete instead
/// of hanging on threads that will never get the baton again.
pub(super) fn abort_schedule(engine: &Engine, payload: &(dyn std::any::Any + Send)) {
    let mut st = engine.mu.lock().unwrap_or_else(|e| e.into_inner());
    match panic_message(payload) {
        Some(msg) => engine.fail(&mut st, msg),
        None => {
            // ModelAbort: the schedule is already being torn down.
            st.abort = true;
            engine.cv.notify_all();
        }
    }
}

/// DFS backtrack: advance `script` to the next unexplored branch
/// within the preemption bound. Returns false when the tree is done.
fn dfs_backtrack(script: &mut Vec<Node>, bound: u32) -> bool {
    while let Some(node) = script.pop() {
        let mut cand = node.chosen + 1;
        while cand < node.n {
            let ok = !node.preemptive.get(cand).copied().unwrap_or(false)
                || node.preempts_before < bound;
            if ok {
                let mut next = node.clone();
                next.chosen = cand;
                script.push(next);
                return true;
            }
            cand += 1;
        }
    }
    false
}

/// Run `f` under every schedule the configuration's budget allows and
/// report what was explored. `f` is run once per schedule; it must
/// rebuild its own state each time and must be deterministic apart
/// from the scheduling the engine injects.
pub fn explore<F>(name: &str, cfg: Config, f: F) -> Report
where
    F: Fn() + Send + Sync,
{
    let _serial = EXPLORE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev_hook = std::panic::take_hook();
    // Model assertions are reported through the Report/trace; the
    // default stderr backtrace per schedule would be noise.
    std::panic::set_hook(Box::new(|_| {}));

    let mut report = Report {
        name: name.to_string(),
        mode: cfg.mode,
        seed: cfg.seed,
        schedules: 0,
        complete: false,
        max_steps: 0,
        preemption_bound: cfg.preemption_bound,
        max_preemptions: 0,
        failure: None,
    };
    let mut dfs_script: Vec<Node> = Vec::new();
    loop {
        if report.schedules >= cfg.max_schedules {
            break;
        }
        report.schedules += 1;
        let driver = match cfg.mode {
            Mode::Dfs => Driver::Dfs {
                script: std::mem::take(&mut dfs_script),
                pos: 0,
                bound: cfg.preemption_bound,
            },
            Mode::Pct => {
                let mut rng = Pcg::new(cfg.seed.wrapping_add(report.schedules));
                let horizon = 1 + rng.next() % cfg.max_steps.clamp(1, 256);
                let change_steps: Vec<u64> = (1..cfg.pct_depth.max(1))
                    .map(|_| 1 + rng.next() % horizon)
                    .collect();
                Driver::Pct { rng, change_steps, step: 0 }
            }
        };
        let gen = {
            let mut g = GEN.lock().unwrap_or_else(|e| e.into_inner());
            *g += 1;
            *g
        };
        let engine = StdArc::new(Engine::new(gen, driver, cfg.max_steps));
        *ACTIVE.lock().unwrap_or_else(|e| e.into_inner()) = Some(engine.clone());
        engine.claim(0);

        let outcome = catch_unwind(AssertUnwindSafe(&f));
        match outcome {
            Ok(()) => engine.finish_thread(0, None),
            Err(p) => record_thread_panic(&engine, 0, p.as_ref()),
        }
        engine.drain();
        SELF_ID.with(|s| s.set(None));
        *ACTIVE.lock().unwrap_or_else(|e| e.into_inner()) = None;

        let st = engine.mu.lock().unwrap_or_else(|e| e.into_inner());
        report.max_steps = report.max_steps.max(st.steps);
        report.max_preemptions = report.max_preemptions.max(st.preemptions);
        if let Some(msg) = &st.failure {
            report.failure = Some(Failure {
                schedule: report.schedules,
                message: msg.clone(),
                trace: st.trace.clone(),
            });
            break;
        }
        let backtrack = match &st.driver {
            Driver::Dfs { script, bound, .. } => {
                dfs_script = script.clone();
                Some(*bound)
            }
            Driver::Pct { .. } => None,
        };
        drop(st);
        if let Some(bound) = backtrack {
            if !dfs_backtrack(&mut dfs_script, bound) {
                report.complete = true;
                break;
            }
        }
    }
    if cfg.mode == Mode::Pct && report.failure.is_none() && report.schedules == cfg.max_schedules {
        // A full PCT sweep is "complete" in the sense of having spent
        // its budget; callers distinguish via `mode`.
        report.complete = true;
    }
    std::panic::set_hook(prev_hook);
    report
}

/// [`explore`] + panic on failure, printing the failing schedule's
/// trace — the assertion form the invariant model tests use.
pub fn check<F>(name: &str, cfg: Config, f: F)
where
    F: Fn() + Send + Sync,
{
    let report = explore(name, cfg, f);
    if let Some(fail) = &report.failure {
        let trace = fail.trace.join("\n  ");
        panic!(
            "model '{name}' failed on schedule {} of {} ({:?}): {}\n  trace tail:\n  {trace}",
            fail.schedule, report.schedules, report.mode, fail.message
        );
    }
}
