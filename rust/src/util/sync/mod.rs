//! Sync-primitive shim: `std::sync` in normal builds, a deterministic
//! model-checking runtime under `--cfg modelcheck`.
//!
//! Every concurrency-bearing module of the serving/durability stack
//! (`serve/`, `data/chunked.rs`, `data/formats/wal.rs`, `util/pool.rs`)
//! imports its atomics, locks, condvars and threads from here instead
//! of `std::sync` / `std::thread` (enforced by repolint's `sync-shim`
//! rule). In a normal build the module is a zero-cost facade: every
//! name is a re-export of the `std` type, so release binaries are
//! bit-identical to direct `std::sync` use.
//!
//! Under `RUSTFLAGS="--cfg modelcheck"` the same names resolve to
//! instrumented wrappers (see `shim`) that route every
//! load/store/RMW, lock/unlock, condvar wait/notify and thread
//! spawn/join through a deterministic scheduler ([`model`]): a
//! bounded-exhaustive DFS with a preemption bound for small models, or
//! seeded PCT-style random scheduling for larger ones. The scheduler
//! honors the declared [`atomic::Ordering`] when deciding which stored
//! value a load may observe — `Relaxed` loads can return stale values,
//! while a `Release` store / `Acquire` load pair transfers the
//! writer's vector clock and prunes the staleness window. The model
//! tests live in `tools/modelcheck` (`cargo test -p modelcheck`); see
//! ARCHITECTURE.md "Schedule exploration".
//!
//! Outside an active exploration (e.g. a plain binary accidentally
//! built with the cfg), the instrumented types fall back to their real
//! `std` counterparts, so the shim is drop-in in both directions.

#[cfg(modelcheck)]
mod sched;
#[cfg(modelcheck)]
mod shim;

/// Deterministic schedule exploration entry points (modelcheck builds
/// only): [`model::explore`] runs a closure under every schedule the
/// configured budget allows and returns a [`model::Report`];
/// [`model::check`] panics with the failing trace.
#[cfg(modelcheck)]
pub mod model {
    pub use super::sched::{check, explore, Config, Failure, Mode, Report};
}

#[cfg(not(modelcheck))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

// The poison-handling vocabulary types are plain data carriers; they
// are shared verbatim between both builds so call sites like
// `lock().unwrap_or_else(|e| e.into_inner())` are mode-independent.
pub use std::sync::{LockResult, PoisonError};

#[cfg(modelcheck)]
pub use shim::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

// `Arc` is never instrumented: its reference counting is not part of
// any protocol under test, and leaving it real keeps model state
// ownership simple. It is still re-exported so shim users need a
// single import root.
#[cfg(modelcheck)]
pub use std::sync::Arc;

/// Atomic integer/bool types plus [`atomic::Ordering`]. Normal builds
/// re-export `std::sync::atomic`; modelcheck builds substitute
/// scheduler-instrumented cells with the same method surface.
pub mod atomic {
    #[cfg(not(modelcheck))]
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};

    #[cfg(modelcheck)]
    pub use super::shim::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};

    // `Ordering` is always the real enum: the instrumented cells take
    // it as an argument and interpret it, so call sites never change.
    pub use std::sync::atomic::Ordering;
}

/// Thread spawn/scope/join. Normal builds re-export `std::thread`;
/// modelcheck builds register every spawned thread with the scheduler
/// so it becomes a schedulable entity with its own vector clock.
pub mod thread {
    #[cfg(not(modelcheck))]
    pub use std::thread::{scope, sleep, spawn, JoinHandle, Scope, ScopedJoinHandle};

    #[cfg(modelcheck)]
    pub use super::shim::thread_shim::{scope, sleep, spawn, JoinHandle, Scope, ScopedJoinHandle};
}
