//! A light property-testing driver (the offline registry lacks
//! `proptest`).
//!
//! [`run_prop`] executes a property over `cases` randomly generated
//! inputs; on failure it performs a bounded greedy shrink by re-seeding
//! the generator with "smaller" size hints, then panics with the
//! reproducing seed so failures are one-line reproducible:
//! `PROP_SEED=<n> cargo test <name>`.

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (overridden by `PROP_SEED` env var).
    pub seed: u64,
    /// Maximum size hint passed to the generator.
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x1a59e, max_size: 256 }
    }
}

/// Run `prop(rng, size)` for each case; `prop` returns `Err(msg)` to fail.
///
/// The generator receives a fresh deterministic `Rng` and a size hint
/// that ramps up from small to `max_size` so early failures are small.
pub fn run_prop<F>(name: &str, cfg: PropConfig, prop: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    let seed = std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(cfg.seed);
    for case in 0..cfg.cases {
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Greedy shrink: retry the same case seed with smaller sizes.
            let mut min_fail = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        min_fail = (s, m);
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property `{name}` failed (case {case}, size {}, reproduce with PROP_SEED={seed}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        run_prop("always-ok", PropConfig { cases: 10, ..Default::default() }, |_, _| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        run_prop("fails", PropConfig::default(), |_, size| {
            if size > 3 {
                Err(format!("size {size} too big"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sizes_ramp_up() {
        let max_seen = std::cell::Cell::new(0usize);
        run_prop("ramp", PropConfig { cases: 32, max_size: 64, ..Default::default() }, |_, s| {
            max_seen.set(max_seen.get().max(s));
            Ok(())
        });
        assert!(max_seen.get() > 32);
    }
}
