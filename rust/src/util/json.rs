//! Minimal JSON parser (no `serde` offline) — enough for the artifact
//! manifest and run reports: objects, arrays, strings, numbers, bools,
//! null. No unicode escapes beyond `\uXXXX` pass-through.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As integer (truncating).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string_compact(&self) -> String {
        match self {
            Json::Null => "null".to_string(),
            Json::Bool(b) => b.to_string(),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::Str(s) => format!("{:?}", s),
            Json::Arr(xs) => {
                let inner: Vec<String> = xs.iter().map(|x| x.to_string_compact()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Obj(m) => {
                let inner: Vec<String> =
                    m.iter().map(|(k, v)| format!("{:?}:{}", k, v.to_string_compact())).collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && (b[*pos] as char).is_ascii_whitespace() {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse()?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("bad escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("bad \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("unknown escape \\{}", c as char),
                }
                *pos += 1;
            }
            c => {
                // Multi-byte UTF-8 passes through.
                let ch_len = utf8_len(c);
                out.push_str(std::str::from_utf8(&b[*pos..*pos + ch_len])?);
                *pos += ch_len;
            }
        }
    }
    bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected , or ] at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected : at byte {pos}");
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected , or }} at byte {pos}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"batch": 1024, "dim": 2, "artifacts": {"grad_kernel": "grad_kernel.hlo.txt"}, "ok": true, "x": null, "arr": [1, 2.5, "s"]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(1024));
        assert_eq!(
            j.get("artifacts").unwrap().get("grad_kernel").unwrap().as_str(),
            Some("grad_kernel.hlo.txt")
        );
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("x"), Some(&Json::Null));
        match j.get("arr").unwrap() {
            Json::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"a":[1,2],"b":"x","c":true}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.to_string_compact(), doc);
    }
}
