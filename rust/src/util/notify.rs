//! Condvar doorbell: the flag-under-lock wakeup protocol used by the
//! serve-side refine loop, extracted so the model checker can drive it
//! as a small closed protocol (see `tools/modelcheck`).
//!
//! The protocol has exactly one liveness-bearing rule: **the flag is
//! set under the same lock the waiter's predicate check and park run
//! under**. A waiter therefore either observes the flag already set
//! (and never parks) or parks *before* the ringer can take the lock —
//! in which case the ringer's notify finds it parked. Setting the flag
//! outside that critical section, or notifying without setting it,
//! reintroduces the classic lost-wakeup race; the mutation corpus
//! seeds exactly that bug under `--cfg modelcheck_mutant_bell_no_flag`
//! and CI asserts the checker reports it as a deadlock.

use crate::util::sync::{Condvar, Mutex};
use std::time::Duration;

/// A lossless one-bit doorbell over `Mutex<bool>` + `Condvar`.
///
/// `ring` wakes current *and future* waiters (the bit stays set until
/// a waiter consumes it); `knock` wakes only currently parked waiters
/// and is meant for "recheck your own predicate" nudges where the
/// caller owns a separate stop condition.
pub struct Doorbell {
    flag: Mutex<bool>,
    cv: Condvar,
}

impl Doorbell {
    /// Creates a doorbell with the bit clear.
    pub fn new() -> Self {
        Doorbell { flag: Mutex::new(false), cv: Condvar::new() }
    }

    /// Sets the bit and wakes every waiter. The set happens under the
    /// doorbell lock, which is what makes the wakeup lossless (see the
    /// module docs).
    pub fn ring(&self) {
        let mut flag = self.flag.lock().unwrap_or_else(|e| e.into_inner());
        // Seeded lost-wakeup bug for the mutation corpus: skip setting
        // the bit, so a ring that fires before the waiter parks leaves
        // nothing behind for the waiter's predicate check and the
        // waiter sleeps forever. The checker must flag this as a
        // deadlock on some schedule.
        #[cfg(not(modelcheck_mutant_bell_no_flag))]
        {
            *flag = true;
        }
        #[cfg(modelcheck_mutant_bell_no_flag)]
        {
            let _ = &mut flag;
        }
        self.cv.notify_all();
    }

    /// Wakes currently parked waiters without setting the bit. A
    /// knock that fires while nobody is parked is deliberately lost;
    /// callers pair it with their own stop/recheck condition (the
    /// refine loop pairs it with the server stop flag).
    pub fn knock(&self) {
        let _flag = self.flag.lock().unwrap_or_else(|e| e.into_inner());
        self.cv.notify_all();
    }

    /// Parks until the bit is set, `stop()` returns true, or `interval`
    /// elapses; consumes the bit before returning. Returns true when
    /// the doorbell was actually rung (the bit was set), false on a
    /// stop-request or timeout wakeup.
    ///
    /// Under the model checker the timeout never fires (model time
    /// does not exist), so a lost wakeup surfaces as a deadlock
    /// instead of being papered over by the periodic timeout.
    pub fn wait_or(&self, interval: Duration, stop: impl Fn() -> bool) -> bool {
        let mut flag = self.flag.lock().unwrap_or_else(|e| e.into_inner());
        while !*flag && !stop() {
            let (f, timeout) = self
                .cv
                .wait_timeout(flag, interval)
                .unwrap_or_else(|e| e.into_inner());
            flag = f;
            if timeout.timed_out() {
                break;
            }
        }
        let rung = *flag;
        *flag = false;
        rung
    }
}

impl Default for Doorbell {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    #[test]
    #[cfg(not(modelcheck_mutant_bell_no_flag))]
    fn ring_before_wait_is_not_lost() {
        let bell = Doorbell::new();
        bell.ring();
        // The bit persists, so a wait that starts after the ring
        // returns immediately without relying on the notify.
        assert!(bell.wait_or(Duration::from_secs(5), || false));
        // ...and is consumed exactly once.
        assert!(!bell.wait_or(Duration::from_millis(1), || false));
    }

    #[test]
    fn stop_predicate_short_circuits() {
        let bell = Doorbell::new();
        let stop = AtomicBool::new(true);
        assert!(!bell.wait_or(Duration::from_secs(5), || stop.load(Ordering::Relaxed)));
    }

    #[test]
    #[cfg(not(modelcheck_mutant_bell_no_flag))]
    fn ring_wakes_parked_waiter() {
        let bell = Doorbell::new();
        std::thread::scope(|s| {
            let h = s.spawn(|| bell.wait_or(Duration::from_secs(30), || false));
            // Give the waiter a moment to park, then ring; either way
            // (parked or not yet parked) the wakeup must not be lost.
            std::thread::sleep(Duration::from_millis(20));
            bell.ring();
            assert!(h.join().unwrap());
        });
    }

    #[test]
    fn knock_without_bit_times_out() {
        let bell = Doorbell::new();
        bell.knock(); // nobody parked: deliberately lost
        assert!(!bell.wait_or(Duration::from_millis(5), || false));
    }
}
