//! Epoch-stamped visited set — the allocation-free replacement for the
//! per-node `HashSet`s the KNN hot loops used to build.
//!
//! A `u8` stamp per point; membership is `stamp[id] == epoch`. One
//! byte (not a `u32`) keeps the per-worker footprint at n bytes — at
//! paper scale (10M points × 32 workers) that is 320 MB instead of
//! 1.28 GB — at the cost of a full rewind every 255 generations, whose
//! n-byte memset amortizes to ~n/255 bytes per query (noise next to a
//! query's candidate scan). Starting a new generation is otherwise a
//! single increment (no clearing, no rehashing, no allocation), and
//! lookups are a single indexed load — measurably faster than hashing
//! in the dedup-heavy neighbor-exploring loop (§Perf).

/// Dense visited set over ids `0..n` with O(1) epoch-based reset.
pub struct VisitedSet {
    stamp: Vec<u8>,
    epoch: u8,
}

impl VisitedSet {
    /// Set over ids `0..n`, initially empty.
    pub fn new(n: usize) -> Self {
        // Epoch starts at 1 so the zero-filled stamps mean "never seen".
        VisitedSet { stamp: vec![0; n], epoch: 1 }
    }

    /// Number of addressable ids.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.stamp.len()
    }

    /// Start a new empty generation in O(1) (a full rewind happens once
    /// every `u8::MAX` generations).
    pub fn clear(&mut self) {
        if self.epoch == u8::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Insert `id`; returns `true` when newly inserted (mirrors
    /// `HashSet::insert`).
    #[inline]
    pub fn insert(&mut self, id: u32) -> bool {
        let slot = &mut self.stamp[id as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        self.stamp[id as usize] == self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_contains() {
        let mut v = VisitedSet::new(10);
        assert!(!v.contains(3));
        assert!(v.insert(3));
        assert!(!v.insert(3));
        assert!(v.contains(3));
        assert!(!v.contains(4));
    }

    #[test]
    fn clear_is_a_new_generation() {
        let mut v = VisitedSet::new(5);
        v.insert(0);
        v.insert(4);
        v.clear();
        for id in 0..5 {
            assert!(!v.contains(id));
        }
        assert!(v.insert(4));
    }

    #[test]
    fn fresh_set_is_empty() {
        let v = VisitedSet::new(4);
        assert!((0..4).all(|id| !v.contains(id)));
        assert_eq!(v.capacity(), 4);
    }

    #[test]
    fn epoch_wraparound_resets_stamps() {
        let mut v = VisitedSet::new(3);
        v.insert(1);
        // Force the wraparound path.
        v.epoch = u8::MAX;
        v.stamp[2] = u8::MAX; // stale entry that must not survive
        v.clear();
        assert!(!v.contains(1));
        assert!(!v.contains(2));
        assert!(v.insert(2));
    }

    #[test]
    fn many_generations_never_false_positive() {
        // Drive well past the u8 epoch wrap: a stale stamp from an old
        // generation must never read as visited in a new one.
        let mut v = VisitedSet::new(8);
        for gen in 0..1000u32 {
            let id = (gen % 8) as u32;
            assert!(!v.contains(id), "gen {gen}: stale hit");
            assert!(v.insert(id));
            assert!(v.contains(id));
            v.clear();
        }
    }
}
