//! Asynchronous stochastic gradient descent (Hogwild) for the LargeVis
//! objective — the paper's optimizer, O(s·M) per step and O(s·M·N)
//! total.
//!
//! Each worker thread independently samples a positive edge (∝ weight),
//! draws M negatives (∝ deg^0.75), computes the fused gradient of
//! Eq. (6) and applies it *without locks*. On sparse graphs the touched
//! vertices rarely collide across threads (Recht et al., 2011), which
//! is exactly the regime here: each step touches 2 + M vertices out of
//! millions.
//!
//! The racy updates are expressed as per-`f32` relaxed atomics (see
//! [`SharedLayout`]), so the Hogwild races are *defined behavior* —
//! `cargo miri test` and ThreadSanitizer verify this loop instead of
//! flagging it — at zero cost: a relaxed `AtomicU32` load/store is the
//! same plain `mov` the unsynchronized code compiled to.

use crate::graph::CsrGraph;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::vis::objective::clip;
use crate::vis::sampler::GraphSamplers;
use crate::vis::LargeVisConfig;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Shared mutable layout for Hogwild updates, viewed as relaxed
/// per-element atomics.
///
/// Workers deliberately race on layout rows; going through `AtomicU32`
/// bit-patterns makes every such race a defined read/write (a reader
/// sees *some* previously stored value, never tearing within an `f32`,
/// never UB) while compiling to the same plain loads/stores on x86-64
/// and aarch64. Single-threaded runs execute the exact same value
/// sequence as the old in-place implementation, so results stay
/// bit-identical (pinned by the multilevel parity test).
struct SharedLayout<'a> {
    slots: &'a [AtomicU32],
}

impl<'a> SharedLayout<'a> {
    fn new(buf: &'a mut [f32]) -> Self {
        let ptr = buf.as_mut_ptr().cast::<AtomicU32>();
        let len = buf.len();
        // SAFETY: `AtomicU32` has the same size and alignment as `f32`
        // (4 bytes each), and the exclusive borrow on `buf` rules out
        // any non-atomic access for the lifetime `'a`, so reborrowing
        // the buffer as a slice of atomics is sound (this mirrors
        // std's `AtomicU32::from_mut_slice` construction).
        let slots = unsafe { std::slice::from_raw_parts(ptr, len) };
        SharedLayout { slots }
    }

    /// Snapshot vertex `v`'s row into a local array.
    #[inline]
    fn load_row<const DIM: usize>(&self, v: usize) -> [f32; DIM] {
        let mut out = [0f32; DIM];
        for (o, slot) in out.iter_mut().zip(&self.slots[v * DIM..v * DIM + DIM]) {
            // ordering: Relaxed — Hogwild tolerates stale values and
            // publishes no other memory through the layout cells; the
            // final happens-before edge is the worker join.
            *o = f32::from_bits(slot.load(Ordering::Relaxed));
        }
        out
    }

    /// Write vertex `v`'s row back from a local array.
    #[inline]
    fn store_row<const DIM: usize>(&self, v: usize, row: &[f32; DIM]) {
        for (x, slot) in row.iter().zip(&self.slots[v * DIM..v * DIM + DIM]) {
            // ordering: Relaxed — counterpart of `load_row`; the join
            // in `spawn_workers` orders these before the caller reads
            // the finished layout.
            slot.store(x.to_bits(), Ordering::Relaxed);
        }
    }
}

/// Progress/throughput counters reported by [`optimize`].
#[derive(Debug, Default, Clone, Copy)]
pub struct SgdReport {
    /// Edge samples actually performed.
    pub samples: u64,
    /// Wall-clock seconds in the optimization loop.
    pub seconds: f64,
}

impl SgdReport {
    /// Edge-samples per second (the §Perf headline number).
    pub fn throughput(&self) -> f64 {
        if self.seconds > 0.0 {
            self.samples as f64 / self.seconds
        } else {
            0.0
        }
    }
}

/// Run asynchronous SGD on `layout` in place; returns throughput stats.
pub fn optimize(
    graph: &CsrGraph,
    layout: &mut crate::data::matrix::Matrix,
    cfg: &LargeVisConfig,
) -> SgdReport {
    assert_eq!(layout.n(), graph.n());
    assert_eq!(layout.d(), cfg.dim);
    let n = graph.n();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let samplers = GraphSamplers::new(graph);
    let total = cfg.total_samples(n);
    let dim = cfg.dim;
    let f = cfg.prob_fn;
    let gamma = cfg.gamma;
    let negatives = cfg.negatives;
    let gclip = cfg.grad_clip;
    let rho0 = cfg.rho0;

    let shared = SharedLayout::new(layout.as_mut_slice());
    let progress = AtomicU64::new(0);
    let base_rng = Rng::new(cfg.seed ^ 0x5bd1);
    let t0 = std::time::Instant::now();

    // Monomorphize the hot loop on the output dimension: the layout dim
    // is 2 (sometimes 3), and a const-length inner loop lets the
    // compiler keep the accumulator in registers and unroll fully
    // (§Perf: +13% over the dynamic-dim loop at dim=2).
    struct LoopArgs<'a> {
        shared: &'a SharedLayout<'a>,
        samplers: &'a GraphSamplers,
        progress: &'a AtomicU64,
        base_rng: &'a Rng,
        threads: usize,
        total: u64,
        f: crate::vis::objective::ProbFn,
        gamma: f32,
        negatives: usize,
        gclip: f32,
        rho0: f32,
    }

    fn worker_loop<const DIM: usize>(a: &LoopArgs<'_>, tid: usize) {
        let mut rng = a.base_rng.split(tid as u64 + 1);
        let my_samples =
            a.total / a.threads as u64 + u64::from(tid == 0) * (a.total % a.threads as u64);
        let mut acc = [0f32; DIM];
        let mut rho = a.rho0;
        for s in 0..my_samples {
            // Refresh the global learning rate every 256 samples (cheap
            // and smooth enough; exact per-step decay is unnecessary).
            // Every worker adds its own 256 to the shared counter, so
            // the counter already tracks global progress — scaling it
            // by the thread count again would decay rho up to threads×
            // too fast.
            if s % 256 == 0 {
                // ordering: Relaxed — the counter only feeds the
                // statistical rho schedule; skew between workers is
                // harmless and nothing is published through it.
                let t = a.progress.fetch_add(256, Ordering::Relaxed);
                let frac = (t.min(a.total)) as f32 / a.total as f32;
                rho = (a.rho0 * (1.0 - frac)).max(a.rho0 * 1e-4);
            }
            let (i, j) = a.samplers.sample_edge(&mut rng);
            let (i, j) = (i as usize, j as usize);
            if i == j {
                continue;
            }
            // Within one step, i, j and every negative v are pairwise
            // distinct (the excluding draw skips i and j), so the local
            // row copies below cannot alias; a repeated draw of the
            // same negative re-loads the row and therefore observes
            // the preceding store. Single-threaded, this reproduces the
            // old in-place value sequence bit-for-bit.
            let mut yi = a.shared.load_row::<DIM>(i);
            acc.iter_mut().for_each(|x| *x = 0.0);

            // Positive edge: attract.
            {
                let mut yj = a.shared.load_row::<DIM>(j);
                let mut d2 = 0f32;
                for k in 0..DIM {
                    let dk = yi[k] - yj[k];
                    d2 += dk * dk;
                }
                let c = a.f.coeff_pos(d2);
                for k in 0..DIM {
                    let g = clip(c * (yi[k] - yj[k]), a.gclip);
                    acc[k] += g;
                    yj[k] -= rho * g; // opposite force on y_j
                }
                a.shared.store_row(j, &yj);
            }
            // M negatives: repel. The excluding draw is total, so every
            // positive update is balanced by exactly M repulsions
            // whenever the graph has any third connected vertex (the
            // old bounded rejection guard could run out on small or
            // hub-dominated graphs and silently apply an attract-only
            // step, collapsing the layout).
            for _ in 0..a.negatives {
                let v = match a.samplers.sample_negative_excluding(&mut rng, i as u32, j as u32) {
                    Some(v) => v as usize,
                    None => break,
                };
                let mut yv = a.shared.load_row::<DIM>(v);
                let mut d2 = 0f32;
                for k in 0..DIM {
                    let dk = yi[k] - yv[k];
                    d2 += dk * dk;
                }
                let c = a.gamma * a.f.coeff_neg(d2);
                for k in 0..DIM {
                    let g = clip(c * (yi[k] - yv[k]), a.gclip);
                    acc[k] += g;
                    yv[k] -= rho * g;
                }
                a.shared.store_row(v, &yv);
            }
            for k in 0..DIM {
                yi[k] += rho * acc[k];
            }
            a.shared.store_row(i, &yi);
        }
    }

    assert!((2..=4).contains(&dim), "hot path supports dim 2..=4 (paper uses 2/3)");
    let args = LoopArgs {
        shared: &shared,
        samplers: &samplers,
        progress: &progress,
        base_rng: &base_rng,
        threads,
        total,
        f,
        gamma,
        negatives,
        gclip,
        rho0,
    };
    pool::spawn_workers(threads, |tid| match dim {
        2 => worker_loop::<2>(&args, tid),
        3 => worker_loop::<3>(&args, tid),
        _ => worker_loop::<4>(&args, tid),
    });

    SgdReport { samples: total, seconds: t0.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CsrGraph;
    use crate::vis::objective::{exact_objective, ProbFn};
    use crate::vis::{init_layout, LargeVisConfig};

    /// Two 6-cliques joined by one weak edge.
    fn two_cliques() -> CsrGraph {
        let mut edges = Vec::new();
        for c in 0..2u32 {
            let base = c * 6;
            for a in 0..6u32 {
                for b in (a + 1)..6u32 {
                    edges.push((base + a, base + b, 1.0f64));
                }
            }
        }
        edges.push((0, 6, 0.05));
        CsrGraph::from_undirected(12, &edges)
    }

    #[test]
    fn objective_increases() {
        let g = two_cliques();
        let cfg = LargeVisConfig {
            samples_per_vertex: 4000,
            threads: 1,
            seed: 7,
            ..Default::default()
        };
        let mut y = init_layout(g.n(), 2, 7);
        let before = exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        optimize(&g, &mut y, &cfg);
        let after = exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        assert!(after > before, "objective did not improve: {before} -> {after}");
        assert!(y.as_slice().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cliques_separate_in_layout() {
        let g = two_cliques();
        let cfg = LargeVisConfig { samples_per_vertex: 8000, threads: 2, seed: 3, ..Default::default() };
        let mut y = init_layout(g.n(), 2, 3);
        optimize(&g, &mut y, &cfg);
        // Mean intra-clique distance << inter-clique distance.
        let mut intra = 0f64;
        let mut inter = 0f64;
        let (mut ni, mut nx) = (0, 0);
        for a in 0..12 {
            for b in (a + 1)..12 {
                let d = y.sqdist(a, b) as f64;
                if (a < 6) == (b < 6) {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        let (mi, mx) = (intra / ni as f64, inter / nx as f64);
        assert!(mx > 3.0 * mi, "intra={mi:.3} inter={mx:.3}");
    }

    #[test]
    fn single_thread_deterministic() {
        let g = two_cliques();
        let cfg = LargeVisConfig { samples_per_vertex: 500, threads: 1, seed: 11, ..Default::default() };
        let mut a = init_layout(g.n(), 2, 11);
        let mut b = init_layout(g.n(), 2, 11);
        optimize(&g, &mut a, &cfg);
        optimize(&g, &mut b, &cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn sigmoid_prob_fn_also_converges() {
        let g = two_cliques();
        let cfg = LargeVisConfig {
            samples_per_vertex: 4000,
            prob_fn: ProbFn::SigmoidSq,
            threads: 1,
            seed: 13,
            ..Default::default()
        };
        let mut y = init_layout(g.n(), 2, 13);
        let before = exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        optimize(&g, &mut y, &cfg);
        let after = exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        assert!(after > before);
    }

    #[test]
    fn pathological_negative_table_still_repels() {
        // Path 0-1-2 with a huge weight disparity. Edge sampling all
        // but always draws (0,1), and the ∝ deg^0.75 noise table holds
        // essentially all its mass on vertices 0 and 1 — so the old
        // bounded rejection guard virtually never produced a negative,
        // and the step degenerated to attract-only: the whole layout
        // collapsed into the ~1e-4 init ball. With the total draw,
        // vertex 2 is repelled on every step.
        let g = CsrGraph::from_undirected(3, &[(0, 1, 1e9), (1, 2, 1e-9)]);
        let cfg =
            LargeVisConfig { samples_per_vertex: 3000, threads: 1, seed: 5, ..Default::default() };
        let mut y = init_layout(g.n(), 2, 5);
        optimize(&g, &mut y, &cfg);
        assert!(y.as_slice().iter().all(|x| x.is_finite()));
        let d02 = y.sqdist(0, 2);
        let d01 = y.sqdist(0, 1);
        assert!(d02 > 1.0, "vertex 2 was never repelled: sqdist(0,2) = {d02}");
        assert!(d01 < d02, "attraction lost to repulsion: d01={d01} d02={d02}");
    }

    #[test]
    fn isolated_vertices_stay_pinned() {
        // Vertex 3 has no edges: it must be excluded from both sampling
        // tables, so SGD never moves its layout row.
        let g = CsrGraph::from_undirected(4, &[(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)]);
        let cfg =
            LargeVisConfig { samples_per_vertex: 2000, threads: 1, seed: 3, ..Default::default() };
        let mut y = init_layout(g.n(), 2, 3);
        let before: Vec<f32> = y.row(3).to_vec();
        optimize(&g, &mut y, &cfg);
        assert_eq!(y.row(3), &before[..], "isolated vertex moved");
        // The connected triangle did move.
        assert!(y.sqdist(0, 1) > 0.0);
    }

    #[test]
    fn report_throughput_positive() {
        let g = two_cliques();
        let cfg = LargeVisConfig { samples_per_vertex: 100, threads: 2, ..Default::default() };
        let mut y = init_layout(g.n(), 2, 1);
        let rep = optimize(&g, &mut y, &cfg);
        assert!(rep.throughput() > 0.0);
        assert_eq!(rep.samples, cfg.total_samples(g.n()));
    }
}
