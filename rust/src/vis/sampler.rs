//! Samplers for the SGD hot loop (paper §3.2 "Optimization"):
//! edges ∝ `w_ij` (edge sampling — decouples step size from weight
//! variance) and negatives ∝ `deg^0.75` (word2vec's noise distribution).

use crate::graph::CsrGraph;
use crate::util::alias::AliasTable;
use crate::util::rng::Rng;

/// Alias samplers bound to one graph.
pub struct GraphSamplers {
    edge_table: AliasTable,
    neg_table: AliasTable,
    /// Directed edge endpoints, aligned with the alias table indices.
    endpoints: Vec<(u32, u32)>,
}

impl GraphSamplers {
    /// Build both tables from the CSR graph.
    pub fn new(graph: &CsrGraph) -> Self {
        let edges = graph.edges();
        assert!(!edges.is_empty(), "cannot lay out a graph with no edges");
        let weights: Vec<f64> = edges.iter().map(|&(_, _, w)| w).collect();
        let endpoints: Vec<(u32, u32)> = edges.iter().map(|&(s, d, _)| (s, d)).collect();
        let deg: Vec<f64> =
            (0..graph.n()).map(|v| graph.weighted_degree(v).max(1e-12).powf(0.75)).collect();
        GraphSamplers {
            edge_table: AliasTable::new(&weights),
            neg_table: AliasTable::new(&deg),
            endpoints,
        }
    }

    /// Sample a positive (directed) edge ∝ weight.
    #[inline]
    pub fn sample_edge(&self, rng: &mut Rng) -> (u32, u32) {
        self.endpoints[self.edge_table.sample(rng)]
    }

    /// Sample a negative vertex ∝ deg^0.75.
    #[inline]
    pub fn sample_negative(&self, rng: &mut Rng) -> u32 {
        self.neg_table.sample(rng) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star_graph() -> CsrGraph {
        // Vertex 0 is a hub with heavy edges; 3-4 have a light edge.
        CsrGraph::from_undirected(
            5,
            &[(0, 1, 4.0), (0, 2, 4.0), (0, 3, 1.0), (3, 4, 0.5)],
        )
    }

    #[test]
    fn edges_sampled_by_weight() {
        let g = star_graph();
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(1);
        let mut heavy = 0usize;
        let mut light = 0usize;
        for _ in 0..100_000 {
            let (a, b) = s.sample_edge(&mut rng);
            let key = (a.min(b), a.max(b));
            if key == (0, 1) {
                heavy += 1;
            }
            if key == (3, 4) {
                light += 1;
            }
        }
        // w=4.0 vs 0.5 → ratio ≈ 8.
        let ratio = heavy as f64 / light.max(1) as f64;
        assert!((ratio - 8.0).abs() < 1.5, "ratio {ratio}");
    }

    #[test]
    fn negatives_prefer_high_degree() {
        let g = star_graph();
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..100_000 {
            counts[s.sample_negative(&mut rng) as usize] += 1;
        }
        // Hub 0 (weighted degree 9) must beat leaf 4 (0.5) but by less
        // than the raw degree ratio (the 0.75 exponent flattens it).
        assert!(counts[0] > counts[4] * 3, "{counts:?}");
        let raw_ratio = (9.0f64 / 0.5).powf(0.75);
        let got = counts[0] as f64 / counts[4].max(1) as f64;
        assert!((got - raw_ratio).abs() < raw_ratio * 0.25, "got {got}, want ≈{raw_ratio}");
    }

    #[test]
    #[should_panic(expected = "no edges")]
    fn empty_graph_panics() {
        let g = CsrGraph::from_undirected(3, &[]);
        GraphSamplers::new(&g);
    }
}
