//! Samplers for the SGD hot loop (paper §3.2 "Optimization"):
//! edges ∝ `w_ij` (edge sampling — decouples step size from weight
//! variance) and negatives ∝ `deg^0.75` (word2vec's noise distribution).
//!
//! Isolated (zero-degree) vertices are excluded from the negative table
//! entirely. They appear in no positive edge either, so the optimizer
//! never touches them: their layout rows stay pinned exactly where they
//! were initialized (for the multilevel engine, at their coarse
//! parent's position). The previous behavior — granting them a
//! `1e-12^0.75` pseudo-mass — meant they were (essentially) never
//! repelled yet still distorted the residual probabilities of every
//! real vertex in the alias table.

use crate::graph::CsrGraph;
use crate::util::alias::AliasTable;
use crate::util::rng::Rng;

/// Alias samplers bound to one graph.
pub struct GraphSamplers {
    edge_table: AliasTable,
    neg_table: AliasTable,
    /// Directed edge endpoints, aligned with the alias table indices.
    endpoints: Vec<(u32, u32)>,
    /// Vertices with at least one edge — the support of the negative
    /// table (`neg_table` indexes into this, not into vertex ids).
    neg_support: Vec<u32>,
}

impl GraphSamplers {
    /// Build both tables from the CSR graph.
    pub fn new(graph: &CsrGraph) -> Self {
        let edges = graph.edges();
        assert!(!edges.is_empty(), "cannot lay out a graph with no edges");
        let weights: Vec<f64> = edges.iter().map(|&(_, _, w)| w).collect();
        let endpoints: Vec<(u32, u32)> = edges.iter().map(|&(s, d, _)| (s, d)).collect();
        let mut neg_support: Vec<u32> = Vec::new();
        let mut deg: Vec<f64> = Vec::new();
        for v in 0..graph.n() {
            let d = graph.weighted_degree(v);
            if d > 0.0 {
                neg_support.push(v as u32);
                deg.push(d.powf(0.75));
            }
        }
        GraphSamplers {
            edge_table: AliasTable::new(&weights),
            neg_table: AliasTable::new(&deg),
            endpoints,
            neg_support,
        }
    }

    /// Sample a positive (directed) edge ∝ weight.
    #[inline]
    pub fn sample_edge(&self, rng: &mut Rng) -> (u32, u32) {
        self.endpoints[self.edge_table.sample(rng)]
    }

    /// Sample a negative vertex ∝ deg^0.75 (never an isolated vertex).
    #[inline]
    pub fn sample_negative(&self, rng: &mut Rng) -> u32 {
        self.neg_support[self.neg_table.sample(rng)]
    }

    /// Sample a negative vertex ∝ deg^0.75 that is neither `i` nor `j`.
    ///
    /// A bare rejection loop over [`GraphSamplers::sample_negative`]
    /// cannot bound its attempts: on small or hub-dominated graphs the
    /// noise distribution can concentrate almost all mass on the edge's
    /// own endpoints, and a bounded guard then gives up and silently
    /// skews the attract/repel balance of the SGD step. This draw is
    /// total instead — a few alias attempts, then a few uniform draws
    /// over the support, then a deterministic scan — so it returns
    /// `None` only when no valid vertex exists at all.
    #[inline]
    pub fn sample_negative_excluding(&self, rng: &mut Rng, i: u32, j: u32) -> Option<u32> {
        const ALIAS_ATTEMPTS: usize = 8;
        const UNIFORM_ATTEMPTS: usize = 8;
        for _ in 0..ALIAS_ATTEMPTS {
            let v = self.sample_negative(rng);
            if v != i && v != j {
                return Some(v);
            }
        }
        // Degenerate regime: the ∝ deg^0.75 table keeps returning the
        // excluded endpoints. Fall back to uniform draws over the
        // support (still never an isolated vertex) — a mild, bounded
        // distortion of the noise distribution beats dropping the
        // repulsion term outright.
        let m = self.neg_support.len();
        for _ in 0..UNIFORM_ATTEMPTS {
            let v = self.neg_support[rng.below(m)];
            if v != i && v != j {
                return Some(v);
            }
        }
        // Guaranteed termination: scan the support from a random start.
        let start = rng.below(m);
        for off in 0..m {
            let v = self.neg_support[(start + off) % m];
            if v != i && v != j {
                return Some(v);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star_graph() -> CsrGraph {
        // Vertex 0 is a hub with heavy edges; 3-4 have a light edge.
        CsrGraph::from_undirected(
            5,
            &[(0, 1, 4.0), (0, 2, 4.0), (0, 3, 1.0), (3, 4, 0.5)],
        )
    }

    #[test]
    fn edges_sampled_by_weight() {
        let g = star_graph();
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(1);
        let mut heavy = 0usize;
        let mut light = 0usize;
        for _ in 0..100_000 {
            let (a, b) = s.sample_edge(&mut rng);
            let key = (a.min(b), a.max(b));
            if key == (0, 1) {
                heavy += 1;
            }
            if key == (3, 4) {
                light += 1;
            }
        }
        // w=4.0 vs 0.5 → ratio ≈ 8.
        let ratio = heavy as f64 / light.max(1) as f64;
        assert!((ratio - 8.0).abs() < 1.5, "ratio {ratio}");
    }

    #[test]
    fn negatives_prefer_high_degree() {
        let g = star_graph();
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..100_000 {
            counts[s.sample_negative(&mut rng) as usize] += 1;
        }
        // Hub 0 (weighted degree 9) must beat leaf 4 (0.5) but by less
        // than the raw degree ratio (the 0.75 exponent flattens it).
        assert!(counts[0] > counts[4] * 3, "{counts:?}");
        let raw_ratio = (9.0f64 / 0.5).powf(0.75);
        let got = counts[0] as f64 / counts[4].max(1) as f64;
        assert!((got - raw_ratio).abs() < raw_ratio * 0.25, "got {got}, want ≈{raw_ratio}");
    }

    #[test]
    fn isolated_vertices_never_negative_sampled() {
        // Vertices 3 and 4 are isolated: zero mass, not ~1e-12^0.75.
        let g = CsrGraph::from_undirected(5, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(9);
        for _ in 0..50_000 {
            let v = s.sample_negative(&mut rng);
            assert!(v < 3, "isolated vertex {v} drawn as negative");
        }
    }

    #[test]
    fn excluding_draw_always_finds_the_only_valid_negative() {
        // Path 0-1-2 with a huge weight disparity: the ∝ deg^0.75 table
        // holds essentially all its mass on vertices 0 and 1, so plain
        // alias draws essentially never yield vertex 2. The total draw
        // must still deliver it, every time.
        let g = CsrGraph::from_undirected(3, &[(0, 1, 1e9), (1, 2, 1e-9)]);
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            assert_eq!(s.sample_negative_excluding(&mut rng, 0, 1), Some(2));
        }
    }

    #[test]
    fn excluding_draw_none_when_no_candidate_exists() {
        let g = CsrGraph::from_undirected(2, &[(0, 1, 1.0)]);
        let s = GraphSamplers::new(&g);
        let mut rng = Rng::new(8);
        for _ in 0..100 {
            assert_eq!(s.sample_negative_excluding(&mut rng, 0, 1), None);
        }
        // With only one endpoint excluded the other is still returned.
        assert_eq!(s.sample_negative_excluding(&mut rng, 0, 0), Some(1));
    }

    #[test]
    #[should_panic(expected = "no edges")]
    fn empty_graph_panics() {
        let g = CsrGraph::from_undirected(3, &[]);
        GraphSamplers::new(&g);
    }
}
