//! Probabilistic edge functions and their gradients (paper §3.2).
//!
//! The model assigns `P(e_ij = 1) = f(||y_i - y_j||)`. The paper
//! compares `f(x) = 1/(1+ax²)` for several `a` and `f(x) = 1/(1+e^{x²})`
//! (Fig 4) and settles on the long-tailed `1/(1+x²)`, which inherits
//! t-SNE's answer to the crowding problem.
//!
//! Gradients below are of the *maximized* objective, i.e. the update is
//! `y += ρ · grad`:
//! * positive edge  (keep close):  ∂/∂y_i log f     = −2a·δ/(1+a·d²)
//! * negative edge  (push apart):  ∂/∂y_i γ·log(1−f) = 2γ·δ/((ε+d²)(1+a·d²))
//!
//! with `δ = y_i − y_j`, `d² = ||δ||²`, and `ε` guarding the repulsive
//! singularity at d → 0 (reference implementation does the same).

/// The probability function family of Fig 4.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProbFn {
    /// `f(x) = 1/(1 + a·x²)` — long-tailed; `a = 1` is the paper's pick.
    InvQuad {
        /// Scale parameter `a > 0`.
        a: f32,
    },
    /// `f(x) = 1/(1 + e^{x²})` — short-tailed logistic alternative.
    SigmoidSq,
}

/// Repulsive-gradient singularity guard.
pub const EPS: f32 = 0.1;

impl ProbFn {
    /// Edge probability given squared distance `d2 = ||y_i − y_j||²`.
    #[inline(always)]
    pub fn prob(&self, d2: f32) -> f32 {
        match *self {
            ProbFn::InvQuad { a } => 1.0 / (1.0 + a * d2),
            ProbFn::SigmoidSq => {
                // Stable for large d2: 1/(1+e^{d2}) = e^{-d2}/(1+e^{-d2}).
                let e = (-d2).exp();
                e / (1.0 + e)
            }
        }
    }

    /// Scalar coefficient `c_pos(d²)` so the positive-edge gradient on
    /// `y_i` is `c_pos · δ`.
    #[inline(always)]
    pub fn coeff_pos(&self, d2: f32) -> f32 {
        match *self {
            ProbFn::InvQuad { a } => -2.0 * a / (1.0 + a * d2),
            ProbFn::SigmoidSq => {
                // ∂ log f / ∂ d² = −(1 − f); grad = −2(1−f)·δ.
                -2.0 * (1.0 - self.prob(d2))
            }
        }
    }

    /// Scalar coefficient `c_neg(d²)` so the negative-edge gradient on
    /// `y_i` is `γ · c_neg · δ`.
    #[inline(always)]
    pub fn coeff_neg(&self, d2: f32) -> f32 {
        match *self {
            ProbFn::InvQuad { a } => 2.0 / ((EPS + d2) * (1.0 + a * d2)),
            ProbFn::SigmoidSq => {
                // ∂ log(1−f) / ∂ d² = f; grad = 2f·δ.
                2.0 * self.prob(d2)
            }
        }
    }
}

/// Clip a gradient component to `[-clip, clip]` (reference impl: 5.0).
#[inline]
pub fn clip(g: f32, clip: f32) -> f32 {
    g.clamp(-clip, clip)
}

/// Full objective (Eq. 5) evaluated exactly with *all* vertex pairs as
/// negatives — O(N²·s), for tests and tiny inputs only.
pub fn exact_objective(
    layout: &crate::data::matrix::Matrix,
    edges: &[(u32, u32, f64)],
    gamma: f32,
    f: ProbFn,
) -> f64 {
    let n = layout.n();
    let mut pos_pairs = std::collections::HashSet::new();
    let mut obj = 0.0f64;
    for &(i, j, w) in edges {
        let d2 = crate::data::matrix::sqdist(layout.row(i as usize), layout.row(j as usize));
        let p = f.prob(d2).max(1e-12) as f64;
        obj += w * p.ln();
        pos_pairs.insert((i.min(j), i.max(j)));
    }
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            if pos_pairs.contains(&(i, j)) {
                continue;
            }
            let d2 = crate::data::matrix::sqdist(layout.row(i as usize), layout.row(j as usize));
            let q = (1.0 - f.prob(d2)).max(1e-12) as f64;
            obj += gamma as f64 * q.ln();
        }
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    #[test]
    fn prob_monotone_decreasing_in_distance() {
        for f in [ProbFn::InvQuad { a: 1.0 }, ProbFn::InvQuad { a: 4.0 }, ProbFn::SigmoidSq] {
            let mut last = f.prob(0.0);
            assert!(last <= 1.0 && last > 0.4);
            for step in 1..50 {
                let p = f.prob(step as f32 * 0.5);
                assert!(p < last, "{f:?} not monotone at {step}");
                last = p;
            }
        }
    }

    #[test]
    fn invquad_matches_closed_form() {
        let f = ProbFn::InvQuad { a: 2.0 };
        assert!((f.prob(3.0) - 1.0 / 7.0).abs() < 1e-6);
    }

    #[test]
    fn sigmoid_stable_at_large_distance() {
        let f = ProbFn::SigmoidSq;
        let p = f.prob(1e4);
        assert!(p >= 0.0 && p < 1e-30);
        assert!(f.coeff_pos(1e4).is_finite());
        assert!(f.coeff_neg(1e4).is_finite());
    }

    #[test]
    fn gradients_match_finite_difference() {
        // d/d(d²) of log f and log(1-f) vs numeric differentiation.
        for f in [ProbFn::InvQuad { a: 1.0 }, ProbFn::InvQuad { a: 0.5 }, ProbFn::SigmoidSq] {
            for &d2 in &[0.3f32, 1.0, 4.0, 9.0] {
                let h = 1e-3f32;
                let num_pos = ((f.prob(d2 + h).ln() - f.prob(d2 - h).ln()) / (2.0 * h)) * 2.0;
                // coeff_pos = 2 * d(log f)/d(d²)  (δ-direction factor)
                assert!(
                    (f.coeff_pos(d2) - num_pos).abs() < 2e-2 * (1.0 + num_pos.abs()),
                    "{f:?} pos at {d2}: {} vs {num_pos}",
                    f.coeff_pos(d2)
                );
                if let ProbFn::SigmoidSq = f {
                    let num_neg = (((1.0 - f.prob(d2 + h)).ln() - (1.0 - f.prob(d2 - h)).ln())
                        / (2.0 * h))
                        * 2.0;
                    assert!(
                        (f.coeff_neg(d2) - num_neg).abs() < 2e-2 * (1.0 + num_neg.abs()),
                        "{f:?} neg at {d2}: {} vs {num_neg}",
                        f.coeff_neg(d2)
                    );
                }
            }
        }
    }

    #[test]
    fn invquad_neg_matches_analytic_with_eps() {
        // For InvQuad the implementation intentionally adds EPS to d²;
        // verify against the analytic form with the same guard.
        let f = ProbFn::InvQuad { a: 1.0 };
        for &d2 in &[0.5f32, 2.0, 8.0] {
            let expect = 2.0 / ((EPS + d2) * (1.0 + d2));
            assert!((f.coeff_neg(d2) - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn clip_bounds() {
        assert_eq!(clip(10.0, 5.0), 5.0);
        assert_eq!(clip(-7.0, 5.0), -5.0);
        assert_eq!(clip(0.5, 5.0), 0.5);
    }

    #[test]
    fn exact_objective_prefers_good_layout() {
        // Two clusters {0,1} and {2,3} with strong intra edges: a layout
        // separating the clusters must score higher than one mixing them.
        let edges = vec![(0u32, 1u32, 1.0f64), (2, 3, 1.0)];
        let good = Matrix::from_vec(vec![0.0, 0.0, 0.1, 0.0, 5.0, 5.0, 5.1, 5.0], 4, 2);
        let bad = Matrix::from_vec(vec![0.0, 0.0, 5.0, 5.0, 0.1, 0.0, 5.1, 5.0], 4, 2);
        let f = ProbFn::InvQuad { a: 1.0 };
        let og = exact_objective(&good, &edges, 7.0, f);
        let ob = exact_objective(&bad, &edges, 7.0, f);
        assert!(og > ob, "good={og} bad={ob}");
    }
}
