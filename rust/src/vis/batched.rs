//! Batched layout optimizer through the AOT/XLA path — the three-layer
//! integration: rust samples edges and negatives, the JAX/Pallas
//! `grad_kernel` artifact computes the fused gradients via PJRT, rust
//! scatter-applies the updates.
//!
//! Semantically this is mini-batch SGD with batch = manifest.batch
//! (the Hogwild engine is batch = 1); both optimize Eq. 6 and their
//! gradients agree to float tolerance (see `rust/tests/xla_parity.rs`).

use crate::data::matrix::Matrix;
use crate::graph::CsrGraph;
use crate::runtime::{literal_f32, literal_f32_2d, literal_to_f32, Runtime};
use crate::util::rng::Rng;
use crate::vis::sampler::GraphSamplers;
use crate::vis::sgd::SgdReport;
use crate::vis::LargeVisConfig;
use anyhow::{ensure, Result};

/// Run batched SGD on `layout` in place using the `grad_kernel`
/// artifact. `cfg.dim` and `cfg.negatives` must match the manifest.
pub fn optimize_batched(
    graph: &CsrGraph,
    layout: &mut Matrix,
    cfg: &LargeVisConfig,
    rt: &Runtime,
) -> Result<SgdReport> {
    let mf = rt.manifest;
    ensure!(cfg.dim == mf.dim, "artifact dim {} != cfg dim {}", mf.dim, cfg.dim);
    ensure!(
        cfg.negatives == mf.negatives,
        "artifact negatives {} != cfg negatives {}",
        mf.negatives,
        cfg.negatives
    );
    let n = graph.n();
    let (b, m, s) = (mf.batch, mf.negatives, mf.dim);
    let samplers = GraphSamplers::new(graph);
    let mut rng = Rng::new(cfg.seed ^ 0xba7c);

    let total = cfg.total_samples(n);
    let n_batches = total.div_ceil(b as u64);
    let t0 = std::time::Instant::now();

    // Reused host buffers.
    let mut idx_i = vec![0usize; b];
    let mut idx_j = vec![0usize; b];
    let mut idx_neg = vec![0usize; b * m];
    let mut yi = vec![0f32; b * s];
    let mut yj = vec![0f32; b * s];
    let mut yneg = vec![0f32; b * m * s];

    for batch in 0..n_batches {
        // Sample edges + negatives, gather embeddings.
        for e in 0..b {
            let (i, j) = samplers.sample_edge(&mut rng);
            let (i, j) = (i as usize, j as usize);
            idx_i[e] = i;
            idx_j[e] = j;
            yi[e * s..(e + 1) * s].copy_from_slice(layout.row(i));
            yj[e * s..(e + 1) * s].copy_from_slice(layout.row(j));
            for k in 0..m {
                // Total draw (same fix as the Hogwild engines). The AOT
                // kernel needs exactly M slots, so when no valid third
                // vertex exists fall back to `i` itself: a zero-length
                // difference vector, i.e. an explicit no-op repulsion —
                // never `j`, which would cancel the pair's attraction.
                let v = match samplers.sample_negative_excluding(&mut rng, i as u32, j as u32) {
                    Some(v) => v as usize,
                    None => i,
                };
                idx_neg[e * m + k] = v;
                let off = (e * m + k) * s;
                yneg[off..off + s].copy_from_slice(layout.row(v));
            }
        }
        // Learning rate decays over the batch schedule.
        let frac = (batch * b as u64).min(total) as f32 / total as f32;
        let rho = (cfg.rho0 * (1.0 - frac)).max(cfg.rho0 * 1e-4);

        // Execute L2/L1: (yi, yj, yneg_flat, gamma) -> (gi, gj, gneg).
        let inputs = [
            literal_f32_2d(&yi, b, s)?,
            literal_f32_2d(&yj, b, s)?,
            literal_f32_2d(&yneg, b, m * s)?,
            literal_f32(cfg.gamma),
        ];
        let outs = rt.run("grad_kernel", &inputs)?;
        ensure!(outs.len() == 3, "grad_kernel returned {} outputs", outs.len());
        let gi = literal_to_f32(&outs[0])?;
        let gj = literal_to_f32(&outs[1])?;
        let gneg = literal_to_f32(&outs[2])?;

        // Scatter-apply.
        for e in 0..b {
            let ri = layout.row_mut(idx_i[e]);
            for k in 0..s {
                ri[k] += rho * gi[e * s + k];
            }
            let rj = layout.row_mut(idx_j[e]);
            for k in 0..s {
                rj[k] += rho * gj[e * s + k];
            }
            for km in 0..m {
                let rv = layout.row_mut(idx_neg[e * m + km]);
                let off = (e * m + km) * s;
                for k in 0..s {
                    rv[k] += rho * gneg[off + k];
                }
            }
        }
    }

    Ok(SgdReport { samples: n_batches * b as u64, seconds: t0.elapsed().as_secs_f64() })
}
