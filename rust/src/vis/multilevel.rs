//! Multilevel coarse-to-fine layout driver.
//!
//! The flat Hogwild optimizer ([`crate::vis::sgd`]) spends most of its
//! sample budget untangling the random initialization. This driver
//! instead contracts the weighted KNN graph into a heavy-edge-matching
//! hierarchy ([`crate::graph::coarsen`]), lays out the coarsest level
//! with the very same Hogwild engine, then walks back down: each fine
//! vertex is seeded at its coarse parent's position plus a small
//! gaussian jitter (prolongation) and a refinement pass polishes the
//! level. Global structure is resolved where it is cheap — on a graph
//! a few hundred vertices wide — so the finest level needs a fraction
//! of the flat sample budget to reach equal or better quality.
//!
//! Per-level schedule:
//! * **samples** — every coarse level gets `samples_per_vertex ×
//!   coarse_samples_multiplier` per (coarse) vertex; the finest level
//!   gets the configured `samples_per_vertex`. Level vertex counts
//!   halve going up, so the whole coarse phase costs about one extra
//!   finest-level pass.
//! * **learning rate** — the coarsest level (depth `L`) starts at the
//!   configured `rho0`; a level at depth `d` (0 = finest) starts at
//!   `rho0 × level_rho_decay^(L − d)`, floored at `0.05·rho0` — each
//!   refinement step down the hierarchy shrinks the rate, since it
//!   only adjusts an already-good layout. Within a level the usual
//!   linear decay runs.
//!
//! The Hogwild engine rebuilds its [`crate::vis::sampler::GraphSamplers`]
//! per level, so each level's edge/negative tables match that level's
//! contracted graph.

use crate::data::matrix::Matrix;
use crate::graph::coarsen::{build_hierarchy, CoarsenConfig};
use crate::graph::CsrGraph;
use crate::util::rng::Rng;
use crate::vis::sgd::{self, SgdReport};
use crate::vis::{init_layout, LargeVisConfig};
use anyhow::Result;

/// Knobs for the coarse-to-fine schedule (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct MultilevelConfig {
    /// Hierarchy construction (levels / min-coarse-size / seed).
    pub coarsen: CoarsenConfig,
    /// Per-vertex sample multiplier applied at every coarse level.
    pub coarse_samples_multiplier: f64,
    /// Stddev of the gaussian jitter added when seeding a fine vertex
    /// at its coarse parent's position (breaks pair degeneracy).
    pub jitter: f32,
    /// Initial learning-rate decay per refinement level (1.0 = every
    /// level restarts at the full `rho0`).
    pub level_rho_decay: f32,
}

impl Default for MultilevelConfig {
    fn default() -> Self {
        MultilevelConfig {
            coarsen: CoarsenConfig::default(),
            coarse_samples_multiplier: 1.0,
            jitter: 0.01,
            level_rho_decay: 0.8,
        }
    }
}

/// What one level's optimization did. `depth` counts from the finest:
/// 0 is the input graph, `levels.len() - 1` the coarsest.
#[derive(Clone, Copy, Debug)]
pub struct LevelReport {
    /// Distance from the finest level (0 = input graph).
    pub depth: usize,
    /// Vertices at this level.
    pub n: usize,
    /// Directed edges at this level.
    pub edges: usize,
    /// Initial learning rate used at this level.
    pub rho0: f32,
    /// Edge samples performed at this level.
    pub samples: u64,
    /// Wall-clock seconds in this level's SGD loop.
    pub seconds: f64,
}

/// Per-level reports in execution order (coarsest first).
#[derive(Clone, Debug, Default)]
pub struct MultilevelReport {
    /// One entry per optimized level, coarsest first.
    pub levels: Vec<LevelReport>,
}

impl MultilevelReport {
    /// The finest level's report (the one comparable to a flat run).
    pub fn fine(&self) -> &LevelReport {
        self.levels.last().expect("at least one level is always optimized")
    }

    /// Aggregate samples/seconds across all levels.
    pub fn total(&self) -> SgdReport {
        let mut samples = 0u64;
        let mut seconds = 0.0f64;
        for l in &self.levels {
            samples += l.samples;
            seconds += l.seconds;
        }
        SgdReport { samples, seconds }
    }
}

/// Derive a level's RNG stream from the base seed; depth 0 maps to the
/// base seed itself so a hierarchy-free run is bit-identical to flat.
fn level_seed(seed: u64, depth: usize) -> u64 {
    seed ^ (depth as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Lay out `graph` coarse-to-fine into `layout` (whose incoming values
/// are ignored — the coarsest level starts from `init_layout`, exactly
/// like a flat run on a graph below the coarsening floor).
///
/// `on_level(depth, level_graph, level_layout)` is called after each
/// level's refinement, coarsest first (depth counts down to 0, the
/// input graph) — the pipeline uses it to checkpoint per-level layouts.
///
/// # Example
///
/// ```
/// use largevis::data::synth::gaussian_mixture;
/// use largevis::graph::weights::{weighted_graph, WeightConfig};
/// use largevis::knn::bruteforce::exact_knn;
/// use largevis::vis::multilevel::{optimize_multilevel, MultilevelConfig};
/// use largevis::vis::LargeVisConfig;
/// use largevis::data::matrix::Matrix;
///
/// # fn main() -> anyhow::Result<()> {
/// let (points, _) = gaussian_mixture(200, 8, 4, 0.0, 3);
/// let knn = exact_knn(&points, 6, 1);
/// let graph = weighted_graph(&knn, &WeightConfig { perplexity: 5.0, ..Default::default() });
/// let cfg = LargeVisConfig { samples_per_vertex: 50, threads: 1, ..Default::default() };
/// let mut ml = MultilevelConfig::default();
/// ml.coarsen.min_coarse_size = 64; // force at least one coarse level
///
/// let mut layout = Matrix::zeros(graph.n(), cfg.dim); // overwritten by the driver
/// let report = optimize_multilevel(&graph, &mut layout, &cfg, &ml, |_d, _g, _y| Ok(()))?;
/// assert_eq!(layout.n(), 200);
/// assert!(report.levels.len() >= 2);
/// assert!(layout.as_slice().iter().all(|v| v.is_finite()));
/// # Ok(())
/// # }
/// ```
pub fn optimize_multilevel<F>(
    graph: &CsrGraph,
    layout: &mut Matrix,
    cfg: &LargeVisConfig,
    ml: &MultilevelConfig,
    mut on_level: F,
) -> Result<MultilevelReport>
where
    F: FnMut(usize, &CsrGraph, &Matrix) -> Result<()>,
{
    assert_eq!(layout.n(), graph.n());
    let hierarchy = build_hierarchy(graph, &ml.coarsen);
    let top = hierarchy.len();
    // Graph at `depth` (0 = input, `top` = coarsest).
    let level_graph = |depth: usize| if depth == 0 { graph } else { &hierarchy[depth - 1].graph };

    let mut report = MultilevelReport::default();
    let mut y = init_layout(level_graph(top).n(), cfg.dim, cfg.seed);
    for depth in (0..=top).rev() {
        let g = level_graph(depth);
        let mut level_cfg = cfg.clone();
        level_cfg.seed = level_seed(cfg.seed, depth);
        if depth > 0 {
            level_cfg.samples_per_vertex = ((cfg.samples_per_vertex as f64
                * ml.coarse_samples_multiplier)
                .ceil() as usize)
                .max(1);
        }
        let rho_scale = ml.level_rho_decay.powi((top - depth) as i32).max(0.05);
        level_cfg.rho0 = cfg.rho0 * rho_scale;
        let r = sgd::optimize(g, &mut y, &level_cfg);
        report.levels.push(LevelReport {
            depth,
            n: g.n(),
            edges: g.n_directed_edges(),
            rho0: level_cfg.rho0,
            samples: r.samples,
            seconds: r.seconds,
        });
        on_level(depth, g, &y)?;
        if depth > 0 {
            // Prolongate: seed each finer vertex at its coarse parent,
            // plus jitter so contracted pairs don't sit coincident.
            let fine = level_graph(depth - 1);
            let map = &hierarchy[depth - 1].map;
            let mut jrng = Rng::new(level_seed(cfg.seed ^ 0x317e4, depth));
            let mut fine_y = Matrix::zeros(fine.n(), cfg.dim);
            for v in 0..fine.n() {
                let parent = y.row(map[v] as usize);
                let row = fine_y.row_mut(v);
                for (x, &p) in row.iter_mut().zip(parent) {
                    *x = p + ml.jitter * jrng.gaussian();
                }
            }
            y = fine_y;
        }
    }
    layout.as_mut_slice().copy_from_slice(y.as_slice());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vis::objective::exact_objective;

    /// Stochastic-block-model-ish graph: `k` cliquish groups of size
    /// `m` with strong internal and weak external edges.
    fn blocks(k: usize, m: usize) -> CsrGraph {
        let n = k * m;
        let mut edges = Vec::new();
        for c in 0..k {
            let base = (c * m) as u32;
            for a in 0..m as u32 {
                for b in (a + 1)..m as u32 {
                    edges.push((base + a, base + b, 1.0));
                }
            }
            let next = (((c + 1) % k) * m) as u32;
            edges.push((base, next, 0.02));
        }
        CsrGraph::from_undirected(n, &edges)
    }

    fn ml_cfg(min_coarse_size: usize) -> MultilevelConfig {
        MultilevelConfig {
            coarsen: CoarsenConfig { min_coarse_size, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn coarse_to_fine_improves_objective_and_separates_blocks() {
        let g = blocks(6, 10);
        let cfg = LargeVisConfig {
            samples_per_vertex: 2000,
            threads: 1,
            seed: 21,
            ..Default::default()
        };
        let mut y = init_layout(g.n(), 2, 21);
        let before = exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        let rep = optimize_multilevel(&g, &mut y, &cfg, &ml_cfg(8), |_, _, _| Ok(())).unwrap();
        assert!(rep.levels.len() > 1, "no coarse levels were built");
        let after = exact_objective(&y, g.edges(), cfg.gamma, cfg.prob_fn);
        assert!(after > before, "objective did not improve: {before} -> {after}");
        assert!(y.as_slice().iter().all(|x| x.is_finite()));
        // Mean intra-block distance well below inter-block distance.
        let (mut intra, mut inter) = (0f64, 0f64);
        let (mut ni, mut nx) = (0usize, 0usize);
        for a in 0..g.n() {
            for b in (a + 1)..g.n() {
                let d = y.sqdist(a, b) as f64;
                if a / 10 == b / 10 {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        let (mi, mx) = (intra / ni as f64, inter / nx as f64);
        assert!(mx > 2.0 * mi, "intra={mi:.3} inter={mx:.3}");
    }

    #[test]
    fn depth_order_and_budget_schedule() {
        let g = blocks(8, 8);
        let cfg =
            LargeVisConfig { samples_per_vertex: 50, threads: 1, seed: 3, ..Default::default() };
        let mut ml = ml_cfg(8);
        ml.coarse_samples_multiplier = 2.0;
        let mut y = init_layout(g.n(), 2, 3);
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let rep = optimize_multilevel(&g, &mut y, &cfg, &ml, |depth, lg, ly| {
            assert_eq!(lg.n(), ly.n());
            seen.push((depth, lg.n()));
            Ok(())
        })
        .unwrap();
        // Hook fired once per level, coarsest (deepest) first, down to 0.
        assert_eq!(seen.len(), rep.levels.len());
        assert_eq!(seen.last().unwrap().0, 0);
        assert_eq!(seen.last().unwrap().1, g.n());
        for w in seen.windows(2) {
            assert_eq!(w[0].0, w[1].0 + 1, "depths not contiguous: {seen:?}");
            assert!(w[0].1 < w[1].1, "levels not growing: {seen:?}");
        }
        // Budget: coarse levels get spv × multiplier, the finest spv.
        for l in &rep.levels {
            let spv = if l.depth == 0 { 50 } else { 100 };
            assert_eq!(l.samples, (spv * l.n) as u64, "depth {}", l.depth);
        }
        // Learning rate shrinks toward fine levels, floored at 5%.
        for w in rep.levels.windows(2) {
            assert!(w[1].rho0 <= w[0].rho0 + 1e-9);
            assert!(w[1].rho0 >= cfg.rho0 * 0.05 - 1e-9);
        }
        assert!((rep.levels[0].rho0 - cfg.rho0).abs() < 1e-9, "coarsest must start at rho0");
        // Errors from the hook propagate.
        let err = optimize_multilevel(&g, &mut y, &cfg, &ml, |_, _, _| anyhow::bail!("stop"));
        assert!(err.is_err());
    }

    #[test]
    fn no_hierarchy_is_bit_identical_to_flat() {
        // A graph at/below the coarsening floor must take the exact
        // flat path: same init, same seed, same sample count.
        let g = blocks(3, 6);
        let cfg = LargeVisConfig {
            samples_per_vertex: 400,
            threads: 1,
            seed: 9,
            ..Default::default()
        };
        let mut flat = init_layout(g.n(), 2, cfg.seed);
        sgd::optimize(&g, &mut flat, &cfg);
        let mut ml_y = init_layout(g.n(), 2, cfg.seed);
        let rep =
            optimize_multilevel(&g, &mut ml_y, &cfg, &ml_cfg(1024), |_, _, _| Ok(())).unwrap();
        assert_eq!(rep.levels.len(), 1);
        assert_eq!(rep.fine().depth, 0);
        assert_eq!(flat, ml_y, "hierarchy-free multilevel diverged from flat SGD");
    }

    #[test]
    fn deterministic_across_runs() {
        let g = blocks(6, 10);
        let cfg =
            LargeVisConfig { samples_per_vertex: 200, threads: 1, seed: 4, ..Default::default() };
        let run = || {
            let mut y = init_layout(g.n(), 2, cfg.seed);
            optimize_multilevel(&g, &mut y, &cfg, &ml_cfg(8), |_, _, _| Ok(())).unwrap();
            y
        };
        assert_eq!(run(), run());
    }
}
