//! Dynamic data — the paper's stated future work ("handle data
//! dynamically changing over time"), implemented as incremental point
//! insertion:
//!
//! 1. the new point's K nearest neighbors are found against the current
//!    index (exact scan per insertion — insertions are assumed rare
//!    relative to N),
//! 2. it is spliced into the KNN graph (its own list, plus any existing
//!    lists it improves),
//! 3. its layout position is initialized at the weight-averaged
//!    position of its neighbors, and
//! 4. a short *localized* SGD pass refines only the new points while
//!    the old layout stays frozen (landmark semantics), so an
//!    interactive view never jumps under the user.
//!
//! `refreeze()` promotes the frozen points back into a full graph for
//! a global re-optimization when drift accumulates.

use crate::data::matrix::Matrix;
use crate::graph::weights::{weighted_graph, WeightConfig};
use crate::kernels::nearest_k;
use crate::knn::KnnGraph;
use crate::util::heap::BoundedMaxHeap;
use crate::util::rng::Rng;
use crate::vis::objective::clip;
use crate::vis::sampler::GraphSamplers;
use crate::vis::LargeVisConfig;

/// An updatable layout over a growing dataset.
///
/// # Example
///
/// ```
/// use largevis::data::synth::gaussian_mixture;
/// use largevis::graph::weights::{weighted_graph, WeightConfig};
/// use largevis::knn::bruteforce::exact_knn;
/// use largevis::vis::incremental::IncrementalLayout;
/// use largevis::vis::LargeVisConfig;
///
/// // Embed a small base dataset.
/// let (points, _labels) = gaussian_mixture(120, 8, 3, 0.0, 7);
/// let knn = exact_knn(&points, 5, 1);
/// let wcfg = WeightConfig { perplexity: 4.0, ..Default::default() };
/// let vcfg = LargeVisConfig { samples_per_vertex: 50, threads: 1, ..Default::default() };
/// let graph = weighted_graph(&knn, &wcfg);
/// let mut layout = largevis::vis::init_layout(points.n(), 2, 1);
/// largevis::vis::sgd::optimize(&graph, &mut layout, &vcfg);
///
/// // Wrap it and insert new points; old positions stay frozen.
/// let mut inc = IncrementalLayout::new(points, knn, layout, wcfg, vcfg);
/// let (extra, _) = gaussian_mixture(10, 8, 3, 0.0, 99);
/// let ids = inc.add_points(&extra);
/// assert_eq!(ids.len(), 10);
/// assert_eq!(inc.n(), 130);
/// ```
pub struct IncrementalLayout {
    /// Current high-dimensional points.
    pub data: Matrix,
    /// Current KNN graph (kept at `k` neighbors per point).
    pub knn: KnnGraph,
    /// Current low-dimensional layout.
    pub layout: Matrix,
    /// Weighting config used for localized refreshes.
    pub weights: WeightConfig,
    /// Layout config used for localized SGD.
    pub vis: LargeVisConfig,
    /// SGD samples per *inserted* point.
    pub samples_per_insert: usize,
}

impl IncrementalLayout {
    /// Wrap an existing pipeline state.
    pub fn new(
        data: Matrix,
        knn: KnnGraph,
        layout: Matrix,
        weights: WeightConfig,
        vis: LargeVisConfig,
    ) -> Self {
        assert_eq!(data.n(), knn.n());
        assert_eq!(data.n(), layout.n());
        IncrementalLayout { data, knn, layout, weights, vis, samples_per_insert: 2000 }
    }

    /// Number of points currently embedded.
    pub fn n(&self) -> usize {
        self.data.n()
    }

    /// Insert a batch of new points; returns their ids.
    ///
    /// Old points' layout positions are frozen; only the inserted
    /// points move during the localized refinement.
    pub fn add_points(&mut self, new_points: &Matrix) -> Vec<usize> {
        assert_eq!(new_points.d(), self.data.d());
        let k = self.knn.k;
        let first_new = self.data.n();
        let mut new_ids = Vec::with_capacity(new_points.n());

        // 1-2: KNN splice, one point at a time (each new point can be a
        // neighbor of subsequent ones). The exact scan goes through the
        // runtime-dispatched batched kernel ([`nearest_k`]): the data
        // rows are already contiguous, so one batched call replaces n
        // scattered scalar `sqdist` calls.
        let mut dists: Vec<f32> = Vec::new();
        let mut heap = BoundedMaxHeap::new(k);
        for r in 0..new_points.n() {
            let id = self.data.n();
            let row = new_points.row(r).to_vec();
            let mine = nearest_k(&row, &self.data, k, &mut dists, &mut heap);
            // Splice into existing lists where the new point improves them.
            for &(j, dist) in &mine {
                let list = &mut self.knn.neighbors[j as usize];
                let worst = list.last().map(|&(_, d)| d).unwrap_or(f32::INFINITY);
                if list.len() < k || dist < worst {
                    if list.len() == k {
                        list.pop();
                    }
                    let pos = list.partition_point(|&(_, d)| d <= dist);
                    list.insert(pos, (id as u32, dist));
                }
            }
            self.knn.neighbors.push(mine);
            self.data.push_row(&row);

            // 3: place at the similarity-weighted centroid of neighbors.
            let dim = self.layout.d();
            let mut pos = vec![0f32; dim];
            let mut total = 0f32;
            for &(j, dist) in &self.knn.neighbors[id] {
                if (j as usize) < self.layout.n() {
                    let w = 1.0 / (1.0 + dist);
                    for (p, &y) in pos.iter_mut().zip(self.layout.row(j as usize)) {
                        *p += w * y;
                    }
                    total += w;
                }
            }
            let mut rng = Rng::new(self.vis.seed ^ id as u64);
            if total > 0.0 {
                for p in pos.iter_mut() {
                    *p = *p / total + 1e-3 * rng.gaussian();
                }
            } else {
                for p in pos.iter_mut() {
                    *p = 1e-4 * rng.gaussian();
                }
            }
            self.layout.push_row(&pos);
            new_ids.push(id);
        }

        // 4: localized SGD over the refreshed weighted graph, moving
        // only the inserted points.
        let graph = weighted_graph(&self.knn, &self.weights);
        let samplers = GraphSamplers::new(&graph);
        let mut rng = Rng::new(self.vis.seed ^ 0x1c2);
        let total = (self.samples_per_insert * new_points.n()) as u64;
        let f = self.vis.prob_fn;
        let gamma = self.vis.gamma;
        let dim = self.layout.d();
        let gclip = self.vis.grad_clip;
        let mut acc = vec![0f32; dim];
        for t in 0..total {
            let rho =
                (self.vis.rho0 * (1.0 - t as f32 / total as f32)).max(self.vis.rho0 * 1e-4);
            let (i, j) = samplers.sample_edge(&mut rng);
            let (i, j) = (i as usize, j as usize);
            // Only steps whose source is a new point move anything.
            if i < first_new || i == j {
                continue;
            }
            acc.iter_mut().for_each(|a| *a = 0.0);
            {
                let d2 = self.layout.sqdist(i, j);
                let c = f.coeff_pos(d2);
                for kk in 0..dim {
                    let g = clip(c * (self.layout.row(i)[kk] - self.layout.row(j)[kk]), gclip);
                    acc[kk] += g;
                    if j >= first_new {
                        self.layout.row_mut(j)[kk] -= rho * g;
                    }
                }
            }
            // Total draw (same fix as the batch optimizer): a bounded
            // rejection guard can silently drop repulsions on small or
            // hub-dominated graphs and degenerate to attract-only steps.
            for _ in 0..self.vis.negatives {
                let v = match samplers.sample_negative_excluding(&mut rng, i as u32, j as u32) {
                    Some(v) => v as usize,
                    None => break,
                };
                let d2 = self.layout.sqdist(i, v);
                let c = gamma * f.coeff_neg(d2);
                for kk in 0..dim {
                    let g = clip(c * (self.layout.row(i)[kk] - self.layout.row(v)[kk]), gclip);
                    acc[kk] += g;
                    if v >= first_new {
                        self.layout.row_mut(v)[kk] -= rho * g;
                    }
                }
            }
            for kk in 0..dim {
                self.layout.row_mut(i)[kk] += rho * acc[kk];
            }
        }
        new_ids
    }

    /// Globally re-optimize (unfreezes everything) — for when many
    /// insertions have accumulated.
    pub fn reoptimize(&mut self) {
        let graph = weighted_graph(&self.knn, &self.weights);
        crate::vis::sgd::optimize(&graph, &mut self.layout, &self.vis);
    }
}

/// Out-of-sample projection against a **frozen** base — the query
/// server's `/embed` path.
///
/// Unlike [`IncrementalLayout::add_points`], nothing is mutated: the
/// base `data`/`layout` are read-only (and can therefore be shared
/// across server worker threads behind an `Arc`), and the projected
/// positions are returned instead of spliced in. Per query point:
///
/// 1. its `k` nearest base points are found with one [`nearest_k`]
///    batch scan (runtime-dispatched SIMD),
/// 2. its position is initialized at the similarity-weighted centroid
///    of those neighbors' layout positions, and
/// 3. a short localized SGD pass (`samples_per_point` steps) refines
///    it — attraction toward its base neighbors sampled ∝ `1/(1+d²)`,
///    repulsion from uniformly sampled base points — while every base
///    position stays exactly where it was.
///
/// Returns the projected positions (one row per query row) and each
/// query point's base-neighbor list (sorted ascending by squared
/// distance), deterministic for a given `vis.seed`.
pub fn project(
    data: &Matrix,
    layout: &Matrix,
    vis: &LargeVisConfig,
    new_points: &Matrix,
    k: usize,
    samples_per_point: usize,
) -> (Matrix, Vec<Vec<(u32, f32)>>) {
    assert_eq!(new_points.d(), data.d(), "query dimensionality mismatch");
    assert_eq!(data.n(), layout.n(), "base data/layout row mismatch");
    assert!(data.n() > 0, "cannot project against an empty base");
    let k = k.max(1).min(data.n());
    let dim = layout.d();
    let mut out = Matrix::zeros(new_points.n(), dim);
    let mut neighbors = Vec::with_capacity(new_points.n());

    let f = vis.prob_fn;
    let gamma = vis.gamma;
    let gclip = vis.grad_clip;
    let mut dists: Vec<f32> = Vec::new();
    let mut heap = BoundedMaxHeap::new(k);
    let mut pos = vec![0f32; dim];
    let mut step = vec![0f32; dim];
    let mut cum: Vec<f32> = Vec::new();

    for r in 0..new_points.n() {
        let q = new_points.row(r);
        let nb = nearest_k(q, data, k, &mut dists, &mut heap);

        // Init at the similarity-weighted centroid (same placement rule
        // as the insert path), with a tiny seeded jitter so coincident
        // queries still separate under SGD.
        let mut rng = Rng::new(vis.seed ^ (0x9e11 + r as u64).wrapping_mul(0x2545F4914F6CDD1D));
        pos.iter_mut().for_each(|p| *p = 0.0);
        let mut total_w = 0f32;
        for &(j, d) in &nb {
            let w = 1.0 / (1.0 + d);
            for (p, &y) in pos.iter_mut().zip(layout.row(j as usize)) {
                *p += w * y;
            }
            total_w += w;
        }
        if total_w > 0.0 {
            for p in pos.iter_mut() {
                *p = *p / total_w + 1e-3 * rng.gaussian();
            }
        } else {
            for p in pos.iter_mut() {
                *p = 1e-4 * rng.gaussian();
            }
        }

        // Cumulative neighbor weights for the attraction draw.
        cum.clear();
        let mut acc_w = 0f32;
        for &(_, d) in &nb {
            acc_w += 1.0 / (1.0 + d);
            cum.push(acc_w);
        }

        // Localized SGD: only `pos` moves; the base layout is never
        // written. Same gradient family and rho schedule as the batch
        // optimizer.
        let steps = samples_per_point as u64;
        for t in 0..steps {
            if acc_w <= 0.0 {
                break;
            }
            let rho = (vis.rho0 * (1.0 - t as f32 / steps as f32)).max(vis.rho0 * 1e-4);
            let u = rng.f32() * acc_w;
            let idx = cum.partition_point(|&c| c < u).min(nb.len() - 1);
            let j = nb[idx].0 as usize;
            step.iter_mut().for_each(|s| *s = 0.0);
            let jr = layout.row(j);
            let mut d2 = 0f32;
            for kk in 0..dim {
                let diff = pos[kk] - jr[kk];
                d2 += diff * diff;
            }
            let c = f.coeff_pos(d2);
            for kk in 0..dim {
                step[kk] += clip(c * (pos[kk] - jr[kk]), gclip);
            }
            // Draw negatives uniformly (with replacement) over the
            // base *excluding* the current attraction target, by
            // drawing from n-1 and remapping — never silently dropping
            // a repulsion: the skip-on-collision pattern PR 3 fixed in
            // the batch and localized optimizers degenerates small
            // bases to attract-only steps. n == 1 has no repulsion
            // candidates at all.
            let negs = if data.n() > 1 { vis.negatives } else { 0 };
            for _ in 0..negs {
                let mut v = rng.below(data.n() - 1);
                if v >= j {
                    v += 1;
                }
                let vr = layout.row(v);
                let mut d2 = 0f32;
                for kk in 0..dim {
                    let diff = pos[kk] - vr[kk];
                    d2 += diff * diff;
                }
                let c = gamma * f.coeff_neg(d2);
                for kk in 0..dim {
                    step[kk] += clip(c * (pos[kk] - vr[kk]), gclip);
                }
            }
            for kk in 0..dim {
                pos[kk] += rho * step[kk];
            }
        }
        out.row_mut(r).copy_from_slice(&pos);
        neighbors.push(nb);
    }
    (out, neighbors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
    use crate::knn::bruteforce::exact_knn;

    /// Build a small embedded base state.
    fn base() -> (IncrementalLayout, Vec<u32>) {
        let (m, labels) = gaussian_mixture(400, 10, 4, 0.0, 21);
        let knn = exact_knn(&m, 10, 2);
        let wcfg = WeightConfig { perplexity: 8.0, ..Default::default() };
        let vcfg = LargeVisConfig { samples_per_vertex: 2000, threads: 1, ..Default::default() };
        let graph = weighted_graph(&knn, &wcfg);
        let mut layout = crate::vis::init_layout(m.n(), 2, 1);
        crate::vis::sgd::optimize(&graph, &mut layout, &vcfg);
        (IncrementalLayout::new(m, knn, layout, wcfg, vcfg), labels)
    }

    #[test]
    fn inserted_points_land_in_their_cluster() {
        let (mut inc, mut labels) = base();
        // New points from the same 4 clusters (same generator, later rows).
        let (extra, extra_labels) = gaussian_mixture(440, 10, 4, 0.0, 21);
        let tail = extra.gather_rows(&(400..440).collect::<Vec<_>>());
        let ids = inc.add_points(&tail);
        assert_eq!(ids.len(), 40);
        assert_eq!(inc.n(), 440);
        labels.extend_from_slice(&extra_labels[400..440]);

        // Quality of the merged layout: classifier accuracy stays high.
        let acc = knn_accuracy(&inc.layout, &labels, &KnnEvalConfig { k: 5, ..Default::default() });
        assert!(acc > 0.8, "accuracy after insertion {acc}");
        // And specifically the new points are classified correctly.
        let mut correct = 0;
        for &id in &ids {
            let mut best = (f32::INFINITY, 0u32);
            for j in 0..400 {
                let d = inc.layout.sqdist(id, j);
                if d < best.0 {
                    best = (d, labels[j]);
                }
            }
            if best.1 == labels[id] {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 new points near their cluster");
    }

    #[test]
    fn old_points_do_not_move() {
        let (mut inc, _) = base();
        let before = inc.layout.clone();
        let (extra, _) = gaussian_mixture(10, 10, 4, 0.0, 99);
        inc.add_points(&extra);
        for i in 0..400 {
            assert_eq!(inc.layout.row(i), before.row(i), "frozen point {i} moved");
        }
    }

    #[test]
    fn knn_graph_stays_consistent() {
        let (mut inc, _) = base();
        let (extra, _) = gaussian_mixture(20, 10, 4, 0.0, 55);
        inc.add_points(&extra);
        inc.knn.check_invariants().unwrap();
        assert_eq!(inc.knn.n(), 420);
    }

    #[test]
    fn project_is_read_only_and_lands_in_cluster() {
        let (inc, labels) = base();
        let data_before = inc.data.clone();
        let layout_before = inc.layout.clone();
        // Project later rows of the same generator (same 4 clusters).
        let (extra, extra_labels) = gaussian_mixture(440, 10, 4, 0.0, 21);
        let tail = extra.gather_rows(&(400..440).collect::<Vec<_>>());
        let (pos, nbs) = project(&inc.data, &inc.layout, &inc.vis, &tail, 10, 500);
        assert_eq!(pos.n(), 40);
        assert_eq!(pos.d(), 2);
        assert_eq!(nbs.len(), 40);
        // Base untouched, bit for bit.
        assert_eq!(inc.data, data_before);
        assert_eq!(inc.layout, layout_before);
        // Neighbor lists sorted, k entries, valid ids.
        for nb in &nbs {
            assert_eq!(nb.len(), 10);
            for w in nb.windows(2) {
                assert!(w[0].1 <= w[1].1);
            }
            assert!(nb.iter().all(|&(id, _)| (id as usize) < inc.n()));
        }
        // Each projected point lands nearest a base point of its class.
        let mut correct = 0;
        for r in 0..40 {
            let mut best = (f32::INFINITY, 0u32);
            for j in 0..400 {
                let mut d = 0f32;
                for kk in 0..2 {
                    let diff = pos.row(r)[kk] - inc.layout.row(j)[kk];
                    d += diff * diff;
                }
                if d < best.0 {
                    best = (d, labels[j]);
                }
            }
            if best.1 == extra_labels[400 + r] {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 projected points near their cluster");
    }

    #[test]
    fn project_deterministic_for_seed() {
        let (inc, _) = base();
        let (extra, _) = gaussian_mixture(5, 10, 4, 0.0, 123);
        let (a, na) = project(&inc.data, &inc.layout, &inc.vis, &extra, 8, 300);
        let (b, nb) = project(&inc.data, &inc.layout, &inc.vis, &extra, 8, 300);
        assert_eq!(a, b);
        assert_eq!(na, nb);
    }

    #[test]
    fn project_clamps_k_and_handles_zero_samples() {
        let (inc, _) = base();
        let (extra, _) = gaussian_mixture(3, 10, 4, 0.0, 5);
        // k larger than the base clamps; zero SGD steps = centroid init.
        let (pos, nbs) = project(&inc.data, &inc.layout, &inc.vis, &extra, 100_000, 0);
        assert_eq!(pos.n(), 3);
        assert_eq!(nbs[0].len(), 400);
        assert!(pos.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn reoptimize_unfreezes() {
        let (mut inc, labels) = base();
        let before = inc.layout.clone();
        inc.reoptimize();
        assert_ne!(inc.layout, before);
        let acc = knn_accuracy(&inc.layout, &labels, &KnnEvalConfig { k: 5, ..Default::default() });
        assert!(acc > 0.8);
    }
}
