//! Dynamic data — the paper's stated future work ("handle data
//! dynamically changing over time"), implemented as incremental point
//! insertion:
//!
//! 1. the new point's K nearest neighbors are found against the current
//!    index (exact scan per insertion — insertions are assumed rare
//!    relative to N),
//! 2. it is spliced into the KNN graph (its own list, plus any existing
//!    lists it improves),
//! 3. its layout position is initialized at the weight-averaged
//!    position of its neighbors, and
//! 4. a short *localized* SGD pass refines only the new points while
//!    the old layout stays frozen (landmark semantics), so an
//!    interactive view never jumps under the user.
//!
//! `refreeze()` promotes the frozen points back into a full graph for
//! a global re-optimization when drift accumulates.

use crate::data::matrix::{sqdist, Matrix};
use crate::graph::weights::{weighted_graph, WeightConfig};
use crate::knn::KnnGraph;
use crate::util::heap::BoundedMaxHeap;
use crate::util::rng::Rng;
use crate::vis::objective::clip;
use crate::vis::sampler::GraphSamplers;
use crate::vis::LargeVisConfig;

/// An updatable layout over a growing dataset.
pub struct IncrementalLayout {
    /// Current high-dimensional points.
    pub data: Matrix,
    /// Current KNN graph (kept at `k` neighbors per point).
    pub knn: KnnGraph,
    /// Current low-dimensional layout.
    pub layout: Matrix,
    /// Weighting config used for localized refreshes.
    pub weights: WeightConfig,
    /// Layout config used for localized SGD.
    pub vis: LargeVisConfig,
    /// SGD samples per *inserted* point.
    pub samples_per_insert: usize,
}

impl IncrementalLayout {
    /// Wrap an existing pipeline state.
    pub fn new(
        data: Matrix,
        knn: KnnGraph,
        layout: Matrix,
        weights: WeightConfig,
        vis: LargeVisConfig,
    ) -> Self {
        assert_eq!(data.n(), knn.n());
        assert_eq!(data.n(), layout.n());
        IncrementalLayout { data, knn, layout, weights, vis, samples_per_insert: 2000 }
    }

    /// Number of points currently embedded.
    pub fn n(&self) -> usize {
        self.data.n()
    }

    /// Insert a batch of new points; returns their ids.
    ///
    /// Old points' layout positions are frozen; only the inserted
    /// points move during the localized refinement.
    pub fn add_points(&mut self, new_points: &Matrix) -> Vec<usize> {
        assert_eq!(new_points.d(), self.data.d());
        let k = self.knn.k;
        let first_new = self.data.n();
        let mut new_ids = Vec::with_capacity(new_points.n());

        // 1-2: KNN splice, one point at a time (each new point can be a
        // neighbor of subsequent ones).
        for r in 0..new_points.n() {
            let id = self.data.n();
            let row = new_points.row(r).to_vec();
            let mut heap = BoundedMaxHeap::new(k);
            for j in 0..self.data.n() {
                let dist = sqdist(&row, self.data.row(j));
                if dist < heap.threshold() {
                    heap.push(j as u32, dist, false);
                }
            }
            let mine: Vec<(u32, f32)> =
                heap.into_sorted().iter().map(|c| (c.id, c.dist)).collect();
            // Splice into existing lists where the new point improves them.
            for &(j, dist) in &mine {
                let list = &mut self.knn.neighbors[j as usize];
                let worst = list.last().map(|&(_, d)| d).unwrap_or(f32::INFINITY);
                if list.len() < k || dist < worst {
                    if list.len() == k {
                        list.pop();
                    }
                    let pos = list.partition_point(|&(_, d)| d <= dist);
                    list.insert(pos, (id as u32, dist));
                }
            }
            self.knn.neighbors.push(mine);
            self.data.push_row(&row);

            // 3: place at the similarity-weighted centroid of neighbors.
            let dim = self.layout.d();
            let mut pos = vec![0f32; dim];
            let mut total = 0f32;
            for &(j, dist) in &self.knn.neighbors[id] {
                if (j as usize) < self.layout.n() {
                    let w = 1.0 / (1.0 + dist);
                    for (p, &y) in pos.iter_mut().zip(self.layout.row(j as usize)) {
                        *p += w * y;
                    }
                    total += w;
                }
            }
            let mut rng = Rng::new(self.vis.seed ^ id as u64);
            if total > 0.0 {
                for p in pos.iter_mut() {
                    *p = *p / total + 1e-3 * rng.gaussian();
                }
            } else {
                for p in pos.iter_mut() {
                    *p = 1e-4 * rng.gaussian();
                }
            }
            self.layout.push_row(&pos);
            new_ids.push(id);
        }

        // 4: localized SGD over the refreshed weighted graph, moving
        // only the inserted points.
        let graph = weighted_graph(&self.knn, &self.weights);
        let samplers = GraphSamplers::new(&graph);
        let mut rng = Rng::new(self.vis.seed ^ 0x1c2);
        let total = (self.samples_per_insert * new_points.n()) as u64;
        let f = self.vis.prob_fn;
        let gamma = self.vis.gamma;
        let dim = self.layout.d();
        let gclip = self.vis.grad_clip;
        let mut acc = vec![0f32; dim];
        for t in 0..total {
            let rho =
                (self.vis.rho0 * (1.0 - t as f32 / total as f32)).max(self.vis.rho0 * 1e-4);
            let (i, j) = samplers.sample_edge(&mut rng);
            let (i, j) = (i as usize, j as usize);
            // Only steps whose source is a new point move anything.
            if i < first_new || i == j {
                continue;
            }
            acc.iter_mut().for_each(|a| *a = 0.0);
            {
                let d2 = self.layout.sqdist(i, j);
                let c = f.coeff_pos(d2);
                for kk in 0..dim {
                    let g = clip(c * (self.layout.row(i)[kk] - self.layout.row(j)[kk]), gclip);
                    acc[kk] += g;
                    if j >= first_new {
                        self.layout.row_mut(j)[kk] -= rho * g;
                    }
                }
            }
            // Total draw (same fix as the batch optimizer): a bounded
            // rejection guard can silently drop repulsions on small or
            // hub-dominated graphs and degenerate to attract-only steps.
            for _ in 0..self.vis.negatives {
                let v = match samplers.sample_negative_excluding(&mut rng, i as u32, j as u32) {
                    Some(v) => v as usize,
                    None => break,
                };
                let d2 = self.layout.sqdist(i, v);
                let c = gamma * f.coeff_neg(d2);
                for kk in 0..dim {
                    let g = clip(c * (self.layout.row(i)[kk] - self.layout.row(v)[kk]), gclip);
                    acc[kk] += g;
                    if v >= first_new {
                        self.layout.row_mut(v)[kk] -= rho * g;
                    }
                }
            }
            for kk in 0..dim {
                self.layout.row_mut(i)[kk] += rho * acc[kk];
            }
        }
        new_ids
    }

    /// Globally re-optimize (unfreezes everything) — for when many
    /// insertions have accumulated.
    pub fn reoptimize(&mut self) {
        let graph = weighted_graph(&self.knn, &self.weights);
        crate::vis::sgd::optimize(&graph, &mut self.layout, &self.vis);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
    use crate::knn::bruteforce::exact_knn;

    /// Build a small embedded base state.
    fn base() -> (IncrementalLayout, Vec<u32>) {
        let (m, labels) = gaussian_mixture(400, 10, 4, 0.0, 21);
        let knn = exact_knn(&m, 10, 2);
        let wcfg = WeightConfig { perplexity: 8.0, ..Default::default() };
        let vcfg = LargeVisConfig { samples_per_vertex: 2000, threads: 1, ..Default::default() };
        let graph = weighted_graph(&knn, &wcfg);
        let mut layout = crate::vis::init_layout(m.n(), 2, 1);
        crate::vis::sgd::optimize(&graph, &mut layout, &vcfg);
        (IncrementalLayout::new(m, knn, layout, wcfg, vcfg), labels)
    }

    #[test]
    fn inserted_points_land_in_their_cluster() {
        let (mut inc, mut labels) = base();
        // New points from the same 4 clusters (same generator, later rows).
        let (extra, extra_labels) = gaussian_mixture(440, 10, 4, 0.0, 21);
        let tail = extra.gather_rows(&(400..440).collect::<Vec<_>>());
        let ids = inc.add_points(&tail);
        assert_eq!(ids.len(), 40);
        assert_eq!(inc.n(), 440);
        labels.extend_from_slice(&extra_labels[400..440]);

        // Quality of the merged layout: classifier accuracy stays high.
        let acc = knn_accuracy(&inc.layout, &labels, &KnnEvalConfig { k: 5, ..Default::default() });
        assert!(acc > 0.8, "accuracy after insertion {acc}");
        // And specifically the new points are classified correctly.
        let mut correct = 0;
        for &id in &ids {
            let mut best = (f32::INFINITY, 0u32);
            for j in 0..400 {
                let d = inc.layout.sqdist(id, j);
                if d < best.0 {
                    best = (d, labels[j]);
                }
            }
            if best.1 == labels[id] {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 new points near their cluster");
    }

    #[test]
    fn old_points_do_not_move() {
        let (mut inc, _) = base();
        let before = inc.layout.clone();
        let (extra, _) = gaussian_mixture(10, 10, 4, 0.0, 99);
        inc.add_points(&extra);
        for i in 0..400 {
            assert_eq!(inc.layout.row(i), before.row(i), "frozen point {i} moved");
        }
    }

    #[test]
    fn knn_graph_stays_consistent() {
        let (mut inc, _) = base();
        let (extra, _) = gaussian_mixture(20, 10, 4, 0.0, 55);
        inc.add_points(&extra);
        inc.knn.check_invariants().unwrap();
        assert_eq!(inc.knn.n(), 420);
    }

    #[test]
    fn reoptimize_unfreezes() {
        let (mut inc, labels) = base();
        let before = inc.layout.clone();
        inc.reoptimize();
        assert_ne!(inc.layout, before);
        let acc = knn_accuracy(&inc.layout, &labels, &KnnEvalConfig { k: 5, ..Default::default() });
        assert!(acc > 0.8);
    }
}
