//! Dynamic data — the paper's stated future work ("handle data
//! dynamically changing over time"), implemented as incremental point
//! insertion:
//!
//! 1. the new point's K nearest neighbors are found against the current
//!    index (the exact scan by default, or the sub-linear navigable-graph
//!    walk of [`crate::knn::search`] when a [`SearchHandle`] is set),
//! 2. it is spliced into the KNN graph (its own list, plus any existing
//!    lists it improves),
//! 3. its layout position is initialized at the weight-averaged
//!    position of its neighbors, and
//! 4. a short *localized* SGD pass refines only the new points while
//!    the old layout stays frozen (landmark semantics), so an
//!    interactive view never jumps under the user.
//!
//! `refreeze()` promotes the frozen points back into a full graph for
//! a global re-optimization when drift accumulates.

use crate::data::chunked::{ChunkedKnn, ChunkedMatrix, KNN_CHUNK_ROWS, MATRIX_CHUNK_ROWS};
use crate::data::matrix::{Matrix, RowStore};
use crate::graph::weights::{calibrate_row, weighted_graph, WeightConfig};
use crate::kernels::nearest_k;
use crate::knn::search::{search_nearest, SearchHandle, SearchTotals};
use crate::knn::{KnnGraph, NeighborStore};
use crate::util::alias::AliasTable;
use crate::util::heap::BoundedMaxHeap;
use crate::util::rng::Rng;
use crate::vis::objective::clip;
use crate::vis::LargeVisConfig;

/// An updatable layout over a growing dataset.
///
/// # Example
///
/// ```
/// use largevis::data::synth::gaussian_mixture;
/// use largevis::graph::weights::{weighted_graph, WeightConfig};
/// use largevis::knn::bruteforce::exact_knn;
/// use largevis::vis::incremental::IncrementalLayout;
/// use largevis::vis::LargeVisConfig;
///
/// // Embed a small base dataset.
/// let (points, _labels) = gaussian_mixture(120, 8, 3, 0.0, 7);
/// let knn = exact_knn(&points, 5, 1);
/// let wcfg = WeightConfig { perplexity: 4.0, ..Default::default() };
/// let vcfg = LargeVisConfig { samples_per_vertex: 50, threads: 1, ..Default::default() };
/// let graph = weighted_graph(&knn, &wcfg);
/// let mut layout = largevis::vis::init_layout(points.n(), 2, 1);
/// largevis::vis::sgd::optimize(&graph, &mut layout, &vcfg);
///
/// // Wrap it and insert new points; old positions stay frozen.
/// let mut inc = IncrementalLayout::new(points, knn, layout, wcfg, vcfg);
/// let (extra, _) = gaussian_mixture(10, 8, 3, 0.0, 99);
/// let ids = inc.add_points(&extra);
/// assert_eq!(ids.len(), 10);
/// assert_eq!(inc.n(), 130);
/// ```
pub struct IncrementalLayout {
    /// Current high-dimensional points (chunked copy-on-write, so the
    /// serving path's per-epoch snapshot clone is O(batch), not O(N)).
    pub data: ChunkedMatrix,
    /// Current KNN graph (kept at `k` neighbors per point; chunked so
    /// a splice dirties one small chunk instead of the whole graph).
    pub knn: ChunkedKnn,
    /// Current low-dimensional layout (chunked copy-on-write).
    pub layout: ChunkedMatrix,
    /// Weighting config used for localized refreshes.
    pub weights: WeightConfig,
    /// Layout config used for localized SGD.
    pub vis: LargeVisConfig,
    /// SGD samples per *inserted* point.
    pub samples_per_insert: usize,
    /// Cost evidence of the most recent [`IncrementalLayout::add_points`]
    /// call's localized reweighting pass (see [`LocalizedStats`]).
    pub last_localized: LocalizedStats,
    /// The directed new-source edges the most recent
    /// [`IncrementalLayout::add_points`] batch weighted — the sampling
    /// window a background refinement pass replays via
    /// [`IncrementalLayout::localized_sgd`].
    pub last_edges: Vec<(u32, u32, f64)>,
    /// When set, [`IncrementalLayout::add_points`] finds each new
    /// point's base neighbors with the navigable-graph walk
    /// ([`search_nearest`]) instead of the exact scan — sub-linear
    /// per insert. `None` keeps the exact path.
    pub search: Option<SearchHandle>,
    /// Accumulated per-query walk counters of the most recent
    /// [`IncrementalLayout::add_points`] batch (all zero on the exact
    /// path) — surfaced as `serve.search_*` metrics by the server.
    pub last_search: SearchTotals,
}

/// Work performed by one localized reweighting pass — the proof that
/// per-insert cost is bounded by the *touched neighborhood*, never by
/// the total graph size.
///
/// `add_points` used to rebuild the full weighted graph and alias
/// tables per call (`weighted_graph` + `GraphSamplers::new`, O(|E|)
/// work and allocation); these counters are populated by the localized
/// replacement so tests can assert the bound: for a batch of `B`
/// inserts into a graph with `k` neighbors per vertex,
/// `calibrations <= B*(k+1)` and `edges <= 4*B*k` — both independent
/// of the total vertex or edge count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LocalizedStats {
    /// Distinct vertices whose conditional distributions were
    /// recalibrated (the inserted points plus every old vertex whose
    /// neighbor list the batch spliced).
    pub calibrations: usize,
    /// Directed new-source edges weighted for the localized sampler.
    pub edges: usize,
}

impl IncrementalLayout {
    /// Wrap an existing pipeline state. The flat inputs are chunked
    /// once here (one O(N) conversion at load time); every subsequent
    /// snapshot clone and insert batch is O(batch).
    pub fn new(
        data: Matrix,
        knn: KnnGraph,
        layout: Matrix,
        weights: WeightConfig,
        vis: LargeVisConfig,
    ) -> Self {
        assert_eq!(data.n(), knn.n());
        assert_eq!(data.n(), layout.n());
        IncrementalLayout {
            data: ChunkedMatrix::from_matrix(&data, MATRIX_CHUNK_ROWS),
            knn: ChunkedKnn::from_graph(&knn, KNN_CHUNK_ROWS),
            layout: ChunkedMatrix::from_matrix(&layout, MATRIX_CHUNK_ROWS),
            weights,
            vis,
            samples_per_insert: 2000,
            last_localized: LocalizedStats::default(),
            last_edges: Vec::new(),
            search: None,
            last_search: SearchTotals::default(),
        }
    }

    /// Number of points currently embedded.
    pub fn n(&self) -> usize {
        self.data.n()
    }

    /// Insert a batch of new points; returns their ids.
    ///
    /// Old points' layout positions are frozen; only the inserted
    /// points move during the localized refinement.
    pub fn add_points(&mut self, new_points: &Matrix) -> Vec<usize> {
        assert_eq!(new_points.d(), self.data.d());
        let k = self.knn.k;
        let first_new = self.data.n();
        let mut new_ids = Vec::with_capacity(new_points.n());

        // 1-2: KNN splice, one point at a time (each new point can be a
        // neighbor of subsequent ones). The exact scan goes through the
        // runtime-dispatched batched kernel ([`nearest_k`]): the data
        // rows are already contiguous, so one batched call replaces n
        // scattered scalar `sqdist` calls.
        let mut dists: Vec<f32> = Vec::new();
        let mut heap = BoundedMaxHeap::new(k);
        let mut touched_old: Vec<u32> = Vec::new();
        let search = self.search.clone();
        self.last_search = SearchTotals::default();
        for r in 0..new_points.n() {
            let id = self.data.n();
            let row = new_points.row(r).to_vec();
            let mine = match &search {
                Some(h) => {
                    let (nb, stats) =
                        search_nearest(&row, &self.data, &self.knn, &h.index, k, h.beam_width);
                    self.last_search.absorb(&stats);
                    nb
                }
                None => nearest_k(&row, &self.data, k, &mut dists, &mut heap),
            };
            // Splice into existing lists where the new point improves
            // them — `row_mut` is a copy-on-write handle, so a splice
            // dirties only the target's (small) chunk.
            let mut got_in_edge = false;
            for &(j, dist) in &mine {
                let list = self.knn.row_mut(j as usize);
                let worst = list.last().map(|&(_, d)| d).unwrap_or(f32::INFINITY);
                if list.len() < k || dist < worst {
                    if list.len() == k {
                        list.pop();
                    }
                    let pos = list.partition_point(|&(_, d)| d <= dist);
                    list.insert(pos, (id as u32, dist));
                    got_in_edge = true;
                    // A spliced old row's conditional distribution is
                    // stale; record it for the localized recalibration.
                    if (j as usize) < first_new {
                        touched_old.push(j);
                    }
                }
            }
            // Directed reachability guarantee for the graph query walk:
            // an outlier whose distance beats no existing list would get
            // zero in-edges and become invisible to `search_nearest`
            // (which follows stored out-lists only). Force one in-edge
            // from its nearest neighbor — at most one evicted entry per
            // insert, and deterministic, so WAL replay stays
            // bit-identical.
            if !got_in_edge {
                if let Some(&(j0, d0)) = mine.first() {
                    let list = self.knn.row_mut(j0 as usize);
                    if list.len() == k {
                        list.pop();
                    }
                    let pos = list.partition_point(|&(_, d)| d <= d0);
                    list.insert(pos, (id as u32, d0));
                    if (j0 as usize) < first_new {
                        touched_old.push(j0);
                    }
                }
            }
            self.knn.push_row(mine);
            self.data.push_row(&row);

            // 3: place at the similarity-weighted centroid of neighbors.
            let dim = self.layout.d();
            let mut pos = vec![0f32; dim];
            let mut total = 0f32;
            for &(j, dist) in self.knn.row(id) {
                if (j as usize) < self.layout.n() {
                    let w = 1.0 / (1.0 + dist);
                    for (p, &y) in pos.iter_mut().zip(self.layout.row(j as usize)) {
                        *p += w * y;
                    }
                    total += w;
                }
            }
            let mut rng = Rng::new(self.vis.seed ^ id as u64);
            if total > 0.0 {
                for p in pos.iter_mut() {
                    *p = *p / total + 1e-3 * rng.gaussian();
                }
            } else {
                for p in pos.iter_mut() {
                    *p = 1e-4 * rng.gaussian();
                }
            }
            self.layout.push_row(&pos);
            new_ids.push(id);
        }

        // 4: localized SGD over a *localized* reweighting. This used to
        // rebuild the full weighted graph and alias tables per call
        // (`weighted_graph` + `GraphSamplers::new`, O(|E|) work every
        // batch) and then discard ~all of its draws (only new-source
        // edges move anything). Now only the conditional distributions
        // the batch actually changed are recalibrated and the edge
        // sampler covers new-source edges alone — O(B·k) per batch of
        // B inserts, independent of the total graph size (see
        // [`LocalizedStats`]). Negative draws are uniform over the
        // current points (the serving-path `project` noise model); the
        // batch optimizer keeps its ∝ deg^0.75 table.
        touched_old.sort_unstable();
        touched_old.dedup();
        let (edges, stats) = localized_edges(&self.knn, &self.weights, first_new, &touched_old);
        self.last_localized = stats;
        let total = (self.samples_per_insert * new_points.n()) as u64;
        self.localized_sgd(&edges, first_new, total, self.vis.seed ^ 0x1c2);
        self.last_edges = edges;
        new_ids
    }

    /// One localized SGD pass over `edges` (directed, `(i, j, w)`),
    /// sampling edges ∝ weight; only vertices `>= first_movable` move,
    /// everything below stays frozen. Negative draws are uniform over
    /// every current point except the sampled edge's endpoints (exact
    /// two-exclusion remap — no silently dropped repulsions, the same
    /// fix the batch optimizer and `project` carry). Deterministic for
    /// a given `seed`; a no-op when `edges` carries no positive mass.
    ///
    /// Shared by the insert path and the serving-side background
    /// refinement worker (which replays the accumulated
    /// [`IncrementalLayout::last_edges`] windows between requests).
    pub fn localized_sgd(
        &mut self,
        edges: &[(u32, u32, f64)],
        first_movable: usize,
        samples: u64,
        seed: u64,
    ) {
        let n_total = self.data.n();
        let edge_weights: Vec<f64> = edges.iter().map(|&(_, _, w)| w).collect();
        let total_w: f64 = edge_weights.iter().sum();
        if edges.is_empty() || total_w <= 0.0 || n_total < 3 || samples == 0 {
            return;
        }
        let table = AliasTable::new(&edge_weights);
        let mut rng = Rng::new(seed);
        let f = self.vis.prob_fn;
        let gamma = self.vis.gamma;
        let dim = self.layout.d();
        let gclip = self.vis.grad_clip;
        let mut acc = vec![0f32; dim];
        for t in 0..samples {
            let rho =
                (self.vis.rho0 * (1.0 - t as f32 / samples as f32)).max(self.vis.rho0 * 1e-4);
            // Every localized edge has a movable source by construction
            // (and KNN lists never contain their own vertex, so i != j).
            let (i, j, _) = edges[table.sample(&mut rng)];
            let (i, j) = (i as usize, j as usize);
            acc.iter_mut().for_each(|a| *a = 0.0);
            {
                let d2 = self.layout.sqdist(i, j);
                let c = f.coeff_pos(d2);
                for kk in 0..dim {
                    let g = clip(c * (self.layout.row(i)[kk] - self.layout.row(j)[kk]), gclip);
                    acc[kk] += g;
                    if j >= first_movable {
                        self.layout.row_mut(j)[kk] -= rho * g;
                    }
                }
            }
            let (lo, hi) = (i.min(j), i.max(j));
            for _ in 0..self.vis.negatives {
                let mut v = rng.below(n_total - 2);
                if v >= lo {
                    v += 1;
                }
                if v >= hi {
                    v += 1;
                }
                let d2 = self.layout.sqdist(i, v);
                let c = gamma * f.coeff_neg(d2);
                for kk in 0..dim {
                    let g = clip(c * (self.layout.row(i)[kk] - self.layout.row(v)[kk]), gclip);
                    acc[kk] += g;
                    if v >= first_movable {
                        self.layout.row_mut(v)[kk] -= rho * g;
                    }
                }
            }
            for kk in 0..dim {
                self.layout.row_mut(i)[kk] += rho * acc[kk];
            }
        }
    }

    /// Globally re-optimize (unfreezes everything) — for when many
    /// insertions have accumulated. Runs on flat copies (the batch
    /// optimizer wants contiguous storage) and re-chunks the result —
    /// an O(N) round-trip, acceptable for this rarely-run full rebuild.
    pub fn reoptimize(&mut self) {
        let graph = weighted_graph(&self.knn.to_graph(), &self.weights);
        let mut layout = self.layout.to_matrix();
        crate::vis::sgd::optimize(&graph, &mut layout, &self.vis);
        self.layout = ChunkedMatrix::from_matrix(&layout, MATRIX_CHUNK_ROWS);
    }
}

/// Localized reweighting: the directed new-source edges of the weighted
/// graph, computed without touching any untouched vertex.
///
/// Vertices `first_new..n` are the batch's inserted points;
/// `touched_old` (sorted, deduplicated, all `< first_new`) are the old
/// vertices whose KNN lists the batch spliced. Exactly these rows are
/// recalibrated ([`calibrate_row`] — the same math `weighted_graph`
/// runs over *every* row), then pair masses
/// `w_ab = (p_{b|a} + p_{a|b}) / 2N` are accumulated for every pair
/// with at least one new endpoint. Both conditional contributions of
/// such a pair live in calibrated rows: a new id can only appear in an
/// old list via a splice, which marks that row touched.
///
/// Returns the directed edges `(source, target, weight)` with
/// `source >= first_new`, sorted by `(source, target)` (deterministic
/// for the replay path), plus the work counters. Weights match a full
/// [`weighted_graph`] rebuild on the same graph bit-for-bit up to
/// two-term addition order (property-tested); old-old pair weights —
/// which a full rebuild would also refresh but which no new-source
/// sampler can ever draw — are the one thing deliberately skipped.
pub(crate) fn localized_edges(
    knn: &impl NeighborStore,
    weights: &WeightConfig,
    first_new: usize,
    touched_old: &[u32],
) -> (Vec<(u32, u32, f64)>, LocalizedStats) {
    use std::collections::HashMap;
    let n = knn.n();
    debug_assert!(touched_old.iter().all(|&v| (v as usize) < first_new));

    // Recalibrate exactly the touched rows.
    let mut cond: HashMap<u32, Vec<f64>> =
        HashMap::with_capacity(touched_old.len() + n - first_new);
    let mut dbuf: Vec<f32> = Vec::new();
    for v in touched_old.iter().copied().chain(first_new as u32..n as u32) {
        let row = knn.row(v as usize);
        dbuf.clear();
        dbuf.extend(row.iter().map(|&(_, d)| d));
        cond.insert(v, calibrate_row(&dbuf, weights.perplexity, weights.max_iters, weights.tol));
    }
    let calibrations = cond.len();

    // Accumulate undirected pair mass exactly like the symmetrizer,
    // restricted to pairs with a new endpoint. Each pair receives at
    // most two contributions (one per direction), so the sum is
    // order-independent even over HashMap iteration.
    let mut pair: HashMap<(u32, u32), f64> = HashMap::new();
    for (&v, pv) in &cond {
        for (slot, &(b, _)) in knn.row(v as usize).iter().enumerate() {
            if (v as usize) < first_new && (b as usize) < first_new {
                continue; // old-old pair: invisible to a new-source sampler
            }
            let key = if v < b { (v, b) } else { (b, v) };
            *pair.entry(key).or_insert(0.0) += pv[slot];
        }
    }

    let scale = 1.0 / (2.0 * n as f64);
    let mut edges: Vec<(u32, u32, f64)> = Vec::with_capacity(pair.len() * 2);
    for (&(a, b), &mass) in &pair {
        let w = mass * scale;
        if (a as usize) >= first_new {
            edges.push((a, b, w));
        }
        if (b as usize) >= first_new {
            edges.push((b, a, w));
        }
    }
    edges.sort_unstable_by_key(|&(s, t, _)| (s, t));
    let stats = LocalizedStats { calibrations, edges: edges.len() };
    (edges, stats)
}

/// Out-of-sample projection against a **frozen** base — the query
/// server's `/embed` path.
///
/// Unlike [`IncrementalLayout::add_points`], nothing is mutated: the
/// base `data`/`layout` are read-only (and can therefore be shared
/// across server worker threads behind an `Arc`), and the projected
/// positions are returned instead of spliced in. Per query point:
///
/// 1. its `k` nearest base points are found with one [`nearest_k`]
///    batch scan (runtime-dispatched SIMD),
/// 2. its position is initialized at the similarity-weighted centroid
///    of those neighbors' layout positions, and
/// 3. a short localized SGD pass (`samples_per_point` steps) refines
///    it — attraction toward its base neighbors sampled ∝ `1/(1+d²)`,
///    repulsion from uniformly sampled base points — while every base
///    position stays exactly where it was.
///
/// Returns the projected positions (one row per query row) and each
/// query point's base-neighbor list (sorted ascending by squared
/// distance), deterministic for a given `vis.seed`.
pub fn project(
    data: &impl RowStore,
    layout: &impl RowStore,
    vis: &LargeVisConfig,
    new_points: &Matrix,
    k: usize,
    samples_per_point: usize,
) -> (Matrix, Vec<Vec<(u32, f32)>>) {
    let mut dists: Vec<f32> = Vec::new();
    let mut heap = BoundedMaxHeap::new(k.max(1));
    project_with(data, layout, vis, new_points, k, samples_per_point, |q, kk| {
        nearest_k(q, data, kk, &mut dists, &mut heap)
    })
}

/// [`project`] with a caller-supplied base-neighbor lookup.
///
/// `lookup(query, k)` must return up to `k` base `(id, sqdist)` pairs
/// sorted ascending — either the exact scan ([`project`] passes
/// [`nearest_k`]) or the navigable-graph walk
/// ([`search_nearest`], how the server makes `/embed` sub-linear).
/// Everything downstream of the lookup (centroid init, localized SGD,
/// returned neighbor lists) is identical, so the two paths differ only
/// in which base neighbors they find.
pub fn project_with<F>(
    data: &impl RowStore,
    layout: &impl RowStore,
    vis: &LargeVisConfig,
    new_points: &Matrix,
    k: usize,
    samples_per_point: usize,
    mut lookup: F,
) -> (Matrix, Vec<Vec<(u32, f32)>>)
where
    F: FnMut(&[f32], usize) -> Vec<(u32, f32)>,
{
    assert_eq!(new_points.d(), data.d(), "query dimensionality mismatch");
    assert_eq!(data.n(), layout.n(), "base data/layout row mismatch");
    assert!(data.n() > 0, "cannot project against an empty base");
    let k = k.max(1).min(data.n());
    let dim = layout.d();
    let mut out = Matrix::zeros(new_points.n(), dim);
    let mut neighbors = Vec::with_capacity(new_points.n());

    let f = vis.prob_fn;
    let gamma = vis.gamma;
    let gclip = vis.grad_clip;
    let mut pos = vec![0f32; dim];
    let mut step = vec![0f32; dim];
    let mut cum: Vec<f32> = Vec::new();

    for r in 0..new_points.n() {
        let q = new_points.row(r);
        let nb = lookup(q, k);
        debug_assert!(!nb.is_empty(), "base-neighbor lookup returned nothing");

        // Init at the similarity-weighted centroid (same placement rule
        // as the insert path), with a tiny seeded jitter so coincident
        // queries still separate under SGD.
        let mut rng = Rng::new(vis.seed ^ (0x9e11 + r as u64).wrapping_mul(0x2545F4914F6CDD1D));
        pos.iter_mut().for_each(|p| *p = 0.0);
        let mut total_w = 0f32;
        for &(j, d) in &nb {
            let w = 1.0 / (1.0 + d);
            for (p, &y) in pos.iter_mut().zip(layout.row(j as usize)) {
                *p += w * y;
            }
            total_w += w;
        }
        if total_w > 0.0 {
            for p in pos.iter_mut() {
                *p = *p / total_w + 1e-3 * rng.gaussian();
            }
        } else {
            for p in pos.iter_mut() {
                *p = 1e-4 * rng.gaussian();
            }
        }

        // Cumulative neighbor weights for the attraction draw.
        cum.clear();
        let mut acc_w = 0f32;
        for &(_, d) in &nb {
            acc_w += 1.0 / (1.0 + d);
            cum.push(acc_w);
        }

        // Localized SGD: only `pos` moves; the base layout is never
        // written. Same gradient family and rho schedule as the batch
        // optimizer.
        let steps = samples_per_point as u64;
        for t in 0..steps {
            if acc_w <= 0.0 {
                break;
            }
            let rho = (vis.rho0 * (1.0 - t as f32 / steps as f32)).max(vis.rho0 * 1e-4);
            let u = rng.f32() * acc_w;
            let idx = cum.partition_point(|&c| c < u).min(nb.len() - 1);
            let j = nb[idx].0 as usize;
            step.iter_mut().for_each(|s| *s = 0.0);
            let jr = layout.row(j);
            let mut d2 = 0f32;
            for kk in 0..dim {
                let diff = pos[kk] - jr[kk];
                d2 += diff * diff;
            }
            let c = f.coeff_pos(d2);
            for kk in 0..dim {
                step[kk] += clip(c * (pos[kk] - jr[kk]), gclip);
            }
            // Draw negatives uniformly (with replacement) over the
            // base *excluding* the current attraction target, by
            // drawing from n-1 and remapping — never silently dropping
            // a repulsion: the skip-on-collision pattern PR 3 fixed in
            // the batch and localized optimizers degenerates small
            // bases to attract-only steps. n == 1 has no repulsion
            // candidates at all.
            let negs = if data.n() > 1 { vis.negatives } else { 0 };
            for _ in 0..negs {
                let mut v = rng.below(data.n() - 1);
                if v >= j {
                    v += 1;
                }
                let vr = layout.row(v);
                let mut d2 = 0f32;
                for kk in 0..dim {
                    let diff = pos[kk] - vr[kk];
                    d2 += diff * diff;
                }
                let c = gamma * f.coeff_neg(d2);
                for kk in 0..dim {
                    step[kk] += clip(c * (pos[kk] - vr[kk]), gclip);
                }
            }
            for kk in 0..dim {
                pos[kk] += rho * step[kk];
            }
        }
        out.row_mut(r).copy_from_slice(&pos);
        neighbors.push(nb);
    }
    (out, neighbors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
    use crate::knn::bruteforce::exact_knn;

    /// Build a small embedded base state.
    fn base() -> (IncrementalLayout, Vec<u32>) {
        let (m, labels) = gaussian_mixture(400, 10, 4, 0.0, 21);
        let knn = exact_knn(&m, 10, 2);
        let wcfg = WeightConfig { perplexity: 8.0, ..Default::default() };
        let vcfg = LargeVisConfig { samples_per_vertex: 2000, threads: 1, ..Default::default() };
        let graph = weighted_graph(&knn, &wcfg);
        let mut layout = crate::vis::init_layout(m.n(), 2, 1);
        crate::vis::sgd::optimize(&graph, &mut layout, &vcfg);
        (IncrementalLayout::new(m, knn, layout, wcfg, vcfg), labels)
    }

    #[test]
    fn inserted_points_land_in_their_cluster() {
        let (mut inc, mut labels) = base();
        // New points from the same 4 clusters (same generator, later rows).
        let (extra, extra_labels) = gaussian_mixture(440, 10, 4, 0.0, 21);
        let tail = extra.gather_rows(&(400..440).collect::<Vec<_>>());
        let ids = inc.add_points(&tail);
        assert_eq!(ids.len(), 40);
        assert_eq!(inc.n(), 440);
        labels.extend_from_slice(&extra_labels[400..440]);

        // Quality of the merged layout: classifier accuracy stays high.
        let acc = knn_accuracy(
            &inc.layout.to_matrix(),
            &labels,
            &KnnEvalConfig { k: 5, ..Default::default() },
        );
        assert!(acc > 0.8, "accuracy after insertion {acc}");
        // And specifically the new points are classified correctly.
        let mut correct = 0;
        for &id in &ids {
            let mut best = (f32::INFINITY, 0u32);
            for j in 0..400 {
                let d = inc.layout.sqdist(id, j);
                if d < best.0 {
                    best = (d, labels[j]);
                }
            }
            if best.1 == labels[id] {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 new points near their cluster");
    }

    #[test]
    fn old_points_do_not_move() {
        let (mut inc, _) = base();
        let before = inc.layout.clone();
        let (extra, _) = gaussian_mixture(10, 10, 4, 0.0, 99);
        inc.add_points(&extra);
        for i in 0..400 {
            assert_eq!(inc.layout.row(i), before.row(i), "frozen point {i} moved");
        }
    }

    #[test]
    fn knn_graph_stays_consistent() {
        let (mut inc, _) = base();
        let (extra, _) = gaussian_mixture(20, 10, 4, 0.0, 55);
        inc.add_points(&extra);
        inc.knn.check_invariants().unwrap();
        assert_eq!(inc.knn.n(), 420);
    }

    #[test]
    fn project_is_read_only_and_lands_in_cluster() {
        let (inc, labels) = base();
        let data_before = inc.data.clone();
        let layout_before = inc.layout.clone();
        // Project later rows of the same generator (same 4 clusters).
        let (extra, extra_labels) = gaussian_mixture(440, 10, 4, 0.0, 21);
        let tail = extra.gather_rows(&(400..440).collect::<Vec<_>>());
        let (pos, nbs) = project(&inc.data, &inc.layout, &inc.vis, &tail, 10, 500);
        assert_eq!(pos.n(), 40);
        assert_eq!(pos.d(), 2);
        assert_eq!(nbs.len(), 40);
        // Base untouched, bit for bit.
        assert_eq!(inc.data, data_before);
        assert_eq!(inc.layout, layout_before);
        // Neighbor lists sorted, k entries, valid ids.
        for nb in &nbs {
            assert_eq!(nb.len(), 10);
            for w in nb.windows(2) {
                assert!(w[0].1 <= w[1].1);
            }
            assert!(nb.iter().all(|&(id, _)| (id as usize) < inc.n()));
        }
        // Each projected point lands nearest a base point of its class.
        let mut correct = 0;
        for r in 0..40 {
            let mut best = (f32::INFINITY, 0u32);
            for j in 0..400 {
                let mut d = 0f32;
                for kk in 0..2 {
                    let diff = pos.row(r)[kk] - inc.layout.row(j)[kk];
                    d += diff * diff;
                }
                if d < best.0 {
                    best = (d, labels[j]);
                }
            }
            if best.1 == extra_labels[400 + r] {
                correct += 1;
            }
        }
        assert!(correct >= 30, "only {correct}/40 projected points near their cluster");
    }

    #[test]
    fn project_deterministic_for_seed() {
        let (inc, _) = base();
        let (extra, _) = gaussian_mixture(5, 10, 4, 0.0, 123);
        let (a, na) = project(&inc.data, &inc.layout, &inc.vis, &extra, 8, 300);
        let (b, nb) = project(&inc.data, &inc.layout, &inc.vis, &extra, 8, 300);
        assert_eq!(a, b);
        assert_eq!(na, nb);
    }

    #[test]
    fn project_clamps_k_and_handles_zero_samples() {
        let (inc, _) = base();
        let (extra, _) = gaussian_mixture(3, 10, 4, 0.0, 5);
        // k larger than the base clamps; zero SGD steps = centroid init.
        let (pos, nbs) = project(&inc.data, &inc.layout, &inc.vis, &extra, 100_000, 0);
        assert_eq!(pos.n(), 3);
        assert_eq!(nbs[0].len(), 400);
        assert!(pos.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn localized_weights_match_full_rebuild() {
        let (mut inc, _) = base();
        let first_new = inc.n();
        let (extra, _) = gaussian_mixture(12, 10, 4, 0.0, 77);
        inc.add_points(&extra);

        // Reconstruct the touched-old set from the final graph state: a
        // new id enters an old list only via a splice.
        let touched: Vec<u32> = (0..first_new)
            .filter(|&j| inc.knn.row(j).iter().any(|&(l, _)| (l as usize) >= first_new))
            .map(|j| j as u32)
            .collect();
        let (edges, stats) = localized_edges(&inc.knn, &inc.weights, first_new, &touched);
        assert!(!edges.is_empty());
        assert_eq!(stats.edges, edges.len());

        // Oracle: the full O(|E|) rebuild the localized pass replaced.
        let full = weighted_graph(&inc.knn.to_graph(), &inc.weights);
        let mut want: Vec<(u32, u32, f64)> = Vec::new();
        for i in first_new..inc.n() {
            for (c, w) in full.row(i).collect_pairs() {
                want.push((i as u32, c, w));
            }
        }
        want.sort_unstable_by_key(|&(s, t, _)| (s, t));
        // Same directed new-source edge set, same weights (identical
        // calibration math; the tolerance only covers two-term
        // addition reassociation).
        assert_eq!(edges.len(), want.len(), "edge sets differ in size");
        for (&(a, b, w), &(wa, wb, ww)) in edges.iter().zip(&want) {
            assert_eq!((a, b), (wa, wb));
            assert!(
                (w - ww).abs() <= ww.abs() * 1e-9 + 1e-300,
                "edge {a}->{b}: localized {w} vs full {ww}"
            );
        }
    }

    #[test]
    fn localized_cost_independent_of_base_size() {
        // Insert the same batch into bases an order of magnitude apart:
        // the reweighting work must obey bounds that mention only the
        // batch size B and the graph's k — never the base size.
        let k = 10;
        let b = 8;
        let (extra, _) = gaussian_mixture(b, 10, 4, 0.0, 31);
        let mut all_stats = Vec::new();
        for n_base in [200usize, 2000] {
            let (m, _) = gaussian_mixture(n_base, 10, 4, 0.0, 21);
            let knn = exact_knn(&m, k, 2);
            let wcfg = WeightConfig { perplexity: 8.0, ..Default::default() };
            let vcfg =
                LargeVisConfig { samples_per_vertex: 100, threads: 1, ..Default::default() };
            let graph = weighted_graph(&knn, &wcfg);
            let mut layout = crate::vis::init_layout(m.n(), 2, 1);
            crate::vis::sgd::optimize(&graph, &mut layout, &vcfg);
            let mut inc = IncrementalLayout::new(m, knn, layout, wcfg, vcfg);
            inc.samples_per_insert = 50;
            inc.add_points(&extra);
            let stats = inc.last_localized;
            assert!(
                stats.calibrations <= b * (k + 1),
                "n_base={n_base}: {} calibrations for B={b}, k={k}",
                stats.calibrations
            );
            assert!(
                stats.edges <= 4 * b * k,
                "n_base={n_base}: {} localized edges for B={b}, k={k}",
                stats.edges
            );
            all_stats.push(stats);
        }
        // The bound held at both scales with the identical formula —
        // the per-insert reweighting cost does not grow with the graph.
        assert_eq!(all_stats.len(), 2);
    }

    #[test]
    fn reoptimize_unfreezes() {
        let (mut inc, labels) = base();
        let before = inc.layout.clone();
        inc.reoptimize();
        assert_ne!(inc.layout, before);
        let acc = knn_accuracy(
            &inc.layout.to_matrix(),
            &labels,
            &KnnEvalConfig { k: 5, ..Default::default() },
        );
        assert!(acc > 0.8);
    }
}
